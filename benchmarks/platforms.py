"""The two measured platforms for the paper-table benchmarks.

Platform A ("Hadoop" analog): WordCount — the paper's own job, measured wall
time (repro.apps.wordcount).

Platform B ("Spark" analog): a smoke-scale LM training job, measured wall
time. Several of the 12 training knobs bind on CPU (matmul precision, scan
vs. unroll, remat, microbatching); mesh knobs are inert on one device — the
long-tail shape the paper's Table VII also shows.

Both give the CMPE a *measured* ``config → execution time`` function, which
is the paper-faithful experiment; the production-mesh (roofline) tables live
in EXPERIMENTS.md §Dry-run/§Roofline.
"""
from __future__ import annotations

import jax

from repro.compat import set_mesh as compat_set_mesh

from repro.apps.wordcount import WORDCOUNT_SPACE, build_wordcount, make_corpus
from repro.configs.archs import get_arch
from repro.configs.base import RunConfig, ShapeConfig
from repro.core.evaluators import WalltimeEvaluator
from repro.core.space import TRAIN_SPACE
from repro.distributed.steps import init_train_state, make_train_step
from repro.launch.mesh import make_host_mesh

LM_ARCH = "llama3.2-1b"
LM_SHAPE = ShapeConfig("bench", 128, 8, "train")
LM_STEPS = 2

# grid knobs for the search tables (kept to 3 axes: 27 + finer cells per run)
LM_ACTIVE = ["matmul_precision", "remat_policy", "microbatch_size"]
WC_ACTIVE = ["replication", "block_tokens", "num_map_tasks"]


def wordcount_evaluator(num_tokens: int = 1 << 21, repeats: int = 2):
    corpus = make_corpus(num_tokens)
    # fidelity-aware builder: ASHA's cheap rungs run a corpus prefix (and
    # WalltimeEvaluator scales the repeat count); full fidelity is unchanged
    return WalltimeEvaluator(
        builder=lambda cfg, fidelity=1.0: build_wordcount(
            cfg, corpus, fidelity=fidelity),
        repeats=repeats,
    ), WORDCOUNT_SPACE


def lm_train_evaluator(repeats: int = 2):
    arch = get_arch(LM_ARCH, smoke=True)
    mesh = make_host_mesh(model_parallel=1)

    def builder(cfg):
        run = TRAIN_SPACE.to_run_config(cfg, RunConfig(mesh_model_parallel=1))
        with compat_set_mesh(mesh):
            bundle = make_train_step(arch, run, LM_SHAPE, mesh)
            state = init_train_state(bundle)
            batch = bundle.model.make_inputs(LM_SHAPE)
            state, batch = bundle.place(mesh, state, batch)
            fn = bundle.jit(donate=False)  # job re-runs from the same state

        def job(state=state):
            with compat_set_mesh(mesh):
                s = state
                for _ in range(LM_STEPS):
                    s, m = fn(s, batch)
                jax.block_until_ready(m["loss"])
            return m

        return job

    return WalltimeEvaluator(builder=builder, repeats=repeats), TRAIN_SPACE
