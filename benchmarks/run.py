"""Benchmark driver — one section per paper table. Prints CSV rows and writes
JSON artifacts under results/benchmarks/.

    PYTHONPATH=src python -m benchmarks.run            # all tables
    PYTHONPATH=src python -m benchmarks.run --quick    # skip the search tables
"""
from __future__ import annotations

import argparse
import csv
import json
import sys
import time
from pathlib import Path

from benchmarks import tables


def emit(rows):
    if not rows:
        return
    cols = list(rows[0].keys())
    w = csv.DictWriter(sys.stdout, fieldnames=cols)
    w.writeheader()
    for r in rows:
        w.writerow(r)
    sys.stdout.flush()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="defaults + roofline only")
    ap.add_argument("--skip-lm", action="store_true", help="wordcount platform only")
    ap.add_argument("--jobs", type=int, default=None,
                    help="parallel trials per batch (TrialScheduler thread "
                         "pool; default 1)")
    ap.add_argument("--study", type=Path, default=None,
                    help="Study directory — every table run shares its "
                         "persistent evaluation cache (created on first use)")
    ap.add_argument("--cache", type=Path, default=None,
                    help="legacy persistent JSONL evaluation cache — a warm "
                         "re-run of the search tables performs no fresh "
                         "evaluations (ignored when --study is given)")
    ap.add_argument("--strategy", default="all",
                    choices=["all", "gsft", "crs", "tpe", "asha"],
                    help="which search strategy's tables to run (default all, "
                         "incl. the GSFT-vs-CRS-vs-TPE shootout)")
    ap.add_argument("--isolation", default=None,
                    choices=["inline", "subprocess"],
                    help="trial execution backend for every table run: "
                         "inline threads (the default) or hard-deadline "
                         "worker processes")
    ap.add_argument("--trial-timeout", "--timeout", dest="trial_timeout",
                    type=float, default=None,
                    help="per-trial timeout in seconds (hard SIGKILL under "
                         "--isolation subprocess)")
    args = ap.parse_args(argv)
    # one validated EngineConfig instead of loose kwargs; --study routes every
    # table's trials into the study's shared cache. Explicitly-typed flags
    # overlay the stored engine per-field; untyped flags don't clobber it.
    from repro.launch.tune import engine_config, engine_overrides, \
        open_persistent_study

    engine = engine_config(args)
    cache = args.cache
    if args.study:
        study = open_persistent_study(args.study, engine_overrides(args))
        cache, engine = study.cache_path, study.engine
    # every TrialScheduler-level knob of the engine flows through (patience/
    # batch_size are per-run knobs the table functions own themselves)
    tables.ENGINE.update(cache_path=cache, **engine.scheduler_kwargs())

    t0 = time.time()
    all_rows = []
    platforms = ["wordcount"] + ([] if args.skip_lm else ["lm_train"])

    for platform in platforms:
        print(f"\n## Table {'III' if platform == 'wordcount' else 'VI'} — "
              f"{platform}: all-defaults execution time")
        rows = tables.table_defaults(platform)
        emit(rows); all_rows += rows

    want = lambda s: args.strategy in ("all", s)
    if not args.quick:
        for platform in platforms:
            print(f"\n## Table {'IV' if platform == 'wordcount' else 'VII'} — "
                  f"{platform}: one parameter at optimal, rest default")
            rows = tables.table_one_opt(platform)
            emit(rows); all_rows += rows

            print(f"\n## Table {'V' if platform == 'wordcount' else 'VIII'} — "
                  f"{platform}: all parameters at individual optimal")
            rows = tables.table_all_opt(platform)
            emit(rows); all_rows += rows

            if want("gsft"):
                print(f"\n## Table {'IX' if platform == 'wordcount' else 'X'} — "
                      f"{platform}: Grid Search with Finer Tuning")
                rows = tables.table_gsft(platform)
                emit(rows); all_rows += rows

            if want("crs"):
                print(f"\n## Table {'XI' if platform == 'wordcount' else 'XII'} — "
                      f"{platform}: Controlled Random Search")
                rows = tables.table_crs(platform)
                emit(rows); all_rows += rows

            if want("tpe"):
                print(f"\n## §TPE — {platform}: Tree-structured Parzen "
                      f"Estimator (full knob set, GSFT-comparable budget)")
                rows = tables.table_tpe(platform)
                emit(rows); all_rows += rows

        if args.strategy == "all":
            print("\n## §XI comparison — reduction in execution time")
            rows = tables.table_comparison()
            emit(rows); all_rows += rows

            print("\n## §Strategy shootout — GSFT vs CRS vs TPE on WordCount "
                  "(equal trial budgets)")
            rows = tables.table_strategy_shootout("wordcount")
            emit(rows); all_rows += rows

            print("\n## §Cross-cell transfer — WordCount matrix, sibling "
                  "cell with --transfer off vs prior (equal budgets)")
            rows = tables.table_transfer()
            emit(rows); all_rows += rows

            print("\n## §Learned cost surrogate — WordCount matrix, sibling "
                  "cell with --surrogate off vs rank (equal budgets)")
            rows = tables.table_surrogate()
            emit(rows); all_rows += rows

        if args.strategy in ("all", "asha"):
            print("\n## §Multi-fidelity ASHA — vs full-fidelity CRS/TPE on "
                  "WordCount (equal search width, fraction of the cost)")
            rows = tables.table_asha("wordcount")
            emit(rows); all_rows += rows

        if args.strategy == "all":
            print("\n## §Kernel autotuning — default vs study-tuned block "
                  "configs per Pallas kernel (interpret mode)")
            rows = tables.table_kernels()
            emit(rows); all_rows += rows

    print("\n## §Roofline — per (arch × shape) on the 16×16 production mesh "
          "(from the dry-run artifacts)")
    rows = tables.table_roofline()
    emit(rows); all_rows += rows

    out = Path("results/benchmarks/all_tables.json")
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(all_rows, indent=1, default=str))
    print(f"\nDONE in {time.time() - t0:.0f}s -> {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
