"""One function per paper table (III–XII + §XI comparison), run on the two
measured platforms. Each returns a list of CSV-able row dicts."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List

from repro.core import CMPE, tune
from repro.core.tuner import TuneOutcome

from benchmarks import platforms

RESULTS = Path("results/benchmarks")

# Engine options (TrialScheduler kwargs) applied to every table run —
# benchmarks.run sets these from --jobs / --cache so the whole suite shares
# one thread-pool size and one persistent evaluation cache.
ENGINE: Dict[str, Any] = {}


def _scheduler_opts() -> Dict[str, Any]:
    return {k: v for k, v in ENGINE.items() if v is not None}


def _eval_for(platform: str):
    if platform == "wordcount":
        return platforms.wordcount_evaluator()
    return platforms.lm_train_evaluator()


def _actives(platform: str):
    return platforms.WC_ACTIVE if platform == "wordcount" else platforms.LM_ACTIVE


# -------------------------------------------------- Tables III / VI: defaults


def table_defaults(platform: str) -> List[Dict[str, Any]]:
    ev, space = _eval_for(platform)
    cmpe = CMPE(ev, platform=platform, **_scheduler_opts())
    t = cmpe.evaluate(space.defaults(), tag="defaults")
    return [{"table": "III" if platform == "wordcount" else "VI",
             "platform": platform, "config": "all-defaults", "time_s": round(t, 4)}]


# ----------------------------------- Tables IV / VII: one-at-optimal sweeps


def one_opt_candidates(space, name):
    """Candidate 'optimal' values per knob (the paper took these from prior
    manual-tuning work; we sweep each knob's grid and keep the best)."""
    p = space.param(name)
    vals = p.grid(4)
    return [v for v in vals if v != p.default] or [p.default]


def table_one_opt(platform: str) -> List[Dict[str, Any]]:
    ev, space = _eval_for(platform)
    cmpe = CMPE(ev, platform=platform, **_scheduler_opts())
    base = space.defaults()
    t_default = cmpe.evaluate(base, tag="defaults")
    rows = []
    best_values = {}
    for p in space.params:
        best_t, best_v = t_default, p.default
        for v in one_opt_candidates(space, p.name):
            t = cmpe.evaluate({**base, p.name: v}, tag=f"one_opt/{p.name}")
            if t < best_t:
                best_t, best_v = t, v
        impr = 100.0 * (t_default - best_t) / t_default
        best_values[p.name] = best_v
        rows.append({
            "table": "IV" if platform == "wordcount" else "VII",
            "platform": platform, "param": p.name, "tuned_value": best_v,
            "time_s": round(best_t, 4), "improvement_pct": round(impr, 2),
        })
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"one_opt_{platform}.json").write_text(
        json.dumps({"default_time": t_default, "best_values": best_values,
                    "rows": rows}, indent=1, default=str))
    return rows


# -------------------------------- Tables V / VIII: all-at-individual-optimal


def table_all_opt(platform: str) -> List[Dict[str, Any]]:
    ev, space = _eval_for(platform)
    path = RESULTS / f"one_opt_{platform}.json"
    if not path.exists():
        table_one_opt(platform)
    prior = json.loads(path.read_text())
    cmpe = CMPE(ev, platform=platform, **_scheduler_opts())
    t_default = cmpe.evaluate(space.defaults(), tag="defaults")
    config = space.snap({**space.defaults(), **prior["best_values"]})
    t = cmpe.evaluate(config, tag="all_opt")
    impr = 100.0 * (t_default - t) / t_default
    return [{"table": "V" if platform == "wordcount" else "VIII",
             "platform": platform, "config": "all-at-individual-optimal",
             "time_s": round(t, 4), "improvement_pct": round(impr, 2)}]


# ------------------------------------------------- Tables IX / X: GSFT


def table_gsft(platform: str) -> List[Dict[str, Any]]:
    ev, space = _eval_for(platform)
    out: TuneOutcome = tune(
        platform, "gsft", ev,  # real platform name namespaces the cache
        space=space, active_params=_actives(platform), samples_per_param=3,
        log_path=RESULTS / f"gsft_{platform}.jsonl", **_scheduler_opts(),
    )
    (RESULTS / f"gsft_{platform}.json").write_text(json.dumps(out.summary(), indent=1, default=str))
    return [{"table": "IX" if platform == "wordcount" else "X",
             "platform": platform, "algorithm": "gsft",
             "default_time_s": round(out.default_time, 4),
             "tuned_time_s": round(out.best_time, 4),
             "reduction_pct": round(out.reduction_pct, 2),
             "evaluations": out.evaluations}]


# ------------------------------------------------ Tables XI / XII: CRS


def table_crs(platform: str) -> List[Dict[str, Any]]:
    ev, space = _eval_for(platform)
    out = tune(
        platform, "crs", ev,
        space=space, m=10, k=3, max_rounds=4, seed=0,
        log_path=RESULTS / f"crs_{platform}.jsonl", **_scheduler_opts(),
    )
    (RESULTS / f"crs_{platform}.json").write_text(json.dumps(out.summary(), indent=1, default=str))
    return [{"table": "XI" if platform == "wordcount" else "XII",
             "platform": platform, "algorithm": "crs",
             "default_time_s": round(out.default_time, 4),
             "tuned_time_s": round(out.best_time, 4),
             "reduction_pct": round(out.reduction_pct, 2),
             "evaluations": out.evaluations}]


# ---------------------------------------------------------- TPE (model-based)


def table_tpe(platform: str, budget: int = 36) -> List[Dict[str, Any]]:
    """TPE over the full knob set at a GSFT-comparable trial budget.

    ``history=[]`` so that with a shared ``--cache`` the other tables'
    records can't leak into this table's incumbent — the row must report
    what TPE itself found with its own budget."""
    ev, space = _eval_for(platform)
    out = tune(
        platform, "tpe", ev,
        space=space, max_trials=budget, round_size=8, seed=0, history=[],
        log_path=RESULTS / f"tpe_{platform}.jsonl", **_scheduler_opts(),
    )
    (RESULTS / f"tpe_{platform}.json").write_text(json.dumps(out.summary(), indent=1, default=str))
    return [{"table": "tpe",
             "platform": platform, "algorithm": "tpe",
             "default_time_s": round(out.default_time, 4),
             "tuned_time_s": round(out.best_time, 4),
             "reduction_pct": round(out.reduction_pct, 2),
             "evaluations": out.evaluations}]


# --------------------------------- GSFT vs CRS vs TPE shootout (equal budget)


def table_strategy_shootout(platform: str = "wordcount", seed: int = 0) -> List[Dict[str, Any]]:
    """The three strategies head-to-head on one platform. GSFT's grid sets
    the trial budget; CRS and TPE get the same number of trials (CRS may stop
    early on its variation rule — the evaluations column keeps it honest). TPE
    runs with an empty warm-start history so every strategy pays full price.
    Writes ``results/benchmarks/strategy_comparison.json``."""
    ev, space = _eval_for(platform)
    opts = _scheduler_opts()

    gsft = tune(platform, "gsft", ev, space=space, active_params=_actives(platform),
                samples_per_param=3,
                log_path=RESULTS / f"shootout_gsft_{platform}.jsonl", **opts)
    budget = gsft.evaluations
    crs = tune(platform, "crs", ev, space=space,
               m=max(4, budget // 4), k=3, max_rounds=4, seed=seed,
               log_path=RESULTS / f"shootout_crs_{platform}.jsonl", **opts)
    # budget - 1 proposals: tune() spends one trial on the defaults config,
    # which gsft.evaluations already counts — totals come out equal
    tpe = tune(platform, "tpe", ev, space=space, max_trials=budget - 1,
               round_size=8, seed=seed, history=[],
               log_path=RESULTS / f"shootout_tpe_{platform}.jsonl", **opts)

    best_baseline = min(gsft.best_time, crs.best_time)
    rows = []
    for name, out in (("gsft", gsft), ("crs", crs), ("tpe", tpe)):
        rows.append({
            "table": "shootout", "platform": platform, "strategy": name,
            "budget": budget, "evaluations": out.evaluations,
            "default_time_s": round(out.default_time, 4),
            "best_time_s": round(out.best_time, 4),
            "reduction_pct": round(out.reduction_pct, 2),
        })
    rows[-1]["matches_or_beats_baselines"] = tpe.best_time <= best_baseline
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "strategy_comparison.json").write_text(json.dumps({
        "platform": platform, "budget": budget, "rows": rows,
        "best_configs": {"gsft": gsft.best_config, "crs": crs.best_config,
                         "tpe": tpe.best_config},
    }, indent=1, default=str))
    return rows


# ------------------------- ASHA vs full fidelity (equal config width)


def _log_cost(path: Path) -> Dict[str, float]:
    """Paid evaluation cost of a session from its trial log: fresh ok
    trials only (cache replays cost nothing). ``cost_s`` sums the measured
    per-trial time — fidelity-weighted by construction, since a cheap rung
    runs a corpus prefix — and ``trial_equiv`` sums raw fidelities."""
    from repro.core.scheduler import read_log

    recs = [r for r in read_log(path)
            if not r["cached"] and r.get("status", "ok") == "ok"]
    return {
        "fresh_trials": len(recs),
        "cost_s": sum(float(r["time_s"]) for r in recs),
        "trial_equiv": sum(float(r.get("fidelity", 1.0)) for r in recs),
    }


def table_asha(platform: str = "wordcount", budget: int = 32,
               seed: int = 0) -> List[Dict[str, Any]]:
    """Multi-fidelity ASHA against full-fidelity TPE and CRS at the same
    search width (``budget`` distinct configurations each). The claim under
    test: ASHA lands within 2% of the best full-fidelity incumbent while
    paying no more than half the evaluation cost (sum of fidelity-weighted
    fresh-trial time), because most of its configs die at the 1/9 rung.
    A steep 4-rung ladder (eta=4 from 1/64) is what hits the cost target
    under the eager top-``ceil(n/eta)`` promotion rule: a completion stream
    that improves over time (TPE proposals) keeps entering the top set, so
    shallow ladders over-promote into the expensive full rung. Rows (with
    per-rung trial/promotion counts) are merged into
    ``results/benchmarks/strategy_comparison.json``.

    Every strategy here measures best-of-4 repeats (vs the suite's usual 2):
    the comparison is between incumbents, and ASHA keeps only a handful of
    full-fidelity measurements, so per-trial walltime noise that washes out
    over TPE's 32 full trials would otherwise dominate its reported best."""
    if platform == "wordcount":
        ev, space = platforms.wordcount_evaluator(repeats=4)
    else:
        ev, space = _eval_for(platform)
    opts = _scheduler_opts()

    crs = tune(platform, "crs", ev, space=space,
               m=max(4, budget // 4), k=3, max_rounds=4, seed=seed,
               log_path=RESULTS / f"asha_crs_{platform}.jsonl", **opts)
    tpe = tune(platform, "tpe", ev, space=space, max_trials=budget,
               round_size=8, seed=seed, history=[],
               log_path=RESULTS / f"asha_tpe_{platform}.jsonl", **opts)
    asha = tune(platform, "asha", ev, space=space, max_trials=budget,
                inner="tpe", eta=4.0, min_fidelity=1.0 / 64.0, seed=seed,
                log_path=RESULTS / f"asha_asha_{platform}.jsonl", **opts)

    # the within-2% verdict compares the *configs* each strategy chose,
    # re-measured back to back under one best-of-8 yardstick — an in-run
    # best is a min over N noisy measurements, which structurally favours
    # the strategy that paid for more full-fidelity trials
    judge, _ = (platforms.wordcount_evaluator(repeats=8)
                if platform == "wordcount" else _eval_for(platform))
    rows = []
    for name, out in (("crs", crs), ("tpe", tpe), ("asha", asha)):
        cost = _log_cost(RESULTS / f"asha_{name}_{platform}.jsonl")
        rows.append({
            "table": "asha", "platform": platform, "strategy": name,
            "fidelity": "multi" if name == "asha" else "full",
            "budget": budget,
            "best_time_s": round(out.best_time, 4),
            "verified_best_s": round(judge(out.best_config)[0], 4),
            "default_time_s": round(out.default_time, 4),
            "reduction_pct": round(out.reduction_pct, 2),
            "fresh_trials": cost["fresh_trials"],
            "cost_s": round(cost["cost_s"], 4),
            "trial_equiv": round(cost["trial_equiv"], 2),
        })
    full_best = min(rows[0]["verified_best_s"], rows[1]["verified_best_s"])
    full_cost = min(r["cost_s"] for r in rows[:2])
    rows[-1]["rungs"] = asha.summary()["rungs"]
    rows[-1]["within_2pct_of_full"] = (
        rows[-1]["verified_best_s"] <= full_best * 1.02)
    rows[-1]["cost_vs_full"] = round(rows[-1]["cost_s"] / full_cost, 3)
    rows[-1]["half_cost_or_less"] = rows[-1]["cost_s"] <= 0.5 * full_cost

    RESULTS.mkdir(parents=True, exist_ok=True)
    comparison = RESULTS / "strategy_comparison.json"
    doc = json.loads(comparison.read_text()) if comparison.exists() else {
        "platform": platform, "rows": []}
    doc["rows"] = [r for r in doc.get("rows", [])
                   if r.get("table") != "asha"] + rows
    comparison.write_text(json.dumps(doc, indent=1, default=str))
    return rows


# ------------------------------------- cross-cell transfer (WordCount matrix)


def table_transfer(budget: int = 24, seed: int = 2) -> List[Dict[str, Any]]:
    """Cross-cell transfer on a WordCount matrix: a half-size-corpus cell
    (``wordcount/wc:1m``) tunes first, then the full-corpus sibling
    (``wordcount/wc:2m``) runs at the same budget with ``transfer`` off vs
    prior. Reports, per mode, the sibling cell's best time and how many fresh
    evaluations it needed to reach the off-run's final incumbent — the
    transfer claim made measurable on the paper's own workload. Rows are
    merged into ``results/benchmarks/strategy_comparison.json``."""
    import shutil
    import tempfile

    from repro.apps.wordcount import make_corpus, make_evaluator
    from repro.core import Study

    cell_a, cell_b = "wordcount/wc:1m", "wordcount/wc:2m"
    runs: Dict[str, Dict[str, Any]] = {}
    for mode in ("off", "prior"):
        tmp = Path(tempfile.mkdtemp(prefix=f"wc_transfer_{mode}_"))
        try:
            study = Study.create(tmp / "study")
            # the donor cell gets a deeper sweep — its evidence is the prior
            study.optimize(cell_a, "tpe", make_evaluator(make_corpus(1 << 20)),
                           budget=budget + 12, seed=seed)
            out = study.optimize(cell_b, "tpe",
                                 make_evaluator(make_corpus(1 << 21)),
                                 budget=budget, seed=seed, transfer=mode)
            fresh = [float(r["time_s"]) for r in study.trials(platform=cell_b)
                     if not r["cached"] and r.get("status", "ok") == "ok"]
            runs[mode] = {"outcome": out, "fresh_times": fresh}
        finally:
            shutil.rmtree(tmp, ignore_errors=True)

    # walltime measurements carry run-to-run noise; "reached the incumbent"
    # means within 2% of the off-run's final best
    incumbent = runs["off"]["outcome"].best_time * 1.02
    rows = []
    for mode in ("off", "prior"):
        out = runs[mode]["outcome"]
        reached = next((i for i, t in enumerate(runs[mode]["fresh_times"], 1)
                        if t <= incumbent), None)
        rows.append({
            "table": "transfer", "platform": "wordcount-matrix",
            "strategy": "tpe", "transfer": mode, "budget": budget,
            "cell": cell_b.split("/", 1)[1],
            "default_time_s": round(out.default_time, 4),
            "best_time_s": round(out.best_time, 4),
            "reduction_pct": round(out.reduction_pct, 2),
            "evaluations": out.evaluations,
            "evals_to_off_incumbent_2pct": reached,
        })
    off_reached = rows[0]["evals_to_off_incumbent_2pct"] or (budget + 2)
    pri_reached = rows[1]["evals_to_off_incumbent_2pct"] or (budget + 2)
    rows[1]["fewer_evals_than_off"] = pri_reached < off_reached

    RESULTS.mkdir(parents=True, exist_ok=True)
    comparison = RESULTS / "strategy_comparison.json"
    doc = json.loads(comparison.read_text()) if comparison.exists() else {
        "platform": "wordcount", "rows": []}
    doc["rows"] = [r for r in doc.get("rows", [])
                   if r.get("table") != "transfer"] + rows
    comparison.write_text(json.dumps(doc, indent=1, default=str))
    return rows


def table_surrogate(budget: int = 24, seed: int = 3) -> List[Dict[str, Any]]:
    """Learned cost surrogate on the WordCount matrix: the half-size-corpus
    donor cell (``wordcount/wc:1m``) tunes first, then the full-corpus
    sibling (``wordcount/wc:2m``) runs at the same budget with ``surrogate``
    off vs rank (``--transfer`` stays off — the donor's evidence reaches the
    rank run only through the cost model). Reports, per mode, the sibling
    cell's best time and how many fresh evaluations it needed to reach the
    off-run's final incumbent. Rows are merged into
    ``results/benchmarks/strategy_comparison.json``."""
    import shutil
    import tempfile

    from repro.apps.wordcount import make_corpus, make_evaluator
    from repro.core import Study

    cell_a, cell_b = "wordcount/wc:1m", "wordcount/wc:2m"
    runs: Dict[str, Dict[str, Any]] = {}
    for mode in ("off", "rank"):
        tmp = Path(tempfile.mkdtemp(prefix=f"wc_surrogate_{mode}_"))
        try:
            study = Study.create(tmp / "study")
            # the donor cell gets a deeper sweep — its trials are the
            # surrogate's training set
            study.optimize(cell_a, "tpe", make_evaluator(make_corpus(1 << 20)),
                           budget=budget + 24, seed=seed)
            # a short random startup (same for both modes — the comparison
            # stays fair) puts most of the budget in model rounds, where the
            # donor-trained surrogate actually gets to steer
            out = study.optimize(cell_b, "tpe",
                                 make_evaluator(make_corpus(1 << 21)),
                                 budget=budget, seed=seed, n_startup=4,
                                 engine=study.engine.replace(surrogate=mode))
            fresh = [float(r["time_s"]) for r in study.trials(platform=cell_b)
                     if not r["cached"] and r.get("status", "ok") == "ok"]
            runs[mode] = {"outcome": out, "fresh_times": fresh}
        finally:
            shutil.rmtree(tmp, ignore_errors=True)

    # walltime measurements carry run-to-run noise; "reached the incumbent"
    # means within 2% of the off-run's final best
    incumbent = runs["off"]["outcome"].best_time * 1.02
    rows = []
    for mode in ("off", "rank"):
        out = runs[mode]["outcome"]
        reached = next((i for i, t in enumerate(runs[mode]["fresh_times"], 1)
                        if t <= incumbent), None)
        rows.append({
            "table": "surrogate", "platform": "wordcount-matrix",
            "strategy": "tpe", "surrogate": mode, "budget": budget,
            "cell": cell_b.split("/", 1)[1],
            "default_time_s": round(out.default_time, 4),
            "best_time_s": round(out.best_time, 4),
            "reduction_pct": round(out.reduction_pct, 2),
            "evaluations": out.evaluations,
            "evals_to_off_incumbent_2pct": reached,
        })
    off_reached = rows[0]["evals_to_off_incumbent_2pct"] or (budget + 2)
    rank_reached = rows[1]["evals_to_off_incumbent_2pct"] or (budget + 2)
    rows[1]["fewer_evals_than_off"] = rank_reached < off_reached

    RESULTS.mkdir(parents=True, exist_ok=True)
    comparison = RESULTS / "strategy_comparison.json"
    doc = json.loads(comparison.read_text()) if comparison.exists() else {
        "platform": "wordcount", "rows": []}
    doc["rows"] = [r for r in doc.get("rows", [])
                   if r.get("table") != "surrogate"] + rows
    comparison.write_text(json.dumps(doc, indent=1, default=str))
    return rows


# ------------------------------------- kernel autotuning (default vs tuned)


def table_kernels(budget: int = 10, seed: int = 0) -> List[Dict[str, Any]]:
    """Default vs study-tuned block configs per Pallas kernel, interpret
    mode (kernel bodies execute on CPU — the relative ordering of block
    configs is what transfers to hardware, the same way the WordCount tables
    transfer the paper's method, not its cluster). Per kernel at one
    representative shape: a TPE session over the kernel's TunableSpace finds
    an incumbent, then default and tuned configs are re-measured back to
    back on the same evaluator and inputs. Rows are merged into
    ``results/benchmarks/strategy_comparison.json``."""
    from repro.core import Study
    from repro.core.kernel_tune import KERNEL_SPACES, make_kernel_evaluator

    shapes = {
        "flash_attention": (2, 256, 4, 2, 64),
        "rwkv6": (2, 160, 3, 32),
        "ssm_scan": (2, 128, 64, 8),
    }
    rows = []
    for kernel, shape in shapes.items():
        ev = make_kernel_evaluator(kernel, shape, repeats=3, seed=seed)
        space = KERNEL_SPACES[kernel]
        with Study() as study:  # ephemeral: the table re-measures for itself
            out = study.optimize(ev.platform_key(), "tpe", ev, space=space,
                                 budget=budget, seed=seed)
        t_default, _ = ev(space.defaults())
        t_tuned, _ = ev(out.best_config)
        impr = 100.0 * (t_default - t_tuned) / t_default if t_default else 0.0
        rows.append({
            "table": "kernels", "kernel": kernel,
            "shape_class": ev.shape_class(), "mode": "interpret",
            "default_config": space.defaults(),
            "tuned_config": out.best_config,
            "default_time_s": round(t_default, 5),
            "tuned_time_s": round(t_tuned, 5),
            "improvement_pct": round(impr, 2),
            "evaluations": out.evaluations,
        })

    RESULTS.mkdir(parents=True, exist_ok=True)
    comparison = RESULTS / "strategy_comparison.json"
    doc = json.loads(comparison.read_text()) if comparison.exists() else {
        "platform": "wordcount", "rows": []}
    doc["rows"] = [r for r in doc.get("rows", [])
                   if r.get("table") != "kernels"] + rows
    comparison.write_text(json.dumps(doc, indent=1, default=str))
    return rows


# --------------------------------------------------- §XI comparison table


def table_comparison() -> List[Dict[str, Any]]:
    rows = []
    for platform in ("wordcount", "lm_train"):
        g = json.loads((RESULTS / f"gsft_{platform}.json").read_text())
        c = json.loads((RESULTS / f"crs_{platform}.json").read_text())
        rows.append({
            "table": "comparison", "platform": platform,
            "gsft_reduction_pct": g["reduction_pct"],
            "crs_reduction_pct": c["reduction_pct"],
            "gsft_ge_crs": g["reduction_pct"] >= c["reduction_pct"],
        })
    return rows


# ------------------------------------------ §Roofline table (from dry-run)


def table_roofline(dryrun_dir: Path = Path("results/dryrun/single")) -> List[Dict[str, Any]]:
    rows = []
    for f in sorted(dryrun_dir.glob("*.json")):
        c = json.loads(f.read_text())
        if c.get("skipped"):
            rows.append({"table": "roofline", "arch": c["arch"], "shape": c["shape"],
                         "status": "SKIP"})
            continue
        r = c.get("roofline", {})
        rows.append({
            "table": "roofline", "arch": c["arch"], "shape": c["shape"],
            "status": "ok" if c.get("compile_ok") else "FAIL",
            "t_compute_s": round(r.get("t_compute_s", 0), 5),
            "t_memory_s": round(r.get("t_memory_s", 0), 5),
            "t_collective_s": round(r.get("t_collective_s", 0), 5),
            "bottleneck": r.get("bottleneck", ""),
            "mfu_at_step": round(r.get("roofline_fraction_mfu", 0), 4),
            "hbm_est_gib": round(c.get("tpu_hbm_estimate", {}).get("total_gib", 0), 2),
        })
    return rows
