"""Elastic re-scaling: device loss → largest valid mesh → checkpoint re-shard.

When a pod loses hosts, the surviving device count is refactorized into the
largest usable ``(data, model)`` (or ``(pod, data, model)``) mesh that still
satisfies the model's divisibility needs, and the restored checkpoint is
``device_put`` onto the new mesh's shardings (CheckpointManager.restore does
the placement). Scale-up is the same path in reverse.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax

from repro.compat import mesh_kwargs
from repro.configs.base import ArchConfig


def _largest_pow2_le(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def plan_mesh_shape(
    n_devices: int,
    *,
    prefer_model: int = 16,
    arch: Optional[ArchConfig] = None,
    global_batch: Optional[int] = None,
) -> Tuple[int, int]:
    """(data, model) for the largest usable power-of-two device subset.

    Preference order: keep the model axis at ``prefer_model`` (weights keep
    their layout → cheapest re-shard), shrink the data axis; if the surviving
    count is too small, shrink the model axis to the largest power of two
    that still divides the model's sharded dimensions.
    """
    usable = _largest_pow2_le(n_devices)
    model = min(prefer_model, usable)
    if arch is not None:
        # the model axis must divide d_model (densest constraint we use)
        while model > 1 and arch.d_model % model != 0:
            model //= 2
    data = usable // model
    if global_batch is not None:
        while data > 1 and global_batch % data != 0:
            data //= 2
    return data, model


def make_elastic_mesh(n_devices: int, **kw):
    data, model = plan_mesh_shape(n_devices, **kw)
    devices = jax.devices()[: data * model]
    import numpy as np

    return jax.sharding.Mesh(
        np.asarray(devices).reshape(data, model),
        ("data", "model"),
        **mesh_kwargs(2),
    )


@dataclass
class ElasticPlan:
    old_devices: int
    new_devices: int
    mesh_shape: Tuple[int, int]

    @property
    def changed(self) -> bool:
        return self.old_devices != self.new_devices
