"""Straggler / anomaly detection over per-step wall times.

An EMA of step time and its variance; a step whose time exceeds
``mean + z_threshold·std`` (with a floor on relative slowdown) is flagged.
On a real fleet this signal feeds the scheduler (evict/replace the slow
host); here it feeds the trial log and the fault-tolerance tests. The same
monitor drives the runner's "deadline skip" mitigation: a flagged step's
host-side work (data fetch) is overlapped rather than serialized.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class StepTimeMonitor:
    alpha: float = 0.1  # EMA weight
    z_threshold: float = 3.0
    min_relative: float = 1.5  # also require t > 1.5×mean (guards tiny std)
    warmup_steps: int = 3

    mean: float = 0.0
    var: float = 0.0
    count: int = 0
    stragglers: List[int] = field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.count += 1
        if self.count <= self.warmup_steps:
            # prime the statistics; never flag during warmup
            if self.count == 1:
                self.mean = dt
            else:
                self.mean += self.alpha * (dt - self.mean)
                self.var += self.alpha * ((dt - self.mean) ** 2 - self.var)
            return False
        std = max(self.var, 1e-12) ** 0.5
        is_straggler = dt > self.mean + self.z_threshold * std and dt > self.min_relative * self.mean
        if is_straggler:
            self.stragglers.append(step)
        else:
            # stragglers are excluded from the EMA so one bad host does not
            # mask the next one
            self.mean += self.alpha * (dt - self.mean)
            self.var += self.alpha * ((dt - self.mean) ** 2 - self.var)
        return is_straggler

    @property
    def ema_step_time(self) -> float:
        return self.mean
