"""Restartable training runner: checkpoint/restart, failure injection,
straggler monitoring, preemption-signal save.

The loop is deliberately dumb-robust (the part of a fleet trainer that must
never be clever): every step is
    fetch batch → step() → record time → maybe checkpoint
wrapped in a recovery boundary. A ``FailureError`` (injected by tests, or
mapped from a real device error) triggers: restore last checkpoint → rewind
the data pipeline → continue. The run is deterministic, so recovery is
bit-exact (verified by tests/test_ft.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.ft.monitor import StepTimeMonitor


class FailureError(RuntimeError):
    """A (simulated or mapped) fatal device/host failure."""


@dataclass
class RunnerConfig:
    total_steps: int = 20
    checkpoint_every: int = 5
    max_restarts: int = 5


@dataclass
class ResilientTrainer:
    step_fn: Callable[[Any, Dict[str, Any]], Any]  # (state, batch) -> (state, metrics)
    state: Any
    pipeline: Any  # SyntheticLMPipeline-like (step counter + batches)
    ckpt: CheckpointManager
    cfg: RunnerConfig = field(default_factory=RunnerConfig)
    fail_at: Optional[Iterable[int]] = None  # inject failures at these steps
    monitor: StepTimeMonitor = field(default_factory=StepTimeMonitor)

    restarts: int = 0
    history: List[Dict[str, Any]] = field(default_factory=list)

    def run(self) -> Any:
        fail_at = set(self.fail_at or ())
        step = int(self.state["step"])
        if self.ckpt.latest_step() is None:
            self.ckpt.save(step, self.state, blocking=True)

        while step < self.cfg.total_steps:
            try:
                self.pipeline.step = step
                batch_iter = iter(self.pipeline)
                while step < self.cfg.total_steps:
                    batch = next(batch_iter)
                    if step in fail_at:
                        fail_at.discard(step)
                        raise FailureError(f"injected failure at step {step}")
                    t0 = time.perf_counter()
                    self.state, metrics = self.step_fn(self.state, batch)
                    jax.block_until_ready(metrics)
                    dt = time.perf_counter() - t0
                    straggler = self.monitor.record(step, dt)
                    self.history.append(
                        {"step": step, "dt": dt, "straggler": straggler,
                         "loss": float(metrics.get("loss", float("nan")))}
                    )
                    step += 1
                    if step % self.cfg.checkpoint_every == 0:
                        self.ckpt.save(step, self.state)
            except FailureError:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                self.ckpt.wait()
                restored = self.ckpt.restore(self.state)
                self.state = restored
                step = int(self.state["step"])
                self.pipeline.step = step  # rewind data to the restored step
        self.ckpt.wait()
        self.ckpt.save(step, self.state, blocking=True)
        return self.state
