"""JAX version compatibility layer.

The repo targets the current JAX API (``jax.set_mesh``, ``jax.shard_map``,
``jax.sharding.AxisType``); the pinned container ships jax 0.4.x where those
names either do not exist or take different keywords. Every module that
touches mesh construction, ambient-mesh contexts, or partial-manual
``shard_map`` goes through this shim so the same source runs on both.

Exports
  AxisType        — ``jax.sharding.AxisType`` or ``None`` when unavailable
  mesh_kwargs(n)  — ``{"axis_types": (AxisType.Auto,) * n}`` or ``{}``
  make_mesh       — ``jax.make_mesh`` with axis_types only when supported
  set_mesh        — ambient-mesh context manager (falls back to ``with mesh:``)
  shard_map       — new-style keywords mapped onto the legacy
                    ``jax.experimental.shard_map`` (axis_names→auto,
                    check_vma→check_rep)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x
    AxisType = None  # type: ignore[assignment]


def mesh_kwargs(n_axes: int) -> Dict[str, Any]:
    """axis_types kwargs for Mesh/make_mesh, empty on jax without AxisType."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_mesh(shape: Sequence[int], axes: Sequence[str], **kw):
    """``jax.make_mesh`` passing axis_types only where the API accepts it."""
    return jax.make_mesh(tuple(shape), tuple(axes), **mesh_kwargs(len(axes)), **kw)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    New jax: ``jax.set_mesh(mesh)``. Old jax: ``Mesh`` is itself a context
    manager that sets the thread-local physical mesh, which is what
    PartitionSpec-valued ``in_shardings`` resolve against.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def axis_size(name: str):
    """``jax.lax.axis_size`` fallback: psum of ones over the named axis."""
    import jax.numpy as jnp

    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(jnp.ones((), jnp.int32), name)


# New jax resolves PartitionSpec-valued in_shardings against the ambient mesh
# set by jax.set_mesh; 0.4.x jax.jit only accepts concrete Sharding objects.
SUPPORTS_SPEC_SHARDINGS = hasattr(jax, "set_mesh")


def concrete_shardings(tree, mesh):
    """Resolve a PartitionSpec/None tree to NamedShardings where jax.jit
    requires concrete Shardings (no-op on jax with ambient-mesh specs)."""
    if SUPPORTS_SPEC_SHARDINGS or mesh is None:
        return tree
    from jax.sharding import NamedSharding, PartitionSpec

    def conv(x):
        if x is None:
            return NamedSharding(mesh, PartitionSpec())
        if isinstance(x, PartitionSpec):
            return NamedSharding(mesh, x)
        return x

    return jax.tree.map(
        conv, tree, is_leaf=lambda x: x is None or isinstance(x, PartitionSpec)
    )


def shard_map(
    f,
    *,
    mesh,
    in_specs,
    out_specs,
    axis_names: Optional[Sequence[str]] = None,
    check_vma: bool = True,
):
    """New-style ``jax.shard_map`` signature on either jax.

    ``axis_names`` lists the axes the body is *manual* over; the legacy API
    expresses the same thing inversely via ``auto`` (the axes left to GSPMD).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            axis_names=set(axis_names) if axis_names is not None else None,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    kw: Dict[str, Any] = {"check_rep": check_vma}
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kw["auto"] = auto
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
