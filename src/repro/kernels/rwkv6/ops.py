"""Jitted wrapper for the chunked WKV6 kernel.

``chunk`` is clamped to the sequence length (a tuner-proposed 256-token
chunk on a 64-token input would otherwise quadruple the padded work) and,
when the caller passes nothing, filled from the study-tuned table for this
(dtype, shape-class)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import dtype_token, rwkv6_shape_class, tuned_config
from repro.kernels.rwkv6.kernel import wkv6_chunked

DEFAULT_CHUNK = 64


def snap_chunk(chunk: int, seq_len: int) -> int:
    """Clamp a chunk length to the sequence (idempotent)."""
    return max(1, min(int(chunk), int(seq_len)))


def vmem_footprint(chunk: int, hd: int, dtype_bytes: int = 4) -> int:
    """Analytic per-core VMEM bytes for one (batch, head, chunk) grid step:
    the five (chunk × hd) tiles (r/k/v/logw/out) plus the u row at the input
    dtype, the intra-chunk (chunk × chunk) f32 score/decay matrices, and the
    (hd × hd) f32 state scratch. Monotone in ``chunk``."""
    c, hd = int(chunk), int(hd)
    tiles = (5 * c + 1) * hd * int(dtype_bytes)
    scores = 2 * c * c * 4
    scratch = hd * hd * 4
    return tiles + scores + scratch


def wkv6(r, k, v, logw, u, *, chunk: Optional[int] = None,
         interpret: bool = False):
    if r.shape[1] == 1:
        raise ValueError("decode steps use the exact single-step recurrence")
    if chunk is None:
        tuned = tuned_config(
            "rwkv6", dtype_token(r.dtype), rwkv6_shape_class(r.shape)
        ) or {}
        chunk = int(tuned.get("chunk", DEFAULT_CHUNK))
    chunk = snap_chunk(chunk, r.shape[1])
    return wkv6_chunked(r, k, v, logw, u, chunk=chunk, interpret=interpret)
