"""Jitted wrapper for the chunked WKV6 kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import wkv6_chunked


def wkv6(r, k, v, logw, u, *, chunk: int = 64, interpret: bool = False):
    if r.shape[1] == 1:
        raise ValueError("decode steps use the exact single-step recurrence")
    return wkv6_chunked(r, k, v, logw, u, chunk=chunk, interpret=interpret)
