"""RWKV-6 (Finch) chunked WKV recurrence as a Pallas TPU kernel.

The data-dependent-decay linear attention is computed chunk-parallel: within
a chunk of C tokens the decay products are factored into the queries/keys so
the intra-chunk part is two C×C / C×K matmuls (MXU work); across chunks a
(K, V) state matrix is carried in VMEM scratch — the time axis is the
sequential grid dimension, exactly mirroring the ``lax.scan`` in
``repro.models.rwkv6.time_mix`` (the pure-jnp oracle).

Grid: (B, H, n_chunks) with the chunk axis innermost/sequential. Blocks:
r/k/v/logw tiles of (1, C, 1, hd) straight from the (B, S, H, hd) layout,
``u`` (per-head bonus) as a (1, hd) tile. All accumulation in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0, :, 0, :].astype(jnp.float32)  # (C, K)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # (C, V)
    lw = lw_ref[0, :, 0, :].astype(jnp.float32)  # (C, K) log-decay (<0)
    u = u_ref[0, :].astype(jnp.float32)  # (K,)

    lcum = jnp.cumsum(lw, axis=0)  # inclusive
    ltot = lcum[-1:, :]  # (1, K)
    q_f = r * jnp.exp(lcum - lw)
    k_f = k * jnp.exp(-lcum)

    scores = jax.lax.dot_general(
        q_f, k_f, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, C)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(tj < ti, scores, 0.0)  # strictly past tokens

    diag = jnp.sum(r * u[None, :] * k, axis=1)  # (C,) current-token bonus
    o = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o += diag[:, None] * v
    o += jax.lax.dot_general(
        q_f, state_scr[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    k_s = k * jnp.exp(ltot - lcum)  # decays from token to end of chunk
    state_scr[...] = jnp.exp(ltot).T * state_scr[...] + jax.lax.dot_general(
        k_s, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0, :, 0, :] = o.astype(o_ref.dtype)


def wkv6_chunked(r, k, v, logw, u, *, chunk: int = 64, interpret: bool = False):
    """r/k/v/logw: (B, S, H, hd); u: (H, hd). Returns (B, S, H, hd) (the WKV
    mix output, before group-norm/gating)."""
    b, s, h, hd = r.shape
    pad = (-s) % chunk
    if pad:
        r, k, v = (jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))) for x in (r, k, v))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    n_chunks = sp // chunk

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(b, h, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, chunk, 1, hd), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, chunk, 1, hd), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, chunk, 1, hd), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, hd), lambda b_, h_, ci: (h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, hd), lambda b_, h_, ci: (b_, ci, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sp, h, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
    return out[:, :s]
