"""Sequential-recurrence oracle for the WKV6 kernel (exact, O(S) steps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, logw, u):
    """r/k/v/logw: (B, S, H, hd); u: (H, hd). Token-by-token recurrence:
        o_t = r_t · (S + (u ⊙ k_t) v_tᵀ);   S ← diag(e^{logw_t}) S + k_t v_tᵀ
    """
    b, s, h, hd = r.shape
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    lw = logw.astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def step(state, xs):
        r_t, k_t, v_t, lw_t = xs  # (B, H, hd)
        att = state + (uf[None] * k_t)[..., None] * v_t[:, :, None, :]
        o_t = jnp.einsum("bhk,bhkv->bhv", r_t, att)
        state = jnp.exp(lw_t)[..., None] * state + k_t[..., None] * v_t[:, :, None, :]
        return state, o_t

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (rf, kf, vf, lw))
    state0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    _, outs = jax.lax.scan(step, state0, xs)
    return outs.transpose(1, 0, 2, 3).astype(r.dtype)
