"""Sequential oracle for the selective-scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(dt, u, b_t, c_t, a):
    """dt/u: (B, S, di); b_t/c_t: (B, S, N); a: (di, N) -> y (B, S, di)."""
    dtf, uf = dt.astype(jnp.float32), u.astype(jnp.float32)
    bf, cf = b_t.astype(jnp.float32), c_t.astype(jnp.float32)
    af = a.astype(jnp.float32)
    b, s, di = dt.shape

    def step(h, xs):
        dt_t, u_t, b_tt, c_tt = xs  # (B, di), (B, di), (B, N), (B, N)
        da = jnp.exp(dt_t[..., None] * af[None])  # (B, di, N)
        h = da * h + (dt_t * u_t)[..., None] * b_tt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_tt)
        return h, y

    xs = (dtf.transpose(1, 0, 2), uf.transpose(1, 0, 2),
          bf.transpose(1, 0, 2), cf.transpose(1, 0, 2))
    h0 = jnp.zeros((b, di, af.shape[1]), jnp.float32)
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(dt.dtype)
