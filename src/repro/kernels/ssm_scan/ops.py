"""Jitted wrapper for the selective-scan kernel.

``chunk`` is clamped to the sequence length and ``d_block`` halved until it
divides the channel dim (both idempotent, so any tuner proposal is legal);
when the caller passes nothing the study-tuned table for this
(dtype, shape-class) fills them."""
from __future__ import annotations

from typing import Optional

from repro.kernels import dtype_token, ssm_shape_class, tuned_config
from repro.kernels.ssm_scan.kernel import ssm_scan

DEFAULT_CHUNK = 128
DEFAULT_D_BLOCK = 256


def snap_chunk(chunk: int, seq_len: int) -> int:
    """Clamp a chunk length to the sequence (idempotent)."""
    return max(1, min(int(chunk), int(seq_len)))


def snap_d_block(d_block: int, di: int) -> int:
    """Halve until it divides the channel dim (idempotent)."""
    d_block = max(1, int(d_block))
    while di % d_block:
        d_block //= 2
    return max(d_block, 1)


def vmem_footprint(chunk: int, d_block: int, n: int, dtype_bytes: int = 4) -> int:
    """Analytic per-core VMEM bytes for one (batch, d_block, chunk) grid
    step: the dt/u/out (chunk × d_block) and B/C (chunk × n) tiles plus the
    (d_block × n) A row at the input dtype, the (d_block × n) f32 state
    scratch, and the f32 working tiles the in-kernel scan materializes.
    Monotone in both ``chunk`` and ``d_block``."""
    c, db, n = int(chunk), int(d_block), int(n)
    tiles = (3 * c * db + 2 * c * n + db * n) * int(dtype_bytes)
    scratch = db * n * 4
    work = (c * db + c * n) * 4
    return tiles + scratch + work


def selective_scan(dt, u, b_t, c_t, a, *, chunk: Optional[int] = None,
                   d_block: Optional[int] = None, interpret: bool = False):
    if chunk is None or d_block is None:
        tuned = tuned_config(
            "ssm_scan", dtype_token(dt.dtype),
            ssm_shape_class(dt.shape, a.shape[-1]),
        ) or {}
        if chunk is None:
            chunk = int(tuned.get("chunk", DEFAULT_CHUNK))
        if d_block is None:
            d_block = int(tuned.get("d_block", DEFAULT_D_BLOCK))
    chunk = snap_chunk(chunk, dt.shape[1])
    d_block = snap_d_block(d_block, dt.shape[-1])
    return ssm_scan(dt, u, b_t, c_t, a, chunk=chunk, d_block=d_block,
                    interpret=interpret)
