"""Jitted wrapper for the selective-scan kernel."""
from __future__ import annotations

from repro.kernels.ssm_scan.kernel import ssm_scan


def selective_scan(dt, u, b_t, c_t, a, *, chunk: int = 128, d_block: int = 256,
                   interpret: bool = False):
    di = dt.shape[-1]
    while di % d_block:
        d_block //= 2
    return ssm_scan(dt, u, b_t, c_t, a, chunk=chunk, d_block=max(d_block, 1),
                    interpret=interpret)
