"""Mamba (S6) selective-scan as a Pallas TPU kernel.

The diagonal recurrence h_t = e^{Δ_t·A} ⊙ h_{t−1} + (Δ_t u_t) B_t is
sequential in time but embarrassingly parallel over the (d_inner × state)
plane — on TPU the natural mapping is: channel blocks on the parallel grid
axes, time as an in-kernel ``fori_loop`` over a VMEM-resident (d_block, N)
state (GPU implementations instead use warp-level prefix scans; the VREG/VMEM
hierarchy prefers the wide-vector sequential form — DESIGN.md §5).

Inputs are the *factored* tensors (Δ, A, B, C, u) — the (B, S, d, N) outer
products are never materialized in HBM (the XLA associative-scan path
materializes both ``da`` and ``dbu``; this kernel is the memory-roofline fix
for mamba layers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dt_ref, u_ref, b_ref, c_ref, a_ref, y_ref, h_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    dt = dt_ref[0].astype(jnp.float32)  # (C, dib)
    u = u_ref[0].astype(jnp.float32)  # (C, dib)
    b_t = b_ref[0].astype(jnp.float32)  # (C, N)
    c_t = c_ref[0].astype(jnp.float32)  # (C, N)
    a = a_ref[...].astype(jnp.float32)  # (dib, N)

    def step(t, _):
        da = jnp.exp(dt[t][:, None] * a)  # (dib, N)
        h = da * h_scr[...] + (dt[t] * u[t])[:, None] * b_t[t][None, :]
        h_scr[...] = h
        y_ref[0, t, :] = jnp.sum(h * c_t[t][None, :], axis=1).astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)


def ssm_scan(dt, u, b_t, c_t, a, *, chunk: int = 128, d_block: int = 256,
             interpret: bool = False):
    """dt/u: (B, S, di); b_t/c_t: (B, S, N); a: (di, N). Returns y (B, S, di)
    (the h·C contraction; caller adds the D-skip and gating)."""
    b, s, di = dt.shape
    n = a.shape[1]
    d_block = min(d_block, di)
    assert di % d_block == 0, (di, d_block)
    pad = (-s) % chunk
    if pad:
        dt, u = (jnp.pad(x, ((0, 0), (0, pad), (0, 0))) for x in (dt, u))
        b_t, c_t = (jnp.pad(x, ((0, 0), (0, pad), (0, 0))) for x in (b_t, c_t))
    sp = s + pad
    n_chunks = sp // chunk

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(b, di // d_block, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b_, dbi, ci: (b_, ci, dbi)),
            pl.BlockSpec((1, chunk, d_block), lambda b_, dbi, ci: (b_, ci, dbi)),
            pl.BlockSpec((1, chunk, n), lambda b_, dbi, ci: (b_, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, dbi, ci: (b_, ci, 0)),
            pl.BlockSpec((d_block, n), lambda b_, dbi, ci: (dbi, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block), lambda b_, dbi, ci: (b_, ci, dbi)),
        out_shape=jax.ShapeDtypeStruct((b, sp, di), dt.dtype),
        scratch_shapes=[pltpu.VMEM((d_block, n), jnp.float32)],
        interpret=interpret,
    )(dt, u, b_t, c_t, a)
    return out[:, :s]
