"""Flash attention as a Pallas TPU kernel (online softmax, VMEM-resident
blocks, MXU-aligned tiles).

TPU adaptation of the FlashAttention idea (the paper's GPU formulation works
around SRAM/shared-memory; here the tiling is driven by VMEM capacity and the
128×128 MXU):

  - grid = (batch, q_heads, q_blocks, kv_blocks); the kv axis is the
    innermost, sequential ("arbitrary") dimension — running-max/denominator/
    accumulator live in VMEM scratch across kv iterations, so scores never
    round-trip to HBM (the XLA fallback path materializes every (S × block)
    score tile — that difference IS the memory-roofline gap the dry-run
    shows).
  - ``block_q × block_kv`` tiles are the tunable knobs ``attn_block_q/kv``
    exposed to the paper's tuner; both must be multiples of 128 to keep the
    MXU systolic array full.
  - GQA: the kv BlockSpec maps query-head h → kv-head h·Hkv//Hq, so K/V
    blocks are fetched once per query head directly from the (B,T,Hkv,Dh)
    layout — no repeated/materialized K/V.
  - causal + sliding-window masking is applied with block-level early-exit:
    fully-masked (q-block, kv-block) pairs are skipped before the matmul
    (``@pl.when``), which is where the causal 2× win comes from.

Supports: causal/full, sliding window, logit softcap, GQA, optional
``kv_length`` (valid-prefix) masking. f32 accumulation throughout.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    # refs
    q_ref,  # (1, block_q, 1, dh)
    k_ref,  # (1, block_kv, 1, dh)
    v_ref,  # (1, block_kv, 1, dh)
    o_ref,  # (1, block_q, 1, dh)
    m_scr,  # (block_q,) f32 running max
    l_scr,  # (block_q,) f32 running denominator
    acc_scr,  # (block_q, dh) f32 accumulator
    *,
    causal: bool,
    window: int,
    softcap: float,
    scale: float,
    block_q: int,
    block_kv: int,
    n_kv: int,
    t_valid: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_kv

    # block-level early exit: skip fully-masked tiles before touching the MXU
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + block_q - 1  # block fully in the future
    if window > 0:
        # block fully older than the window of the youngest query in the tile
        live &= k_start + block_kv - 1 >= q_start - window + 1

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (bq, dh)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bkv, dh)
        v = v_ref[0, :, 0, :].astype(jnp.float32)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bkv)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = k_pos < t_valid
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])  # (bq, bkv)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,  # (B, S, Hq, Dh)
    k: jnp.ndarray,  # (B, T, Hkv, Dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    kv_length: Optional[int] = None,
    block_q: int = 128,
    block_kv: int = 128,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pallas flash attention. ``scale`` defaults to dh^-0.5 (pass 1.0 for
    pre-scaled q). Static window / kv_length (the model routes traced windows
    to the XLA path)."""
    b, s, hq, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    scale = dh**-0.5 if scale is None else scale

    # clamp to the 128-padded lengths, not the raw ones: min(block, s) on a
    # ragged s (e.g. 200) would silently de-align the MXU tile the ops layer
    # just snapped; the pad below absorbs the overhang instead
    block_q = max(1, min(block_q, -(-s // 128) * 128))
    block_kv = max(1, min(block_kv, -(-t // 128) * 128))
    pad_q = (-s) % block_q
    pad_kv = (-t) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    sp, tp = s + pad_q, t + pad_kv
    n_q, n_kv = sp // block_q, tp // block_kv
    t_valid = t if kv_length is None else int(kv_length)

    kernel = functools.partial(
        _kernel,
        causal=causal,
        window=int(window),
        softcap=float(softcap),
        scale=scale,
        block_q=block_q,
        block_kv=block_kv,
        n_kv=n_kv,
        t_valid=t_valid,
    )

    out = pl.pallas_call(
        kernel,
        grid=(b, hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, dh), lambda b_, h, qi, ki: (b_, qi, h, 0)),
            pl.BlockSpec((1, block_kv, 1, dh), lambda b_, h, qi, ki: (b_, ki, h * hkv // hq, 0)),
            pl.BlockSpec((1, block_kv, 1, dh), lambda b_, h, qi, ki: (b_, ki, h * hkv // hq, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, dh), lambda b_, h, qi, ki: (b_, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sp, hq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :s]
