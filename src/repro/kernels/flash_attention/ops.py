"""Jitted public wrapper for the flash-attention kernel.

Routing rules (enforced here so the model layer stays simple):
  - traced / dynamic sliding windows → ValueError (the model's XLA path
    handles those; gemma-style local:global stacks scan a traced window),
  - decode (S == 1) → ValueError (the decode path is gather-bound, not a
    flash workload).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    q_positions=None,  # accepted for API parity; kernel assumes iota
    kv_length=None,
    causal: bool = True,
    window=0,
    softcap_val: float = 0.0,
    block_q: int = 128,
    block_kv: int = 128,
    scale: Optional[float] = 1.0,  # model pre-scales q
    interpret: bool = False,
) -> jnp.ndarray:
    if not isinstance(window, (int, float)):
        raise ValueError(
            "pallas flash attention needs a static window; traced per-layer "
            "windows must use attention_impl='xla'"
        )
    if q.shape[1] == 1:
        raise ValueError("decode steps use the XLA attention path")
    kv_len = None
    if kv_length is not None:
        if hasattr(kv_length, "shape") and getattr(kv_length, "shape", None):
            raise ValueError("pallas path needs a static scalar kv_length")
        kv_len = int(kv_length)
    # MXU alignment: snap blocks to multiples of 128 within bounds
    block_q = max(128, (int(block_q) // 128) * 128)
    block_kv = max(128, (int(block_kv) // 128) * 128)
    return flash_attention_fwd(
        q, k, v,
        causal=causal, window=int(window), softcap=float(softcap_val),
        kv_length=kv_len, block_q=block_q, block_kv=block_kv, scale=scale,
        interpret=interpret,
    )
