"""Jitted public wrapper for the flash-attention kernel.

Routing rules (enforced here so the model layer stays simple):
  - traced / dynamic sliding windows → ValueError (the model's XLA path
    handles those; gemma-style local:global stacks scan a traced window),
  - decode (S == 1) → ValueError (the decode path is gather-bound, not a
    flash workload).

Block sizes: callers that pass ``block_q``/``block_kv`` get them snapped to
legal values (multiple of 128 for the MXU, clamped to the 128-padded
sequence so an oversized tuner proposal can never over-allocate VMEM or
fault). Callers that pass **nothing** get the study-tuned entry for this
(dtype, shape-class) from ``repro.kernels.tuned_table.json`` when one
exists, else the hardcoded defaults.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import dtype_token, flash_shape_class, tuned_config
from repro.kernels.flash_attention.kernel import flash_attention_fwd

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128


def snap_block(block: int, seq_len: int) -> int:
    """MXU-align then bound a block size: snap down to a multiple of 128
    (floor 128), then clamp to the 128-padded sequence length. Idempotent —
    snapping a snapped value is a no-op, the contract ``TunableSpace.snap``
    assumes of every knob."""
    block = max(128, (int(block) // 128) * 128)
    padded = -(-int(seq_len) // 128) * 128  # ceil to the 128 grid
    return min(block, max(128, padded))


def vmem_footprint(
    block_q: int, block_kv: int, dh: int, dtype_bytes: int = 4
) -> int:
    """Analytic per-core VMEM bytes for one grid step of the kernel: the
    q/k/v/o tiles at the input dtype, the (block_q × block_kv) score matrix
    in f32, and the f32 scratch (accumulator + running max/denominator).
    Monotone in both block sizes — the feasibility gate relies on that."""
    bq, bkv, dh = int(block_q), int(block_kv), int(dh)
    tiles = (2 * bq + 2 * bkv) * dh * int(dtype_bytes)  # q, o, k, v
    scores = bq * bkv * 4
    scratch = (bq * dh + 2 * bq) * 4  # acc + m/l
    return tiles + scores + scratch


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    q_positions=None,  # accepted for API parity; kernel assumes iota
    kv_length=None,
    causal: bool = True,
    window=0,
    softcap_val: float = 0.0,
    block_q: Optional[int] = None,
    block_kv: Optional[int] = None,
    scale: Optional[float] = 1.0,  # model pre-scales q
    interpret: bool = False,
) -> jnp.ndarray:
    if not isinstance(window, (int, float)):
        raise ValueError(
            "pallas flash attention needs a static window; traced per-layer "
            "windows must use attention_impl='xla'"
        )
    if q.shape[1] == 1:
        raise ValueError("decode steps use the XLA attention path")
    kv_len = None
    if kv_length is not None:
        if hasattr(kv_length, "shape") and getattr(kv_length, "shape", None):
            raise ValueError("pallas path needs a static scalar kv_length")
        kv_len = int(kv_length)
    if block_q is None or block_kv is None:
        tuned = tuned_config(
            "flash_attention", dtype_token(q.dtype),
            flash_shape_class(q.shape, k.shape),
        ) or {}
        if block_q is None:
            block_q = int(tuned.get("block_q", DEFAULT_BLOCK_Q))
        if block_kv is None:
            block_kv = int(tuned.get("block_kv", DEFAULT_BLOCK_KV))
    # MXU alignment + clamp to the (padded) sequence lengths
    block_q = snap_block(block_q, q.shape[1])
    block_kv = snap_block(block_kv, k.shape[1])
    return flash_attention_fwd(
        q, k, v,
        causal=causal, window=int(window), softcap=float(softcap_val),
        kv_length=kv_len, block_q=block_q, block_kv=block_kv, scale=scale,
        interpret=interpret,
    )
