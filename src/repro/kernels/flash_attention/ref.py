"""Pure-jnp oracle for the flash-attention kernel (O(S·T) materialized)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,  # (B, S, Hq, Dh)
    k: jnp.ndarray,  # (B, T, Hkv, Dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    kv_length: Optional[int] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    b, s, hq, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = dh**-0.5 if scale is None else scale
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum(
        "bshd,bthd->bhst", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = jnp.arange(s)[None, None, :, None]
    kpos = jnp.arange(t)[None, None, None, :]
    mask = jnp.ones((1, 1, s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= qpos - kpos < window
    if kv_length is not None:
        mask &= kpos < kv_length
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
