"""Pallas kernels + the study-backed autotune table.

Kernel subpackages (``flash_attention``, ``rwkv6``, ``ssm_scan``) each ship
``kernel.py`` (the Pallas implementation), ``ops.py`` (the jitted public
wrapper), and ``ref.py`` (a pure-jnp oracle the tuner checks numerics
against).

This package root holds the **tuned-config table**: the classic Triton-style
autotune cache, but produced by a persistent :class:`~repro.core.study.Study`
(``repro.launch.kernel_tune``) instead of a per-process benchmark loop, and
shipped with the repo (``tuned_table.json``). The public entry points
(``flash_attention`` / ``wkv6`` / ``selective_scan``) consult it at call time
whenever the caller passes no explicit block sizes, keyed by
``(kernel, dtype, shape-class)``:

    >>> tuned_config("flash_attention", "f32", "b2s256h4k2d64")
    {'block_q': 128, 'block_kv': 128}

A shape class is a compact dims string (``b2s256h4k2d64``); an exact-match
miss falls back to the nearest tuned class of the same kernel and dtype by
summed |log2| dim distance — tuned blocks transfer across input scales, and
the ops-layer snap/clamp makes any carried-over block size legal for the
actual shape. No table, a corrupt table, or an unknown kernel all degrade to
the hardcoded defaults (with one warning for corruption, never an error).

Everything here is stdlib-only — importing ``repro.kernels`` must never pull
in jax.
"""
from __future__ import annotations

import json
import math
import os
import re
import warnings
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

__all__ = [
    "DEFAULT_TABLE_PATH",
    "TUNED_TABLE_ENV",
    "dtype_token",
    "flash_shape_class",
    "invalidate_tuned_table_cache",
    "load_tuned_table",
    "parse_shape_class",
    "rwkv6_shape_class",
    "shape_class_distance",
    "ssm_shape_class",
    "table_key",
    "tuned_config",
]

TUNED_TABLE_ENV = "REPRO_KERNEL_TUNED_TABLE"
DEFAULT_TABLE_PATH = Path(__file__).with_name("tuned_table.json")

_TABLE_VERSION = 1

# one cache slot per resolved path; invalidated explicitly (tests, the tuner
# after writing) — kernel call sites hit a dict lookup, not the filesystem
_table_cache: Dict[Path, Dict[str, Dict[str, Any]]] = {}


# ------------------------------------------------------------- shape classes


def dtype_token(dtype: Any) -> str:
    """Canonical short dtype name (``f32``/``bf16``/``f16``/...) from a jax
    or numpy dtype, dtype-like, or string."""
    name = getattr(dtype, "name", None) or str(dtype)
    name = name.rsplit(".", 1)[-1]  # e.g. "jax.numpy.float32"
    return {
        "float32": "f32",
        "float16": "f16",
        "bfloat16": "bf16",
        "float64": "f64",
        "int8": "i8",
    }.get(name, name)


def flash_shape_class(q_shape: Tuple[int, ...], k_shape: Tuple[int, ...]) -> str:
    """(B,S,Hq,Dh) × (B,T,Hkv,Dh) → ``b{B}s{S}h{Hq}k{Hkv}d{Dh}``."""
    b, s, hq, dh = q_shape
    hkv = k_shape[2]
    return f"b{b}s{s}h{hq}k{hkv}d{dh}"


def rwkv6_shape_class(r_shape: Tuple[int, ...]) -> str:
    """(B,S,H,Hd) → ``b{B}s{S}h{H}d{Hd}``."""
    b, s, h, hd = r_shape
    return f"b{b}s{s}h{h}d{hd}"


def ssm_shape_class(dt_shape: Tuple[int, ...], n: int) -> str:
    """(B,S,Di) + state size N → ``b{B}s{S}di{Di}n{N}``."""
    b, s, di = dt_shape
    return f"b{b}s{s}di{di}n{n}"


_DIM_RE = re.compile(r"([a-z]+)(\d+)")


def parse_shape_class(cls: str) -> Dict[str, int]:
    """``"b2s256h4k2d64"`` → ``{"b": 2, "s": 256, "h": 4, "k": 2, "d": 64}``."""
    return {m.group(1): int(m.group(2)) for m in _DIM_RE.finditer(cls)}


def shape_class_distance(a: str, b: str) -> float:
    """Summed |log2| ratio over the dims two classes share; ``inf`` when the
    dim alphabets differ (different kernel families never match)."""
    da, db = parse_shape_class(a), parse_shape_class(b)
    if set(da) != set(db) or not da:
        return float("inf")
    return sum(
        abs(math.log2(max(da[k], 1) / max(db[k], 1))) for k in da
    )


# ------------------------------------------------------------- table loading


def table_key(kernel: str, dtype: Any, shape_class: str) -> str:
    return f"{kernel}|{dtype_token(dtype)}|{shape_class}"


def _table_path(path: Optional[Path] = None) -> Path:
    if path is not None:
        return Path(path)
    env = os.environ.get(TUNED_TABLE_ENV)
    return Path(env) if env else DEFAULT_TABLE_PATH


def load_tuned_table(path: Optional[Path] = None) -> Dict[str, Dict[str, Any]]:
    """The tuned-config entries, ``{table_key: {"config": {...}, ...}}``.

    Missing file → empty table (kernels keep their hardcoded defaults).
    Corrupt file or wrong schema → one warning, then the same clean fallback
    — a bad shipped table must never break a forward pass."""
    p = _table_path(path)
    if p in _table_cache:
        return _table_cache[p]
    entries: Dict[str, Dict[str, Any]] = {}
    if p.exists():
        try:
            raw = json.loads(p.read_text())
            if not isinstance(raw, dict) or not isinstance(
                raw.get("entries"), dict
            ):
                raise ValueError("expected {'version': .., 'entries': {..}}")
            for key, rec in raw["entries"].items():
                if not isinstance(rec, dict) or not isinstance(
                    rec.get("config"), dict
                ):
                    raise ValueError(f"entry {key!r} has no config dict")
                entries[str(key)] = rec
        except (ValueError, OSError, UnicodeDecodeError) as e:
            warnings.warn(
                f"ignoring corrupt kernel tuned table {p}: {e} "
                "(kernels fall back to their hardcoded defaults)",
                RuntimeWarning,
                stacklevel=2,
            )
            entries = {}
    _table_cache[p] = entries
    return entries


def invalidate_tuned_table_cache() -> None:
    """Drop every cached table (call after writing a new one)."""
    _table_cache.clear()


def tuned_config(
    kernel: str, dtype: Any, shape_class: str, path: Optional[Path] = None
) -> Optional[Dict[str, Any]]:
    """Best tuned knob dict for ``(kernel, dtype, shape_class)`` or None.

    Exact shape-class hit wins; otherwise the nearest tuned class of the
    same kernel + dtype (finite :func:`shape_class_distance`) donates its
    config — the ops-layer snap/clamp re-legalises its blocks for the actual
    shape."""
    table = load_tuned_table(path)
    if not table:
        return None
    exact = table.get(table_key(kernel, dtype, shape_class))
    if exact is not None:
        return dict(exact["config"])
    prefix = f"{kernel}|{dtype_token(dtype)}|"
    best, best_d = None, float("inf")
    for key, rec in table.items():
        if not key.startswith(prefix):
            continue
        d = shape_class_distance(shape_class, key[len(prefix):])
        if d < best_d:
            best, best_d = rec, d
    return dict(best["config"]) if best is not None else None
