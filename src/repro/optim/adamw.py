"""AdamW with configurable moment dtype and ZeRO-friendly state layout.

The optimizer state is a plain pytree mirroring the parameter tree, so the
distribution layer can assign it *different* shardings from the parameters
(ZeRO-1: moments sharded over the data axis; see
``repro.distributed.sharding.opt_state_rules``). The ``optimizer_moment_dtype``
RunConfig knob (bfloat16 moments) halves optimizer-state HBM — one of the
paper-analog "memory.mb"-class parameters.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"


def init_opt_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
    }


def abstract_opt_state(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    z = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return {"mu": jax.tree.map(z, params), "nu": jax.tree.map(z, params)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(
    grads,
    opt_state: Dict[str, Any],
    params,
    step: jnp.ndarray,
    lr: jnp.ndarray,
    cfg: AdamWConfig,
) -> Tuple[Any, Dict[str, Any]]:
    """One AdamW step. Returns (new_params, new_opt_state)."""
    dt = jnp.dtype(cfg.moment_dtype)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, mu, nu, p):
        g32 = g.astype(jnp.float32)
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1.0 - cfg.b1) * g32
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1.0 - cfg.b2) * jnp.square(g32)
        mhat = mu32 / bc1
        nhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), mu32.astype(dt), nu32.astype(dt)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    flat_p = jax.tree.leaves(params)
    out = [upd(g, mu, nu, p) for g, mu, nu, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu}
