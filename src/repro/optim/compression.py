"""Int8 error-feedback gradient compression for the cross-pod (DCI) axis.

On a multi-pod fleet, the intra-pod gradient reduction rides the fast ICI
torus while the cross-pod reduction crosses the (much slower) data-center
interconnect. We compress ONLY the cross-pod hop: per-tensor symmetric int8
quantization with an error-feedback residual (the quantization error is added
back into the next step's gradient, keeping the long-run update unbiased —
Seide et al. 2014 / Karimireddy et al. 2019).

Usage: the train-step builder wraps its loss+grad computation in a
*partial-manual* ``shard_map`` over just the ``pod`` mesh axis (data/model
stay under GSPMD inside), computes pod-local gradients, and calls
``compress_psum_pod_tree`` to reduce them across pods. The dry-run HLO then
shows the cross-pod hop as an ``all-reduce`` over s32 operands with
``replica_groups`` of size n_pods — 4× narrower on the wire than f32.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size


def _compress_psum_pod(g, err):
    """Per-pod body: g is this pod's partial gradient (still GSPMD-sharded
    over data/model inside the pod). Returns (cross-pod mean, new residual)."""
    g32 = g.astype(jnp.float32) + err.astype(jnp.float32)
    # shared symmetric scale: max |g| across pods so every pod decodes alike
    amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), "pod")
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale  # error feedback
    total = jax.lax.psum(q.astype(jnp.int32), "pod")
    npod = axis_size("pod")
    out = (total.astype(jnp.float32) * scale / npod).astype(g.dtype)
    return out, new_err.astype(err.dtype)


def compress_psum_pod_tree(grads, err_state) -> Tuple[Any, Any]:
    """Cross-pod compressed mean of a gradient pytree. MUST be called inside a
    ``shard_map(..., axis_names={"pod"})`` body."""
    pairs = jax.tree.map(_compress_psum_pod, grads, err_state)
    is_pair = lambda x: isinstance(x, tuple)
    synced = jax.tree.map(lambda p: p[0], pairs, is_leaf=is_pair)
    new_err = jax.tree.map(lambda p: p[1], pairs, is_leaf=is_pair)
    return synced, new_err


def uncompressed_psum_pod_tree(grads) -> Any:
    """Reference path (same structure, f32 wire) for A/B tests."""
    npod = axis_size("pod")
    return jax.tree.map(lambda g: jax.lax.psum(g, "pod") / npod, grads)


def compress_sum_chunked(g, err):
    """GSPMD fallback for jax without partial-manual ``shard_map``: ``g`` and
    ``err`` carry an explicit pod-chunk leading dim ([n_pod, *param]) sharded
    over the pod mesh axis; the int32 sum over that dim IS the cross-pod
    all-reduce once SPMD-partitioned. Same quantization math as
    ``_compress_psum_pod``."""
    n_pod = g.shape[0]
    g32 = g.astype(jnp.float32) + err.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g32))  # max over pods == the shared decode scale
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale
    total = q.astype(jnp.int32).sum(axis=0)  # cross-pod s32 all-reduce
    out = (total.astype(jnp.float32) * scale / n_pod).astype(g.dtype)
    return out, new_err.astype(err.dtype)


def compress_sum_chunked_tree(grads, err_state) -> Tuple[Any, Any]:
    """Tree version of :func:`compress_sum_chunked` (pure GSPMD, no manual
    axes — usable on jax 0.4.x)."""
    pairs = jax.tree.map(compress_sum_chunked, grads, err_state)
    is_pair = lambda x: isinstance(x, tuple)
    synced = jax.tree.map(lambda p: p[0], pairs, is_leaf=is_pair)
    new_err = jax.tree.map(lambda p: p[1], pairs, is_leaf=is_pair)
    return synced, new_err


def init_error_state(params, dtype=jnp.float32):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)


def abstract_error_state(params, dtype=jnp.float32):
    return jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, dtype), params)


def abstract_chunked_error_state(params, n_pod: int, dtype=jnp.float32):
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct((n_pod,) + tuple(p.shape), dtype), params
    )
