"""Mesh construction. Functions (not module-level constants) so importing
this module never touches JAX device state."""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's production mesh: one v5e pod = 16×16 = 256 chips
    (data × model); multi-pod adds a leading pod axis (2 × 16 × 16 = 512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_tuning_mesh(model_parallel: int, *, chips: int = 256, multi_pod: bool = False):
    """Mesh for a tuner-chosen ``mesh_model_parallel`` factorization of the
    same chip count: data = chips // model (× optional pod axis)."""
    if chips % model_parallel:
        raise ValueError(f"model_parallel {model_parallel} !| chips {chips}")
    data = chips // model_parallel
    if multi_pod:
        return make_mesh((2, data, model_parallel), ("pod", "data", "model"))
    return make_mesh((data, model_parallel), ("data", "model"))


def make_host_mesh(model_parallel: int = 1, *, pod: int = 0):
    """Small mesh over however many (possibly fake) devices exist — used by
    tests and CPU examples."""
    n = len(jax.devices())
    if pod:
        data = n // (model_parallel * pod)
        return make_mesh((pod, data, model_parallel), ("pod", "data", "model"))
    data = n // model_parallel
    return make_mesh((data, model_parallel), ("data", "model"))
