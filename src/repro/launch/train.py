"""End-to-end training driver (CPU-runnable with --smoke; pod-ready as-is).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires together: config registry → step bundle (sharded train step) → data
pipeline → checkpoint manager → resilient runner. ``--fail-at`` injects
failures to demo checkpoint/restart; ``--tuned-config`` applies a JSON knob
dict produced by ``repro.launch.tune``.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax

from repro.compat import set_mesh as compat_set_mesh

from repro.configs.base import SHAPES, RunConfig, ShapeConfig
from repro.configs.archs import ARCH_NAMES, get_arch
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import PipelineConfig, SyntheticLMPipeline
from repro.distributed.steps import init_train_state, make_train_step
from repro.ft.runner import ResilientTrainer, RunnerConfig
from repro.launch.mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--tuned-config", type=Path, default=None)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch, smoke=args.smoke)
    shape = ShapeConfig("cli_train", args.seq, args.batch, "train")
    run = RunConfig(mesh_model_parallel=args.model_parallel)
    if args.tuned_config:
        from repro.core.space import TRAIN_SPACE

        knobs = json.loads(args.tuned_config.read_text())
        run = TRAIN_SPACE.to_run_config(knobs, run)
    mesh = make_host_mesh(model_parallel=args.model_parallel)

    with compat_set_mesh(mesh):
        bundle = make_train_step(arch, run, shape, mesh)
        state = init_train_state(bundle)
        (state,) = bundle.place(mesh, state)
        step_fn = bundle.jit()

        pipeline = SyntheticLMPipeline(
            arch, shape, PipelineConfig(), mesh=mesh,
            batch_sharding=bundle.in_shardings[1],
        )
        ckpt = CheckpointManager(args.ckpt_dir, keep_n=3)
        trainer = ResilientTrainer(
            step_fn=step_fn,
            state=state,
            pipeline=pipeline,
            ckpt=ckpt,
            cfg=RunnerConfig(total_steps=args.steps, checkpoint_every=args.ckpt_every),
            fail_at=args.fail_at,
        )
        t0 = time.time()
        state = trainer.run()
        wall = time.time() - t0

    losses = [h["loss"] for h in trainer.history]
    print(f"trained {args.steps} steps in {wall:.1f}s "
          f"({wall / max(len(trainer.history), 1):.3f}s/step, "
          f"restarts={trainer.restarts}, stragglers={len(trainer.monitor.stragglers)})")
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f}")
    assert losses[-1] < losses[0], "loss did not decrease"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
