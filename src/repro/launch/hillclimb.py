import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver: evaluate a curated, hypothesis-tagged list of
knob changes for one (arch × shape) cell on the production mesh, recording
hypothesis → change → before → after per iteration.

The curated lists are now ``Move`` sequences fed to the shared
``CuratedHillclimbStrategy`` + ``TrialScheduler`` engine (same path as
GSFT/CRS), so a sweep gets the persistent evaluation cache and per-trial
failure handling for free.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell qwen2-72b:train_4k
"""
import argparse
import json
from pathlib import Path

from repro.configs.base import SHAPES
from repro.configs.archs import get_arch
from repro.core import SPACES, CuratedHillclimbStrategy, Study, TrialScheduler
from repro.core.evaluators import RooflineEvaluator

# (name, hypothesis, overrides) per cell — the napkin math lives in
# EXPERIMENTS.md §Perf next to the measured outcome.
#
# ITERATION 2 lists (results/perf/). Iteration 1 (results/perf/iter1/) ran the
# broad screen and two code-level findings came out of it:
#   (a) seq-parallel residual + head-sharded qkv collided in one PartitionSpec
#       (fixed: shard fn drops duplicate mesh-axis uses), and
#   (b) the MoE dispatch scatter had NO sharding constraints — GSPMD replicated
#       it ("involuntary full rematerialization"), which was the collective
#       bottleneck of the MoE cells (fixed: explicit dispatch shardings).
# ITERATION 3: after the bf16 pre-cast (FSDP weight all-gathers move bf16
# instead of f32 masters — code change in Model._cast_params). Baselines are
# re-measured so the code-level gains are attributed.
CANDIDATES = {
    "qwen2-72b:train_4k": [
        ("baseline", "paper-faithful defaults, now with bf16 weight-gathers (code-level change — expect collective ≈ halved vs iter2 baseline)", {}),
        ("rs_mp8_micro", "iter2 winner re-measured: seqpar + TP=8 + 8 microbatches + bf16 moments", {"collective_matmul": "rs", "mesh_model_parallel": 8, "microbatch_size": 32, "optimizer_moment_dtype": "bfloat16"}),
        ("rs_mp8_micro64", "fewer microbatches (4): fewer weight-gather rounds, bigger live set", {"collective_matmul": "rs", "mesh_model_parallel": 8, "microbatch_size": 64, "optimizer_moment_dtype": "bfloat16"}),
        ("rs_mp8_micro16", "more microbatches (16): more gathers, less memory", {"collective_matmul": "rs", "mesh_model_parallel": 8, "microbatch_size": 16, "optimizer_moment_dtype": "bfloat16"}),
        ("rs_mp4_micro", "TP=4: even smaller activation collectives; kv=8 still divides", {"collective_matmul": "rs", "mesh_model_parallel": 4, "microbatch_size": 32, "optimizer_moment_dtype": "bfloat16"}),
    ],
    "jamba-1.5-large-398b:prefill_32k": [
        ("baseline", "re-measure with bf16 weight-gathers (serve weights were already bf16 — expect ≈ iter2; stop criterion already met)", {}),
        ("rs_final", "seqpar residual (≈ tied in iter2) — final confirmation", {"collective_matmul": "rs"}),
    ],
    "llama4-maverick-400b-a17b:train_4k": [
        ("baseline", "defaults with bf16 weight-gathers", {}),
        ("rs_micro32_bf16m", "iter2 best re-measured", {"collective_matmul": "rs", "microbatch_size": 32, "optimizer_moment_dtype": "bfloat16"}),
        ("rs_micro16_bf16m", "16 microbatches: the last ~4 GiB to get under 16 GiB", {"collective_matmul": "rs", "microbatch_size": 16, "optimizer_moment_dtype": "bfloat16"}),
        ("rs_micro8_bf16m", "32 microbatches — probe the gather-overhead tail", {"collective_matmul": "rs", "microbatch_size": 8, "optimizer_moment_dtype": "bfloat16"}),
    ],
}


def run_cell_sweep(cell: str, out_dir: Path, *, cache_path: Path = None,
                   scheduler: TrialScheduler = None, study=None):
    if study is not None and (cache_path is not None or scheduler is not None):
        raise ValueError(
            "run_cell_sweep(): cache_path/scheduler would be silently "
            "ignored when a study is passed — the study owns storage and "
            "engine configuration"
        )
    arch_name, shape_name = cell.split(":")
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    platform = "train" if shape.kind == "train" else "serve"
    space = SPACES[platform]
    # per-cell namespace in any shared cache (same discipline as Study.cell):
    # the same knob dict on a different cell must never collide
    platform_key = f"{platform}/{cell}"

    if study is not None:
        # a full Study session: the sweep lands in sessions.jsonl (report()
        # rows, resumable provenance) and shares the study-wide cache under
        # the cell's namespace — "hillclimb" is a registered strategy like
        # any other
        outcome = study.cell(arch_name, shape_name).optimize(
            "hillclimb", moves=CANDIDATES[cell]
        )
        res = outcome.detail
    else:
        created = scheduler is None
        if scheduler is None:
            evaluator = RooflineEvaluator(
                arch, shape, space, chips=256, memory_penalty="soft"
            )
            scheduler = TrialScheduler(
                evaluator,
                platform=platform_key,
                cache_path=cache_path,
                clear_caches_between_trials=True,
            )
        strategy = CuratedHillclimbStrategy(space, moves=CANDIDATES[cell])
        try:
            res = scheduler.run(strategy)
        finally:
            if created:
                scheduler.close()

    results = res.records
    base = results[0].get("t_step_s", float("nan")) if results else float("nan")
    for rec in results:
        print(f"[{cell}] {rec['name']:16s} t_step={rec.get('t_step_s', float('nan')):8.3f}s "
              f"({rec.get('bottleneck', 'ERR'):10s}) vs baseline {base:8.3f}s "
              f"hbm={rec.get('hbm_est_gib', 0):6.1f}GiB", flush=True)

    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{arch_name}__{shape_name}.json").write_text(
        json.dumps(results, indent=1, default=float))
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CANDIDATES), required=True)
    ap.add_argument("--out", type=Path, default=Path("results/perf"))
    ap.add_argument("--study", type=Path, default=None,
                    help="Study directory (cache + trial log; replaces --cache)")
    ap.add_argument("--cache", type=Path, default=None,
                    help="legacy persistent JSONL evaluation cache "
                         "(ignored when --study is given)")
    args = ap.parse_args()
    study = Study.open(args.study) if args.study else None
    try:
        run_cell_sweep(args.cell, args.out, cache_path=args.cache, study=study)
    finally:
        if study is not None:
            study.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
