import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede every other import — JAX locks the device
count at first initialization, and the production meshes (16×16 single-pod,
2×16×16 multi-pod) need 512 placeholder host devices.

For each cell this driver:
  1. builds the step bundle (train_step / prefill / decode) with full
     sharding trees,
  2. ``.lower().compile()`` — the pass/fail gate for deliverable (e),
  3. prints ``memory_analysis()`` (fits-in-HBM proof) and ``cost_analysis()``,
  4. extracts collective traffic from the partitioned HLO,
  5. (single-pod) compiles the loop-free reduced-depth probes and writes the
     extrapolated roofline terms (§Roofline),
  6. dumps one JSON artifact per cell under ``results/dryrun/``.

Usage:
  python -m repro.launch.dryrun --all
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --multi-pod
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.compat import set_mesh as compat_set_mesh

from repro.configs.base import SHAPES, RunConfig
from repro.configs.archs import ARCH_NAMES, applicable_shapes, get_arch
from repro.core import roofline as rl
from repro.distributed.steps import make_step
from repro.launch.mesh import make_production_mesh

DEFAULT_OUT = Path("results/dryrun")


def run_cell(
    arch_name: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    run: RunConfig = None,
    mesh=None,
    with_probes: bool = True,
    verbose: bool = True,
) -> dict:
    """Compile one cell and return its artifact dict."""
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    run = run or RunConfig()
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    cell = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": mesh_name,
        "run_config": dataclasses.asdict(run),
        "skipped": False,
    }
    if shape_name in arch.skip_shapes:
        cell["skipped"] = True
        cell["skip_reason"] = "inapplicable shape for this architecture (DESIGN.md §6)"
        return cell

    with compat_set_mesh(mesh):
        t0 = time.time()
        bundle = make_step(arch, run, shape, mesh)
        lowered = bundle.lower()
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = rl.extract_memory(compiled)
        full_costs = rl.extract_costs(compiled)
        if verbose:
            print(f"  memory_analysis: {compiled.memory_analysis()}")
            ca = compiled.cost_analysis()
            ca = ca[0] if isinstance(ca, (list, tuple)) else ca
            print(
                "  cost_analysis: flops={:.4g} bytes={:.4g}".format(
                    ca.get("flops", 0.0), ca.get("bytes accessed", 0.0)
                )
            )
        cell.update(
            compile_ok=True,
            t_lower_s=round(t_lower, 2),
            t_compile_s=round(t_compile, 2),
            memory=mem.summary(),
            tpu_hbm_estimate=rl.estimate_tpu_hbm(arch, run, shape, mesh),
            scanned_artifact={
                "flops_per_device": full_costs.flops,
                "bytes_per_device": full_costs.bytes_accessed,
                "collectives": full_costs.collectives.summary(),
                "note": "while-loop bodies counted once (see extrapolated)",
            },
        )

        if with_probes:
            per_dev, probe_times = rl.extrapolated_costs(
                arch, run, shape, mesh, make_step
            )
            roof = rl.make_roofline(per_dev, arch, shape, mesh)
            cell.update(
                extrapolated={
                    "flops_per_device": per_dev.flops,
                    "bytes_per_device": per_dev.bytes_accessed,
                    "collectives": per_dev.collectives.summary(),
                },
                roofline=roof.summary(),
                probe_times=probe_times,
            )
            if verbose:
                s = roof.summary()
                print(
                    "  roofline: compute={t_compute_s:.4g}s memory={t_memory_s:.4g}s "
                    "collective={t_collective_s:.4g}s -> {bottleneck} "
                    "(MFU@step={roofline_fraction_mfu:.3f})".format(**s)
                )
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=None, choices=list(ARCH_NAMES) + [None])
    ap.add_argument("--shape", nargs="*", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args()

    archs = args.arch or list(ARCH_NAMES)
    shapes = args.shape or list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_tag = "multi" if multi_pod else "single"
        outdir = args.out / mesh_tag
        outdir.mkdir(parents=True, exist_ok=True)
        for arch_name in archs:
            arch = get_arch(arch_name)
            for shape_name in shapes:
                if shape_name not in SHAPES:
                    continue
                tag = f"{arch_name}__{shape_name} [{mesh_tag}]"
                print(f"=== {tag}")
                try:
                    cell = run_cell(
                        arch_name,
                        shape_name,
                        multi_pod=multi_pod,
                        mesh=mesh,
                        with_probes=not args.no_probes and not multi_pod,
                    )
                except Exception as e:  # noqa: BLE001 — record and continue
                    traceback.print_exc()
                    cell = {
                        "arch": arch_name,
                        "shape": shape_name,
                        "mesh": mesh_tag,
                        "compile_ok": False,
                        "error": f"{type(e).__name__}: {e}",
                    }
                    failures.append(tag)
                path = outdir / f"{arch_name}__{shape_name}.json"
                path.write_text(json.dumps(cell, indent=1, default=float))
                if cell.get("skipped"):
                    print("  SKIPPED (inapplicable)")
    print(f"\nDONE. failures: {failures or 'none'}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
