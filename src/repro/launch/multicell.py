"""Multi-cell tuning driver: tune several (arch × shape) cells in ONE
invocation, all sessions going through one Study (shared persistent
evaluation cache, shared trial log, session provenance per cell).

The paper's Admin tunes one platform at a time; a production fleet has a
matrix of cells (model × context shape) to keep tuned. This driver walks the
matrix through ``Study.cell(arch, shape)`` handles — each cell shares one
scheduler across its sessions and the study-wide cache across cells — so
repeated configurations are free, and a re-run after a crash resumes where
the cache left off.

    PYTHONPATH=src python -m repro.launch.multicell \
        --cells llama3.2-1b:train_4k llama3.2-1b:decode_32k \
        --algorithm gsft --study results/studies/fleet

Emits one summary JSON per cell plus a fleet table on stdout. The legacy
``--cache``/``--log-dir`` pair still works when no ``--study`` is given.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
from pathlib import Path

from repro.configs.archs import get_arch
from repro.configs.base import SHAPES
from repro.core import SPACES, EngineConfig, Study


def cell_platform(shape_name: str) -> str:
    return "train" if SHAPES[shape_name].kind == "train" else "serve"


def tune_cells(
    cells,
    *,
    algorithm: str = "gsft",
    chips: int = None,  # None = no opinion (256 on cell creation)
    study: Study = None,
    cache_path: Path = None,
    log_dir: Path = None,
    patience: int = None,
    batch_size: int = None,
    isolation: str = "inline",
    jobs: int = 1,
    trial_timeout: float = None,
    prefilter: str = "off",
    surrogate: str = "off",
    evaluator_factory=None,
    transfer: str = "off",
    **algo_kwargs,
):
    """Tune each ``arch:shape`` cell; returns {cell: TuneOutcome}.

    Pass ``study`` to make the matrix incremental across sessions (the CLI's
    ``--study``); without one, a throwaway in-memory Study wraps the legacy
    ``cache_path``/``log_dir`` files. Engine knobs and ``study`` are mutually
    exclusive (configure the study's EngineConfig instead) — a conflicting
    combination raises rather than silently ignoring the knobs, like
    ``tune()``. ``evaluator_factory(arch, shape, space, platform)`` overrides
    the default RooflineEvaluator per cell (tests use a FunctionEvaluator
    matrix).

    ``transfer`` (off|warm|prior) feeds each cell the earlier cells' sibling
    histories from the shared cache (``Study.histories_for``): the matrix is
    walked in order, so cell N+1 transfers from cells 1..N (and from any cell
    a previous invocation left in the study)."""
    owns_study = study is None
    if owns_study:
        study = Study(
            engine=EngineConfig(
                workers=jobs, isolation=isolation, timeout_s=trial_timeout,
                patience=patience, batch_size=batch_size, prefilter=prefilter,
                surrogate=surrogate,
            ),
            cache_path=cache_path,
        )
    else:
        ignored = [
            name for name, off_default in (
                ("jobs", jobs != 1),
                ("isolation", isolation != "inline"),
                ("trial_timeout", trial_timeout is not None),
                ("patience", patience is not None),
                ("batch_size", batch_size is not None),
                ("cache_path", cache_path is not None),
                ("prefilter", prefilter != "off"),
                ("surrogate", surrogate != "off"),
            ) if off_default
        ]
        if ignored:
            raise ValueError(
                f"tune_cells(): {', '.join(ignored)} would be silently "
                "ignored when an explicit study is passed — configure them "
                "on the study's EngineConfig instead"
            )
    outcomes = {}
    try:
        for cell in cells:
            arch_name, sep, shape_name = cell.partition(":")
            if not sep or not shape_name:
                raise SystemExit(
                    f"bad cell {cell!r}: expected ARCH:SHAPE, e.g. llama3.2-1b:train_4k"
                )
            if shape_name not in SHAPES:
                raise SystemExit(
                    f"bad cell {cell!r}: unknown shape {shape_name!r} "
                    f"(known: {sorted(SHAPES)})"
                )
            arch = get_arch(arch_name)
            if SHAPES[shape_name].name in arch.skip_shapes:
                print(f"[{cell}] SKIP (arch skips shape)")
                continue
            platform = cell_platform(shape_name)
            if study.has_cell(arch_name, shape_name):
                # repeat pass over an open study (second algorithm, or a
                # duplicated --cells entry): reuse the handle — and never
                # build a second evaluator for the same cell. An explicit
                # chips request still hits cell()'s conflict guard.
                handle = study.cell(arch_name, shape_name, chips=chips)
            else:
                handle = study.cell(
                    arch_name, shape_name, chips=chips,
                    evaluator=(
                        evaluator_factory(
                            arch_name, shape_name, SPACES[platform], platform,
                        ) if evaluator_factory else None
                    ),
                    log_path=(
                        log_dir / f"{arch_name}__{shape_name}.jsonl"
                        if log_dir else None
                    ),
                )
            outcome = handle.optimize(algorithm, transfer=transfer, **algo_kwargs)
            outcomes[cell] = outcome
            s = outcome.summary()
            print(f"[{cell}] best={s['best_time_s']:.4f}s "
                  f"default={s['default_time_s']:.4f}s "
                  f"reduction={s['reduction_pct']:.1f}% "
                  f"evals={s['evaluations']} cache={s.get('cache_stats')}", flush=True)
    finally:
        if owns_study:
            study.close()
    return outcomes


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", nargs="+", required=True,
                    metavar="ARCH:SHAPE", help="e.g. llama3.2-1b:train_4k")
    ap.add_argument("--algorithm", "--strategy", dest="algorithm", default="gsft",
                    choices=["gsft", "crs", "tpe", "random", "asha"])
    ap.add_argument("--chips", type=int, default=None,
                    help="chip count for new cells (default 256); an explicit "
                         "value conflicting with a study cell's stored setup "
                         "raises instead of silently reusing it")
    ap.add_argument("--samples", type=int, default=2)
    ap.add_argument("--budget", type=int, default=32,
                    help="tpe per-cell trial budget (shared-cache history counts)")
    ap.add_argument("--seed", type=int, default=0,
                    help="crs/tpe/random/asha rng seed")
    ap.add_argument("--inner", default="random", choices=["random", "tpe"],
                    help="asha inner proposer drawing rung-0 candidates")
    ap.add_argument("--eta", type=float, default=3.0,
                    help="asha promotion factor: rung fidelities r0*eta^k, "
                         "top 1/eta of each rung promoted")
    ap.add_argument("--min-fidelity", type=float, default=1.0 / 9.0,
                    help="asha cheapest rung (fraction of a full trial)")
    ap.add_argument("--max-fidelity", type=float, default=1.0,
                    help="asha top rung (1.0 = the full evaluation)")
    ap.add_argument("--transfer", default="off", choices=["off", "warm", "prior"],
                    help="cross-cell transfer: each cell ingests the earlier "
                         "cells' histories from the shared cache (warm = "
                         "sibling incumbents seed candidates; prior = "
                         "distance-decayed tpe Parzen prior; sibling trials "
                         "never count toward --budget)")
    ap.add_argument("--evaluator-factory", default=None, metavar="PKG.MOD:FN",
                    help="dotted path to an evaluator factory "
                         "fn(arch, shape, space, platform) overriding the "
                         "default RooflineEvaluator per cell (tests/CI use a "
                         "deterministic synthetic matrix)")
    ap.add_argument("--study", type=Path, default=None,
                    help="Study directory shared by every cell (cache + log + "
                         "session provenance; replaces --cache/--log-dir)")
    ap.add_argument("--cache", type=Path, default=Path("results/eval_cache.jsonl"),
                    help="legacy shared cache (ignored when --study is given)")
    ap.add_argument("--log-dir", type=Path, default=Path("results/multicell"),
                    help="legacy per-cell logs (ignored when --study is given)")
    ap.add_argument("--out", type=Path, default=Path("results/multicell/summary.json"))
    # None defaults = "flag not given" so explicit values can override a
    # persistent study's stored engine without untyped flags clobbering it
    ap.add_argument("--patience", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--jobs", type=int, default=None,
                    help="parallel trials per batch (default 1)")
    ap.add_argument("--trial-timeout", "--timeout", dest="trial_timeout",
                    type=float, default=None,
                    help="per-trial timeout in seconds (hard SIGKILL under "
                         "--isolation subprocess)")
    ap.add_argument("--isolation", default=None,
                    choices=["inline", "subprocess"],
                    help="trial execution backend (see launch/tune.py)")
    ap.add_argument("--surrogate", default=None, choices=["off", "rank"],
                    help="learned cost surrogate: pre-rank TPE acquisition "
                         "at the predicted frontier (see launch/tune.py)")
    args = ap.parse_args(argv)

    if args.algorithm == "gsft":
        algo_kwargs = {"samples_per_param": args.samples}
    elif args.algorithm == "crs":
        algo_kwargs = {"seed": args.seed}
    elif args.algorithm == "random":
        algo_kwargs = {"budget": args.budget, "seed": args.seed}
    elif args.algorithm == "asha":
        # multi-fidelity per cell: --budget caps distinct rung-0 configs
        algo_kwargs = {
            "budget": args.budget, "seed": args.seed, "inner": args.inner,
            "eta": args.eta, "min_fidelity": args.min_fidelity,
            "max_fidelity": args.max_fidelity,
        }
    else:  # tpe — each cell warm-starts from its own slice of the shared cache
        algo_kwargs = {"budget": args.budget, "seed": args.seed}
    from repro.launch.tune import engine_config, engine_overrides, \
        open_persistent_study

    # explicitly-typed flags overlay the stored engine per-field; untyped
    # flags don't clobber what the study was configured with
    study = open_persistent_study(args.study, engine_overrides(args)) \
        if args.study else None
    # with --study the engine flags configure the Study's EngineConfig above;
    # without one they flow into tune_cells' throwaway in-memory study
    if study is not None:
        engine_kwargs = {}
    else:
        engine = engine_config(args)  # fills engine defaults for untyped flags
        engine_kwargs = dict(
            cache_path=args.cache,
            log_dir=args.log_dir,
            patience=engine.patience,
            batch_size=engine.batch_size,
            isolation=engine.isolation,
            jobs=engine.workers,
            trial_timeout=engine.timeout_s,
            prefilter=engine.prefilter,
            surrogate=engine.surrogate,
        )
    evaluator_factory = None
    if args.evaluator_factory:
        import importlib

        mod, _, attr = args.evaluator_factory.partition(":")
        if not attr:
            raise SystemExit(
                f"bad --evaluator-factory {args.evaluator_factory!r}: "
                "expected PKG.MOD:FN"
            )
        evaluator_factory = getattr(importlib.import_module(mod), attr)
    try:
        outcomes = tune_cells(
            args.cells,
            algorithm=args.algorithm,
            chips=args.chips,
            study=study,
            transfer=args.transfer,
            evaluator_factory=evaluator_factory,
            **engine_kwargs,
            **algo_kwargs,
        )
    finally:
        if study is not None:
            study.close()
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(
        {cell: o.summary() for cell, o in outcomes.items()}, indent=1, default=str
    ))
    print(f"summary -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
