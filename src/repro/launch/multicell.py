"""Multi-cell tuning driver: tune several (arch × shape) cells in ONE
invocation, all sessions sharing one persistent evaluation cache.

The paper's Admin tunes one platform at a time; a production fleet has a
matrix of cells (model × context shape) to keep tuned. This driver walks the
matrix, builds a RooflineEvaluator per cell, and runs the chosen strategy for
each through TrialSchedulers that append to the same JSONL cache — so
repeated configurations across cells and across invocations are free, and a
re-run after a crash resumes where the cache left off.

    PYTHONPATH=src python -m repro.launch.multicell \
        --cells llama3.2-1b:train_4k llama3.2-1b:decode_32k \
        --algorithm gsft --cache results/eval_cache.jsonl

Emits one summary JSON per cell plus a fleet table on stdout.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
from pathlib import Path

from repro.configs.archs import get_arch
from repro.configs.base import SHAPES
from repro.core import SPACES, tune
from repro.core.evaluators import RooflineEvaluator


def cell_platform(shape_name: str) -> str:
    return "train" if SHAPES[shape_name].kind == "train" else "serve"


def tune_cells(
    cells,
    *,
    algorithm: str = "gsft",
    chips: int = 256,
    cache_path: Path = None,
    log_dir: Path = None,
    patience: int = None,
    batch_size: int = None,
    isolation: str = "inline",
    jobs: int = 1,
    trial_timeout: float = None,
    **algo_kwargs,
):
    """Tune each ``arch:shape`` cell; returns {cell: TuneOutcome}. One shared
    ``cache_path`` makes the matrix incremental across sessions."""
    outcomes = {}
    for cell in cells:
        arch_name, sep, shape_name = cell.partition(":")
        if not sep or not shape_name:
            raise SystemExit(
                f"bad cell {cell!r}: expected ARCH:SHAPE, e.g. llama3.2-1b:train_4k"
            )
        if shape_name not in SHAPES:
            raise SystemExit(
                f"bad cell {cell!r}: unknown shape {shape_name!r} "
                f"(known: {sorted(SHAPES)})"
            )
        arch = get_arch(arch_name)
        shape = SHAPES[shape_name]
        if shape.name in arch.skip_shapes:
            print(f"[{cell}] SKIP (arch skips shape)")
            continue
        platform = cell_platform(shape_name)
        space = SPACES[platform]
        evaluator = RooflineEvaluator(arch, shape, space, chips=chips)
        # platform key namespaces the shared cache per cell: same knob dict
        # on a different cell must never collide
        outcome = tune(
            f"{platform}/{cell}",
            algorithm,
            evaluator,
            space=space,
            log_path=(log_dir / f"{arch_name}__{shape_name}.jsonl") if log_dir else None,
            cache_path=cache_path,
            patience=patience,
            batch_size=batch_size,
            clear_caches_between_trials=True,
            isolation=isolation,
            max_workers=jobs,
            timeout_s=trial_timeout,
            **algo_kwargs,
        )
        outcomes[cell] = outcome
        s = outcome.summary()
        print(f"[{cell}] best={s['best_time_s']:.4f}s "
              f"default={s['default_time_s']:.4f}s "
              f"reduction={s['reduction_pct']:.1f}% "
              f"evals={s['evaluations']} cache={s.get('cache_stats')}", flush=True)
    return outcomes


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", nargs="+", required=True,
                    metavar="ARCH:SHAPE", help="e.g. llama3.2-1b:train_4k")
    ap.add_argument("--algorithm", "--strategy", dest="algorithm", default="gsft",
                    choices=["gsft", "crs", "tpe"])
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--samples", type=int, default=2)
    ap.add_argument("--budget", type=int, default=32,
                    help="tpe per-cell trial budget (shared-cache history counts)")
    ap.add_argument("--seed", type=int, default=0, help="crs/tpe rng seed")
    ap.add_argument("--cache", type=Path, default=Path("results/eval_cache.jsonl"))
    ap.add_argument("--log-dir", type=Path, default=Path("results/multicell"))
    ap.add_argument("--out", type=Path, default=Path("results/multicell/summary.json"))
    ap.add_argument("--patience", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--jobs", type=int, default=1,
                    help="parallel trials per batch")
    ap.add_argument("--trial-timeout", "--timeout", dest="trial_timeout",
                    type=float, default=None,
                    help="per-trial timeout in seconds (hard SIGKILL under "
                         "--isolation subprocess)")
    ap.add_argument("--isolation", default="inline",
                    choices=["inline", "subprocess"],
                    help="trial execution backend (see launch/tune.py)")
    args = ap.parse_args(argv)

    if args.algorithm == "gsft":
        algo_kwargs = {"samples_per_param": args.samples}
    elif args.algorithm == "crs":
        algo_kwargs = {"seed": args.seed}
    else:  # tpe — each cell warm-starts from its own slice of the shared cache
        algo_kwargs = {"max_trials": args.budget, "seed": args.seed}
    outcomes = tune_cells(
        args.cells,
        algorithm=args.algorithm,
        chips=args.chips,
        cache_path=args.cache,
        log_dir=args.log_dir,
        patience=args.patience,
        batch_size=args.batch,
        isolation=args.isolation,
        jobs=args.jobs,
        trial_timeout=args.trial_timeout,
        **algo_kwargs,
    )
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(
        {cell: o.summary() for cell, o in outcomes.items()}, indent=1, default=str
    ))
    print(f"summary -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
