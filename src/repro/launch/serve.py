"""Batched serving driver: prefill a request batch, then greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
        --batch 4 --prompt-len 32 --max-new 16

Demonstrates the serving path end-to-end on real arrays: the prefill bundle
fills the KV/state caches (capacity = prompt + max-new), the decode bundle is
stepped token-by-token with donated caches, and the driver reports prefill
latency + decode throughput. ``--tuned-config`` applies a knob dict from the
tuner.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax

from repro.compat import set_mesh as compat_set_mesh
import jax.numpy as jnp

from repro.configs.base import RunConfig, ShapeConfig
from repro.configs.archs import ARCH_NAMES, get_arch
from repro.distributed.steps import make_decode_step, make_prefill_step
from repro.launch.mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--tuned-config", type=Path, default=None)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch, smoke=args.smoke)
    total = args.prompt_len + args.max_new
    prefill_shape = ShapeConfig("cli_prefill", args.prompt_len, args.batch, "prefill")
    decode_shape = ShapeConfig("cli_decode", total, args.batch, "decode")
    run = RunConfig(mesh_model_parallel=args.model_parallel)
    if args.tuned_config:
        from repro.core.space import SERVE_SPACE

        run = SERVE_SPACE.to_run_config(json.loads(args.tuned_config.read_text()), run)
    mesh = make_host_mesh(model_parallel=args.model_parallel)

    with compat_set_mesh(mesh):
        pre = make_prefill_step(arch, run, prefill_shape, mesh)
        dec = make_decode_step(arch, run, decode_shape, mesh)
        model = pre.model
        params = model.init_params(jax.random.PRNGKey(0))
        batch = model.make_inputs(prefill_shape)

        prefill_fn = pre.jit()
        decode_fn = dec.jit()

        t0 = time.perf_counter()
        logits, caches = jax.block_until_ready(prefill_fn(params, batch))
        t_prefill = time.perf_counter() - t0

        # grow prefill caches (capacity=prompt) to decode capacity (total)
        def grow(path, x):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name in ("k", "v", "ks", "vs"):
                pad = [(0, 0)] * x.ndim
                pad[2] = (0, args.max_new)
                return jnp.pad(x, pad)
            return x

        caches = jax.tree_util.tree_map_with_path(grow, caches)

        tokens = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        generated = [tokens]
        t0 = time.perf_counter()
        for i in range(args.max_new - 1):
            step_batch = {
                "tokens": tokens,
                "cache_len": jnp.asarray(args.prompt_len + i, jnp.int32),
            }
            logits, caches = decode_fn(params, caches, step_batch)
            tokens = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            generated.append(tokens)
        jax.block_until_ready(tokens)
        t_decode = time.perf_counter() - t0

    n_new = args.max_new * args.batch
    print(f"prefill: {args.batch}×{args.prompt_len} tokens in {t_prefill:.3f}s")
    print(f"decode : {n_new} tokens in {t_decode:.3f}s "
          f"({n_new / max(t_decode, 1e-9):.1f} tok/s)")
    out = jnp.concatenate(generated, axis=1)
    print("sampled token ids (first request):", out[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
