"""Batched serving driver: prefill a request batch, then greedy decode —
optionally under the online safety-bounded tuner.

Offline (one measured serve of one config):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
        --batch 4 --prompt-len 32 --max-new 16

The prefill bundle fills the KV/state caches (capacity = prompt + max-new),
the decode bundle is stepped token-by-token with donated caches. Compilation
happens in an untimed warmup pass, so the reported numbers are execution
latency, and the decode loop reports per-window p50/p99 through
:class:`repro.serving.metrics.DecodeWindowMonitor` rather than one aggregate.
``--tuned-config`` applies a knob dict from the tuner (snapped into
SERVE_SPACE first — a hand-edited or stale dict lands on the space's grid
instead of silently running an off-space config).

Online (--online-tune): the decode path runs under the
:class:`repro.serving.controller.OnlineController` — the baseline config
always serves the majority of decode windows, one strategy-proposed candidate
at a time serves a probation slice inside a p99 safety envelope, and every
guard decision is journaled into the --study directory:

    PYTHONPATH=src python -m repro.launch.serve --online-tune \
        --study results/studies/online --traffic drift --strategy tpe

``--traffic flat|regression|drift`` drives the scripted synthetic traffic
generator (phase shifts, injected regressions — see repro.serving.traffic);
``--traffic real`` serves measured decode windows on real arrays. A re-run
against the same study resumes from the surviving baseline.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.configs.archs import ARCH_NAMES

ONLINE_TRACES = ("flat", "regression", "drift")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--tuned-config", type=Path, default=None)
    ap.add_argument("--window-steps", type=int, default=8,
                    help="decode steps per metrics window (p50/p99 reported "
                         "per window)")
    online = ap.add_argument_group("online tuning (--online-tune)")
    online.add_argument("--online-tune", action="store_true",
                        help="run the decode path under the safety-bounded "
                             "online controller (requires --study)")
    online.add_argument("--study", type=Path, default=None,
                        help="Study directory receiving the online session's "
                             "journal (guard decisions, window records); a "
                             "re-run resumes from the surviving baseline")
    online.add_argument("--traffic", default="drift",
                        choices=("real",) + ONLINE_TRACES,
                        help="scripted synthetic trace, or 'real' to serve "
                             "measured decode windows on real arrays")
    online.add_argument("--windows", type=int, default=None,
                        help="decode windows to serve (default: the scripted "
                             "trace length, or 12 for real traffic)")
    online.add_argument("--strategy", default="tpe",
                        choices=["tpe", "random", "crs"],
                        help="ask/tell strategy proposing candidates")
    online.add_argument("--budget", type=int, default=32,
                        help="strategy observation budget (tpe/random)")
    online.add_argument("--seed", type=int, default=0,
                        help="strategy + synthetic-traffic rng seed")
    online.add_argument("--slice-frac", type=float, default=0.2,
                        help="fraction of windows the candidate may serve "
                             "(must stay < 0.5: baseline keeps the majority)")
    online.add_argument("--safety-p99", type=float, default=1.25,
                        help="rollback bound: candidate p99 above this "
                             "multiple of the baseline p99 rolls back")
    online.add_argument("--probation", type=int, default=3,
                        help="candidate windows before promote/demote")
    online.add_argument("--promote-margin", type=float, default=0.03,
                        help="fractional p99 improvement required to promote")
    online.add_argument("--warmup-windows", type=int, default=2,
                        help="baseline-only windows before the first candidate")
    online.add_argument("--prefilter", default="static",
                        choices=["off", "static"],
                        help="static feasibility vet on proposals before they "
                             "serve traffic (default static)")
    return ap


def load_tuned_config(path: Path) -> dict:
    """A --tuned-config dict snapped onto SERVE_SPACE's grid: out-of-bounds
    or off-grid values (hand edits, stale files from an older space) land on
    the nearest legal point instead of reaching the run config raw."""
    from repro.core.space import SERVE_SPACE
    from repro.core.transfer import snap_into_space

    return snap_into_space(SERVE_SPACE, json.loads(Path(path).read_text()))


def main(argv=None):
    args = build_parser().parse_args(argv)
    if args.online_tune:
        if args.study is None:
            raise SystemExit("--online-tune requires --study DIR")
        return run_online(args)
    return run_offline(args)


# --------------------------------------------------------------- offline path


def _measured_serve(run, args, monitor):
    """One full serve of ``run``: compile + warm up untimed, then measure
    prefill latency and per-step decode latencies into ``monitor`` (one
    metrics window per --window-steps decode steps).

    Returns (t_prefill, t_decode, generated_token_array)."""
    import jax
    import jax.numpy as jnp

    from repro.compat import set_mesh as compat_set_mesh
    from repro.configs.base import ShapeConfig
    from repro.configs.archs import get_arch
    from repro.distributed.steps import make_decode_step, make_prefill_step
    from repro.launch.mesh import make_host_mesh

    arch = get_arch(args.arch, smoke=args.smoke)
    total = args.prompt_len + args.max_new
    prefill_shape = ShapeConfig("cli_prefill", args.prompt_len, args.batch, "prefill")
    decode_shape = ShapeConfig("cli_decode", total, args.batch, "decode")
    mesh = make_host_mesh(model_parallel=run.mesh_model_parallel)

    with compat_set_mesh(mesh):
        pre = make_prefill_step(arch, run, prefill_shape, mesh)
        dec = make_decode_step(arch, run, decode_shape, mesh)
        model = pre.model
        params = model.init_params(jax.random.PRNGKey(0))
        batch = model.make_inputs(prefill_shape)

        prefill_fn = pre.jit()
        decode_fn = dec.jit()

        # grow prefill caches (capacity=prompt) to decode capacity (total)
        def grow(path, x):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name in ("k", "v", "ks", "vs"):
                pad = [(0, 0)] * x.ndim
                pad[2] = (0, args.max_new)
                return jnp.pad(x, pad)
            return x

        def prefilled():
            logits, caches = jax.block_until_ready(prefill_fn(params, batch))
            return logits, jax.tree_util.tree_map_with_path(grow, caches)

        # untimed warmup: the first prefill_fn/decode_fn calls compile, which
        # must not land inside the timed loop. The decode step donates its
        # caches, so the warmup step consumes this prefill's output — the
        # timed run below re-prefills (now compiled) for fresh caches.
        logits, caches = prefilled()
        warm_tokens = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        jax.block_until_ready(decode_fn(params, caches, {
            "tokens": warm_tokens,
            "cache_len": jnp.asarray(args.prompt_len, jnp.int32),
        }))

        t0 = time.perf_counter()
        logits, caches = prefilled()
        t_prefill = time.perf_counter() - t0

        tokens = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        generated = [tokens]
        steps = args.max_new - 1
        in_window = 0
        t0 = time.perf_counter()
        for i in range(steps):
            if in_window == 0:
                monitor.begin_window()
            step_batch = {
                "tokens": tokens,
                "cache_len": jnp.asarray(args.prompt_len + i, jnp.int32),
            }
            t_step = time.perf_counter()
            logits, caches = decode_fn(params, caches, step_batch)
            tokens = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            jax.block_until_ready(tokens)
            monitor.record(time.perf_counter() - t_step, tokens=args.batch)
            generated.append(tokens)
            in_window += 1
            if in_window >= args.window_steps:
                monitor.end_window()
                in_window = 0
        t_decode = time.perf_counter() - t0
        if in_window:
            monitor.end_window()

    out = jnp.concatenate(generated, axis=1)
    return t_prefill, t_decode, out


def run_offline(args) -> int:
    from repro.configs.base import RunConfig
    from repro.serving.metrics import DecodeWindowMonitor

    run = RunConfig(mesh_model_parallel=args.model_parallel)
    if args.tuned_config:
        from repro.core.space import SERVE_SPACE

        tuned = load_tuned_config(args.tuned_config)
        # the host topology is a fact of this machine, not a knob a config
        # file may override — --model-parallel always wins
        tuned["mesh_model_parallel"] = args.model_parallel
        run = SERVE_SPACE.to_run_config(tuned, run)

    monitor = DecodeWindowMonitor(clock=time.perf_counter)
    t_prefill, t_decode, out = _measured_serve(run, args, monitor)

    n_new = args.max_new * args.batch
    print(f"prefill: {args.batch}×{args.prompt_len} tokens in {t_prefill:.3f}s")
    print(f"decode : {n_new} tokens in {t_decode:.3f}s "
          f"({n_new / max(t_decode, 1e-9):.1f} tok/s)")
    for w in monitor.history:
        print(f"  window {w.window}: {w.count} steps  "
              f"p50 {w.p50 * 1e3:.2f}ms  p99 {w.p99 * 1e3:.2f}ms  "
              f"{w.tokens_per_s:.1f} tok/s")
    agg = monitor.aggregate()
    if agg is not None:
        print(f"decode p50 {agg.p50 * 1e3:.2f}ms  p99 {agg.p99 * 1e3:.2f}ms "
              f"over {len(monitor.history)} windows")
    print("sampled token ids (first request):", out[0].tolist())
    return 0


# ---------------------------------------------------------------- online path


def online_platform_key(args) -> str:
    """Cache/journal namespace for an online session. Synthetic traces get
    their own namespace per trace (a 'drift' journal must not seed a
    'regression' run's baseline); real traffic namespaces by arch."""
    if args.traffic == "real":
        return f"serve-online/{args.arch}"
    return f"serve-online/{args.traffic}"


def make_online_strategy(args, space, fixed=None):
    from repro.core.strategies import make_strategy

    if args.strategy == "tpe":
        # round_size=1: the controller asks for one candidate at a time
        kwargs = dict(max_trials=args.budget, round_size=1, seed=args.seed)
    elif args.strategy == "random":
        kwargs = dict(max_trials=args.budget, seed=args.seed)
    else:  # crs
        kwargs = dict(seed=args.seed)
    return make_strategy(args.strategy, space, fixed=fixed, **kwargs)


def _serve_windows_synthetic(args, controller, windows):
    """Scripted traffic: latencies come from the deterministic synthetic
    model; the monitor runs clock-free (wall time = sum of scripted
    latencies), so the whole run is a pure function of (seed, trace)."""
    from repro.serving.metrics import DecodeWindowMonitor
    from repro.serving.traffic import SyntheticServeModel, scripted_trace

    model = SyntheticServeModel(scripted_trace(args.traffic), seed=args.seed)
    total = windows if windows is not None else model.total_windows
    monitor = DecodeWindowMonitor()
    for w in range(total):
        plan = controller.next_window()
        phase = model.phase_at(w)
        monitor.begin_window()
        for lat in model.latencies(w, plan.config, plan.slice):
            monitor.record(lat, tokens=phase.batch)
        controller.observe(plan, monitor.end_window())


def _serve_windows_real(args, controller, windows):
    """Measured traffic: each window is one full serve (prefill + decode)
    under the planned config on real arrays. Compiled bundles would be
    rebuilt per config; mesh-topology knobs are pinned by the strategy's
    ``fixed=`` so every candidate runs on the host mesh we actually have."""
    from repro.configs.base import RunConfig
    from repro.core.space import SERVE_SPACE
    from repro.serving.metrics import DecodeWindowMonitor, WindowStats

    total = windows if windows is not None else 12
    inf = float("inf")
    for w in range(total):
        plan = controller.next_window()
        run = SERVE_SPACE.to_run_config(
            plan.config, RunConfig(mesh_model_parallel=args.model_parallel))
        # one metrics window per serve: all of this serve's decode steps
        monitor = DecodeWindowMonitor(
            clock=time.perf_counter, max_samples=4096)
        saved, args.window_steps = args.window_steps, max(args.max_new - 1, 1)
        try:
            _measured_serve(run, args, monitor)
            stats = monitor.history[-1]
        except Exception as exc:
            if plan.slice == "baseline":
                raise  # the incumbent must be runnable — nothing to fall back to
            # a candidate the executor cannot even run is an unserveable
            # window: infinite p99 trips the guard, which rolls back and
            # penalty-tells the strategy — crashing configs are contained
            # the same way regressing ones are
            print(f"window {w}: candidate failed ({type(exc).__name__}: "
                  f"{exc}); rolling back")
            stats = WindowStats(window=w, count=0, p50=inf, p99=inf,
                                mean=inf, max=inf, tokens_per_s=0.0,
                                wall_s=0.0)
        finally:
            args.window_steps = saved
        controller.observe(plan, stats)


def run_online(args) -> int:
    from repro.core.feasibility import make_prefilter
    from repro.core.space import SERVE_SPACE
    from repro.core.transfer import snap_into_space
    from repro.launch.tune import open_persistent_study
    from repro.serving.controller import GuardConfig, OnlineController
    from repro.serving.journal import OnlineJournal, surviving_baseline

    guard = GuardConfig(
        safety_p99=args.safety_p99,
        slice_frac=args.slice_frac,
        probation_windows=args.probation,
        promote_margin=args.promote_margin,
        warmup_windows=args.warmup_windows,
    )
    platform_key = online_platform_key(args)
    study = open_persistent_study(args.study, {})

    # resume semantics: the surviving baseline from this platform's previous
    # online sessions (last promote wins) outranks --tuned-config/defaults
    baseline = surviving_baseline(study, platform_key)
    resumed = baseline is not None
    if baseline is None:
        baseline = (load_tuned_config(args.tuned_config)
                    if args.tuned_config else snap_into_space(SERVE_SPACE, {}))

    # real traffic runs on the host mesh we actually have — pin the topology
    # knob (baseline and every proposal) so no config asks for a mesh this
    # machine can't build
    fixed = ({"mesh_model_parallel": args.model_parallel}
             if args.traffic == "real" else None)
    if fixed:
        baseline = {**baseline, **fixed}
    strategy = make_online_strategy(args, SERVE_SPACE, fixed=fixed)
    prefilter = make_prefilter(args.prefilter)

    with study:
        journal = OnlineJournal(
            study, platform_key,
            algorithm=f"online-{args.strategy}",
            guard=guard, baseline=baseline,
            strategy_args={
                "strategy": args.strategy, "seed": args.seed,
                "budget": args.budget, "traffic": args.traffic,
                "windows": args.windows, "resumed": resumed,
            },
        )
        controller = OnlineController(
            SERVE_SPACE, strategy, baseline,
            guard=guard, journal=journal, prefilter=prefilter,
            platform=platform_key,
        )
        if args.traffic == "real":
            _serve_windows_real(args, controller, args.windows)
        else:
            _serve_windows_synthetic(args, controller, args.windows)
        summary = controller.summary()
        journal.finish(summary)

    print(json.dumps(summary, indent=1, default=str))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
