"""Tuning driver — the paper's Admin box: pick platform × algorithm, run it
through the ask/tell Strategy + TrialScheduler engine.

Roofline evaluator (production mesh, AOT — needs the 512 fake devices, so run
it the same way as the dry-run):

    PYTHONPATH=src python -m repro.launch.tune --platform train \
        --algorithm gsft --arch qwen2-72b --shape train_4k --evaluator roofline

Walltime evaluator on the paper's WordCount job (CPU-measured, the faithful
reproduction), four trials at a time with a persistent evaluation cache:

    PYTHONPATH=src python -m repro.launch.tune --platform wordcount \
        --algorithm crs --jobs 4 --cache results/eval_cache.jsonl

A warm-cache re-run of the same command performs zero fresh evaluations.

TPE (model-based, batched acquisition) on the same platform — the persistent
cache also warm-starts its observation history, so a crashed or repeated
session resumes with the budget it already spent:

    PYTHONPATH=src python -m repro.launch.tune --platform wordcount \
        --strategy tpe --budget 48 --jobs 4 --cache results/eval_cache.jsonl
"""
import os

if "--evaluator" in __import__("sys").argv and "roofline" in __import__("sys").argv:
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
from pathlib import Path

from repro.configs.base import SHAPES
from repro.configs.archs import ARCH_NAMES, get_arch
from repro.core import SPACES, tune
from repro.core.evaluators import RooflineEvaluator


def add_engine_args(ap: argparse.ArgumentParser):
    """Engine knobs shared by every driver that runs the TrialScheduler."""
    ap.add_argument("--jobs", type=int, default=1,
                    help="parallel trials per batch (thread pool)")
    ap.add_argument("--batch", type=int, default=None,
                    help="max configs per ask() batch (default: whole phase)")
    ap.add_argument("--cache", type=Path, default=None,
                    help="persistent JSONL evaluation cache shared across runs")
    ap.add_argument("--patience", type=int, default=None,
                    help="stop when best hasn't improved in N batches")
    ap.add_argument("--trial-timeout", "--timeout", dest="trial_timeout",
                    type=float, default=None,
                    help="per-trial timeout in seconds (timeout => infeasible; "
                         "hard SIGKILL under --isolation subprocess)")
    ap.add_argument("--retries", type=int, default=0,
                    help="per-trial retries before recording a failure")
    ap.add_argument("--isolation", default="inline",
                    choices=["inline", "subprocess"],
                    help="trial execution backend: inline threads (soft "
                         "timeouts) or worker processes (hard deadlines, "
                         "crash containment, warm reuse)")


def engine_kwargs(args) -> dict:
    return dict(
        max_workers=args.jobs,
        batch_size=args.batch,
        cache_path=args.cache,
        patience=args.patience,
        timeout_s=args.trial_timeout,
        retries=args.retries,
        isolation=args.isolation,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--platform", default="train", choices=["train", "serve", "wordcount"])
    ap.add_argument("--algorithm", "--strategy", dest="algorithm", default="gsft",
                    choices=["gsft", "crs", "tpe"])
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_NAMES)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--evaluator", default="roofline", choices=["roofline", "walltime"])
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--active", nargs="*", default=None, help="grid knobs (gsft)")
    ap.add_argument("--samples", type=int, default=3)
    ap.add_argument("--m", type=int, default=12, help="crs draws per round")
    ap.add_argument("--k", type=int, default=4, help="crs survivors")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--budget", type=int, default=48,
                    help="tpe total trial budget (cache history counts toward it)")
    ap.add_argument("--startup", type=int, default=None,
                    help="tpe random trials before the first model round")
    ap.add_argument("--round-size", type=int, default=8,
                    help="tpe proposals per acquisition round (size --jobs to this)")
    ap.add_argument("--seed", type=int, default=0, help="crs/tpe rng seed")
    ap.add_argument("--log", type=Path, default=Path("results/tune_log.jsonl"))
    ap.add_argument("--out", type=Path, default=None, help="write best config JSON")
    add_engine_args(ap)
    args = ap.parse_args(argv)

    if args.platform == "wordcount":
        from repro.apps.wordcount import WORDCOUNT_SPACE, make_evaluator

        evaluator = make_evaluator()
        space = WORDCOUNT_SPACE
        active = args.active or ["replication", "block_tokens", "num_map_tasks"]
    else:
        arch = get_arch(args.arch)
        shape = SHAPES[args.shape]
        if shape.name in arch.skip_shapes:
            raise SystemExit(f"{args.shape} skipped for {args.arch} (DESIGN.md §6)")
        space = SPACES[args.platform]
        evaluator = RooflineEvaluator(arch, shape, space, chips=args.chips)
        active = args.active or list(space.most_influential)

    if args.algorithm == "gsft":
        kwargs = dict(active_params=active, samples_per_param=args.samples)
    elif args.algorithm == "crs":
        kwargs = dict(m=args.m, k=args.k, max_rounds=args.rounds, seed=args.seed)
    else:  # tpe — warm-starts its observation history from --cache on re-runs
        kwargs = dict(max_trials=args.budget, n_startup=args.startup,
                      round_size=args.round_size, seed=args.seed)
    # the real platform name namespaces the persistent cache — wordcount
    # records must never alias the roofline "train" platform's
    outcome = tune(
        args.platform,
        args.algorithm,
        evaluator,
        space=space,
        log_path=args.log,
        **engine_kwargs(args),
        **kwargs,
    )
    print(json.dumps(outcome.summary(), indent=1, default=str))
    if args.out:
        args.out.parent.mkdir(parents=True, exist_ok=True)
        args.out.write_text(json.dumps(outcome.best_config, indent=1, default=str))
        print(f"best config -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
