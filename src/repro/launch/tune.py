"""Tuning driver — the paper's Admin box: pick platform × algorithm, run one
Study session through the ask/tell Strategy + TrialScheduler engine.

All state (persistent evaluation cache, trial log, session provenance) lives
in one Study directory. Roofline evaluator (production mesh, AOT — needs the
512 fake devices, so run it the same way as the dry-run):

    PYTHONPATH=src python -m repro.launch.tune --platform train \
        --algorithm gsft --arch qwen2-72b --shape train_4k --evaluator roofline \
        --study results/studies/train

Walltime evaluator on the paper's WordCount job (CPU-measured, the faithful
reproduction), four trials at a time:

    PYTHONPATH=src python -m repro.launch.tune --platform wordcount \
        --algorithm crs --jobs 4 --study results/studies/wc

A warm re-run of the same command performs zero fresh evaluations, and an
interrupted run resumes from everything it already paid. TPE (model-based,
batched acquisition) warm-starts its observation history from the same study:

    PYTHONPATH=src python -m repro.launch.tune --platform wordcount \
        --strategy tpe --budget 48 --jobs 4 --study results/studies/wc

The legacy ``--cache``/``--log`` pair still works for ad-hoc runs without a
study directory.
"""
import os

if "--evaluator" in __import__("sys").argv and "roofline" in __import__("sys").argv:
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
from pathlib import Path

from repro.configs.base import SHAPES
from repro.configs.archs import ARCH_NAMES, get_arch
from repro.core import SPACES, EngineConfig, Study
from repro.core.evaluators import RooflineEvaluator


def add_engine_args(ap: argparse.ArgumentParser):
    """Engine knobs shared by every driver that runs the TrialScheduler.
    They populate one validated EngineConfig (see ``engine_config``)."""
    ap.add_argument("--study", type=Path, default=None,
                    help="Study directory owning cache + log + session "
                         "provenance (created on first use; replaces the "
                         "ad-hoc --cache/--log pair)")
    # engine flags default to None (= "not given") so an explicitly-typed
    # value — even one equal to the engine default, like --jobs 1 — is
    # distinguishable and can override a persistent study's stored engine
    ap.add_argument("--jobs", type=int, default=None,
                    help="parallel trials per batch (thread pool; default 1)")
    ap.add_argument("--batch", type=int, default=None,
                    help="max configs per ask() batch (default: whole phase)")
    ap.add_argument("--cache", type=Path, default=None,
                    help="persistent JSONL evaluation cache shared across "
                         "runs (ignored when --study is given)")
    ap.add_argument("--patience", type=int, default=None,
                    help="stop when best hasn't improved in N batches")
    ap.add_argument("--trial-timeout", "--timeout", dest="trial_timeout",
                    type=float, default=None,
                    help="per-trial timeout in seconds (timeout => infeasible; "
                         "hard SIGKILL under --isolation subprocess)")
    ap.add_argument("--retries", type=int, default=None,
                    help="per-trial retries before recording a failure "
                         "(default 0)")
    ap.add_argument("--isolation", default=None,
                    choices=["inline", "subprocess"],
                    help="trial execution backend: inline threads (soft "
                         "timeouts, the default) or worker processes (hard "
                         "deadlines, crash containment, warm reuse)")
    ap.add_argument("--pin-devices", dest="pin_devices", type=int, default=None,
                    help="restrict each subprocess worker to ONE of N device "
                         "slots (env set before the worker's first jax "
                         "import), so N workers run N truly concurrent "
                         "device trials; requires --isolation subprocess")
    ap.add_argument("--prefilter", default=None, choices=["off", "static"],
                    help="static feasibility gate at propose time: 'static' "
                         "rejects provably-doomed configs (clamp aliases, "
                         "VMEM/HBM overflow) as infeasible_static records "
                         "without spawning a worker (default off)")
    ap.add_argument("--surrogate", default=None, choices=["off", "rank"],
                    help="learned cost surrogate over the study cache: "
                         "'rank' makes TPE over-sample acquisition "
                         "candidates and propose only the model-predicted "
                         "frontier, training on local + sibling-cell "
                         "observations (default off)")


def roofline_platform_key(platform: str, arch: str, shape: str,
                          chips: int) -> str:
    """Per-cell cache namespace (same discipline as Study.cell), with the
    chip count baked in when non-default — runs against different topologies
    must never replay each other's cached measurements."""
    key = f"{platform}/{arch}:{shape}"
    return key if chips == 256 else f"{key}@{chips}c"


def engine_overrides(args) -> dict:
    """EngineConfig fields for exactly the engine flags the user typed."""
    flag_to_field = {
        "jobs": "workers",
        "isolation": "isolation",
        "trial_timeout": "timeout_s",
        "retries": "retries",
        "patience": "patience",
        "batch": "batch_size",
        "pin_devices": "pin_devices",
        "prefilter": "prefilter",
        "surrogate": "surrogate",
    }
    return {
        field: getattr(args, flag)
        for flag, field in flag_to_field.items()
        if getattr(args, flag, None) is not None
    }


def engine_config(args) -> EngineConfig:
    """One validated EngineConfig from the CLI engine flags (engine defaults
    fill anything the user didn't type)."""
    return EngineConfig(**engine_overrides(args))


def open_persistent_study(path: Path, overrides: dict) -> Study:
    """Open (or create) the study at ``path``, overlaying exactly the engine
    flags the CLI user typed onto the study's stored engine — an untyped
    flag never resets a stored knob (e.g. hard subprocess deadlines the
    study was configured with), while an explicit flag always wins, even at
    its default value. Shared by every ``--study``-taking driver."""
    if (Path(path) / Study.MANIFEST).exists():
        study = Study.load(path)
        if overrides:
            study.engine = study.engine.replace(**overrides)
        return study
    return Study.create(path, engine=EngineConfig(**overrides))


def open_study(args, engine: EngineConfig) -> Study:
    """``--study DIR`` opens (or creates) a persistent Study; without it an
    in-memory Study wraps the legacy --cache/--log files."""
    if args.study:
        return open_persistent_study(args.study, engine_overrides(args))
    return Study(engine=engine, cache_path=args.cache,
                 log_path=getattr(args, "log", None))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--platform", default="train", choices=["train", "serve", "wordcount"])
    ap.add_argument("--algorithm", "--strategy", dest="algorithm", default="gsft",
                    choices=["gsft", "crs", "tpe", "random", "asha"])
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_NAMES)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--evaluator", default="roofline", choices=["roofline", "walltime"])
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--active", nargs="*", default=None, help="grid knobs (gsft)")
    ap.add_argument("--samples", type=int, default=3)
    ap.add_argument("--m", type=int, default=12, help="crs draws per round")
    ap.add_argument("--k", type=int, default=4, help="crs survivors")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--budget", type=int, default=48,
                    help="tpe total trial budget (study history counts toward it)")
    ap.add_argument("--startup", type=int, default=None,
                    help="tpe random trials before the first model round")
    ap.add_argument("--round-size", type=int, default=8,
                    help="tpe proposals per acquisition round (size --jobs to this)")
    ap.add_argument("--seed", type=int, default=0, help="crs/tpe/random/asha rng seed")
    ap.add_argument("--inner", default="random", choices=["random", "tpe"],
                    help="asha inner proposer drawing rung-0 candidates")
    ap.add_argument("--eta", type=float, default=3.0,
                    help="asha promotion factor: rung fidelities r0*eta^k, "
                         "top 1/eta of each rung promoted")
    ap.add_argument("--min-fidelity", type=float, default=1.0 / 9.0,
                    help="asha cheapest rung (fraction of a full trial)")
    ap.add_argument("--max-fidelity", type=float, default=1.0,
                    help="asha top rung (1.0 = the full evaluation)")
    ap.add_argument("--transfer", default="off", choices=["off", "warm", "prior"],
                    help="cross-cell transfer from sibling cells in the same "
                         "study: warm = seed candidates from sibling "
                         "incumbents (gsft/crs/tpe), prior = distance-decayed "
                         "Parzen prior over sibling observations (tpe); "
                         "sibling trials never count toward --budget")
    ap.add_argument("--log", type=Path, default=Path("results/tune_log.jsonl"),
                    help="trial log (ignored when --study is given)")
    ap.add_argument("--out", type=Path, default=None, help="write best config JSON")
    add_engine_args(ap)
    args = ap.parse_args(argv)

    if args.platform == "wordcount":
        from repro.apps.wordcount import WORDCOUNT_SPACE, make_evaluator

        evaluator = make_evaluator()
        space = WORDCOUNT_SPACE
        platform_key = args.platform
        active = args.active or ["replication", "block_tokens", "num_map_tasks"]
    else:
        arch = get_arch(args.arch)
        shape = SHAPES[args.shape]
        if shape.name in arch.skip_shapes:
            raise SystemExit(f"{args.shape} skipped for {args.arch} (DESIGN.md §6)")
        space = SPACES[args.platform]
        evaluator = RooflineEvaluator(arch, shape, space, chips=args.chips)
        # per-cell (and per-topology) namespace in the shared cache: a
        # different arch/shape/chips must never replay this cell's records
        platform_key = roofline_platform_key(
            args.platform, args.arch, args.shape, args.chips)
        active = args.active or list(space.most_influential)

    budget = None
    if args.algorithm == "gsft":
        kwargs = dict(samples_per_param=args.samples)
    elif args.algorithm == "crs":
        kwargs = dict(m=args.m, k=args.k, max_rounds=args.rounds, seed=args.seed)
    elif args.algorithm == "random":
        budget = args.budget
        kwargs = dict(seed=args.seed)
    elif args.algorithm == "asha":
        # multi-fidelity: --budget caps distinct rung-0 configs; promotions
        # up the rung ladder ride on top of it
        budget = args.budget
        kwargs = dict(inner=args.inner, eta=args.eta,
                      min_fidelity=args.min_fidelity,
                      max_fidelity=args.max_fidelity, seed=args.seed)
    else:  # tpe — warm-starts its observation history from the study on re-runs
        budget = args.budget
        kwargs = dict(n_startup=args.startup, round_size=args.round_size,
                      seed=args.seed)
    # the real platform name namespaces the persistent cache — wordcount
    # records must never alias the roofline "train" platform's
    study = open_study(args, engine_config(args))
    with study:
        outcome = study.optimize(
            platform_key,
            args.algorithm,
            evaluator,
            space=space,
            budget=budget,
            active_params=active if args.algorithm == "gsft" else None,
            transfer=args.transfer,
            **kwargs,
        )
    print(json.dumps(outcome.summary(), indent=1, default=str))
    if args.out:
        args.out.parent.mkdir(parents=True, exist_ok=True)
        args.out.write_text(json.dumps(outcome.best_config, indent=1, default=str))
        print(f"best config -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
