"""Kernel autotuning driver — turn the Study tuner on our own Pallas kernels.

One cell per (kernel, dtype, shape-class); each trial benchmarks one kernel
variant (numerics-gated against the ``ref.py`` oracle), and the study cache
makes warm re-runs free. Tune flash attention at two shapes with TPE and
ship the incumbents into the tuned table the public entry points consult:

    PYTHONPATH=src python -m repro.launch.kernel_tune \
        --kernel flash_attention --shapes 2x256x4x2x64 1x512x4x2x64 \
        --strategy tpe --budget 12 --study results/studies/kernels \
        --write-table

``--transfer prior`` carries block-size evidence between shape classes of
the same kernel (and never across kernels — :func:`kernel_similarity`).
On a multi-chip host, fan trials out one-device-per-worker:

    PYTHONPATH=src python -m repro.launch.kernel_tune --kernel all \
        --isolation subprocess --jobs 4 --pin-devices 4 --study ...

Shapes are ``x``-separated dims per kernel: flash ``B x S x Hq x Hkv x Dh``,
rwkv6 ``B x S x H x Hd``, ssm_scan ``B x S x Di x N`` (defaults in
``DEFAULT_SHAPES``). Interpret mode (the default) runs kernel bodies on CPU
— CI-safe; pass ``--no-interpret`` on a real accelerator.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Tuple

from repro.core.kernel_tune import (
    DEFAULT_SHAPES,
    KERNEL_NAMES,
    KERNEL_SPACES,
    kernel_similarity,
    make_kernel_evaluator,
    tuned_entry,
    write_tuned_entries,
)
from repro.kernels import DEFAULT_TABLE_PATH
from repro.launch.tune import add_engine_args, engine_config, open_study


def parse_shape(text: str) -> Tuple[int, ...]:
    try:
        return tuple(int(d) for d in text.lower().replace(",", "x").split("x"))
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"shape must be x-separated ints (e.g. 2x256x4x2x64), got {text!r}"
        )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", default="all",
                    choices=list(KERNEL_NAMES) + ["all"])
    ap.add_argument("--shapes", nargs="*", type=parse_shape, default=None,
                    help="shape tuples for --kernel (x-separated dims; "
                         "default: DEFAULT_SHAPES sweep). Only valid with a "
                         "single --kernel.")
    ap.add_argument("--dtype", default="f32", choices=["f32", "bf16", "f16"])
    ap.add_argument("--algorithm", "--strategy", dest="algorithm",
                    default="tpe",
                    choices=["gsft", "crs", "tpe", "random", "asha"])
    ap.add_argument("--budget", type=int, default=12,
                    help="trial budget per cell (tpe/random/asha)")
    ap.add_argument("--samples", type=int, default=3, help="gsft grid samples")
    ap.add_argument("--m", type=int, default=8, help="crs draws per round")
    ap.add_argument("--k", type=int, default=3, help="crs survivors")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--inner", default="random", choices=["random", "tpe"])
    ap.add_argument("--eta", type=float, default=3.0)
    ap.add_argument("--min-fidelity", type=float, default=1.0 / 3.0)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed runs per trial (best-of)")
    ap.add_argument("--no-interpret", dest="interpret", action="store_false",
                    help="run compiled kernels on the real accelerator "
                         "instead of interpret mode")
    ap.add_argument("--tolerance", type=float, default=None,
                    help="relative-error numerics gate (default per dtype)")
    ap.add_argument("--transfer", default="off",
                    choices=["off", "warm", "prior"],
                    help="carry sibling shape-class evidence within the same "
                         "kernel+dtype (kernel_similarity)")
    ap.add_argument("--write-table", nargs="?", type=Path, default=None,
                    const=DEFAULT_TABLE_PATH,
                    help="persist each cell's incumbent into the tuned table "
                         "(default path: the shipped "
                         "src/repro/kernels/tuned_table.json)")
    ap.add_argument("--out", type=Path, default=None,
                    help="write the per-cell summary JSON")
    add_engine_args(ap)
    args = ap.parse_args(argv)

    kernels = list(KERNEL_NAMES) if args.kernel == "all" else [args.kernel]
    if args.shapes and len(kernels) > 1:
        ap.error("--shapes needs a single --kernel (dims differ per kernel)")

    if args.algorithm == "gsft":
        budget, kwargs = None, dict(samples_per_param=args.samples)
    elif args.algorithm == "crs":
        budget = None
        kwargs = dict(m=args.m, k=args.k, max_rounds=args.rounds,
                      seed=args.seed)
    elif args.algorithm == "asha":
        budget = args.budget
        kwargs = dict(inner=args.inner, eta=args.eta,
                      min_fidelity=args.min_fidelity, seed=args.seed)
    else:  # tpe / random
        budget, kwargs = args.budget, dict(seed=args.seed)

    summaries, table_updates = {}, {}
    fresh = memo = cached = 0
    study = open_study(args, engine_config(args))
    with study:
        for kernel in kernels:
            shapes = args.shapes or DEFAULT_SHAPES[kernel]
            for shape in shapes:
                evaluator = make_kernel_evaluator(
                    kernel, shape, args.dtype,
                    repeats=args.repeats, interpret=args.interpret,
                    tolerance=args.tolerance, seed=args.seed,
                )
                platform = evaluator.platform_key()
                outcome = study.optimize(
                    platform, args.algorithm, evaluator,
                    space=KERNEL_SPACES[kernel], budget=budget,
                    transfer=args.transfer, similarity=kernel_similarity,
                    **kwargs,
                )
                summaries[platform] = outcome.summary()
                stats = outcome.cache_stats or {}
                fresh += stats.get("fresh", 0)
                memo += stats.get("memo_hits", 0)
                cached += stats.get("cache_hits", 0)
                if outcome.best_config and outcome.best_time < float("inf"):
                    table_updates.update(tuned_entry(
                        kernel, args.dtype, evaluator.shape_class(),
                        outcome.best_config, outcome.best_time,
                        source=f"study:{args.study or 'ephemeral'}"
                               f" algo={args.algorithm} seed={args.seed}",
                    ))

    report = {
        "cells": summaries,
        # aggregate across every cell's session — the cold/warm CI smoke
        # asserts fresh == 0 on the warm re-run
        "cache_stats": {"fresh": fresh, "memo_hits": memo,
                        "cache_hits": cached},
    }
    if args.write_table is not None and table_updates:
        path = write_tuned_entries(table_updates, args.write_table)
        report["tuned_table"] = str(path)
        report["tuned_entries"] = sorted(table_updates)
    print(json.dumps(report, indent=1, default=str))
    if args.out:
        args.out.parent.mkdir(parents=True, exist_ok=True)
        args.out.write_text(json.dumps(report, indent=1, default=str))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
