"""Fidelity — the resource axis multi-fidelity strategies (ASHA) promote
along.

A *fidelity* is a fraction ``0 < f <= 1`` of the full evaluation budget for
one trial: input scale for the measured WordCount job (a prefix of the
corpus), probe depth for the roofline evaluator (skip the second/third
cost-model probes), or whatever a custom ``fidelity``-aware evaluator makes
of it. ``fidelity=1.0`` is — by definition and by construction everywhere in
the engine — byte-identical to the pre-fidelity behaviour: full-fidelity
cache keys, log records, and evaluator calls carry no fidelity marker at
all, so existing caches replay unchanged.

:class:`FidelitySchedule` owns the successive-halving rung geometry
``r0·eta^k``: the cheapest rung is ``min_fidelity``, each promotion
multiplies the budget by ``eta``, and the ladder is clamped to end exactly
at ``max_fidelity`` (the top rung is always the full requested fidelity,
never an overshoot).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List

__all__ = ["FidelitySchedule", "full_fidelity"]


def full_fidelity(fidelity: float) -> bool:
    """Whether ``fidelity`` means "the full evaluation" (no marker anywhere)."""
    return fidelity >= 1.0


@dataclass(frozen=True)
class FidelitySchedule:
    """Geometric successive-halving rungs ``min_fidelity · eta^k``.

    ``min_fidelity``  the cheapest rung (fraction of a full evaluation)
    ``max_fidelity``  the top rung — what "winning" costs (usually 1.0)
    ``eta``           promotion factor: each rung is eta× the previous one,
                      and ASHA promotes the top ``1/eta`` of each rung
    """

    min_fidelity: float
    max_fidelity: float = 1.0
    eta: float = 3.0

    def __post_init__(self):
        if not 0.0 < self.min_fidelity <= self.max_fidelity:
            raise ValueError(
                f"need 0 < min_fidelity <= max_fidelity, got "
                f"{self.min_fidelity} / {self.max_fidelity}"
            )
        if self.max_fidelity > 1.0:
            raise ValueError(
                f"max_fidelity must be <= 1.0, got {self.max_fidelity}"
            )
        if not self.eta > 1.0:
            raise ValueError(f"eta must be > 1, got {self.eta}")

    def rungs(self) -> List[float]:
        """Ascending rung fidelities; the last entry is exactly
        ``max_fidelity``. A geometric step that would overshoot the top is
        clamped onto it rather than emitted past it, and a degenerate
        schedule (min == max) is the single-rung ladder — plain full-fidelity
        search."""
        out: List[float] = []
        f = float(self.min_fidelity)
        # bound the ladder length analytically; float drift must not loop
        k_max = int(math.ceil(
            math.log(self.max_fidelity / self.min_fidelity) / math.log(self.eta)
        )) if self.max_fidelity > self.min_fidelity else 0
        for k in range(k_max + 1):
            f = min(self.min_fidelity * self.eta ** k, self.max_fidelity)
            if out and f <= out[-1]:
                break
            out.append(f)
        if out[-1] < self.max_fidelity:
            out.append(self.max_fidelity)
        return out
