"""The paper's contribution: auto-tuning of platform configuration parameters.

  - ``space``      — the curated 12-train / 11-serve knob tables (§III)
  - ``scheduler``  — TrialScheduler: batched/cached/pruned trial execution
                     (grown from the paper's CMPE, §VII)
  - ``executors``  — trial isolation backends: inline threads (soft
                     timeouts) / subprocess workers (hard SIGKILL deadlines)
  - ``cmpe``       — back-compat serial CMPE facade over the scheduler
  - ``strategies`` — ask/tell Strategy engine: gsft, crs, hillclimb, tpe
  - ``grid_finer`` — Algorithm I wrapper: Grid Search with Finer Tuning (§VIII)
  - ``crs``        — Algorithm II wrapper: Controlled Random Search (§IX)
  - ``study``      — Study: persistent, resumable tuning sessions + EngineConfig
  - ``transfer``   — cross-cell transfer: sibling histories, cell similarity,
                     config snapping (the ``--transfer off|warm|prior`` modes)
  - ``surrogate``  — learned cost model over the study cache: ridge
                     regression that pre-ranks TPE acquisition candidates
                     (the ``--surrogate off|rank`` modes)
  - ``tuner``      — the Admin facade (Figure I) — deprecated shim over Study
  - ``evaluators`` — walltime (paper-faithful) / roofline (AOT) backends
  - ``roofline``   — TPU v5e roofline terms from compiled artifacts
  - ``hlo``        — collective-traffic parser over partitioned HLO
"""
from repro.core.cmpe import CMPE, best_from_log, read_log
from repro.core.crs import CRSResult, controlled_random_search
from repro.core.executors import (
    EvaluatorSpec,
    ExecutionBackend,
    InlineBackend,
    SubprocessBackend,
    make_backend,
)
from repro.core.grid_finer import GridResult, grid_search_finer_tuning
from repro.core.scheduler import Trial, TrialScheduler, config_hash, config_key
from repro.core.space import SERVE_SPACE, SPACES, TRAIN_SPACE, TunableSpace
from repro.core.strategies import (
    CRSStrategy,
    CuratedHillclimbStrategy,
    GridFinerStrategy,
    HillclimbResult,
    Move,
    Strategy,
    TPEResult,
    TPEStrategy,
    make_strategy,
    register_strategy,
)
from repro.core.study import EngineConfig, Study, StudyCell, TuneOutcome, run_session
from repro.core.surrogate import SURROGATE_MODES, CostSurrogate
from repro.core.transfer import (
    TRANSFER_MODES,
    CellKey,
    SiblingHistory,
    default_similarity,
    parse_namespace,
    snap_into_space,
)
from repro.core.tuner import tune

__all__ = [
    "CMPE",
    "EngineConfig",
    "Study",
    "StudyCell",
    "run_session",
    "CRSResult",
    "CRSStrategy",
    "CuratedHillclimbStrategy",
    "EvaluatorSpec",
    "ExecutionBackend",
    "GridFinerStrategy",
    "GridResult",
    "HillclimbResult",
    "InlineBackend",
    "SubprocessBackend",
    "Move",
    "SERVE_SPACE",
    "SPACES",
    "Strategy",
    "TPEResult",
    "TPEStrategy",
    "TRAIN_SPACE",
    "Trial",
    "TrialScheduler",
    "TuneOutcome",
    "TunableSpace",
    "TRANSFER_MODES",
    "SURROGATE_MODES",
    "CostSurrogate",
    "CellKey",
    "SiblingHistory",
    "default_similarity",
    "parse_namespace",
    "snap_into_space",
    "best_from_log",
    "config_hash",
    "config_key",
    "controlled_random_search",
    "grid_search_finer_tuning",
    "make_backend",
    "make_strategy",
    "read_log",
    "register_strategy",
    "tune",
]
