"""The paper's contribution: auto-tuning of platform configuration parameters.

  - ``space``      — the curated 12-train / 11-serve knob tables (§III)
  - ``cmpe``       — Configuration Manager & Performance Evaluator (§VII)
  - ``grid_finer`` — Algorithm I: Grid Search with Finer Tuning (§VIII)
  - ``crs``        — Algorithm II: Controlled Random Search (§IX)
  - ``tuner``      — the Admin facade (Figure I)
  - ``evaluators`` — walltime (paper-faithful) / roofline (AOT) backends
  - ``roofline``   — TPU v5e roofline terms from compiled artifacts
  - ``hlo``        — collective-traffic parser over partitioned HLO
"""
from repro.core.cmpe import CMPE, best_from_log, read_log
from repro.core.crs import CRSResult, controlled_random_search
from repro.core.grid_finer import GridResult, grid_search_finer_tuning
from repro.core.space import SERVE_SPACE, SPACES, TRAIN_SPACE, TunableSpace
from repro.core.tuner import TuneOutcome, tune

__all__ = [
    "CMPE",
    "CRSResult",
    "GridResult",
    "SERVE_SPACE",
    "SPACES",
    "TRAIN_SPACE",
    "TuneOutcome",
    "TunableSpace",
    "best_from_log",
    "controlled_random_search",
    "grid_search_finer_tuning",
    "read_log",
    "tune",
]
