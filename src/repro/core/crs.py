"""Algorithm II — Controlled Random Search (paper §IX), faithful.

Back-compat wrapper: the algorithm now lives in
:class:`repro.core.strategies.crs.CRSStrategy` (ask/tell) and runs through
the :class:`~repro.core.scheduler.TrialScheduler`. A round's m draws are
generated before any is evaluated (the rng never observes results
mid-round), so serial and parallel execution produce identical draw
sequences and the wrapper is bit-compatible with the legacy loop.

Inner routine: draw m uniform configurations within the current
per-parameter bounds, keep the top-k by execution time. Outer loop (after
W.L. Price): contract each numeric parameter's bounds to [min, max] of the
survivors, freeze booleans/categoricals to the survivor majority, re-run,
stop when round-over-round improvement falls below the threshold.
Complexity O(n·m) evaluations.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.core.scheduler import TrialScheduler
from repro.core.space import TunableSpace
from repro.core.strategies.crs import CRSResult, CRSStrategy, _random_config  # noqa: F401


def controlled_random_search(
    space: TunableSpace,
    cmpe: TrialScheduler,
    *,
    fixed: Optional[Dict[str, Any]] = None,
    m: int = 12,
    k: int = 4,
    threshold: float = 0.0,
    max_rounds: int = 6,
    seed: int = 0,
    batch_size: Optional[int] = None,
    patience: Optional[int] = None,
) -> CRSResult:
    strategy = CRSStrategy(
        space, fixed=fixed, m=m, k=k, threshold=threshold,
        max_rounds=max_rounds, seed=seed,
    )
    return cmpe.run(strategy, batch_size=batch_size, patience=patience)
