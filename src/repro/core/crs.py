"""Algorithm II — Controlled Random Search (paper §IX), faithful.

Inner routine ``random_search``: draw m uniform configurations within the
current per-parameter bounds, evaluate through the CMPE, keep the top-k by
execution time. Outer loop (after W.L. Price): contract each numeric
parameter's bounds to [min, max] of the survivors, re-run the random search,
and stop when the round-over-round improvement of the best time falls below a
threshold. Complexity O(n·m) evaluations.

Booleans/categoricals are drawn uniformly from their choice set each round
(the paper: "randomly, either TRUE or FALSE is chosen"), then *frozen* to the
survivor majority once bounds contract — the closest faithful reading of
"minimum and maximum of each parameter" for non-numeric values.
"""
from __future__ import annotations

import random
from collections import Counter
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.cmpe import CMPE
from repro.core.space import CatParam, TunableSpace


@dataclass
class CRSResult:
    best_config: Dict[str, Any]
    best_time: float
    rounds: int
    evaluations: int
    bound_history: List[Dict[str, Any]] = field(default_factory=list)


def _random_config(space, bounds, frozen, rng) -> Dict[str, Any]:
    cfg = {}
    for p in space.params:
        if p.name in frozen:
            cfg[p.name] = frozen[p.name]
        elif p.numeric:
            lo, hi = bounds[p.name]
            cfg[p.name] = p.sample(rng, lo, hi)
        else:
            cfg[p.name] = p.sample(rng)
    return cfg


def _random_search(space, cmpe, bounds, frozen, rng, m, k, fixed, tag):
    """Paper's ``random_search``: m draws, keep top-k (config, time)."""
    results: List[Tuple[Dict[str, Any], float]] = []
    for _ in range(m):
        cfg = {**_random_config(space, bounds, frozen, rng), **fixed}
        t = cmpe.evaluate(cfg, tag=tag)
        results.append((cfg, t))
    results.sort(key=lambda ct: ct[1])
    return results[:k]


def controlled_random_search(
    space: TunableSpace,
    cmpe: CMPE,
    *,
    fixed: Optional[Dict[str, Any]] = None,
    m: int = 12,
    k: int = 4,
    threshold: float = 0.0,
    max_rounds: int = 6,
    seed: int = 0,
) -> CRSResult:
    rng = random.Random(seed)
    fixed = dict(fixed or {})
    numeric = [p for p in space.params if p.numeric and p.name not in fixed]
    bounds = {p.name: (p.lo, p.hi) for p in numeric}
    frozen: Dict[str, Any] = {}
    history = [dict(bounds)]

    survivors = _random_search(space, cmpe, bounds, frozen, rng, m, k, fixed, "crs/round0")
    best_config, best_time = survivors[0]
    rounds = 1

    while rounds < max_rounds:
        # contract bounds to the survivors' [min, max] per numeric parameter
        for p in numeric:
            vals = [c[p.name] for c, _ in survivors]
            bounds[p.name] = (min(vals), max(vals))
        # freeze categoricals to the survivor majority
        for p in space.params:
            if not p.numeric and p.name not in fixed:
                maj = Counter(c[p.name] for c, _ in survivors).most_common(1)[0][0]
                frozen[p.name] = maj
        history.append(dict(bounds))

        survivors = _random_search(
            space, cmpe, bounds, frozen, rng, m, k, fixed, f"crs/round{rounds}"
        )
        new_best_config, new_best_time = survivors[0]
        rounds += 1
        improvement = best_time - new_best_time
        if new_best_time < best_time:
            best_config, best_time = new_best_config, new_best_time
        if improvement <= threshold:
            break  # variation fell below the threshold (paper's stop rule)

    return CRSResult(
        best_config=best_config,
        best_time=best_time,
        rounds=rounds,
        evaluations=cmpe.num_evaluations,
        bound_history=history,
    )
