"""Execution backends for :class:`repro.core.scheduler.TrialScheduler` — the
per-trial isolation seam.

The paper's CMPE restarts the Hadoop/Spark daemons between trials precisely
because a bad configuration can wedge the job. The scheduler's thread path
cannot reproduce that guarantee: Python threads cannot be killed, so a hung
trial keeps its core and memory until interpreter exit ("soft" timeout).
This module makes isolation pluggable:

  - ``InlineBackend``   (``isolation="inline"``, the default) — the original
    in-process path: serial or thread-pool evaluation, soft timeouts. Fast,
    zero setup cost, byte-for-byte compatible logs.
  - ``SubprocessBackend`` (``isolation="subprocess"``) — each fresh trial runs
    in a long-lived **worker process** built from a picklable
    :class:`EvaluatorSpec`. The deadline is *hard*: a trial that overruns
    ``timeout_s`` gets SIGKILLed and reaped, a segfaulting / ``os._exit``-ing
    / OOM-killed trial becomes a ``status="error"`` Trial instead of a dead
    tuning session, and workers are **reused warm** across trials and batches
    so device/jit initialisation is paid once per worker, not per trial.

Both backends expose two execution paths: the round-batched ``run_batch``
(one fidelity per batch, returns in plan order) and the streaming
``submit``/``poll`` pair the scheduler's async seam drives (results come
back the moment each trial finishes — what ASHA's no-barrier promotion
rides on). Per-trial deadlines are **rung-scaled**: a trial at fidelity
``f`` gets ``timeout_s × f``, so a hung rung-0 probe dies on the short
deadline, not the full-fidelity one.

Worker protocol (one duplex pipe per worker):

    parent -> worker   ("run", seq, config, clear_caches, fidelity) | ("exit",)
    worker -> parent   ("ready", pid)
                       ("init_error", message)
                       ("ok", seq, time_s, scalar_info, eval_wall_s)
                       ("err", seq, message, eval_wall_s)

Device pinning (``pin_devices=N``): worker *i* is restricted to one device —
slot ``i % N`` — by environment variables applied at the top of the worker
process **before** the evaluator spec resolves (and therefore before the
worker's first ``import jax``; jax reads ``CUDA_VISIBLE_DEVICES`` /
``JAX_PLATFORMS`` / ``XLA_FLAGS`` once, at backend init). N workers then run
N truly concurrent trials instead of serializing on device 0. A guard after
evaluator construction checks ``len(jax.devices()) == 1`` and fails worker
init loudly if the pin didn't take (e.g. a ``fork`` context after jax was
already imported — the env change lands too late to matter).

A worker that vanishes mid-trial surfaces as EOF on its pipe; the parent
reaps it, records the trial, and respawns a replacement lazily. Because
worker processes isolate all global compiler state, the subprocess backend
runs ``parallel_safe=False`` evaluators (e.g. ``RooflineEvaluator``)
concurrently — the flag only constrains the shared-interpreter thread path.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import pickle
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from importlib import import_module
from multiprocessing.connection import wait as _mp_wait
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

from repro.core.scheduler import Trial, _scalar_info, call_evaluator

__all__ = [
    "EvaluatorSpec",
    "ExecutionBackend",
    "InlineBackend",
    "SubprocessBackend",
    "make_backend",
]


# ---------------------------------------------------------------- spec layer


@dataclass
class EvaluatorSpec:
    """Picklable recipe for constructing an Evaluator inside a worker.

    ``target`` is either a ``"pkg.module:attr"`` dotted path (resolved by
    import in the worker — survives any start method) or a picklable
    callable. With ``construct=True`` the resolved object is called as
    ``target(*args, **kwargs)`` and must return an Evaluator; with
    ``construct=False`` the resolved object *is* the evaluator (the pickled
    instance round-trips as-is).
    """

    target: Union[str, Callable[..., Any]]
    args: Tuple[Any, ...] = ()
    kwargs: Dict[str, Any] = field(default_factory=dict)
    construct: bool = True

    @classmethod
    def factory(cls, target: Union[str, Callable[..., Any]], *args: Any,
                **kwargs: Any) -> "EvaluatorSpec":
        """Spec that calls ``target(*args, **kwargs)`` in the worker."""
        return cls(target=target, args=args, kwargs=kwargs, construct=True)

    @classmethod
    def from_evaluator(cls, evaluator: Any) -> "EvaluatorSpec":
        """Best spec for an evaluator instance: its attached ``.spec`` if it
        carries one, else the pickled instance itself."""
        spec = getattr(evaluator, "spec", None)
        if isinstance(spec, EvaluatorSpec):
            return spec
        try:
            pickle.dumps(evaluator)
        except Exception as e:  # noqa: BLE001 — reported with guidance
            raise TypeError(
                f"{type(evaluator).__name__} cannot be shipped to a worker "
                f"process (pickle failed: {e}). Attach a spec — e.g. "
                "evaluator.spec = EvaluatorSpec.factory('pkg.mod:make_evaluator', "
                "...) — or use isolation='inline'."
            ) from e
        return cls(target=evaluator, construct=False)

    def resolve(self) -> Any:
        obj = self.target
        if isinstance(obj, str):
            mod, _, attr = obj.partition(":")
            if not attr:
                raise ValueError(
                    f"EvaluatorSpec target must be 'pkg.module:attr', got {obj!r}"
                )
            obj = getattr(import_module(mod), attr)
        if not self.construct:
            return obj
        return obj(*self.args, **dict(self.kwargs))


# ----------------------------------------------------------- device pinning


def _device_pin_env(slot: int, pin_devices: int) -> Dict[str, str]:
    """Env vars restricting one worker to one device (slot ``slot``).

    Computed parent-side (so it sees the parent's device-visibility env) but
    applied worker-side before jax is imported. Mechanism by platform:

    - CUDA/ROCm: narrow ``CUDA_VISIBLE_DEVICES`` to the slot's entry (keeps
      the parent's explicit ordering when it set a list), so the worker's
      device 0 *is* physical device ``slot``.
    - TPU: one chip per process via the megacore-style bounds vars.
    - CPU (this container, and any JAX_PLATFORMS=cpu run): a single host
      device per worker — each worker is its own "chip".
    """
    cuda = os.environ.get("CUDA_VISIBLE_DEVICES", "").strip()
    plat = os.environ.get("JAX_PLATFORMS", "").split(",")[0].strip().lower()
    if cuda and cuda != "-1":
        ids = [s.strip() for s in cuda.split(",") if s.strip()]
        return {"CUDA_VISIBLE_DEVICES": ids[slot % len(ids)]}
    if plat in ("cuda", "gpu", "rocm"):
        return {"CUDA_VISIBLE_DEVICES": str(slot)}
    if plat == "tpu" or os.environ.get("TPU_WORKER_ID") is not None:
        return {
            "TPU_VISIBLE_CHIPS": str(slot),
            "TPU_PROCESS_BOUNDS": "1,1,1",
            "TPU_CHIPS_PER_PROCESS_BOUNDS": "1,1,1",
        }
    # CPU fallback: force the host platform with exactly one device, dropping
    # any inherited multi-device override (e.g. the roofline driver's 512)
    xla = os.environ.get("XLA_FLAGS", "")
    xla = " ".join(
        f for f in xla.split()
        if not f.startswith("--xla_force_host_platform_device_count=")
    )
    return {
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": (xla + " --xla_force_host_platform_device_count=1").strip(),
    }


def _apply_pin_guard(pin_env: Optional[Dict[str, str]]) -> Optional[str]:
    """Worker-side post-init check: if pinning was requested and the
    evaluator pulled jax in, the worker must see exactly one device.
    Returns an error message (init failure) or None."""
    if not pin_env:
        return None
    import sys

    jax = sys.modules.get("jax")
    if jax is None:
        return None  # evaluator never imported jax — nothing to mispin
    try:
        n = len(jax.devices())
    except Exception as e:  # noqa: BLE001 — backend init itself broke
        return f"device pin guard: jax.devices() failed: {type(e).__name__}: {e}"
    if n != 1:
        return (
            f"device pin guard: worker sees {n} devices, expected exactly 1 — "
            "the pin env landed after jax initialised (use mp_context='spawn', "
            "and never import jax at executors module scope)"
        )
    return None


# -------------------------------------------------------------- worker child


def _worker_main(conn, spec: EvaluatorSpec,
                 pin_env: Optional[Dict[str, str]] = None) -> None:
    """Worker process loop: build the evaluator once (warm), then serve
    trials until told to exit or killed."""
    if pin_env:
        # before spec.resolve(): jax must first initialise under these vars
        os.environ.update(pin_env)
    try:
        evaluator = spec.resolve()
        err = _apply_pin_guard(pin_env)
        if err is not None:
            raise RuntimeError(err)
    except BaseException as e:  # noqa: BLE001 — parent decides what to do
        try:
            conn.send(("init_error", f"{type(e).__name__}: {e}"))
        finally:
            return
    conn.send(("ready", os.getpid()))
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            return  # parent went away
        if not msg or msg[0] == "exit":
            return
        _, seq, config, clear_caches = msg[:4]
        fidelity = float(msg[4]) if len(msg) > 4 else 1.0
        if clear_caches:
            try:
                import jax

                jax.clear_caches()
            except Exception:  # noqa: BLE001 — evaluator may not use jax
                pass
        t0 = time.time()
        try:
            t, info = call_evaluator(evaluator, config, fidelity)
            conn.send(("ok", seq, float(t), _scalar_info(dict(info)),
                       time.time() - t0))
        except Exception as e:  # noqa: BLE001 — a failed run is a trial
            conn.send(("err", seq, f"{type(e).__name__}: {e}", time.time() - t0))


# ------------------------------------------------------------- parent bookkeeping


@dataclass
class _Task:
    key: str
    config: Dict[str, Any]
    attempt: int
    seq: int
    t0_wall: float  # time.time() at dispatch — Trial.wall_s base
    deadline: Optional[float]  # time.monotonic() hard-kill point (rung-scaled)
    fidelity: float = 1.0
    tag: Optional[str] = None


class _Worker:
    """Parent-side handle: process + pipe + readiness/task state."""

    def __init__(self, ctx, spec: EvaluatorSpec, init_timeout_s: float,
                 pin_slot: Optional[int] = None,
                 pin_env: Optional[Dict[str, str]] = None):
        parent_conn, child_conn = ctx.Pipe()
        self.proc = ctx.Process(
            target=_worker_main, args=(child_conn, spec, pin_env), daemon=True
        )
        self.proc.start()
        child_conn.close()
        self.conn = parent_conn
        self.pid = self.proc.pid
        self.ready = False
        self.dead = False
        self.task: Optional[_Task] = None
        self.init_deadline = time.monotonic() + init_timeout_s
        self.pin_slot = pin_slot
        self.pin_env = pin_env

    def kill(self) -> None:
        """SIGKILL + reap. SIGKILL cannot be caught, so a wedged trial —
        sleeping in C, spinning under the GIL, stuck in a collective — dies."""
        self.dead = True
        try:
            self.proc.kill()
        except Exception:  # noqa: BLE001
            pass
        self.proc.join(5.0)
        try:
            self.conn.close()
        except Exception:  # noqa: BLE001
            pass

    def stop(self) -> None:
        """Graceful shutdown; falls back to kill."""
        if self.dead:
            return
        try:
            self.conn.send(("exit",))
        except Exception:  # noqa: BLE001
            pass
        self.proc.join(1.0)
        if self.proc.is_alive():
            self.kill()
        else:
            self.dead = True
            try:
                self.conn.close()
            except Exception:  # noqa: BLE001
                pass


# ------------------------------------------------------------------ backends


class ExecutionBackend:
    """Where fresh trials run. ``bind`` receives the owning scheduler (the
    source of evaluator, timeout/retry policy, and the persistence hook).

    Two execution paths:

    - ``run_batch(plan, fidelity)`` — round-batched; returns ``(key, Trial)``
      pairs in plan order after the whole batch drains.
    - ``submit(key, config, fidelity, tag)`` + ``poll(timeout)`` — streaming;
      each ``poll`` returns whichever trials finished, the moment they do.
      The scheduler's async seam (``TrialScheduler.submit/poll/run_async``)
      drives this path; ASHA's no-barrier promotions depend on it.
    """

    name = "abstract"

    def bind(self, scheduler) -> None:
        self.sched = scheduler

    def run_batch(
        self, plan: List[Tuple[str, Dict[str, Any]]], fidelity: float = 1.0
    ) -> List[Tuple[str, Trial]]:
        raise NotImplementedError

    def submit(self, key: str, config: Dict[str, Any],
               fidelity: float = 1.0, tag: Optional[str] = None) -> None:
        raise NotImplementedError(f"{self.name} backend has no async path")

    def poll(self, timeout: Optional[float] = None) -> List[Tuple[str, Trial]]:
        raise NotImplementedError(f"{self.name} backend has no async path")

    def close(self) -> None:  # noqa: B027 — optional hook
        pass


@dataclass
class _InlineRun:
    """One in-flight async trial on the inline backend's thread path."""

    key: str
    config: Dict[str, Any]
    fidelity: float
    tag: Optional[str]
    started: Optional[float] = None  # time.monotonic() at evaluation start
    abandoned: bool = False  # soft-timeout fired; late result is discarded


class InlineBackend(ExecutionBackend):
    """The original in-process path: serial (or thread-pooled) evaluation via
    the scheduler's ``_run_one`` / ``_run_parallel``, soft timeouts only.
    ``clear_caches_between_trials`` forces the serial path with a global jit
    cache clear before every fresh trial (clearing is global state).

    The async ``submit``/``poll`` path runs each trial on its own daemon
    thread with its *own* concurrency accounting rather than a thread pool:
    a hung trial is abandoned at its (rung-scaled) soft deadline and drops
    out of the running count, so it cannot poison a pool slot for the rest
    of the session. ``parallel_safe=False`` evaluators and
    ``clear_caches_between_trials`` serialize the thread path to one trial
    at a time, matching the batch path's semantics.
    """

    name = "inline"

    def __init__(self):
        self._cond = threading.Condition()
        self._queue: deque = deque()  # (key, config, fidelity, tag)
        self._running: Dict[str, _InlineRun] = {}
        self._finished: List[Tuple[str, Trial]] = []

    def run_batch(self, plan, fidelity=1.0):
        s = self.sched
        if s.clear_caches:
            import jax

            out = []
            for k, c in plan:
                jax.clear_caches()
                out.append((k, s._run_one(c, fidelity)))
            return out
        parallel_ok = getattr(s.evaluator, "parallel_safe", True)
        if s.max_workers > 1 and parallel_ok and len(plan) > 1:
            return s._run_parallel(plan, fidelity)
        return [(k, s._run_one(c, fidelity)) for k, c in plan]

    # -- async path

    def submit(self, key, config, fidelity=1.0, tag=None):
        with self._cond:
            self._queue.append((key, dict(config), fidelity, tag))
            self._start_ready_locked()

    def poll(self, timeout=None):
        s = self.sched
        end = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while True:
                self._reap_timeouts_locked()
                if self._finished or not (self._running or self._queue):
                    break
                now = time.monotonic()
                if end is not None and now >= end:
                    break
                waits = [] if end is None else [end - now]
                if s.timeout_s is not None:
                    for run in self._running.values():
                        if run.started is None:
                            waits.append(0.05)  # thread not scheduled yet
                        else:
                            waits.append(
                                run.started + s._deadline_for(run.fidelity) - now
                            )
                self._cond.wait(max(0.01, min(waits)) if waits else None)
            out, self._finished = self._finished, []
            return out

    def _start_ready_locked(self) -> None:
        s = self.sched
        serial = s.clear_caches or not getattr(s.evaluator, "parallel_safe", True)
        cap = 1 if serial else max(1, s.max_workers)
        while self._queue and len(self._running) < cap:
            key, config, fidelity, tag = self._queue.popleft()
            run = _InlineRun(key, config, fidelity, tag)
            self._running[key] = run
            threading.Thread(target=self._work, args=(run,), daemon=True).start()

    def _work(self, run: _InlineRun) -> None:
        s = self.sched
        if s.clear_caches:
            try:
                import jax

                jax.clear_caches()
            except Exception:  # noqa: BLE001 — evaluator may not use jax
                pass
        run.started = time.monotonic()
        trial = s._run_one(run.config, run.fidelity, tag=run.tag)
        with self._cond:
            if not run.abandoned:
                self._running.pop(run.key, None)
                self._finished.append((run.key, trial))
                self._start_ready_locked()
            self._cond.notify_all()

    def _reap_timeouts_locked(self) -> None:
        """Abandon runs past their rung-scaled soft deadline. The thread
        itself cannot be killed (inline semantics); it keeps running but no
        longer counts against the concurrency cap, and its eventual result
        is dropped here (``_run_one`` already persisted the real measurement
        as a ``status="timeout"`` record)."""
        s = self.sched
        if s.timeout_s is None:
            return
        now = time.monotonic()
        for key, run in list(self._running.items()):
            eff = s._deadline_for(run.fidelity)
            if run.started is not None and now >= run.started + eff:
                run.abandoned = True
                self._running.pop(key)
                self._finished.append((key, Trial(
                    dict(run.config), s.infeasible_time, {}, wall_s=eff,
                    error=f"TrialTimeout: no result within {eff}s of start "
                          "(soft; worker thread abandoned)",
                    status="timeout", fidelity=run.fidelity,
                )))
        self._start_ready_locked()


class SubprocessBackend(ExecutionBackend):
    """Hard per-trial isolation: worker processes with SIGKILL deadlines.

    - ``spec``: how workers construct the evaluator; defaults to
      ``EvaluatorSpec.from_evaluator(scheduler.evaluator)`` at bind time.
    - ``mp_context``: multiprocessing start method. ``"spawn"`` (default) is
      safe after jax/XLA has initialised in the parent; ``"fork"`` starts
      faster but inherits the parent's threads and is unsafe once jax is up.
    - ``worker_init_timeout_s``: budget for worker startup (imports + device
      init + evaluator construction). Init failures raise — they are
      configuration errors, not trial failures.
    - ``pin_devices``: restrict each worker to ONE device, round-robin over
      ``N`` device slots (worker env set before its first ``import jax`` —
      see :func:`_device_pin_env`). A respawned worker inherits the lowest
      free slot, so a crashed worker's device is reused, not leaked.

    Timeout semantics: the deadline clock starts when a config is dispatched
    to an already-warm worker, so worker startup never eats trial budget. A
    result that arrives before the kill but over the deadline keeps its real
    measurement (``status="timeout"``, persisted), exactly like the inline
    soft-timeout path.
    """

    name = "subprocess"

    def __init__(
        self,
        *,
        spec: Optional[EvaluatorSpec] = None,
        mp_context: str = "spawn",
        worker_init_timeout_s: float = 120.0,
        pin_devices: Optional[int] = None,
    ):
        self.spec = spec
        self.mp_context = mp_context
        self.worker_init_timeout_s = float(worker_init_timeout_s)
        if pin_devices is not None and int(pin_devices) < 1:
            raise ValueError(
                f"pin_devices must be a positive device count, got {pin_devices}"
            )
        self.pin_devices = None if pin_devices is None else int(pin_devices)
        self._pin_rr = 0  # round-robin cursor once every slot is occupied
        self._ctx = mp.get_context(mp_context)
        self._workers: List[_Worker] = []
        self._seq = 0
        # init-failure policy: before any worker has EVER come up, an init
        # death is a configuration error and raises immediately; afterwards
        # it is treated as transient (e.g. respawn under the memory pressure
        # a contained OOM trial created) and retried a few times
        self._ever_ready = False
        self._init_failures = 0
        # shared task state both execution paths pump through:
        # (key, config, fidelity, tag, attempt) awaiting a worker, and
        # finished (key, Trial) pairs not yet handed back to a caller
        self._pending: deque = deque()
        self._done: List[Tuple[str, Trial]] = []

    def bind(self, scheduler) -> None:
        super().bind(scheduler)
        if self.spec is None:
            self.spec = EvaluatorSpec.from_evaluator(scheduler.evaluator)

    # -- pool plumbing

    def _next_pin_slot(self) -> int:
        """Lowest device slot no live worker holds; round-robin overflow when
        the pool is larger than the device count."""
        used = {w.pin_slot for w in self._workers if not w.dead}
        for slot in range(self.pin_devices):
            if slot not in used:
                return slot
        self._pin_rr += 1
        return self._pin_rr % self.pin_devices

    def _spawn(self) -> _Worker:
        slot = env = None
        if self.pin_devices is not None:
            slot = self._next_pin_slot()
            env = _device_pin_env(slot, self.pin_devices)
        w = _Worker(self._ctx, self.spec, self.worker_init_timeout_s,
                    pin_slot=slot, pin_env=env)
        self._workers.append(w)
        return w

    _MAX_INIT_FAILURES = 3  # consecutive; any successful init resets

    def _init_failed(self, detail: str) -> None:
        """A worker never reached "ready". Raise for a cold pool or a streak
        (deterministic breakage); otherwise let the pool respawn."""
        self._init_failures += 1
        if not self._ever_ready or self._init_failures >= self._MAX_INIT_FAILURES:
            raise RuntimeError(detail)

    # -- task plumbing (shared by run_batch and submit/poll)

    def _dispatch(self, w: _Worker, key: str, config: Dict[str, Any],
                  fidelity: float, tag: Optional[str], attempt: int) -> None:
        s = self.sched
        self._seq += 1
        eff = s._deadline_for(fidelity)
        task = _Task(
            key, config, attempt, self._seq, time.time(),
            None if eff is None else time.monotonic() + eff,
            fidelity=fidelity, tag=tag,
        )
        try:
            w.conn.send(("run", task.seq, config, s.clear_caches, fidelity))
        except (BrokenPipeError, OSError):
            # worker died while idle — not the trial's fault; requeue at
            # the same attempt and let the pool respawn
            w.kill()
            self._pending.appendleft((key, config, fidelity, tag, attempt))
            return
        w.task = task

    def _settle_failure(self, t: _Task, error: str) -> None:
        """Crash or evaluator exception: retry if budget allows."""
        if t.attempt < self.sched.retries:
            self._pending.append((t.key, t.config, t.fidelity, t.tag,
                                  t.attempt + 1))
        else:
            self._done.append((t.key, Trial(
                dict(t.config), self.sched.infeasible_time, {},
                wall_s=time.time() - t.t0_wall, error=error, status="error",
                fidelity=t.fidelity,
            )))

    def _on_readable(self, w: _Worker) -> None:
        s = self.sched
        try:
            msg = w.conn.recv()
        except (EOFError, OSError):
            # hard crash: segfault, os._exit, OOM-kill — contain it
            w.proc.join(1.0)  # reap so exitcode is real, not None
            t, code = w.task, w.proc.exitcode
            w.task = None
            was_ready = w.ready
            w.kill()
            if t is not None:
                self._settle_failure(
                    t, f"WorkerCrash: trial process pid {w.pid} died "
                       f"(exit code {code})",
                )
            elif not was_ready:
                self._init_failed(
                    f"subprocess worker pid {w.pid} died during evaluator "
                    f"construction (exit code {code})"
                )
            return
        kind = msg[0]
        if kind == "ready":
            w.ready = True
            self._ever_ready = True
            self._init_failures = 0
            return
        if kind == "init_error":
            w.kill()
            # an exception out of the evaluator factory is deterministic
            # config breakage — always fatal, no retry
            raise RuntimeError(
                f"evaluator construction failed in subprocess worker: {msg[1]}"
            )
        t = w.task
        if t is None or msg[1] != t.seq:
            return  # stale message from a superseded dispatch
        w.task = None
        if kind == "ok":
            _, _, time_s, info, _eval_wall = msg
            wall = time.time() - t.t0_wall
            eff = s._deadline_for(t.fidelity)
            if eff is not None and wall > eff:
                trial = Trial(
                    dict(t.config), float(time_s), dict(info), wall_s=wall,
                    error=f"TrialTimeout: wall {wall:.1f}s > {eff}s "
                          "(completed over deadline; measurement kept)",
                    status="timeout", fidelity=t.fidelity,
                )
            else:
                trial = Trial(dict(t.config), float(time_s), dict(info),
                              wall_s=wall, fidelity=t.fidelity)
            s._persist(trial, tag=t.tag)
            self._done.append((t.key, trial))
        else:  # "err" — exception inside the evaluator; worker stays warm
            _, _, err, _eval_wall = msg
            self._settle_failure(t, err)

    def _outstanding(self) -> bool:
        return bool(self._pending) or any(w.task for w in self._workers)

    def _pump(self, wait_cap: Optional[float]) -> None:
        """One scheduling iteration: reap dead workers, top up the pool,
        dispatch pending tasks to idle warm workers, wait (bounded by the
        nearest deadline and ``wait_cap``, an absolute ``time.monotonic()``
        point or None for "until a message") for worker messages, and
        SIGKILL anything past its deadline."""
        s = self.sched
        self._workers = [w for w in self._workers if not w.dead]
        busy = sum(1 for w in self._workers if w.task)
        target = max(1, min(s.max_workers, busy + len(self._pending)))
        while len(self._workers) < target:
            self._spawn()
        for w in self._workers:
            if not self._pending:
                break
            if w.ready and w.task is None and not w.dead:
                self._dispatch(w, *self._pending.popleft())

        conns = {
            w.conn: w for w in self._workers
            if not w.dead and (w.task is not None or not w.ready)
        }
        if not conns:
            return  # everything respawning; caller loops to top up the pool
        now = time.monotonic()
        deadlines = [
            w.task.deadline for w in conns.values()
            if w.task is not None and w.task.deadline is not None
        ] + [w.init_deadline for w in conns.values() if not w.ready]
        if wait_cap is not None:
            deadlines.append(wait_cap)
        wait_s = None if not deadlines else max(0.0, min(deadlines) - now)
        for conn in _mp_wait(list(conns), timeout=wait_s):
            self._on_readable(conns[conn])

        now = time.monotonic()
        for w in self._workers:
            if w.dead:
                continue
            t = w.task
            if t is not None and t.deadline is not None and now >= t.deadline:
                w.task = None
                w.kill()  # the hard part: SIGKILL + reap, no appeal
                self._done.append((t.key, Trial(
                    dict(t.config), s.infeasible_time, {},
                    wall_s=time.time() - t.t0_wall,
                    error=f"TrialTimeout: exceeded hard deadline "
                          f"{s._deadline_for(t.fidelity)}s — worker pid "
                          f"{w.pid} SIGKILLed",
                    status="timeout", fidelity=t.fidelity,
                )))
            elif not w.ready and now >= w.init_deadline:
                w.kill()
                self._init_failed(
                    f"subprocess worker pid {w.pid} failed to initialise "
                    f"within {self.worker_init_timeout_s}s"
                )

    # -- execution paths

    def submit(self, key, config, fidelity=1.0, tag=None):
        self._pending.append((key, dict(config), fidelity, tag, 0))

    def poll(self, timeout=None):
        end = None if timeout is None else time.monotonic() + timeout
        while not self._done and self._outstanding():
            self._pump(end)
            if end is not None and time.monotonic() >= end:
                break
        out, self._done = self._done, []
        return out

    def run_batch(self, plan, fidelity=1.0):
        for k, c in plan:
            self.submit(k, c, fidelity)
        want = {k for k, _ in plan}
        done: Dict[str, Trial] = {}
        stash: List[Tuple[str, Trial]] = []  # earlier async submissions
        while want - done.keys():
            for k, trial in self.poll(None):
                if k in want:
                    done[k] = trial
                else:
                    stash.append((k, trial))
        self._done = stash + self._done
        return [(k, done[k]) for k, _ in plan]

    def close(self) -> None:
        for w in self._workers:
            w.stop()
        self._workers = []
        self._pending.clear()
        self._done = []


def make_backend(name: str, **options: Any) -> ExecutionBackend:
    """Backend registry: ``inline`` | ``subprocess``."""
    if name == "inline":
        return InlineBackend()
    if name in ("subprocess", "process"):
        return SubprocessBackend(**options)
    raise ValueError(
        f"unknown isolation backend {name!r} (use 'inline' or 'subprocess')"
    )
