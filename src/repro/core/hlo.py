"""Collective-traffic extraction from post-SPMD-partitioning HLO text.

``compiled.cost_analysis()`` does not report collective bytes, so we parse
``compiled.as_text()`` (the partitioned module — shapes in it are already
PER-DEVICE) and sum the wire bytes of every collective op.

Per-device wire-byte model (ring algorithms, g = devices per replica group,
R = result bytes as printed):

  all-gather          (g-1)/g · R        (result = full gathered tensor)
  all-reduce          2(g-1)/g · R       (reduce-scatter + all-gather phases)
  reduce-scatter      (g-1) · R          (operand = g·R leaves the device once)
  all-to-all          (g-1)/g · R
  collective-permute  R

Ops inside ``while`` bodies appear once in the text; trip-count scaling is the
roofline module's job (it compiles loop-free reduced-depth variants and
extrapolates), so this parser stays a pure single-pass accountant.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# `%name = <shape> opcode(` where <shape> is a single array or a (tuple)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>" + "|".join(COLLECTIVES) + r")(?P<async>-start|-done)?\("
)
_ARRAY_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](T\([0-9,]+\))?")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _ARRAY_RE.findall(shape_str):
        if dtype not in DTYPE_BYTES:
            continue  # token types etc.
        numel = 1
        for d in dims.split(","):
            if d:
                numel *= int(d)
        total += numel * DTYPE_BYTES[dtype]
    return total


def _group_info(line: str) -> Tuple[int, str]:
    """(devices per group, 'contig'|'strided'|'pairs')."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g = int(m.group(2))
        contig = m.group(4) is None and "," not in m.group(3)
        return g, ("contig" if contig else "strided")
    m = _GROUPS_LIST_RE.search(line)
    if m:
        members = [x for x in m.group(1).split(",") if x.strip()]
        return len(members), "pairs"
    return 1, "pairs"


@dataclass
class CollectiveStats:
    """Aggregated per-device collective traffic for one HLO module."""

    count: int = 0
    wire_bytes: float = 0.0
    by_op: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    by_group_size: Dict[int, float] = field(default_factory=lambda: defaultdict(float))
    counts_by_op: Dict[str, int] = field(default_factory=lambda: defaultdict(int))

    def add(self, op: str, g: int, result_bytes: int):
        if op == "all-gather":
            wire = (g - 1) / max(g, 1) * result_bytes
        elif op == "all-reduce":
            wire = 2 * (g - 1) / max(g, 1) * result_bytes
        elif op == "reduce-scatter":
            wire = (g - 1) * result_bytes
        elif op == "all-to-all":
            wire = (g - 1) / max(g, 1) * result_bytes
        else:  # collective-permute
            wire = float(result_bytes)
        self.count += 1
        self.wire_bytes += wire
        self.by_op[op] += wire
        self.by_group_size[g] += wire
        self.counts_by_op[op] += 1

    def scaled(self, factor: float) -> "CollectiveStats":
        out = CollectiveStats()
        out.count = self.count
        out.wire_bytes = self.wire_bytes * factor
        for k, v in self.by_op.items():
            out.by_op[k] = v * factor
        for k, v in self.by_group_size.items():
            out.by_group_size[k] = v * factor
        out.counts_by_op = dict(self.counts_by_op)
        return out

    @staticmethod
    def combine(a: "CollectiveStats", b: "CollectiveStats", wa: float = 1.0, wb: float = 1.0):
        out = CollectiveStats()
        out.count = a.count + b.count
        out.wire_bytes = wa * a.wire_bytes + wb * b.wire_bytes
        for src, w in ((a, wa), (b, wb)):
            for k, v in src.by_op.items():
                out.by_op[k] += w * v
            for k, v in src.by_group_size.items():
                out.by_group_size[k] += w * v
            for k, v in src.counts_by_op.items():
                out.counts_by_op[k] = out.counts_by_op.get(k, 0) + v
        return out

    def summary(self) -> Dict:
        return {
            "count": self.count,
            "wire_bytes": self.wire_bytes,
            "by_op": dict(self.by_op),
            "by_group_size": {str(k): v for k, v in self.by_group_size.items()},
            "counts_by_op": dict(self.counts_by_op),
        }


# any op definition line: `%name = <shape> opcode(`
_ANY_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>[\w\-]+)\("
)


@dataclass
class MemoryEstimate:
    """Static peak-buffer estimate for one HLO module, from text alone.

    ``peak_bytes`` is the conservative residency model the feasibility gate
    checks: parameters and the root output are live for the whole program,
    plus the single largest temporary (XLA reuses temp buffers, so summing
    every intermediate would wildly over-reject)."""

    param_bytes: int = 0
    output_bytes: int = 0
    max_temp_bytes: int = 0
    total_temp_bytes: int = 0
    op_count: int = 0

    @property
    def peak_bytes(self) -> int:
        return self.param_bytes + self.output_bytes + self.max_temp_bytes

    def summary(self) -> Dict:
        return {
            "param_bytes": self.param_bytes,
            "output_bytes": self.output_bytes,
            "max_temp_bytes": self.max_temp_bytes,
            "total_temp_bytes": self.total_temp_bytes,
            "peak_bytes": self.peak_bytes,
            "op_count": self.op_count,
        }


def parse_memory(hlo_text: str) -> MemoryEstimate:
    """Peak-buffer estimator over HLO text (post-partitioning: shapes are
    per-device). Pure text analysis — no executable, no
    ``memory_analysis()`` — so it works on any ``jax.jit(...).lower()``
    output before paying a compile.

    Accounting: ``parameter`` shapes are inputs, the ``ROOT`` shape is the
    live output, everything else is a temp. Malformed or non-array shape
    strings contribute zero bytes (the ``_shape_bytes`` regex only consumes
    well-formed ``dtype[dims]`` arrays); empty text yields the zero
    estimate."""
    est = MemoryEstimate()
    for line in hlo_text.splitlines():
        m = _ANY_OP_RE.match(line)
        if m is None:
            continue
        nbytes = _shape_bytes(m.group("shape"))
        est.op_count += 1
        if m.group("op") == "parameter":
            est.param_bytes += nbytes
        elif line.lstrip().startswith("ROOT"):
            est.output_bytes += nbytes
        else:
            est.total_temp_bytes += nbytes
            est.max_temp_bytes = max(est.max_temp_bytes, nbytes)
    return est


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        # async pairs must count ONCE, with the same bytes as the sync op:
        # skip every -done (its result duplicates the pair's traffic), and on
        # the -start — whose printed shape is the tuple (operand, result[,
        # context]) — charge only the result element, never operand + result
        suffix = m.group("async")
        if suffix == "-done":
            continue
        shape = m.group("shape")
        if suffix == "-start" and shape.startswith("("):
            arrays = _ARRAY_RE.findall(shape)
            shape = "".join(
                f"{dtype}[{dims}]" for dtype, dims in arrays[1:2]
            ) or shape
        op = m.group("op")
        result_bytes = _shape_bytes(shape)
        g, _kind = _group_info(line)
        stats.add(op, g, result_bytes)
    return stats
