"""Kernel autotuning as a first-class Study workload.

The paper's premise — hand-picked configuration parameters leave execution
time on the table — applies to our own Pallas kernels: ``flash_attention``,
``rwkv6`` and ``ssm_scan`` ship hardcoded block/tile guesses. This module
turns each kernel's knobs into a :class:`~repro.core.space.TunableSpace` and
benchmarks one kernel *variant* per trial with a :class:`KernelEvaluator`:

  - **numerics gate**: every variant's output is checked against the
    shipped pure-jnp oracle (``ref.py``) *before* it is timed; a mismatch
    returns the infeasible penalty, so a fast-but-wrong block configuration
    can never become the incumbent.
  - **fidelity** via scaled repeats (``max(1, round(repeats × f))``), so
    ASHA's cheap rungs time fewer runs of the same variant.
  - **isolation**: ``parallel_safe = False`` — in-process trials share one
    jax runtime and must not race on it. Under ``isolation="subprocess"``
    each worker builds its own evaluator from the attached
    :class:`~repro.core.executors.EvaluatorSpec` (and with
    ``pin_devices=N`` each worker owns one device), so a multi-chip host
    runs N truly concurrent kernel trials.

Cells are keyed ``kernel/<kernel>.<dtype>:<shape-class>`` — one cache
namespace per (kernel, dtype, shape-class) — and :func:`kernel_similarity`
makes shape classes of the *same* kernel+dtype finite-distance siblings, so
the PR 5 transfer priors carry block-size evidence between input scales
(different kernels never exchange evidence: their knobs don't even share
names). Study-tuned incumbents persist to the shipped
``repro/kernels/tuned_table.json`` (:func:`write_tuned_entries`), which the
public kernel entry points consult when the caller passes no explicit block
sizes.
"""
from __future__ import annotations

import json
import math
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

from repro.core.space import IntParam, TunableSpace
from repro.core.transfer import CellKey
from repro.kernels import (
    DEFAULT_TABLE_PATH,
    dtype_token,
    flash_shape_class,
    invalidate_tuned_table_cache,
    rwkv6_shape_class,
    shape_class_distance,
    ssm_shape_class,
    table_key,
)

__all__ = [
    "KERNEL_NAMES",
    "KERNEL_SPACES",
    "DEFAULT_SHAPES",
    "KernelEvaluator",
    "kernel_platform_key",
    "kernel_similarity",
    "make_kernel_evaluator",
    "parse_kernel_platform",
    "shape_class_for",
    "tuned_entry",
    "write_tuned_entries",
]

KERNEL_NAMES = ("flash_attention", "rwkv6", "ssm_scan")

# One TunableSpace per kernel — every knob is a real argument of the public
# entry point, every value the grids can emit is legal after the ops-layer
# snap/clamp (pow2 snapping here, 128-align + clamp-to-sequence there).
KERNEL_SPACES: Dict[str, TunableSpace] = {
    "flash_attention": TunableSpace(
        platform="kernel.flash_attention",
        params=(
            IntParam("block_q", 128, lo=128, hi=1024, pow2=True),
            IntParam("block_kv", 128, lo=128, hi=1024, pow2=True),
        ),
        most_influential=("block_q", "block_kv"),
    ),
    "rwkv6": TunableSpace(
        platform="kernel.rwkv6",
        # hi=64: the chunked factorization carries exp(-cumsum(logw)) per
        # chunk, and float32 overflows once a chunk accumulates ~88 nats of
        # decay — chunks past 64 NaN for typical decay magnitudes (the
        # evaluator's numerics gate would reject them anyway; bounding the
        # space just stops the tuner paying for known-infeasible trials)
        params=(IntParam("chunk", 64, lo=16, hi=64, pow2=True),),
        most_influential=("chunk",),
    ),
    "ssm_scan": TunableSpace(
        platform="kernel.ssm_scan",
        params=(
            IntParam("chunk", 128, lo=16, hi=256, pow2=True),
            IntParam("d_block", 256, lo=16, hi=1024, pow2=True),
        ),
        most_influential=("chunk", "d_block"),
    ),
}

# Shape tuples per kernel (the CLI default sweep):
#   flash_attention: (B, S, Hq, Hkv, Dh)
#   rwkv6:           (B, S, H, Hd)
#   ssm_scan:        (B, S, Di, N)
DEFAULT_SHAPES: Dict[str, Tuple[Tuple[int, ...], ...]] = {
    "flash_attention": ((2, 256, 4, 2, 64), (1, 512, 4, 2, 64)),
    "rwkv6": ((2, 160, 3, 32), (1, 256, 2, 64)),
    "ssm_scan": ((2, 128, 64, 8), (1, 256, 64, 16)),
}

_SHAPE_RANK = {"flash_attention": 5, "rwkv6": 4, "ssm_scan": 4}

# default relative-error gates per dtype (the parity tests' thresholds with
# headroom for accumulated rounding at large blocks)
_DEFAULT_TOL = {"f32": 1e-4, "bf16": 3e-2, "f16": 3e-2}


def shape_class_for(kernel: str, shape: Tuple[int, ...]) -> str:
    """The compact dims string a shape tuple belongs to (see
    ``repro.kernels``)."""
    if kernel == "flash_attention":
        b, s, hq, hkv, dh = shape
        return flash_shape_class((b, s, hq, dh), (b, s, hkv, dh))
    if kernel == "rwkv6":
        return rwkv6_shape_class(shape)
    if kernel == "ssm_scan":
        b, s, di, n = shape
        return ssm_shape_class((b, s, di), n)
    raise ValueError(f"unknown kernel {kernel!r} (one of {KERNEL_NAMES})")


def kernel_platform_key(kernel: str, dtype: Any, shape_class: str) -> str:
    """Cache namespace for one kernel cell:
    ``kernel/<kernel>.<dtype>:<shape-class>``."""
    return f"kernel/{kernel}.{dtype_token(dtype)}:{shape_class}"


def parse_kernel_platform(platform: str) -> Tuple[str, str, str]:
    """Inverse of :func:`kernel_platform_key` → (kernel, dtype, shape_class)."""
    base, _, cell = platform.partition("/")
    if base != "kernel" or ":" not in cell:
        raise ValueError(f"not a kernel cell namespace: {platform!r}")
    arch, _, shape_class = cell.partition(":")
    kernel, _, dtype = arch.rpartition(".")
    if kernel not in KERNEL_NAMES:
        raise ValueError(f"unknown kernel in namespace {platform!r}")
    return kernel, dtype, shape_class


def kernel_similarity(a: CellKey, b: CellKey) -> float:
    """Sibling distance for kernel cells: ``inf`` across different kernels
    or dtypes (their knob sets / numerics aren't comparable evidence),
    summed |log2| dim distance between shape classes otherwise — a 256-token
    sweep informs the 512-token cell at weight exp(-1)."""
    if a.base != b.base or a.arch != b.arch:
        return math.inf
    if a.shape is None or b.shape is None:
        return 0.5 if a.shape == b.shape else math.inf
    return shape_class_distance(a.shape, b.shape)


# ---------------------------------------------------------------- evaluator


@dataclass
class KernelEvaluator:
    """Benchmark one Pallas-kernel variant per trial.

    ``__call__(config)`` builds the kernel entry point with the trial's
    block knobs, runs it once (compile + **numerics gate** against the
    ``ref.py`` oracle — mismatch ⇒ infeasible penalty before any timing),
    then times ``repeats`` runs under ``jax.block_until_ready`` and returns
    the best.

    Inputs and the oracle output are generated once per evaluator (seeded)
    and reused across trials, so every variant is measured on identical
    data. ``interpret=True`` (the default) runs the Pallas kernel bodies on
    CPU — the CI-safe mode; on real accelerators pass ``interpret=False``.
    """

    kernel: str
    shape: Tuple[int, ...]
    dtype: str = "f32"
    repeats: int = 5
    interpret: bool = True
    tolerance: Optional[float] = None
    seed: int = 0
    spec: Optional[Any] = None  # EvaluatorSpec for subprocess workers
    # one jax runtime per process: in-process trials must not race on it —
    # subprocess isolation (one runtime per worker) is the parallel path
    parallel_safe = False
    supports_fidelity = True  # scaled repeats

    INFEASIBLE = float("inf")

    def __post_init__(self):
        if self.kernel not in KERNEL_NAMES:
            raise ValueError(
                f"unknown kernel {self.kernel!r} (one of {KERNEL_NAMES})"
            )
        self.shape = tuple(int(d) for d in self.shape)
        want = _SHAPE_RANK[self.kernel]
        if len(self.shape) != want:
            raise ValueError(
                f"{self.kernel} shapes have {want} dims "
                f"({'B,S,Hq,Hkv,Dh' if want == 5 else 'see DEFAULT_SHAPES'}), "
                f"got {self.shape}"
            )
        if self.tolerance is None:
            self.tolerance = _DEFAULT_TOL.get(self.dtype, 1e-4)
        self._data: Optional[Tuple[Any, ...]] = None  # inputs + oracle output

    def __getstate__(self):
        # device arrays must never cross a process boundary; workers rebuild
        state = self.__dict__.copy()
        state["_data"] = None
        return state

    # -- identity helpers

    def shape_class(self) -> str:
        return shape_class_for(self.kernel, self.shape)

    def platform_key(self) -> str:
        return kernel_platform_key(self.kernel, self.dtype, self.shape_class())

    # -- data / variant construction

    def _jnp_dtype(self):
        import jax.numpy as jnp

        return {
            "f32": jnp.float32, "bf16": jnp.bfloat16, "f16": jnp.float16,
        }.get(self.dtype, jnp.float32)

    def _materialize(self) -> Tuple[Any, ...]:
        """(inputs..., oracle output) — generated once, reused per trial."""
        if self._data is not None:
            return self._data
        import jax
        import jax.numpy as jnp

        dt = self._jnp_dtype()
        key = jax.random.PRNGKey(self.seed)
        if self.kernel == "flash_attention":
            from repro.kernels.flash_attention.ref import attention_ref

            b, s, hq, hkv, dh = self.shape
            ks = jax.random.split(key, 3)
            # q pre-scaled, scale=1.0 everywhere (the model's convention)
            q = (jax.random.normal(ks[0], (b, s, hq, dh), dt) * dh**-0.5)
            k = jax.random.normal(ks[1], (b, s, hkv, dh), dt)
            v = jax.random.normal(ks[2], (b, s, hkv, dh), dt)
            ref = attention_ref(q, k, v, causal=True, scale=1.0)
            data = (q, k, v, ref)
        elif self.kernel == "rwkv6":
            from repro.kernels.rwkv6.ref import wkv6_ref

            b, s, h, hd = self.shape
            ks = jax.random.split(key, 5)
            r, k, v = (
                0.5 * jax.random.normal(ks[i], (b, s, h, hd), dt)
                for i in range(3)
            )
            logw = -jnp.exp(0.3 * jax.random.normal(ks[3], (b, s, h, hd), dt))
            u = 0.3 * jax.random.normal(ks[4], (h, hd), dt)
            ref = wkv6_ref(r, k, v, logw, u)
            data = (r, k, v, logw, u, ref)
        else:  # ssm_scan
            from repro.kernels.ssm_scan.ref import ssm_scan_ref

            b, s, di, n = self.shape
            ks = jax.random.split(key, 5)
            dt_in = jax.nn.softplus(jax.random.normal(ks[0], (b, s, di), dt))
            u = jax.random.normal(ks[1], (b, s, di), dt)
            bt = jax.random.normal(ks[2], (b, s, n), dt)
            ct = jax.random.normal(ks[3], (b, s, n), dt)
            a = -jnp.exp(0.3 * jax.random.normal(ks[4], (di, n), dt))
            ref = ssm_scan_ref(dt_in, u, bt, ct, a)
            data = (dt_in, u, bt, ct, a, ref)
        self._data = tuple(jax.block_until_ready(x) for x in data)
        return self._data

    def _variant(self, config: Dict[str, Any]):
        """(zero-arg jitted job, oracle output) for one knob config."""
        import functools

        import jax

        data = self._materialize()
        if self.kernel == "flash_attention":
            from repro.kernels.flash_attention.ops import flash_attention

            q, k, v, ref = data
            fn = jax.jit(functools.partial(
                flash_attention,
                causal=True, scale=1.0,
                block_q=int(config["block_q"]),
                block_kv=int(config["block_kv"]),
                interpret=self.interpret,
            ))
            return (lambda: fn(q, k, v)), ref
        if self.kernel == "rwkv6":
            from repro.kernels.rwkv6.ops import wkv6

            r, k, v, logw, u, ref = data
            fn = jax.jit(functools.partial(
                wkv6, chunk=int(config["chunk"]), interpret=self.interpret,
            ))
            return (lambda: fn(r, k, v, logw, u)), ref
        from repro.kernels.ssm_scan.ops import selective_scan

        dt_in, u, bt, ct, a, ref = data
        fn = jax.jit(functools.partial(
            selective_scan,
            chunk=int(config["chunk"]), d_block=int(config["d_block"]),
            interpret=self.interpret,
        ))
        return (lambda: fn(dt_in, u, bt, ct, a)), ref

    # -- the evaluator protocol

    def __call__(
        self, config: Dict[str, Any], fidelity: float = 1.0
    ) -> Tuple[float, Dict[str, Any]]:
        import jax
        import jax.numpy as jnp

        job, ref = self._variant(config)
        out = jax.block_until_ready(job())  # compile + warmup

        # numerics gate BEFORE timing: a wrong variant must never be ranked
        out32 = out.astype(jnp.float32)
        ref32 = ref.astype(jnp.float32)
        rel = float(
            jnp.max(jnp.abs(out32 - ref32)) / (jnp.max(jnp.abs(ref32)) + 1e-9)
        )
        info: Dict[str, Any] = {
            "kernel": self.kernel,
            "shape_class": self.shape_class(),
            "max_rel_err": rel,
        }
        if not math.isfinite(rel) or rel > self.tolerance:
            info["numerics_mismatch"] = True
            info["tolerance"] = self.tolerance
            return self.INFEASIBLE, info

        repeats = self.repeats
        if fidelity < 1.0:
            repeats = max(1, int(round(self.repeats * fidelity)))
            info["fidelity"] = fidelity
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(job())
            best = min(best, time.perf_counter() - t0)
        info["repeats"] = repeats
        return best, info


def make_kernel_evaluator(
    kernel: str,
    shape: Tuple[int, ...],
    dtype: str = "f32",
    *,
    repeats: int = 5,
    interpret: bool = True,
    tolerance: Optional[float] = None,
    seed: int = 0,
) -> KernelEvaluator:
    """Module-level factory (the dotted-path target subprocess workers
    resolve), with the matching :class:`EvaluatorSpec` pre-attached."""
    from repro.core.executors import EvaluatorSpec

    ev = KernelEvaluator(
        kernel, tuple(int(d) for d in shape), dtype,
        repeats=repeats, interpret=interpret, tolerance=tolerance, seed=seed,
    )
    ev.spec = EvaluatorSpec.factory(
        "repro.core.kernel_tune:make_kernel_evaluator",
        kernel, tuple(int(d) for d in shape), dtype,
        repeats=repeats, interpret=interpret, tolerance=tolerance, seed=seed,
    )
    return ev


# -------------------------------------------------------------- tuned table


def write_tuned_entries(
    entries: Dict[str, Dict[str, Any]],
    path: Optional[Path] = None,
) -> Path:
    """Merge ``{table_key: {"config": .., "time_s": .., "source": ..}}``
    into the tuned table (creating it if absent) and invalidate the loader
    cache so the very next kernel call sees the new incumbents."""
    p = Path(path) if path is not None else DEFAULT_TABLE_PATH
    existing: Dict[str, Any] = {}
    if p.exists():
        try:
            raw = json.loads(p.read_text())
            if isinstance(raw, dict) and isinstance(raw.get("entries"), dict):
                existing = raw["entries"]
        except (ValueError, OSError):
            existing = {}  # a corrupt table is replaced wholesale
    existing.update(entries)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(
        {"version": 1, "entries": dict(sorted(existing.items()))}, indent=1,
    ) + "\n")
    invalidate_tuned_table_cache()
    return p


def tuned_entry(
    kernel: str, dtype: str, shape_class: str,
    config: Dict[str, Any], time_s: float, source: str,
) -> Dict[str, Dict[str, Any]]:
    """One table entry, keyed for :func:`write_tuned_entries`."""
    space = KERNEL_SPACES[kernel]
    known = set(space.names())
    return {
        table_key(kernel, dtype, shape_class): {
            "config": {k: v for k, v in config.items() if k in known},
            "time_s": float(time_s),
            "source": source,
        }
    }
