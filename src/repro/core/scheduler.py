"""TrialScheduler — the execution engine under every search strategy.

The paper's CMPE (Configuration Manager and Performance Evaluator, §VII) ran
one trial at a time: apply the config, run the job, log, return the time.
This module grows that into a batched scheduler the ask/tell strategies
(:mod:`repro.core.strategies`) drive:

  - **concurrent batches** — ``evaluate_batch`` fans a strategy's batch over
    a thread pool (wall-clock-bound evaluators like ``WalltimeEvaluator`` and
    ``FunctionEvaluator`` parallelize; evaluators that mutate global compiler
    state declare ``parallel_safe = False`` and run serially),
  - **persistent cross-session cache** — a JSONL file keyed by the canonical
    config hash; re-runs and resumed sessions replay trial times without a
    single fresh evaluation,
  - **per-trial timeout / retry / infeasible penalty** — a hung or crashing
    trial becomes a logged infeasible trial instead of killing the session,
  - **pluggable isolation** — fresh trials run through an
    :class:`repro.core.executors.ExecutionBackend`: ``isolation="inline"``
    (threads, soft timeouts — the default) or ``isolation="subprocess"``
    (worker processes, hard SIGKILL deadlines, crash containment),
  - **early stopping** — ``run(strategy, patience=k)`` kills a sweep when the
    running best hasn't improved in k consecutive batches.

Everything the old CMPE promised still holds: identical configs are memoized
within a session, every trial (fresh, memoized, cached, failed) is appended
to the JSONL log, and failures are trials, not exceptions.
"""
from __future__ import annotations

import hashlib
import json
import math
import threading
import time
from concurrent.futures import CancelledError, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeoutError
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Protocol, Sequence, Tuple

INFEASIBLE = float("inf")


class Evaluator(Protocol):
    """config dict -> (execution time in seconds, info dict)."""

    def __call__(self, config: Dict[str, Any]) -> Tuple[float, Dict[str, Any]]: ...


@dataclass
class Trial:
    config: Dict[str, Any]
    time_s: float
    info: Dict[str, Any] = field(default_factory=dict)
    wall_s: float = 0.0
    error: Optional[str] = None
    source: str = "fresh"  # fresh | cache (persistent) — memo hits reuse the Trial
    status: str = "ok"  # ok | error | timeout — timeouts are NOT generic failures

    @property
    def ok(self) -> bool:
        return self.error is None

    @property
    def timed_out(self) -> bool:
        return self.status == "timeout"

    @property
    def score(self) -> float:
        """What a strategy ranks on. A timeout Trial may carry its real
        measured ``time_s`` (kept for resume accounting and analysis), but a
        config that blows the deadline must never win the sweep — non-ok
        trials score as infeasible."""
        return self.time_s if self.ok else INFEASIBLE


def config_key(config: Dict[str, Any]) -> str:
    """Canonical JSON of the config — the memo/log identity of a trial."""
    return json.dumps(config, sort_keys=True, default=str)


def config_hash(config: Dict[str, Any]) -> str:
    """Short stable hash of :func:`config_key` — the persistent-cache key."""
    return hashlib.sha256(config_key(config).encode()).hexdigest()[:24]


# legacy name used by the old cmpe module
_key = config_key


class TrialScheduler:
    """Batched trial executor with memoization, persistence, and pruning.

    ``max_workers=1`` (the default) reproduces the old CMPE behaviour
    byte-for-byte: serial evaluation in ask order, identical log records.
    """

    def __init__(
        self,
        evaluator: Evaluator,
        *,
        platform: str = "train",
        log_path: Optional[Path] = None,
        clear_caches_between_trials: bool = False,
        max_workers: int = 1,
        cache_path: Optional[Path] = None,
        timeout_s: Optional[float] = None,
        retries: int = 0,
        infeasible_time: float = INFEASIBLE,
        isolation: str = "inline",
        backend: Optional[Any] = None,
    ):
        self.evaluator = evaluator
        self.platform = platform
        self.log_path = Path(log_path) if log_path else None
        self.clear_caches = clear_caches_between_trials
        self.max_workers = max(1, int(max_workers))
        self.timeout_s = timeout_s
        self.retries = max(0, int(retries))
        self.infeasible_time = infeasible_time
        self.trials: List[Trial] = []
        self._memo: Dict[str, Trial] = {}
        self._log_lock = threading.Lock()
        self._batch_tag = ""  # provenance stamped into persisted records
        # cache-accounting counters (the engine tests assert on these)
        self.fresh_evaluations = 0
        self.memo_hits = 0
        self.cache_hits = 0
        # outcome counters — timeouts (incl. abandoned hung threads) are
        # reported distinctly, not folded into the generic failure count
        self.timeout_trials = 0
        self.error_trials = 0
        if self.log_path:
            self.log_path.parent.mkdir(parents=True, exist_ok=True)
        self.cache_path = Path(cache_path) if cache_path else None
        self._persistent: Dict[str, Dict[str, Any]] = {}
        if self.cache_path:
            self._persistent = _load_cache(self.cache_path, self.platform)
            self.cache_path.parent.mkdir(parents=True, exist_ok=True)
        if backend is None:
            # local import: executors imports Trial from this module
            from repro.core.executors import make_backend

            backend = make_backend(isolation)
        self.isolation = getattr(backend, "name", isolation)
        self._backend = backend
        self._backend.bind(self)

    # ------------------------------------------------------------------- api

    def evaluate(self, config: Dict[str, Any], tag: str = "") -> float:
        """Tune the platform to ``config``, run the job, return execution
        time. Logs every call (the one-trial path the old CMPE exposed).

        The scalar return is a *rankable score*: a trial that completed over
        the deadline keeps its real measurement on the Trial (and in the
        cache) but scores as ``infeasible_time`` here, so legacy callers
        comparing bare floats never crown a deadline-busting config."""
        trial = self.evaluate_batch([config], tag=tag)[0]
        return self.infeasible_time if trial.timed_out else trial.time_s

    def evaluate_batch(
        self, configs: Sequence[Dict[str, Any]], tag: str = ""
    ) -> List[Trial]:
        """Evaluate a batch, returning one Trial per config **in input
        order**. Duplicates (within the batch or vs. earlier batches) are
        served from the memo; persistent-cache hits cost nothing fresh."""
        self._batch_tag = tag
        keys = [config_key(c) for c in configs]
        plan: List[Tuple[str, Dict[str, Any]]] = []  # unique keys needing a run
        first_served = set()  # keys whose first occurrence is logged below
        for k, c in zip(keys, configs):
            if k in self._memo or k in first_served:
                continue
            hit = self._persistent.get(config_hash(c))
            if hit is not None:
                # replay preserves the measurement but re-judges a persisted
                # over-deadline record against THIS session's deadline: a
                # cache written under a tight timeout must not permanently
                # poison configs whose measured wall now fits
                status = hit.get("status", "ok")
                error = hit.get("error")
                if status == "timeout":
                    rec_wall = float(hit.get("wall_s", INFEASIBLE))
                    if self.timeout_s is None or rec_wall <= self.timeout_s:
                        status, error = "ok", None
                trial = Trial(
                    dict(c), float(hit["time_s"]), dict(hit.get("info", {})),
                    wall_s=0.0, source="cache", error=error, status=status,
                )
                self.cache_hits += 1
                self.trials.append(trial)
                self._memo[k] = trial
                self._log(trial, tag=tag, cached=True)
            else:
                plan.append((k, c))
            first_served.add(k)

        if plan:
            # how/where fresh trials run is the backend's business: inline
            # (threads, soft timeouts) or subprocess (hard SIGKILL deadlines)
            fresh = self._backend.run_batch(plan)
            for k, trial in fresh:
                self.fresh_evaluations += 1
                if trial.timed_out:
                    self.timeout_trials += 1
                elif not trial.ok:
                    self.error_trials += 1
                self.trials.append(trial)
                self._memo[k] = trial
                # successful trials were already persisted the moment they
                # completed (inside _run_one) — a mid-batch crash loses nothing
                self._log(trial, tag=tag, cached=False)

        out: List[Trial] = []
        for k in keys:
            trial = self._memo[k]
            out.append(trial)
            if k in first_served:
                first_served.discard(k)  # first occurrence logged above
            else:  # repeat of this batch or of an earlier one — memo hit
                self.memo_hits += 1
                self._log(trial, tag=tag, cached=True)
        return out

    def run(
        self,
        strategy,
        *,
        batch_size: Optional[int] = None,
        patience: Optional[int] = None,
    ):
        """Drive an ask/tell strategy to completion (or early stop).

        ``patience=k`` prunes the sweep when the running best time has not
        improved for k consecutive batches — the grid-pass killer.

        Result accounting (``evaluations`` / ``timeouts``) reports **this
        run's deltas**, not scheduler-lifetime totals — a shared multi-cell
        scheduler must not inflate every cell's numbers."""
        evals_before = self.num_evaluations
        timeouts_before = self.timeout_trials
        best = INFEASIBLE
        stale = 0
        stopped_early = False
        while not strategy.done:
            configs = strategy.ask(batch_size)
            if not configs:
                break
            trials = self.evaluate_batch(configs, tag=strategy.tag)
            strategy.tell(trials)
            batch_best = min(
                (t.time_s for t in trials if t.ok), default=INFEASIBLE
            )
            if batch_best < best:
                best = batch_best
                stale = 0
            else:
                stale += 1
            if patience is not None and stale >= patience:
                stopped_early = True
                break
        result = strategy.result()
        if hasattr(result, "evaluations"):
            result.evaluations = self.num_evaluations - evals_before
        if hasattr(result, "stopped_early"):
            result.stopped_early = stopped_early
        if hasattr(result, "timeouts"):
            result.timeouts = self.timeout_trials - timeouts_before
        return result

    def best(self) -> Trial:
        ok = [t for t in self.trials if t.ok]
        if not ok:
            raise RuntimeError("no successful trials")
        return min(ok, key=lambda t: t.time_s)

    def close(self) -> None:
        """Release backend resources (warm subprocess workers). Idempotent;
        a no-op for the inline backend."""
        self._backend.close()

    def __enter__(self) -> "TrialScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # best-effort — don't leak worker processes
        try:
            backend = getattr(self, "_backend", None)
            if backend is not None:
                backend.close()
        except Exception:  # noqa: BLE001
            pass

    @property
    def num_evaluations(self) -> int:
        return len(self.trials)

    def cache_stats(self) -> Dict[str, int]:
        return {
            "fresh": self.fresh_evaluations,
            "memo_hits": self.memo_hits,
            "cache_hits": self.cache_hits,
        }

    def run_stats(self) -> Dict[str, int]:
        """Cache accounting plus trial outcomes — the run-summary block."""
        return {
            **self.cache_stats(),
            "trials": self.num_evaluations,
            "timeouts": self.timeout_trials,
            "errors": self.error_trials,
        }

    def stats_snapshot(self) -> Dict[str, int]:
        """Point-in-time counters for per-session delta accounting: a Study
        (or the tune shim) subtracts two snapshots so a shared multi-session
        scheduler reports each session's own numbers, never lifetime totals.
        Same counters as :meth:`run_stats` under the outcome-facing name."""
        stats = self.run_stats()
        stats["evaluations"] = stats.pop("trials")
        return stats

    def cached_observations(
        self, with_platform: bool = False
    ) -> List[Tuple[Any, ...]]:
        """``(config, time_s, tag)`` triples from the persistent cache, this
        platform only, in file order — the warm-start history a model-based
        strategy (TPE) seeds its observation set from on resume. The tag
        carries provenance: a strategy charges only its *own* records against
        its trial budget and treats the rest as free model observations.
        Persisted timeout records are excluded — an over-deadline measurement
        must not feed a density model as if it were a clean observation.

        ``with_platform=True`` appends each record's **stored** cell
        namespace as a fourth element. The stored namespace is the record's
        identity, not this scheduler's view of it: a legacy record with no
        platform field matched this scheduler's filter by default and reads
        back as ``None`` — callers bucketing records per cell (the cross-cell
        ``Study.histories_for``) must never attribute it to a real cell."""
        out: List[Tuple[Any, ...]] = []
        for rec in self._persistent.values():
            if "config" not in rec or "time_s" not in rec:
                continue
            if rec.get("status", "ok") != "ok":
                continue
            row = (dict(rec["config"]), float(rec["time_s"]), rec.get("tag"))
            out.append(row + (rec.get("platform"),) if with_platform else row)
        return out

    # ------------------------------------------------------------- execution

    def _run_one(self, config: Dict[str, Any]) -> Trial:
        """One fresh evaluation with retry + soft timeout + penalty. The
        result is persisted immediately (not at batch end), so a session
        killed mid-batch resumes from everything already evaluated."""
        t0 = time.time()
        last_err = None
        for _attempt in range(self.retries + 1):
            try:
                t, info = self.evaluator(config)
                trial = Trial(dict(config), float(t), info, wall_s=time.time() - t0)
                if self.timeout_s is not None and trial.wall_s > self.timeout_s:
                    # completed over the soft deadline: the measurement is
                    # real — keep and persist it (a resume must not re-pay
                    # it); status="timeout" lets strategies score it (they
                    # rank on Trial.score, which is infeasible for non-ok)
                    trial = Trial(
                        dict(config), float(t), info, wall_s=trial.wall_s,
                        error=f"TrialTimeout: wall {trial.wall_s:.1f}s > "
                              f"{self.timeout_s}s (soft; measurement kept)",
                        status="timeout",
                    )
                self._persist(trial)
                return trial
            except Exception as e:  # noqa: BLE001 — a failed run is a trial
                last_err = f"{type(e).__name__}: {e}"
        return Trial(
            dict(config), self.infeasible_time, {}, wall_s=time.time() - t0,
            error=last_err, status="error",
        )

    def _run_parallel(
        self, plan: List[Tuple[str, Dict[str, Any]]]
    ) -> List[Tuple[str, Trial]]:
        """Fan the batch over a thread pool; a future that misses the hard
        deadline becomes an infeasible trial. The batch returns promptly
        regardless: queued futures are cancelled and a hung worker thread is
        abandoned, not joined (threads can't be killed — it still holds until
        interpreter exit; ``isolation="subprocess"`` kills for real).

        Deadline semantics: every trial gets ``timeout_s`` from the moment
        its thread actually *starts* — not from the previous ``result()``
        call (the old cumulative bug: N stragglers serialized into N×timeout
        wall clock), and not from batch start (which would falsely time out
        trials queued behind a full pool). A trial still queued once every
        pool slot has had a full timeout window (``timeout_s × ceil(N/W)``
        from batch start) is stuck behind hung threads and is cancelled. A
        started-then-abandoned thread that eventually completes has
        ``wall_s > timeout_s`` by construction, so its late ``_run_one``
        persist is the same measured-timeout record — never a conflicting
        ok record."""
        out: List[Tuple[str, Trial]] = []
        pool = ThreadPoolExecutor(max_workers=self.max_workers)
        starts: Dict[int, float] = {}  # future index -> monotonic start

        def timed(i: int, c: Dict[str, Any]) -> Trial:
            starts[i] = time.monotonic()
            return self._run_one(c)

        batch_cap = (
            None if self.timeout_s is None
            else time.monotonic()
            + self.timeout_s * math.ceil(len(plan) / self.max_workers)
        )
        try:
            futures = [
                (i, k, c, pool.submit(timed, i, c))
                for i, (k, c) in enumerate(plan)
            ]
            for i, k, c, fut in futures:
                trial: Optional[Trial] = None
                while trial is None:
                    if self.timeout_s is None:
                        trial = fut.result()
                        break
                    now = time.monotonic()
                    t_start = starts.get(i)
                    if t_start is None:
                        if now >= batch_cap and fut.cancel():
                            trial = Trial(
                                dict(c), self.infeasible_time, {}, wall_s=0.0,
                                error="TrialTimeout: cancelled before start "
                                      "(batch cap exhausted by hung earlier "
                                      "trials)",
                                status="timeout",
                            )
                            break
                        wait = min(0.05, max(0.0, batch_cap - now))
                    else:
                        deadline_i = t_start + self.timeout_s
                        if now >= deadline_i:
                            trial = Trial(
                                dict(c), self.infeasible_time, {},
                                wall_s=self.timeout_s,
                                error="TrialTimeout: no result within "
                                      f"{self.timeout_s}s of start "
                                      "(worker thread abandoned)",
                                status="timeout",
                            )
                            break
                        wait = deadline_i - now
                    try:
                        trial = fut.result(timeout=wait)
                    except FutureTimeoutError:
                        continue  # re-evaluate start/deadline state
                    except CancelledError:
                        trial = Trial(
                            dict(c), self.infeasible_time, {}, wall_s=0.0,
                            error="TrialTimeout: cancelled before start "
                                  f"(batch deadline {self.timeout_s}s)",
                            status="timeout",
                        )
                out.append((k, trial))
        finally:
            # don't block on stragglers; drop whatever never started
            pool.shutdown(wait=False, cancel_futures=True)
        return out

    # ------------------------------------------------------------------- io

    def _persist(self, trial: Trial):
        # ok trials always persist; timeout trials persist only when they
        # carry a real finite measurement (a SIGKILLed / abandoned trial has
        # nothing worth replaying). Extra keys appear ONLY on non-ok records,
        # keeping ok-record bytes identical to every cache written before.
        measured_timeout = trial.timed_out and math.isfinite(trial.time_s)
        if not self.cache_path or not (trial.ok or measured_timeout):
            return
        rec = {
            "key": config_hash(trial.config),
            "platform": self.platform,
            "tag": self._batch_tag,  # which strategy/phase proposed this
            "ts": time.time(),
            "config": trial.config,
            "time_s": trial.time_s,
            "info": _scalar_info(trial.info),
        }
        if not trial.ok:
            rec["status"] = trial.status
            rec["error"] = trial.error
            rec["wall_s"] = trial.wall_s  # replay re-judges vs. the live deadline
        with self._log_lock:
            self._persistent[rec["key"]] = rec
            with self.cache_path.open("a") as f:
                f.write(json.dumps(rec, default=str) + "\n")

    def _log(self, trial: Trial, tag: str, cached: bool):
        if not self.log_path:
            return
        rec = {
            "ts": time.time(),
            "platform": self.platform,
            "tag": tag,
            "cached": cached,
            "config": trial.config,
            "time_s": trial.time_s,
            "wall_s": trial.wall_s,
            "error": trial.error,
            "status": trial.status,
            "source": trial.source,
            "info": _scalar_info(trial.info),
        }
        with self._log_lock, self.log_path.open("a") as f:
            f.write(json.dumps(rec, default=str) + "\n")


def _scalar_info(info: Dict[str, Any]) -> Dict[str, Any]:
    return {k: v for k, v in info.items() if isinstance(v, (int, float, str, bool))}


def iter_jsonl(path: Path) -> List[Dict[str, Any]]:
    """Parse a JSONL records file, tolerating the torn tail line a crashed
    session can leave behind — the one parser under the eval cache, the trial
    log, and the Study accessors."""
    out: List[Dict[str, Any]] = []
    path = Path(path)
    if not path.exists():
        return out
    for line in path.read_text().splitlines():
        if not line.strip():
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            continue  # torn tail write from a crashed session
    return out


def _load_cache(path: Path, platform: str) -> Dict[str, Dict[str, Any]]:
    """Load a JSONL evaluation cache (last record per key wins). Records are
    namespaced by platform so one shared file serves a multi-cell session."""
    return {
        rec["key"]: rec for rec in iter_jsonl(path)
        if rec.get("platform", platform) == platform and "key" in rec
    }


def read_cache_by_platform(path: Path) -> Dict[str, Dict[str, Dict[str, Any]]]:
    """One pass over a shared evaluation cache, grouped by each record's
    **stored** platform namespace: ``{namespace: {key: record}}``.

    This is the cross-cell read under ``Study.histories_for``: grouping is by
    the namespace string the record was *written* with, so ``train/a:s`` and
    its ``train/a:s@512c`` chip-count variant land in separate buckets
    (PR-4's topology keying), and legacy records with no platform field —
    which ``_load_cache`` would have matched against ANY platform — are
    collected under ``""`` rather than attributed to a real cell. Per bucket,
    the last record per key wins but keeps its first-write position, so a
    bucket's iteration order is the append order the sibling session produced
    (resume replays a recorded prefix of it)."""
    grouped: Dict[str, Dict[str, Dict[str, Any]]] = {}
    for rec in iter_jsonl(path):
        if "key" not in rec:
            continue
        ns = rec.get("platform") or ""
        grouped.setdefault(ns, {})[rec["key"]] = rec
    return grouped


def read_log(path: Path, platform: Optional[str] = None) -> List[Dict[str, Any]]:
    """Recover trials from a scheduler log file (the paper's 'analyzing the
    log file helps in finding the optimal configuration').

    Tolerates a torn tail line from a crashed session (like ``_load_cache``)
    and, given ``platform``, filters a shared multi-cell log down to one
    cell's records (legacy records without a platform field are kept). A
    missing file raises (a typo'd path must not read as an empty log)."""
    path = Path(path)
    if not path.exists():
        raise FileNotFoundError(f"no trial log at {path}")
    return [
        rec for rec in iter_jsonl(path)
        if platform is None or rec.get("platform", platform) == platform
    ]


def best_from_log(path: Path, platform: Optional[str] = None) -> Dict[str, Any]:
    recs = [r for r in read_log(path, platform=platform)
            if r.get("error") is None]
    if not recs:
        where = f"{path}" + (f" (platform={platform!r})" if platform else "")
        raise ValueError(f"no successful trials in log {where}")
    return min(recs, key=lambda r: r["time_s"])
