"""TrialScheduler — the execution engine under every search strategy.

The paper's CMPE (Configuration Manager and Performance Evaluator, §VII) ran
one trial at a time: apply the config, run the job, log, return the time.
This module grows that into a batched scheduler the ask/tell strategies
(:mod:`repro.core.strategies`) drive:

  - **concurrent batches** — ``evaluate_batch`` fans a strategy's batch over
    a thread pool (wall-clock-bound evaluators like ``WalltimeEvaluator`` and
    ``FunctionEvaluator`` parallelize; evaluators that mutate global compiler
    state declare ``parallel_safe = False`` and run serially),
  - **persistent cross-session cache** — a JSONL file keyed by the canonical
    config hash; re-runs and resumed sessions replay trial times without a
    single fresh evaluation,
  - **per-trial timeout / retry / infeasible penalty** — a hung or crashing
    trial becomes a logged infeasible trial instead of killing the session,
  - **pluggable isolation** — fresh trials run through an
    :class:`repro.core.executors.ExecutionBackend`: ``isolation="inline"``
    (threads, soft timeouts — the default) or ``isolation="subprocess"``
    (worker processes, hard SIGKILL deadlines, crash containment),
  - **early stopping** — ``run(strategy, patience=k)`` kills a sweep when the
    running best hasn't improved in k consecutive batches.

Everything the old CMPE promised still holds: identical configs are memoized
within a session, every trial (fresh, memoized, cached, failed) is appended
to the JSONL log, and failures are trials, not exceptions.
"""
from __future__ import annotations

import hashlib
import json
import math
import threading
import time
from concurrent.futures import CancelledError, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeoutError
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Protocol, Sequence, Tuple

INFEASIBLE = float("inf")


class Evaluator(Protocol):
    """config dict -> (execution time in seconds, info dict).

    Fidelity-aware evaluators additionally accept ``fidelity=`` (a fraction
    ``0 < f <= 1`` of the full per-trial budget — see
    :mod:`repro.core.fidelity`) and set ``supports_fidelity = True``; the
    scheduler only forwards the kwarg to evaluators that declare it, so a
    plain full-fidelity evaluator never sees it."""

    def __call__(self, config: Dict[str, Any]) -> Tuple[float, Dict[str, Any]]: ...


@dataclass
class Trial:
    config: Dict[str, Any]
    time_s: float
    info: Dict[str, Any] = field(default_factory=dict)
    wall_s: float = 0.0
    error: Optional[str] = None
    # fresh | cache (persistent) | prefilter (statically rejected) — memo
    # hits reuse the Trial
    source: str = "fresh"
    # ok | error | timeout | infeasible_static — timeouts are NOT generic
    # failures, and a statically-rejected config never ran at all
    status: str = "ok"
    fidelity: float = 1.0  # fraction of the full evaluation this trial paid

    @property
    def ok(self) -> bool:
        return self.error is None

    @property
    def timed_out(self) -> bool:
        return self.status == "timeout"

    @property
    def score(self) -> float:
        """What a strategy ranks on. A timeout Trial may carry its real
        measured ``time_s`` (kept for resume accounting and analysis), but a
        config that blows the deadline must never win the sweep — non-ok
        trials score as infeasible."""
        return self.time_s if self.ok else INFEASIBLE


def config_key(config: Dict[str, Any]) -> str:
    """Canonical JSON of the config — the memo/log identity of a trial."""
    return json.dumps(config, sort_keys=True, default=str)


def config_hash(config: Dict[str, Any]) -> str:
    """Short stable hash of :func:`config_key` — the persistent-cache key."""
    return hashlib.sha256(config_key(config).encode()).hexdigest()[:24]


def trial_key(config: Dict[str, Any], fidelity: float = 1.0) -> str:
    """Memo/log identity of a (config, fidelity) evaluation. Full fidelity
    is byte-identical to :func:`config_key` — pre-fidelity caches, memos,
    and logs keep their exact keys — while a low-rung evaluation gets a
    distinct identity so it can never replay as the full measurement."""
    key = config_key(config)
    if fidelity >= 1.0:
        return key
    return f"{key}|fidelity={fidelity:g}"


def trial_hash(config: Dict[str, Any], fidelity: float = 1.0) -> str:
    """Persistent-cache key for a (config, fidelity) evaluation; equals
    :func:`config_hash` at full fidelity."""
    return hashlib.sha256(trial_key(config, fidelity).encode()).hexdigest()[:24]


# legacy name used by the old cmpe module
_key = config_key


class TrialScheduler:
    """Batched trial executor with memoization, persistence, and pruning.

    ``max_workers=1`` (the default) reproduces the old CMPE behaviour
    byte-for-byte: serial evaluation in ask order, identical log records.
    """

    def __init__(
        self,
        evaluator: Evaluator,
        *,
        platform: str = "train",
        log_path: Optional[Path] = None,
        clear_caches_between_trials: bool = False,
        max_workers: int = 1,
        cache_path: Optional[Path] = None,
        timeout_s: Optional[float] = None,
        retries: int = 0,
        infeasible_time: float = INFEASIBLE,
        isolation: str = "inline",
        pin_devices: Optional[int] = None,
        backend: Optional[Any] = None,
        prefilter: Optional[Any] = None,
    ):
        self.evaluator = evaluator
        self.platform = platform
        # static feasibility gate: a mode string ("off"/"static") or any
        # callable (config, platform, fidelity) -> Optional[Rejection];
        # None/off = every config runs
        if isinstance(prefilter, str):
            from repro.core.feasibility import make_prefilter

            prefilter = make_prefilter(prefilter)
        self.prefilter = prefilter
        self.log_path = Path(log_path) if log_path else None
        self.clear_caches = clear_caches_between_trials
        self.max_workers = max(1, int(max_workers))
        self.timeout_s = timeout_s
        self.retries = max(0, int(retries))
        self.infeasible_time = infeasible_time
        self.trials: List[Trial] = []
        self._memo: Dict[str, Trial] = {}
        self._log_lock = threading.Lock()
        self._batch_tag = ""  # provenance stamped into persisted records
        # async submit/poll state: tickets are handed out in submission
        # order; a completion resolves every ticket of its trial key at once
        self._next_ticket = 0
        self._ready: List[Tuple[int, Trial]] = []
        self._inflight: Dict[str, List[int]] = {}
        self._inflight_info: Dict[str, Tuple[Dict[str, Any], float, str]] = {}
        # cache-accounting counters (the engine tests assert on these)
        self.fresh_evaluations = 0
        self.memo_hits = 0
        self.cache_hits = 0
        # outcome counters — timeouts (incl. abandoned hung threads) are
        # reported distinctly, not folded into the generic failure count
        self.timeout_trials = 0
        self.error_trials = 0
        # configs the static prefilter rejected at propose time — they never
        # charged a worker and are excluded from every evaluation count
        self.infeasible_static = 0
        if self.log_path:
            self.log_path.parent.mkdir(parents=True, exist_ok=True)
        self.cache_path = Path(cache_path) if cache_path else None
        self._persistent: Dict[str, Dict[str, Any]] = {}
        if self.cache_path:
            self._persistent = _load_cache(self.cache_path, self.platform)
            self.cache_path.parent.mkdir(parents=True, exist_ok=True)
        if backend is None:
            # local import: executors imports Trial from this module
            from repro.core.executors import make_backend

            options: Dict[str, Any] = {}
            if pin_devices is not None:
                if isolation not in ("subprocess", "process"):
                    raise ValueError(
                        "pin_devices requires isolation='subprocess' — the "
                        "inline thread path shares one jax runtime and "
                        "cannot re-pin devices per trial"
                    )
                options["pin_devices"] = pin_devices
            backend = make_backend(isolation, **options)
        self.isolation = getattr(backend, "name", isolation)
        self._backend = backend
        self._backend.bind(self)

    # ------------------------------------------------------------------- api

    def evaluate(
        self, config: Dict[str, Any], tag: str = "", fidelity: float = 1.0
    ) -> float:
        """Tune the platform to ``config``, run the job, return execution
        time. Logs every call (the one-trial path the old CMPE exposed).

        The scalar return is a *rankable score*: a trial that completed over
        the deadline keeps its real measurement on the Trial (and in the
        cache) but scores as ``infeasible_time`` here, so legacy callers
        comparing bare floats never crown a deadline-busting config."""
        trial = self.evaluate_batch([config], tag=tag, fidelity=fidelity)[0]
        return self.infeasible_time if trial.timed_out else trial.time_s

    def evaluate_batch(
        self, configs: Sequence[Dict[str, Any]], tag: str = "",
        fidelity: float = 1.0,
    ) -> List[Trial]:
        """Evaluate a batch at one ``fidelity``, returning one Trial per
        config **in input order**. Duplicates (within the batch or vs.
        earlier batches) are served from the memo; persistent-cache hits
        cost nothing fresh. Fidelity is part of a trial's identity: a
        low-rung record never replays as the full-fidelity measurement (and
        vice versa)."""
        self._batch_tag = tag
        keys = [trial_key(c, fidelity) for c in configs]
        plan: List[Tuple[str, Dict[str, Any]]] = []  # unique keys needing a run
        first_served = set()  # keys whose first occurrence is logged below
        for k, c in zip(keys, configs):
            if k in self._memo or k in first_served:
                continue
            if self._replay(c, fidelity, tag) is None:
                rejection = self._prefilter_check(c, fidelity)
                if rejection is not None:
                    self._reject(c, fidelity, tag, rejection)
                else:
                    plan.append((k, c))
            first_served.add(k)

        if plan:
            # how/where fresh trials run is the backend's business: inline
            # (threads, soft timeouts) or subprocess (hard SIGKILL deadlines)
            fresh = self._backend.run_batch(plan, fidelity=fidelity)
            for k, trial in fresh:
                self.fresh_evaluations += 1
                if trial.timed_out:
                    self.timeout_trials += 1
                elif not trial.ok:
                    self.error_trials += 1
                self.trials.append(trial)
                self._memo[k] = trial
                # successful trials were already persisted the moment they
                # completed (inside _run_one) — a mid-batch crash loses nothing
                self._log(trial, tag=tag, cached=False)

        out: List[Trial] = []
        for k in keys:
            trial = self._memo[k]
            out.append(trial)
            if k in first_served:
                first_served.discard(k)  # first occurrence logged above
            else:  # repeat of this batch or of an earlier one — memo hit
                self.memo_hits += 1
                self._log(trial, tag=tag, cached=True)
        return out

    def _replay(
        self, config: Dict[str, Any], fidelity: float, tag: str
    ) -> Optional[Trial]:
        """Serve one (config, fidelity) from the persistent cache if it is
        there. The replay preserves the measurement but re-judges a persisted
        over-deadline record against THIS session's (rung-scaled) deadline: a
        cache written under a tight timeout must not permanently poison
        configs whose measured wall now fits."""
        hit = self._persistent.get(trial_hash(config, fidelity))
        if hit is None:
            return None
        status = hit.get("status", "ok")
        error = hit.get("error")
        if status == "infeasible_static" and self.prefilter is None:
            # the gate's verdicts bind only while the gate is on: a session
            # running --prefilter off measures the config for real instead
            # of replaying another session's static rejection
            return None
        if status == "timeout":
            deadline = self._deadline_for(fidelity)
            rec_wall = float(hit.get("wall_s", INFEASIBLE))
            if deadline is None or rec_wall <= deadline:
                status, error = "ok", None
        trial = Trial(
            dict(config), float(hit["time_s"]), dict(hit.get("info", {})),
            wall_s=0.0, source="cache", error=error, status=status,
            fidelity=float(hit.get("fidelity", 1.0)),
        )
        self.cache_hits += 1
        if trial.status == "infeasible_static":
            # a replayed rejection still isn't an evaluation — keep the
            # counter in step so the accounting subtraction stays exact
            self.infeasible_static += 1
        self.trials.append(trial)
        self._memo[trial_key(config, fidelity)] = trial
        self._log(trial, tag=tag, cached=True)
        return trial

    def _prefilter_check(self, config: Dict[str, Any], fidelity: float):
        """Run the static feasibility gate on one proposal (None = passes)."""
        if self.prefilter is None:
            return None
        return self.prefilter(config, self.platform, fidelity)

    def _reject(
        self, config: Dict[str, Any], fidelity: float, tag: str, rejection
    ) -> Trial:
        """Record one statically-rejected proposal: an
        ``status="infeasible_static"`` trial carrying the machine-readable
        rule + evidence, memoized, persisted (it replays on resume) and
        logged — but never dispatched to a worker and never counted as an
        evaluation. Strategies rank it by ``Trial.score`` = infeasible, so
        TPE/CRS steer away and ASHA never promotes it."""
        trial = Trial(
            dict(config), INFEASIBLE,
            {"prefilter_rule": rejection.rule, **rejection.detail},
            wall_s=0.0, source="prefilter",
            error=f"InfeasibleStatic[{rejection.rule}]: {rejection.reason}",
            status="infeasible_static", fidelity=fidelity,
        )
        self.infeasible_static += 1
        self.trials.append(trial)
        self._memo[trial_key(config, fidelity)] = trial
        self._persist(trial, tag=tag)
        self._log(trial, tag=tag, cached=False)
        return trial

    def _deadline_for(self, fidelity: float) -> Optional[float]:
        """Effective per-trial deadline: ``timeout_s`` is the budget of a
        FULL-fidelity trial; a low-rung trial gets a proportionally shorter
        one (a rung-0 trial inheriting the full deadline would defeat
        successive halving)."""
        if self.timeout_s is None:
            return None
        return self.timeout_s * min(max(float(fidelity), 0.0), 1.0)

    # ----------------------------------------------------- async submit/poll

    def submit(
        self, config: Dict[str, Any], tag: str = "", fidelity: float = 1.0
    ) -> int:
        """Enqueue one (config, fidelity) evaluation without waiting for it;
        returns a ticket :meth:`poll` resolves. This is the streaming seam
        under asynchronous strategies (ASHA): results come back as each
        trial finishes, never behind a batch barrier.

        Memo and persistent-cache hits resolve immediately (the next poll
        returns them without touching the backend). A key already in flight
        is not resubmitted — every duplicate ticket resolves with the first
        run's Trial, and duplicates are accounted as memo hits when they
        resolve."""
        ticket = self._next_ticket
        self._next_ticket += 1
        key = trial_key(config, fidelity)
        trial = self._memo.get(key)
        if trial is not None:
            self.memo_hits += 1
            self._log(trial, tag=tag, cached=True)
            self._ready.append((ticket, trial))
            return ticket
        if key in self._inflight:
            self._inflight[key].append(ticket)
            return ticket
        trial = self._replay(config, fidelity, tag)
        if trial is not None:
            self._ready.append((ticket, trial))
            return ticket
        rejection = self._prefilter_check(config, fidelity)
        if rejection is not None:
            trial = self._reject(config, fidelity, tag, rejection)
            self._ready.append((ticket, trial))
            return ticket
        self._inflight[key] = [ticket]
        self._inflight_info[key] = (dict(config), fidelity, tag)
        self._backend.submit(key, dict(config), fidelity, tag)
        return ticket

    def poll(self, timeout: Optional[float] = None) -> List[Tuple[int, Trial]]:
        """Collect completed submissions as ``(ticket, Trial)`` pairs in
        completion order. Anything already resolved returns immediately;
        otherwise blocks up to ``timeout`` seconds (None = until at least one
        in-flight trial completes). Empty list = nothing in flight, or the
        wait timed out."""
        out, self._ready = self._ready, []
        if self._inflight:
            completed = self._backend.poll(0.0 if out else timeout)
            for key, trial in completed:
                self.fresh_evaluations += 1
                if trial.timed_out:
                    self.timeout_trials += 1
                elif not trial.ok:
                    self.error_trials += 1
                self.trials.append(trial)
                self._memo[key] = trial
                _config, _fid, tag = self._inflight_info.pop(key)
                tickets = self._inflight.pop(key)
                self._log(trial, tag=tag, cached=False)
                out.append((tickets[0], trial))
                for t in tickets[1:]:  # duplicate submissions of this key
                    self.memo_hits += 1
                    self._log(trial, tag=tag, cached=True)
                    out.append((t, trial))
        return out

    def run_async(self, strategy, *, patience: Optional[int] = None):
        """Drive an asynchronous strategy (``wants_async = True``, e.g.
        ASHA) through :meth:`submit`/:meth:`poll`: jobs stream out as
        workers free up and results stream back one at a time — no round
        barrier, so a promotion can dispatch while its rung peers are still
        running.

        ``patience`` counts completed trials at the highest fidelity seen so
        far (not batches): the run stops once the best top-fidelity time has
        not improved in N of them. Comparisons are equal-fidelity only — a
        fast low-rung score never resets (or wins) the incumbent."""
        evals_before = self.num_evaluations - self.infeasible_static
        timeouts_before = self.timeout_trials
        inflight: Dict[int, Any] = {}
        best = INFEASIBLE
        top_fidelity = 0.0
        stale = 0
        stopped_early = False
        while inflight or (not stopped_early and not strategy.done):
            jobs: List[Any] = []
            if not stopped_early and not strategy.done:
                free = self.max_workers - len(inflight)
                jobs = strategy.next_jobs(free) if free > 0 else []
                for job in jobs:
                    ticket = self.submit(
                        job.config, tag=job.tag, fidelity=job.fidelity
                    )
                    inflight[ticket] = job
            if not inflight:
                break  # nothing running and nothing proposed: stuck guard
            for ticket, trial in self.poll(timeout=None):
                job = inflight.pop(ticket)
                strategy.on_result(job, trial)
                if not trial.ok:
                    continue
                if trial.fidelity > top_fidelity:
                    # first completion at a new top rung IS an improvement
                    top_fidelity, best, stale = trial.fidelity, trial.time_s, 0
                elif trial.fidelity == top_fidelity:
                    if trial.time_s < best:
                        best, stale = trial.time_s, 0
                    else:
                        stale += 1
                    if patience is not None and stale >= patience:
                        stopped_early = True  # drain in-flight, submit no more
        result = strategy.result()
        if hasattr(result, "evaluations"):
            # statically-rejected proposals are not evaluations
            result.evaluations = (
                self.num_evaluations - self.infeasible_static - evals_before
            )
        if hasattr(result, "stopped_early"):
            result.stopped_early = stopped_early
        if hasattr(result, "timeouts"):
            result.timeouts = self.timeout_trials - timeouts_before
        return result

    def run(
        self,
        strategy,
        *,
        batch_size: Optional[int] = None,
        patience: Optional[int] = None,
    ):
        """Drive an ask/tell strategy to completion (or early stop).

        ``patience=k`` prunes the sweep when the running best time has not
        improved for k consecutive batches — the grid-pass killer.

        Result accounting (``evaluations`` / ``timeouts``) reports **this
        run's deltas**, not scheduler-lifetime totals — a shared multi-cell
        scheduler must not inflate every cell's numbers.

        An asynchronous strategy (``wants_async = True``) is routed to
        :meth:`run_async` — same result stamping, streaming completion
        instead of round batches (``batch_size`` does not apply there;
        concurrency is ``max_workers``)."""
        if getattr(strategy, "wants_async", False):
            return self.run_async(strategy, patience=patience)
        evals_before = self.num_evaluations - self.infeasible_static
        timeouts_before = self.timeout_trials
        best = INFEASIBLE
        stale = 0
        stopped_early = False
        while not strategy.done:
            configs = strategy.ask(batch_size)
            if not configs:
                break
            trials = self.evaluate_batch(configs, tag=strategy.tag)
            strategy.tell(trials)
            batch_best = min(
                (t.time_s for t in trials if t.ok), default=INFEASIBLE
            )
            if batch_best < best:
                best = batch_best
                stale = 0
            else:
                stale += 1
            if patience is not None and stale >= patience:
                stopped_early = True
                break
        result = strategy.result()
        if hasattr(result, "evaluations"):
            # statically-rejected proposals are not evaluations
            result.evaluations = (
                self.num_evaluations - self.infeasible_static - evals_before
            )
        if hasattr(result, "stopped_early"):
            result.stopped_early = stopped_early
        if hasattr(result, "timeouts"):
            result.timeouts = self.timeout_trials - timeouts_before
        return result

    def best(self) -> Trial:
        """Best successful trial **at the highest fidelity any successful
        trial reached** — a fast low-rung measurement is a different (cheaper)
        experiment and must never be crowned over full measurements."""
        ok = [t for t in self.trials if t.ok]
        if not ok:
            raise RuntimeError("no successful trials")
        top = max(t.fidelity for t in ok)
        return min((t for t in ok if t.fidelity == top), key=lambda t: t.time_s)

    def close(self) -> None:
        """Release backend resources (warm subprocess workers). Idempotent;
        a no-op for the inline backend."""
        self._backend.close()

    def __enter__(self) -> "TrialScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # best-effort — don't leak worker processes
        try:
            backend = getattr(self, "_backend", None)
            if backend is not None:
                backend.close()
        except Exception:  # noqa: BLE001
            pass

    @property
    def num_evaluations(self) -> int:
        return len(self.trials)

    def cache_stats(self) -> Dict[str, int]:
        return {
            "fresh": self.fresh_evaluations,
            "memo_hits": self.memo_hits,
            "cache_hits": self.cache_hits,
        }

    def run_stats(self) -> Dict[str, int]:
        """Cache accounting plus trial outcomes — the run-summary block."""
        return {
            **self.cache_stats(),
            "trials": self.num_evaluations,
            "timeouts": self.timeout_trials,
            "errors": self.error_trials,
            "infeasible_static": self.infeasible_static,
        }

    def stats_snapshot(self) -> Dict[str, int]:
        """Point-in-time counters for per-session delta accounting: a Study
        (or the tune shim) subtracts two snapshots so a shared multi-session
        scheduler reports each session's own numbers, never lifetime totals.
        Same counters as :meth:`run_stats` under the outcome-facing name —
        except ``evaluations`` excludes statically-rejected proposals (they
        never ran; they get their own ``infeasible_static`` counter)."""
        stats = self.run_stats()
        stats["evaluations"] = stats.pop("trials") - stats["infeasible_static"]
        return stats

    def cached_observations(
        self, with_platform: bool = False
    ) -> List[Tuple[Any, ...]]:
        """``(config, time_s, tag)`` triples from the persistent cache, this
        platform only, in file order — the warm-start history a model-based
        strategy (TPE) seeds its observation set from on resume. The tag
        carries provenance: a strategy charges only its *own* records against
        its trial budget and treats the rest as free model observations.
        Persisted timeout records are excluded — an over-deadline measurement
        must not feed a density model as if it were a clean observation.
        Sub-fidelity records (ASHA's low rungs) are excluded too: they live
        on a different time scale and would skew any model that mixed them
        with full measurements.

        ``with_platform=True`` appends each record's **stored** cell
        namespace as a fourth element. The stored namespace is the record's
        identity, not this scheduler's view of it: a legacy record with no
        platform field matched this scheduler's filter by default and reads
        back as ``None`` — callers bucketing records per cell (the cross-cell
        ``Study.histories_for``) must never attribute it to a real cell."""
        out: List[Tuple[Any, ...]] = []
        for rec in self._persistent.values():
            if "config" not in rec or "time_s" not in rec:
                continue
            if rec.get("status", "ok") != "ok":
                continue
            if float(rec.get("fidelity", 1.0)) < 1.0:
                continue
            row = (dict(rec["config"]), float(rec["time_s"]), rec.get("tag"))
            out.append(row + (rec.get("platform"),) if with_platform else row)
        return out

    # ------------------------------------------------------------- execution

    def _run_one(
        self, config: Dict[str, Any], fidelity: float = 1.0,
        tag: Optional[str] = None,
    ) -> Trial:
        """One fresh evaluation with retry + soft timeout + penalty. The
        result is persisted immediately (not at batch end), so a session
        killed mid-batch resumes from everything already evaluated. The
        soft deadline is rung-scaled: ``timeout_s × fidelity``."""
        t0 = time.time()
        deadline = self._deadline_for(fidelity)
        last_err = None
        for _attempt in range(self.retries + 1):
            try:
                t, info = call_evaluator(self.evaluator, config, fidelity)
                trial = Trial(dict(config), float(t), info,
                              wall_s=time.time() - t0, fidelity=fidelity)
                if deadline is not None and trial.wall_s > deadline:
                    # completed over the soft deadline: the measurement is
                    # real — keep and persist it (a resume must not re-pay
                    # it); status="timeout" lets strategies score it (they
                    # rank on Trial.score, which is infeasible for non-ok)
                    trial = Trial(
                        dict(config), float(t), info, wall_s=trial.wall_s,
                        error=f"TrialTimeout: wall {trial.wall_s:.1f}s > "
                              f"{deadline}s (soft; measurement kept)",
                        status="timeout", fidelity=fidelity,
                    )
                self._persist(trial, tag=tag)
                return trial
            except Exception as e:  # noqa: BLE001 — a failed run is a trial
                last_err = f"{type(e).__name__}: {e}"
        return Trial(
            dict(config), self.infeasible_time, {}, wall_s=time.time() - t0,
            error=last_err, status="error", fidelity=fidelity,
        )

    def _run_parallel(
        self, plan: List[Tuple[str, Dict[str, Any]]], fidelity: float = 1.0
    ) -> List[Tuple[str, Trial]]:
        """Fan the batch over a thread pool; a future that misses the hard
        deadline becomes an infeasible trial. The batch returns promptly
        regardless: queued futures are cancelled and a hung worker thread is
        abandoned, not joined (threads can't be killed — it still holds until
        interpreter exit; ``isolation="subprocess"`` kills for real).

        Deadline semantics: every trial gets ``timeout_s`` from the moment
        its thread actually *starts* — not from the previous ``result()``
        call (the old cumulative bug: N stragglers serialized into N×timeout
        wall clock), and not from batch start (which would falsely time out
        trials queued behind a full pool). A trial still queued once every
        pool slot has had a full timeout window (``timeout_s × ceil(N/W)``
        from batch start) is stuck behind hung threads and is cancelled. A
        started-then-abandoned thread that eventually completes has
        ``wall_s > timeout_s`` by construction, so its late ``_run_one``
        persist is the same measured-timeout record — never a conflicting
        ok record."""
        out: List[Tuple[str, Trial]] = []
        pool = ThreadPoolExecutor(max_workers=self.max_workers)
        starts: Dict[int, float] = {}  # future index -> monotonic start
        timeout_s = self._deadline_for(fidelity)  # rung-scaled deadline

        def timed(i: int, c: Dict[str, Any]) -> Trial:
            starts[i] = time.monotonic()
            return self._run_one(c, fidelity)

        batch_cap = (
            None if timeout_s is None
            else time.monotonic()
            + timeout_s * math.ceil(len(plan) / self.max_workers)
        )
        try:
            futures = [
                (i, k, c, pool.submit(timed, i, c))
                for i, (k, c) in enumerate(plan)
            ]
            for i, k, c, fut in futures:
                trial: Optional[Trial] = None
                while trial is None:
                    if timeout_s is None:
                        trial = fut.result()
                        break
                    now = time.monotonic()
                    t_start = starts.get(i)
                    if t_start is None:
                        if now >= batch_cap and fut.cancel():
                            trial = Trial(
                                dict(c), self.infeasible_time, {}, wall_s=0.0,
                                error="TrialTimeout: cancelled before start "
                                      "(batch cap exhausted by hung earlier "
                                      "trials)",
                                status="timeout", fidelity=fidelity,
                            )
                            break
                        wait = min(0.05, max(0.0, batch_cap - now))
                    else:
                        deadline_i = t_start + timeout_s
                        if now >= deadline_i:
                            trial = Trial(
                                dict(c), self.infeasible_time, {},
                                wall_s=timeout_s,
                                error="TrialTimeout: no result within "
                                      f"{timeout_s}s of start "
                                      "(worker thread abandoned)",
                                status="timeout", fidelity=fidelity,
                            )
                            break
                        wait = deadline_i - now
                    try:
                        trial = fut.result(timeout=wait)
                    except FutureTimeoutError:
                        continue  # re-evaluate start/deadline state
                    except CancelledError:
                        trial = Trial(
                            dict(c), self.infeasible_time, {}, wall_s=0.0,
                            error="TrialTimeout: cancelled before start "
                                  f"(batch deadline {timeout_s}s)",
                            status="timeout", fidelity=fidelity,
                        )
                out.append((k, trial))
        finally:
            # don't block on stragglers; drop whatever never started
            pool.shutdown(wait=False, cancel_futures=True)
        return out

    # ------------------------------------------------------------------- io

    def _persist(self, trial: Trial, tag: Optional[str] = None):
        # ok trials always persist; timeout trials persist only when they
        # carry a real finite measurement (a SIGKILLed / abandoned trial has
        # nothing worth replaying). Extra keys appear ONLY on non-ok or
        # sub-fidelity records, keeping full-fidelity ok-record bytes
        # identical to every cache written before.
        measured_timeout = trial.timed_out and math.isfinite(trial.time_s)
        rejected = trial.status == "infeasible_static"
        if not self.cache_path or not (trial.ok or measured_timeout or rejected):
            return
        rec = {
            "key": trial_hash(trial.config, trial.fidelity),
            "platform": self.platform,
            # which strategy/phase proposed this: async submissions carry
            # their own tag; the batch path stamps the batch's
            "tag": self._batch_tag if tag is None else tag,
            "ts": time.time(),
            "config": trial.config,
            "time_s": trial.time_s,
            "info": _scalar_info(trial.info),
        }
        if trial.fidelity < 1.0:
            rec["fidelity"] = trial.fidelity
        if not trial.ok:
            rec["status"] = trial.status
            rec["error"] = trial.error
            rec["wall_s"] = trial.wall_s  # replay re-judges vs. the live deadline
        with self._log_lock:
            self._persistent[rec["key"]] = rec
            with self.cache_path.open("a") as f:
                f.write(jsonl_line(rec) + "\n")

    def _log(self, trial: Trial, tag: str, cached: bool):
        if not self.log_path:
            return
        rec = {
            "ts": time.time(),
            "platform": self.platform,
            "tag": tag,
            "cached": cached,
            "config": trial.config,
            "time_s": trial.time_s,
            "wall_s": trial.wall_s,
            "error": trial.error,
            "status": trial.status,
            "source": trial.source,
            "info": _scalar_info(trial.info),
        }
        if trial.fidelity < 1.0:  # full-fidelity records keep legacy shape
            rec["fidelity"] = trial.fidelity
        with self._log_lock, self.log_path.open("a") as f:
            f.write(jsonl_line(rec) + "\n")


def _scalar_info(info: Dict[str, Any]) -> Dict[str, Any]:
    return {k: v for k, v in info.items() if isinstance(v, (int, float, str, bool))}


def call_evaluator(
    evaluator: Evaluator, config: Dict[str, Any], fidelity: float = 1.0
) -> Tuple[float, Dict[str, Any]]:
    """Invoke an evaluator, forwarding ``fidelity`` only when it declares
    ``supports_fidelity`` — a plain evaluator never sees the kwarg. A
    sub-fidelity request on a fidelity-blind evaluator runs the full
    evaluation (correct, just not cheaper); its Trial still records the
    requested fidelity so the cache identity stays consistent."""
    if fidelity < 1.0 and getattr(evaluator, "supports_fidelity", False):
        return evaluator(config, fidelity=fidelity)
    return evaluator(config)


# Non-finite floats (an infinite-p99 window, a score=inf containment) would
# serialize as bare ``Infinity``/``NaN`` tokens — Python extensions that are
# NOT JSON (RFC 8259) and break any strict reader. Records are sanitized to
# string sentinels on write and decoded back to floats in ``iter_jsonl``.
_NONFINITE_SENTINELS = {
    "Infinity": math.inf,
    "-Infinity": -math.inf,
    "NaN": math.nan,
}


def sanitize_nonfinite(obj: Any) -> Any:
    """Deep-copy ``obj`` with every non-finite float replaced by its string
    sentinel (``"Infinity"``/``"-Infinity"``/``"NaN"``)."""
    if isinstance(obj, float) and not math.isfinite(obj):
        if math.isnan(obj):
            return "NaN"
        return "Infinity" if obj > 0 else "-Infinity"
    if isinstance(obj, dict):
        return {k: sanitize_nonfinite(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize_nonfinite(v) for v in obj]
    return obj


def restore_nonfinite(obj: Any) -> Any:
    """Inverse of :func:`sanitize_nonfinite`: exact sentinel strings become
    the non-finite floats they stand for."""
    if isinstance(obj, str):
        return _NONFINITE_SENTINELS.get(obj, obj)
    if isinstance(obj, dict):
        return {k: restore_nonfinite(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [restore_nonfinite(v) for v in obj]
    return obj


def jsonl_line(rec: Dict[str, Any]) -> str:
    """One strictly-RFC-8259 JSONL line for ``rec`` (no trailing newline):
    non-finite floats sanitized to sentinels, everything non-JSON stringified.
    ``allow_nan=False`` makes any unsanitized leak a hard error here, at the
    writer, instead of a corrupt line some later reader chokes on."""
    return json.dumps(sanitize_nonfinite(rec), default=str, allow_nan=False)


def iter_jsonl(path: Path) -> List[Dict[str, Any]]:
    """Parse a JSONL records file, tolerating the torn tail line a crashed
    session can leave behind — the one parser under the eval cache, the trial
    log, and the Study accessors. Non-finite sentinel strings written by
    :func:`jsonl_line` (and the bare ``Infinity``/``NaN`` tokens of records
    written before it existed) decode back to their floats."""
    out: List[Dict[str, Any]] = []
    path = Path(path)
    if not path.exists():
        return out
    for line in path.read_text().splitlines():
        if not line.strip():
            continue
        try:
            out.append(restore_nonfinite(json.loads(line)))
        except json.JSONDecodeError:
            continue  # torn tail write from a crashed session
    return out


def _load_cache(path: Path, platform: str) -> Dict[str, Dict[str, Any]]:
    """Load a JSONL evaluation cache (last record per key wins). Records are
    namespaced by platform so one shared file serves a multi-cell session."""
    return {
        rec["key"]: rec for rec in iter_jsonl(path)
        if rec.get("platform", platform) == platform and "key" in rec
    }


def read_cache_by_platform(path: Path) -> Dict[str, Dict[str, Dict[str, Any]]]:
    """One pass over a shared evaluation cache, grouped by each record's
    **stored** platform namespace: ``{namespace: {key: record}}``.

    This is the cross-cell read under ``Study.histories_for``: grouping is by
    the namespace string the record was *written* with, so ``train/a:s`` and
    its ``train/a:s@512c`` chip-count variant land in separate buckets
    (PR-4's topology keying), and legacy records with no platform field —
    which ``_load_cache`` would have matched against ANY platform — are
    collected under ``""`` rather than attributed to a real cell. Per bucket,
    the last record per key wins but keeps its first-write position, so a
    bucket's iteration order is the append order the sibling session produced
    (resume replays a recorded prefix of it)."""
    grouped: Dict[str, Dict[str, Dict[str, Any]]] = {}
    for rec in iter_jsonl(path):
        if "key" not in rec:
            continue
        ns = rec.get("platform") or ""
        grouped.setdefault(ns, {})[rec["key"]] = rec
    return grouped


def read_log(path: Path, platform: Optional[str] = None) -> List[Dict[str, Any]]:
    """Recover trials from a scheduler log file (the paper's 'analyzing the
    log file helps in finding the optimal configuration').

    Tolerates a torn tail line from a crashed session (like ``_load_cache``)
    and, given ``platform``, filters a shared multi-cell log down to one
    cell's records (legacy records without a platform field are kept). A
    missing file raises (a typo'd path must not read as an empty log)."""
    path = Path(path)
    if not path.exists():
        raise FileNotFoundError(f"no trial log at {path}")
    return [
        rec for rec in iter_jsonl(path)
        if platform is None or rec.get("platform", platform) == platform
    ]


def best_from_log(path: Path, platform: Optional[str] = None) -> Dict[str, Any]:
    """Best successful record at the **highest fidelity the log reached** —
    an ASHA log mixes rungs, and a fast low-rung time (a cheaper experiment
    on a different scale) must never read as the incumbent."""
    recs = [r for r in read_log(path, platform=platform)
            if r.get("error") is None]
    if not recs:
        where = f"{path}" + (f" (platform={platform!r})" if platform else "")
        raise ValueError(f"no successful trials in log {where}")
    top = max(float(r.get("fidelity", 1.0)) for r in recs)
    return min((r for r in recs if float(r.get("fidelity", 1.0)) == top),
               key=lambda r: r["time_s"])
