"""Algorithm I — Grid Search with Finer Tuning (paper §VIII), faithful.

Phase 1 (grid): evenly-stepped samples of each *active* parameter (the paper
shortlists 5 of the 12 Hadoop knobs for the grid because 10^12 cells is
infeasible — we keep the same device), full cartesian product, every cell
evaluated through the CMPE.

Phase 2 (finer tuning): for each *most-influential* parameter, re-sample a
tighter grid around the phase-1 optimum using the paper's bound arithmetic

    new_lower = best_value − old_lower / 2
    new_upper = best_value + old_lower / 2
    increment = new_lower / 2

(idiosyncratic — the finer window and step derive from the *old lower bound* —
but reproduced exactly; bounds are snapped back into each parameter's legal
range/step). All non-influential parameters are pinned at their phase-1 best.
Complexity O(n·m + k) evaluations, as stated in the paper.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.cmpe import CMPE
from repro.core.space import Param, TunableSpace


@dataclass
class GridResult:
    best_config: Dict[str, Any]
    best_time: float
    phase1_best: Dict[str, Any]
    phase1_time: float
    evaluations: int
    grid_sizes: Dict[str, int] = field(default_factory=dict)


def _param_grid_list(param_grid: Dict[str, List[Any]]) -> List[Dict[str, Any]]:
    names = list(param_grid)
    out = []
    for combo in itertools.product(*(param_grid[n] for n in names)):
        out.append(dict(zip(names, combo)))
    return out


def grid_search_finer_tuning(
    space: TunableSpace,
    cmpe: CMPE,
    *,
    active_params: Optional[Sequence[str]] = None,
    fixed: Optional[Dict[str, Any]] = None,
    samples_per_param: int = 3,
    most_influential: Optional[Sequence[str]] = None,
    finer_samples: int = 5,
) -> GridResult:
    """Run Algorithm I. ``active_params``: knobs swept in the coarse grid
    (default: the space's most-influential set plus any categorical knobs
    worth a single extra axis is left to the caller — mirroring the paper's
    manual shortlist). ``fixed``: knobs pinned to known-good values up front
    (the paper pins dfs.replication=1, map.output.compress=TRUE)."""
    defaults = space.defaults()
    fixed = dict(fixed or {})
    active = list(active_params or space.most_influential)
    influential = list(most_influential or space.most_influential)

    # ---- Phase 1: evenly-stepped coarse grid over the active knobs
    param_grid: Dict[str, List[Any]] = {}
    for name in active:
        param_grid[name] = space.param(name).grid(samples_per_param)

    base = {**defaults, **fixed}
    best_config, min_time = None, float("inf")
    for cell in _param_grid_list(param_grid):
        config = {**base, **cell}
        t = cmpe.evaluate(config, tag="gsft/grid")
        if t < min_time:
            min_time, best_config = t, config
    phase1_best, phase1_time = dict(best_config), min_time

    # ---- Phase 2: finer tuning around the best along the influential knobs
    new_param_grid: Dict[str, List[Any]] = {}
    for name in influential:
        p = space.param(name)
        if not p.numeric or name not in param_grid:
            # categorical influential knobs keep their full choice set
            new_param_grid[name] = p.grid(finer_samples)
            continue
        old_lower = float(param_grid[name][0])
        best_value = float(best_config[name])
        new_lower = best_value - old_lower / 2.0
        new_upper = best_value + old_lower / 2.0
        increment = max(new_lower / 2.0, 1e-9)
        new_param_grid[name] = p.grid_between(new_lower, new_upper, increment)

    # pin everything else at the phase-1 optimum (paper: "if param not in
    # most_influential: new_param_grid[param] = best_config[param]")
    pinned = {k: v for k, v in best_config.items() if k not in new_param_grid}

    for cell in _param_grid_list(new_param_grid):
        config = {**pinned, **cell}
        t = cmpe.evaluate(config, tag="gsft/finer")
        if t < min_time:
            min_time, best_config = t, config

    return GridResult(
        best_config=best_config,
        best_time=min_time,
        phase1_best=phase1_best,
        phase1_time=phase1_time,
        evaluations=cmpe.num_evaluations,
        grid_sizes={k: len(v) for k, v in {**param_grid, **new_param_grid}.items()},
    )
