"""Algorithm I — Grid Search with Finer Tuning (paper §VIII), faithful.

Back-compat wrapper: the algorithm now lives in
:class:`repro.core.strategies.gsft.GridFinerStrategy` (ask/tell) and runs
through the :class:`~repro.core.scheduler.TrialScheduler`. Calling this
function with a plain serial CMPE reproduces the legacy evaluation order,
tags, and result exactly; calling it with a parallel/cached scheduler gets
the engine features without touching the algorithm.

The paper's phase arithmetic (finer window and step derived from the *old
lower bound* — idiosyncratic but reproduced exactly) is documented in the
strategy module. Complexity O(n·m + k) evaluations, as stated in the paper.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

from repro.core.scheduler import TrialScheduler
from repro.core.space import TunableSpace
from repro.core.strategies.gsft import GridFinerStrategy, GridResult  # noqa: F401


def grid_search_finer_tuning(
    space: TunableSpace,
    cmpe: TrialScheduler,
    *,
    active_params: Optional[Sequence[str]] = None,
    fixed: Optional[Dict[str, Any]] = None,
    samples_per_param: int = 3,
    most_influential: Optional[Sequence[str]] = None,
    finer_samples: int = 5,
    batch_size: Optional[int] = None,
    patience: Optional[int] = None,
) -> GridResult:
    """Run Algorithm I. ``active_params``: knobs swept in the coarse grid
    (default: the space's most-influential set — mirroring the paper's manual
    shortlist). ``fixed``: knobs pinned to known-good values up front (the
    paper pins dfs.replication=1, map.output.compress=TRUE)."""
    strategy = GridFinerStrategy(
        space,
        active_params=active_params,
        fixed=fixed,
        samples_per_param=samples_per_param,
        most_influential=most_influential,
        finer_samples=finer_samples,
    )
    return cmpe.run(strategy, batch_size=batch_size, patience=patience)
