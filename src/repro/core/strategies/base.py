"""The ask/tell ``Strategy`` protocol every search algorithm implements.

A strategy never runs a trial itself. It *asks* for a batch of candidate
configurations, the :class:`~repro.core.scheduler.TrialScheduler` evaluates
them (possibly concurrently, possibly from cache), and *tells* the results
back. Control flow that used to be welded into each algorithm's module
(`grid_finer`, `crs`, the hillclimb driver) becomes a state machine the one
shared engine drives — so a new optimizer (Bayesian, online, co-tuning) is a
new Strategy subclass and nothing else.

Contract
  - ``ask(n)`` returns up to ``n`` configs (all remaining when ``n`` is
    None). A batch never spans algorithm phases, so ``tag`` is constant per
    batch and log parity with the legacy serial drivers holds.
  - ``tell(trials)`` receives Trials aligned 1:1, in order, with the configs
    of the preceding ``ask``.
  - ``done`` flips once the strategy has nothing left to propose.
  - ``result()`` may be called at any time (early stop) and returns the
    best-so-far summary object.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Protocol, Sequence, runtime_checkable

from repro.core.scheduler import Trial


@runtime_checkable
class Strategy(Protocol):
    tag: str

    @property
    def done(self) -> bool: ...

    def ask(self, n: Optional[int] = None) -> List[Dict[str, Any]]: ...

    def tell(self, trials: Sequence[Trial]) -> None: ...

    def result(self) -> Any: ...


class QueueStrategy:
    """Shared plumbing: a pending queue + outstanding counter. Subclasses
    fill ``self._pending`` and override ``_on_batch_done`` to advance their
    phase machine once every asked config has been told back."""

    tag = "strategy"
    # model-based strategies set True to receive the study's cached
    # observation history (via on_study_attach, or a legacy ``history``
    # constructor kwarg if the hook is not overridden)
    supports_history = False
    # strategies set True to receive sibling-cell histories through the
    # ``siblings=`` channel of on_study_attach (the cross-cell transfer
    # seam) — the engine only passes the transfer kwargs to strategies that
    # declare it, so legacy single-argument hooks keep working
    supports_transfer = False
    # which transfer modes the strategy actually implements; a requested
    # mode outside this set is downgraded to the last supported one and the
    # session records the EFFECTIVE mode (asking gsft for "prior" runs — and
    # reports — its "warm" seeding, never a prior that doesn't exist)
    transfer_modes: tuple = ()
    # name of the constructor kwarg that Study.optimize(budget=N) maps onto
    # (e.g. TPE's "max_trials"); None = the strategy has no trial budget
    budget_kwarg: Optional[str] = None

    def __init__(self):
        self._pending: List[Dict[str, Any]] = []
        self._outstanding = 0
        self._finished = False

    def on_study_attach(
        self,
        history: Sequence[Any],
        siblings: Optional[Sequence[Any]] = None,
        transfer: str = "off",
    ) -> None:
        """Sanctioned seam for study/cross-session state: ``history`` is the
        prior ``(config, time_s[, tag])`` observations from the study's
        persistent cache (this platform only, file order). Called once,
        after construction and before the first ``ask`` — a warm-starting
        strategy (TPE) ingests it here instead of reaching into scheduler
        internals.

        ``siblings`` is the cross-cell transfer channel: a ranked sequence of
        :class:`~repro.core.transfer.SiblingHistory` records (closest cell
        first) that ``Study``/``run_session`` feed when a session runs with
        ``transfer != "off"`` — and only to strategies that declare
        ``supports_transfer``. ``transfer`` names the mode the caller asked
        for (``"warm"``: seed initial candidates from sibling incumbents;
        ``"prior"``: ingest sibling observations as a discounted model
        prior). Sibling evidence must NEVER count toward a strategy's trial
        budget. Default: ignore everything."""
        return None

    @property
    def done(self) -> bool:
        return self._finished

    def ask(self, n: Optional[int] = None) -> List[Dict[str, Any]]:
        take = len(self._pending) if n is None else min(int(n), len(self._pending))
        out, self._pending = self._pending[:take], self._pending[take:]
        self._outstanding += len(out)
        return out

    def tell(self, trials: Sequence[Trial]) -> None:
        for trial in trials:
            self._outstanding -= 1
            self._observe(trial)
        if not self._pending and self._outstanding <= 0 and not self._finished:
            self._on_batch_done()

    # -- subclass hooks

    def _observe(self, trial: Trial) -> None:
        raise NotImplementedError

    def _on_batch_done(self) -> None:
        """Called when the current phase's queue is drained; either refill
        ``self._pending`` (next phase / round) or set ``self._finished``."""
        self._finished = True


# ---------------------------------------------------------------- registry

STRATEGIES: Dict[str, Callable[..., Strategy]] = {}


def register_strategy(*names: str):
    def deco(factory):
        for n in names:
            STRATEGIES[n] = factory
        return factory

    return deco


def make_strategy(name: str, space, **kwargs) -> Strategy:
    try:
        factory = STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r} (known: {sorted(STRATEGIES)})"
        ) from None
    return factory(space, **kwargs)
