"""Pluggable ask/tell search strategies for the tuning engine.

``make_strategy(name, space, **kwargs)`` builds a registered strategy; the
:class:`~repro.core.scheduler.TrialScheduler` drives it:

    strategy = make_strategy("gsft", space, active_params=[...])
    result = scheduler.run(strategy, batch_size=8, patience=3)

Registered: ``gsft``/``grid`` (Algorithm I), ``crs`` (Algorithm II),
``hillclimb`` (curated §Perf moves), ``tpe``/``bayes`` (Tree-structured
Parzen Estimator with batched acquisition), ``random`` (streaming baseline),
``asha`` (asynchronous successive halving over any inner proposer). New
optimizers register with ``@register_strategy("name")`` and implement
ask/tell — no executor changes.
"""
from repro.core.strategies.base import (
    STRATEGIES,
    QueueStrategy,
    Strategy,
    make_strategy,
    register_strategy,
)
from repro.core.strategies.asha import AshaResult, AshaStrategy, AsyncJob
from repro.core.strategies.crs import CRSResult, CRSStrategy
from repro.core.strategies.gsft import GridFinerStrategy, GridResult
from repro.core.strategies.hillclimb import (
    CuratedHillclimbStrategy,
    HillclimbResult,
    Move,
)
from repro.core.strategies.random_search import RandomResult, RandomStrategy
from repro.core.strategies.tpe import TPEResult, TPEStrategy

__all__ = [
    "AshaResult",
    "AshaStrategy",
    "AsyncJob",
    "CRSResult",
    "CRSStrategy",
    "CuratedHillclimbStrategy",
    "GridFinerStrategy",
    "GridResult",
    "HillclimbResult",
    "Move",
    "QueueStrategy",
    "RandomResult",
    "RandomStrategy",
    "STRATEGIES",
    "Strategy",
    "TPEResult",
    "TPEStrategy",
    "make_strategy",
    "register_strategy",
]
