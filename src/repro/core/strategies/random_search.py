"""Pure random search — the streaming baseline proposer.

Unlike the round-based strategies (CRS draws a round before consuming any
result; TPE refills per acquisition round), random search has no round
structure at all: ``ask(n)`` draws the next ``n`` fresh configurations on
demand, so an asynchronous driver can keep every worker busy without a
refill barrier. That makes it the default *inner* proposer under
:class:`~repro.core.strategies.asha.AshaStrategy` — and a useful control in
strategy shootouts (any model-based proposer should beat it).

The proposal stream is a pure function of ``seed``: draws consume the rng in
ask order and de-duplication is by the canonical config key of *proposed*
configs only (never by results), so two runs with the same seed propose the
same sequence regardless of completion order or parallelism.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from repro.core.scheduler import Trial, config_key
from repro.core.space import TunableSpace
from repro.core.strategies.base import QueueStrategy, register_strategy


@dataclass
class RandomResult:
    best_config: Optional[Dict[str, Any]]
    best_time: float
    evaluations: int
    proposals: int
    timeouts: int = 0
    stopped_early: bool = False


@register_strategy("random")
class RandomStrategy(QueueStrategy):
    tag = "random"
    budget_kwarg = "max_trials"

    def __init__(
        self,
        space: TunableSpace,
        *,
        fixed: Optional[Dict[str, Any]] = None,
        max_trials: int = 48,
        seed: int = 0,
    ):
        super().__init__()
        self.space = space
        self.fixed = dict(fixed or {})
        self.max_trials = int(max_trials)
        self.rng = random.Random(seed)
        self._proposed = 0
        self._seen: set = set()
        self.best_config: Optional[Dict[str, Any]] = None
        self.best_time = float("inf")

    def _draw(self) -> Dict[str, Any]:
        cfg = {p.name: p.sample(self.rng) for p in self.space.params}
        return {**cfg, **self.fixed}

    def ask(self, n: Optional[int] = None) -> List[Dict[str, Any]]:
        budget = self.max_trials - self._proposed
        want = budget if n is None else min(int(n), budget)
        out: List[Dict[str, Any]] = []
        attempts = 0
        while len(out) < want and attempts < max(50, want * 50):
            attempts += 1
            cfg = self._draw()
            key = config_key(cfg)
            if key in self._seen:
                continue  # tiny spaces exhaust; keep drawing, bounded above
            self._seen.add(key)
            out.append(cfg)
        self._proposed += len(out)
        self._outstanding += len(out)
        return out

    @property
    def done(self) -> bool:
        return self._finished or (
            self._proposed >= self.max_trials and self._outstanding <= 0
        )

    # -- QueueStrategy hooks

    def _observe(self, trial: Trial) -> None:
        if trial.score < self.best_time:
            self.best_time = trial.score
            self.best_config = dict(trial.config)

    def _on_batch_done(self) -> None:
        if self._proposed >= self.max_trials:
            self._finished = True

    def result(self) -> RandomResult:
        return RandomResult(
            best_config=self.best_config,
            best_time=self.best_time,
            evaluations=0,  # stamped by the scheduler (run delta)
            proposals=self._proposed,
        )
