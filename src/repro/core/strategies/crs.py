"""Algorithm II — Controlled Random Search (paper §IX, after W.L. Price) as
an ask/tell strategy. Draw semantics, bound contraction, categorical
freezing, and the stop rule match the legacy serial implementation exactly:
all of a round's draws are generated before any result is consumed, so the
rng stream is identical whether trials run serially or in parallel."""
from __future__ import annotations

import random
from collections import Counter
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.scheduler import Trial
from repro.core.space import TunableSpace
from repro.core.strategies.base import QueueStrategy, register_strategy


@dataclass
class CRSResult:
    best_config: Dict[str, Any]
    best_time: float
    rounds: int
    evaluations: int
    bound_history: List[Dict[str, Any]] = field(default_factory=list)
    stopped_early: bool = False


def _random_config(space, bounds, frozen, rng) -> Dict[str, Any]:
    cfg = {}
    for p in space.params:
        if p.name in frozen:
            cfg[p.name] = frozen[p.name]
        elif p.numeric:
            lo, hi = bounds[p.name]
            cfg[p.name] = p.sample(rng, lo, hi)
        else:
            cfg[p.name] = p.sample(rng)
    return cfg


@register_strategy("crs")
class CRSStrategy(QueueStrategy):
    """Cross-cell transfer (``supports_transfer``) is the cheap ``warm``
    mode: sibling incumbents, snapped into this cell's space, join round 0's
    draws — a transferring optimum survives the round and pulls the bound
    contraction toward itself; a non-transferring one is just one more draw
    that the survivor cut discards."""

    supports_transfer = True
    transfer_modes = ("warm",)

    def __init__(
        self,
        space: TunableSpace,
        *,
        fixed: Optional[Dict[str, Any]] = None,
        m: int = 12,
        k: int = 4,
        threshold: float = 0.0,
        max_rounds: int = 6,
        seed: int = 0,
    ):
        super().__init__()
        self.space = space
        self.fixed = dict(fixed or {})
        self.m, self.k = m, k
        self.threshold = threshold
        self.max_rounds = max_rounds
        self.rng = random.Random(seed)

        self._numeric = [
            p for p in space.params if p.numeric and p.name not in self.fixed
        ]
        self.bounds = {p.name: (p.lo, p.hi) for p in self._numeric}
        self.frozen: Dict[str, Any] = {}
        self.bound_history: List[Dict[str, Any]] = [dict(self.bounds)]

        self._rounds_completed = 0
        self._round_results: List[Tuple[Dict[str, Any], float]] = []
        self._best_config: Optional[Dict[str, Any]] = None
        self._best_time = float("inf")
        self._prev_best_time = float("inf")  # best as of the last round boundary

        self.tag = "crs/round0"
        self._pending = self._draw_round()

    def on_study_attach(self, history, siblings=None, transfer="off") -> None:
        """Warm transfer: sibling incumbents (snapped into this space) are
        prepended to round 0. The rng draw stream is untouched — the round's
        random draws are already pending — so a seeded run with and without
        siblings explores the same random configs plus the seeds."""
        if transfer == "off" or not siblings:
            return
        from repro.core.transfer import warm_seed_configs

        self._pending = warm_seed_configs(
            self.space, self.fixed, siblings, self._pending
        ) + self._pending

    def _draw_round(self) -> List[Dict[str, Any]]:
        return [
            {**_random_config(self.space, self.bounds, self.frozen, self.rng),
             **self.fixed}
            for _ in range(self.m)
        ]

    # -- QueueStrategy hooks

    def _observe(self, trial: Trial) -> None:
        # rank on Trial.score, not time_s: a timeout trial carries its real
        # measurement but must never survive a round or become the best
        self._round_results.append((dict(trial.config), trial.score))
        # running best per trial (not per round): identical to the legacy
        # survivors-based best for completed runs — every round's survivor[0]
        # is that round's first-drawn minimum and the cross-round update is
        # strict — and it keeps result() meaningful on a mid-round early stop
        if trial.score < self._best_time:
            self._best_config = dict(trial.config)
            self._best_time = trial.score

    def _on_batch_done(self) -> None:
        self._round_results.sort(key=lambda ct: ct[1])  # stable: draw order ties
        survivors = self._round_results[: self.k]
        self._round_results = []

        # (the running best is tracked per trial in _observe; survivors[0]
        # equals it at every round boundary)
        if self._rounds_completed == 0:
            self._rounds_completed = 1
        else:
            _, new_best_time = survivors[0]
            self._rounds_completed += 1
            # paper's stop rule: improvement of this round's best over the
            # best as of the previous round boundary
            improvement = self._prev_best_time - new_best_time
            if improvement <= self.threshold:
                self._finished = True  # variation fell below the threshold
                return

        self._prev_best_time = self._best_time
        if self._rounds_completed >= self.max_rounds:
            self._finished = True
            return

        # contract bounds to the survivors' [min, max] per numeric parameter
        for p in self._numeric:
            vals = [c[p.name] for c, _ in survivors]
            self.bounds[p.name] = (min(vals), max(vals))
        # freeze categoricals to the survivor majority
        for p in self.space.params:
            if not p.numeric and p.name not in self.fixed:
                maj = Counter(c[p.name] for c, _ in survivors).most_common(1)[0][0]
                self.frozen[p.name] = maj
        self.bound_history.append(dict(self.bounds))

        self.tag = f"crs/round{self._rounds_completed}"
        self._pending = self._draw_round()

    def result(self) -> CRSResult:
        return CRSResult(
            best_config=dict(self._best_config or {}),
            best_time=self._best_time,
            rounds=self._rounds_completed,
            evaluations=0,  # stamped by TrialScheduler.run
            bound_history=list(self.bound_history),
        )
