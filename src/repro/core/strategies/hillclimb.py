"""Curated hillclimb — the §Perf sweep as an ask/tell strategy.

The launch driver used to own this loop: a hand-written, hypothesis-tagged
list of knob deltas per (arch × shape) cell, evaluated in order, recording
hypothesis → change → measured outcome. As a Strategy it runs through the
same TrialScheduler as GSFT/CRS, so the curated moves get batch parallelism,
the persistent cache, and pruning for free — and a cell sweep composes with
the multi-cell driver."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler import Trial, _scalar_info
from repro.core.space import TunableSpace
from repro.core.strategies.base import QueueStrategy, register_strategy


@dataclass(frozen=True)
class Move:
    """One curated candidate: a named, hypothesis-tagged set of overrides."""

    name: str
    hypothesis: str
    overrides: Dict[str, Any]


@dataclass
class HillclimbResult:
    best_config: Dict[str, Any]
    best_time: float
    best_name: str
    evaluations: int
    records: List[Dict[str, Any]] = field(default_factory=list)
    stopped_early: bool = False


@register_strategy("hillclimb")
class CuratedHillclimbStrategy(QueueStrategy):
    def __init__(
        self,
        space: TunableSpace,
        *,
        moves: Sequence[Any],
        fixed: Optional[Dict[str, Any]] = None,
    ):
        super().__init__()
        self.tag = "hillclimb"
        self.moves = [m if isinstance(m, Move) else Move(*m) for m in moves]
        base = {**space.defaults(), **(fixed or {})}
        self._queue_moves: List[Move] = list(self.moves)  # aligned with asks
        self._pending = [{**base, **m.overrides} for m in self.moves]
        self._told_moves: List[Move] = []
        self.records: List[Dict[str, Any]] = []
        self._best: Optional[Tuple[str, Dict[str, Any], float]] = None

    def _observe(self, trial: Trial) -> None:
        move = self._queue_moves[len(self._told_moves)]
        self._told_moves.append(move)
        rec: Dict[str, Any] = {
            "name": move.name,
            "hypothesis": move.hypothesis,
            "overrides": dict(move.overrides),
        }
        if trial.ok:
            rec.update(_scalar_info(trial.info))
            # after the info spread: trial.time_s is authoritative (it carries
            # the scheduler's penalties; info may echo a raw t_step_s)
            rec["t_step_s"] = trial.time_s
            rec["wall_s"] = round(trial.wall_s, 1)
            # keys benchmarks.report indexes unconditionally (the roofline
            # evaluator only emits hbm_penalized on overflow)
            rec.setdefault("hbm_penalized", False)
            if "roofline_fraction_mfu" in rec:
                rec.setdefault("mfu", rec["roofline_fraction_mfu"])
            if self._best is None or trial.time_s < self._best[2]:
                self._best = (move.name, dict(trial.config), trial.time_s)
        else:
            rec["error"] = trial.error
        self.records.append(rec)

    def result(self) -> HillclimbResult:
        name, config, t = self._best or ("", {}, float("inf"))
        return HillclimbResult(
            best_config=config,
            best_time=t,
            best_name=name,
            evaluations=0,  # stamped by TrialScheduler.run
            records=list(self.records),
        )
