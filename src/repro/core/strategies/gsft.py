"""Algorithm I — Grid Search with Finer Tuning (paper §VIII) as an ask/tell
strategy. Phase arithmetic is the paper's, unchanged (see the legacy module
docstring in :mod:`repro.core.grid_finer` for the bound derivation); only the
control flow moved from a private evaluate loop to the shared engine."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

from repro.core.scheduler import INFEASIBLE, Trial
from repro.core.space import TunableSpace
from repro.core.strategies.base import QueueStrategy, register_strategy


@dataclass
class GridResult:
    best_config: Dict[str, Any]
    best_time: float
    phase1_best: Dict[str, Any]
    phase1_time: float
    evaluations: int
    grid_sizes: Dict[str, int] = field(default_factory=dict)
    stopped_early: bool = False


def _param_grid_list(param_grid: Dict[str, List[Any]]) -> List[Dict[str, Any]]:
    names = list(param_grid)
    out = []
    for combo in itertools.product(*(param_grid[n] for n in names)):
        out.append(dict(zip(names, combo)))
    return out


@register_strategy("gsft", "grid")
class GridFinerStrategy(QueueStrategy):
    """Phase 1: evenly-stepped coarse grid over the active knobs. Phase 2:
    the paper's finer window around the phase-1 optimum along the
    most-influential knobs, everything else pinned.

    Cross-cell transfer (``supports_transfer``) is the cheap ``warm`` mode:
    sibling incumbents, snapped into this cell's space, are prepended to the
    phase-1 grid — if a sibling's optimum transfers, it wins phase 1 and the
    finer window contracts around it; if not, the full grid still runs, so
    the sweep is never worse than untransferred."""

    supports_transfer = True
    transfer_modes = ("warm",)

    def __init__(
        self,
        space: TunableSpace,
        *,
        active_params: Optional[Sequence[str]] = None,
        fixed: Optional[Dict[str, Any]] = None,
        samples_per_param: int = 3,
        most_influential: Optional[Sequence[str]] = None,
        finer_samples: int = 5,
    ):
        super().__init__()
        self.space = space
        self.fixed = dict(fixed or {})
        self.active = list(active_params or space.most_influential)
        self.influential = list(most_influential or space.most_influential)
        self.finer_samples = finer_samples

        defaults = space.defaults()
        self.param_grid: Dict[str, List[Any]] = {
            name: space.param(name).grid(samples_per_param) for name in self.active
        }
        base = {**defaults, **self.fixed}
        self.tag = "gsft/grid"
        self._phase = 1
        self._pending = [
            {**base, **cell} for cell in _param_grid_list(self.param_grid)
        ]
        self.grid_sizes = {k: len(v) for k, v in self.param_grid.items()}

        self._best_config: Optional[Dict[str, Any]] = None
        self._min_time = INFEASIBLE
        self._phase1_best: Optional[Dict[str, Any]] = None
        self._phase1_time = INFEASIBLE

    def on_study_attach(self, history, siblings=None, transfer="off") -> None:
        """Warm transfer: prepend each sibling's incumbent (snapped into this
        space) to the phase-1 candidate set. History is ignored — the grid is
        exhaustive by design and the scheduler's cache already replays
        repeated cells for free."""
        if transfer == "off" or not siblings:
            return
        from repro.core.transfer import warm_seed_configs

        self._pending = warm_seed_configs(
            self.space, self.fixed, siblings, self._pending
        ) + self._pending

    # -- QueueStrategy hooks

    def _observe(self, trial: Trial) -> None:
        # Trial.score is infeasible for errored/timed-out trials — a timeout
        # Trial's real measured time_s must not win a grid cell
        if trial.score < self._min_time:
            self._min_time = trial.score
            self._best_config = dict(trial.config)

    def _on_batch_done(self) -> None:
        if self._phase == 1:
            self._phase1_best = dict(self._best_config or {})
            self._phase1_time = self._min_time
            self._pending = self._finer_cells()
            self.tag = "gsft/finer"
            self._phase = 2
            if not self._pending:
                self._finished = True
        else:
            self._finished = True

    def _finer_cells(self) -> List[Dict[str, Any]]:
        """The paper's finer window: new bounds derive from the *old lower
        bound* (idiosyncratic but faithful), snapped into each knob's legal
        range; non-influential knobs pinned at the phase-1 optimum."""
        best_config = self._best_config or {}
        new_param_grid: Dict[str, List[Any]] = {}
        for name in self.influential:
            p = self.space.param(name)
            if not p.numeric or name not in self.param_grid:
                # categorical influential knobs keep their full choice set
                new_param_grid[name] = p.grid(self.finer_samples)
                continue
            old_lower = float(self.param_grid[name][0])
            best_value = float(best_config[name])
            new_lower = best_value - old_lower / 2.0
            new_upper = best_value + old_lower / 2.0
            increment = max(new_lower / 2.0, 1e-9)
            new_param_grid[name] = p.grid_between(new_lower, new_upper, increment)
        self.grid_sizes.update({k: len(v) for k, v in new_param_grid.items()})
        pinned = {k: v for k, v in best_config.items() if k not in new_param_grid}
        return [{**pinned, **cell} for cell in _param_grid_list(new_param_grid)]

    def result(self) -> GridResult:
        return GridResult(
            best_config=dict(self._best_config or {}),
            best_time=self._min_time,
            phase1_best=dict(self._phase1_best or self._best_config or {}),
            phase1_time=(
                self._phase1_time if self._phase1_best is not None else self._min_time
            ),
            evaluations=0,  # stamped by TrialScheduler.run
            grid_sizes=dict(self.grid_sizes),
        )
