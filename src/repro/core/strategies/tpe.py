"""Tree-structured Parzen Estimator — the model-based strategy the ask/tell
engine was built to host (ROADMAP "Next optimizer").

TPE (Bergstra et al., 2011) inverts the usual surrogate direction: instead of
modelling p(objective | config) it splits the observations at an objective
quantile ``gamma`` into a *good* set and a *bad* set and fits one kernel
density per parameter to each — ``l(x)`` over the good configs, ``g(x)`` over
the bad. Maximizing expected improvement reduces to maximizing ``l(x)/g(x)``:
candidates are drawn from ``l`` and ranked by the density ratio.

Per-``Param`` kernels respect the space semantics:

  - ``IntParam``/``FloatParam`` — a Parzen mixture of Gaussians centred on
    the observed values plus one uniform prior component; samples are pushed
    through ``Param.snap`` so ``step`` grids and ``pow2`` snapping always
    hold. ``pow2`` params with positive bounds are modelled in log2 space
    (the natural metric for mesh factors and block sizes).
  - ``CatParam`` — a Laplace-smoothed categorical over ``choices``.

**Batched acquisition.** Proposals are generated a *round* at a time, every
round drawn before any of its results is consumed — exactly the CRS
discipline — so ``TrialScheduler.run(batch_size=n)`` keeps its thread pool
full and the proposed-config *set* is identical for any batch size (the
determinism tests assert this). Within a round, each proposal after the first
is conditioned on a **constant-liar penalty**: the already-proposed (in-
flight) configs are told a pessimistic lie (the worst observed objective), so
they join the *bad* density and the ratio ``l/g`` repels the next candidate
away from them — diversity without waiting for results.

**Warm start.** ``history`` (the tuner feeds it from the TrialScheduler's
persistent JSONL cache as ``(config, time_s, tag)`` triples) seeds the
observation set; entries the strategy itself proposed — tpe-tagged cache
records, and untagged/explicit ``(config, time_s)`` pairs — also count
toward ``max_trials``. So a re-run over a complete cache proposes nothing
(zero fresh evaluations), a re-run over a crashed session's cache resumes
with exactly the unpaid remainder of its budget, and records another
strategy left on the platform (a GSFT sweep sharing the same ``--cache``)
are free model evidence rather than silent budget theft.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler import Trial, config_key
from repro.core.space import CatParam, Param, TunableSpace
from repro.core.strategies.base import QueueStrategy, register_strategy
from repro.core.surrogate import SURROGATE_MODES, CostSurrogate

_SQRT_2PI = math.sqrt(2.0 * math.pi)


@dataclass
class TPEResult:
    best_config: Dict[str, Any]
    best_time: float
    rounds: int
    evaluations: int
    n_observations: int = 0
    warm_started: int = 0  # observations seeded from the persistent cache
    timeouts: int = 0
    stopped_early: bool = False
    transfer_mode: str = "off"  # off | warm | prior (cross-cell siblings)
    sibling_observations: int = 0  # prior points ingested — NEVER budget-charged
    surrogate: str = "off"  # off | rank (learned cost pre-ranking)
    surrogate_rows: int = 0  # training rows at the last fit — NEVER budget-charged


# ------------------------------------------------------------- kernel densities


class _NumericDensity:
    """Parzen estimator for an Int/Float param: a mixture of Gaussians at the
    observed values plus one uniform prior component over the bounds. ``pow2``
    params with lo >= 1 live in log2 space.

    ``weights`` (default: all 1.0) scale each observation's mass in the
    mixture — the cross-cell transfer prior feeds sibling observations with a
    distance-decayed weight < 1, so near-cell evidence shapes the density
    strongly and far-cell evidence barely at all, while the local cell's own
    observations keep full weight."""

    def __init__(
        self,
        param: Param,
        values: Sequence[Any],
        prior_weight: float = 1.0,
        weights: Optional[Sequence[float]] = None,
    ):
        self.param = param
        self.log2 = bool(getattr(param, "pow2", False)) and param.lo >= 1
        lo, hi = float(param.lo), float(param.hi)
        if self.log2:
            lo, hi = math.log2(lo), math.log2(max(hi, lo * 2.0))
        self.lo, self.hi = lo, hi
        self.width = max(hi - lo, 1e-9)
        self.points = [self._fwd(v) for v in values]
        self.weights = (
            [1.0] * len(self.points) if weights is None else
            [max(float(w), 0.0) for w in weights]
        )
        self.mass = sum(self.weights)
        # bandwidth shrinks as (weighted) evidence accumulates, floored so
        # late rounds still explore the step/pow2 neighbourhood
        self.sigma = max(self.width / max(self.mass, 1), self.width * 0.08)
        self.prior_weight = prior_weight
        self.total = self.mass + prior_weight

    def _fwd(self, v) -> float:
        v = float(v)
        return math.log2(max(v, 2.0 ** self.lo)) if self.log2 else v

    def sample(self, rng):
        r = rng.random() * self.total
        if r < self.prior_weight or not self.points:
            x = self.lo + rng.random() * self.width
        else:
            # a dedicated draw picks the mixture component: with unit weights
            # this selects points[int(r2)] — byte-identical rng consumption
            # to the unweighted implementation, so pre-transfer seeded
            # studies replay the same proposal stream
            r2 = rng.random() * max(self.mass, 1e-12)
            mu = self.points[-1]
            for point, w in zip(self.points, self.weights):
                if r2 < w:
                    mu = point
                    break
                r2 -= w
            x = rng.gauss(mu, self.sigma)
        return self.param.snap(2.0 ** x if self.log2 else x)

    def logpdf(self, v) -> float:
        x = self._fwd(v)
        dens = self.prior_weight / self.width
        for mu, w in zip(self.points, self.weights):
            z = (x - mu) / self.sigma
            dens += w * math.exp(-0.5 * z * z) / (self.sigma * _SQRT_2PI)
        return math.log(dens / self.total)


class _CategoricalDensity:
    """Laplace-smoothed categorical over a CatParam's choices; observation
    ``weights`` discount sibling-cell evidence like in _NumericDensity."""

    def __init__(
        self,
        param: CatParam,
        values: Sequence[Any],
        prior_weight: float = 1.0,
        weights: Optional[Sequence[float]] = None,
    ):
        self.param = param
        if weights is None:
            weights = [1.0] * len(values)
        counts = {c: prior_weight for c in param.choices}
        for v, w in zip(values, weights):
            counts[param.snap(v)] += max(float(w), 0.0)
        total = sum(counts.values())
        self.choices = list(param.choices)
        self.probs = [counts[c] / total for c in self.choices]

    def sample(self, rng):
        r = rng.random()
        acc = 0.0
        for c, p in zip(self.choices, self.probs):
            acc += p
            if r < acc:
                return c
        return self.choices[-1]

    def logpdf(self, v) -> float:
        v = self.param.snap(v)
        return math.log(self.probs[self.choices.index(v)])


def _density(
    param: Param,
    values: Sequence[Any],
    prior_weight: float,
    weights: Optional[Sequence[float]] = None,
):
    if param.numeric:
        return _NumericDensity(param, values, prior_weight, weights)
    return _CategoricalDensity(param, values, prior_weight, weights)


# ------------------------------------------------------------------- strategy


@register_strategy("tpe", "bayes")
class TPEStrategy(QueueStrategy):
    """Tree-structured Parzen Estimator with round-batched EI acquisition.

    Parameters
      max_trials     trial budget; own warm-start history counts toward it
      n_startup      random trials before the first model round
      gamma          good/bad split quantile (fraction of obs in the good set)
      n_candidates   EI candidates sampled from ``l`` per proposal
      round_size     proposals per model round (size the thread pool to this)
      history        prior ``(config, time_s[, tag])`` observations — own
                     (tpe-tagged or untagged) entries are budget-charged,
                     foreign-strategy entries are free model evidence
      seed           rng seed — the proposed-config stream is a pure function
                     of (seed, told results, siblings), independent of batch
                     size
      transfer_weight  scale on the distance-decayed sibling weights of the
                     cross-cell transfer prior (1.0 = exp(-distance))
      transfer_ramp  local observations over which the sibling prior fades
                     linearly to zero (default 2×n_startup) — late rounds are
                     pure local TPE, so a misleading sibling (the outlier
                     cell) costs a bounded number of early proposals, never
                     the whole budget
      surrogate      ``"rank"`` pre-ranks each model round's proposals with a
                     :class:`~repro.core.surrogate.CostSurrogate` trained on
                     the observations (local + sibling namespaces): the round
                     over-samples ``surrogate_oversample``× lie-conditioned
                     proposals and keeps the predicted-fastest ``round_size``.
                     Startup coverage, budget accounting and cache identity
                     are untouched — ranking only reorders within a round
      surrogate_oversample  acquisition over-sampling factor under ``rank``
      platform       this cell's cache namespace — the surrogate's local
                     training rows and prediction context are keyed by it
    """

    supports_history = True  # Study/tuner feed the persistent eval cache in
    supports_transfer = True  # on_study_attach takes the siblings= channel
    supports_surrogate = True  # EngineConfig.surrogate plumbs to surrogate=
    transfer_modes = ("warm", "prior")
    budget_kwarg = "max_trials"  # Study.optimize(budget=N) maps here

    def __init__(
        self,
        space: TunableSpace,
        *,
        fixed: Optional[Dict[str, Any]] = None,
        max_trials: int = 48,
        n_startup: Optional[int] = None,
        gamma: float = 0.25,
        n_candidates: int = 24,
        round_size: int = 8,
        prior_weight: float = 1.0,
        seed: int = 0,
        history: Optional[Sequence[Tuple[Dict[str, Any], float]]] = None,
        transfer_weight: float = 1.0,
        transfer_ramp: Optional[int] = None,
        surrogate: str = "off",
        surrogate_oversample: int = 3,
        platform: Optional[str] = None,
    ):
        super().__init__()
        import random

        if surrogate not in SURROGATE_MODES:
            raise ValueError(
                f"surrogate must be one of {SURROGATE_MODES}, got {surrogate!r}"
            )
        self.surrogate = surrogate
        self.surrogate_oversample = max(1, int(surrogate_oversample))
        self.platform = platform or ""
        self.surrogate_rows = 0  # rows at the last fit (telemetry only)
        self.space = space
        self.fixed = dict(fixed or {})
        self.max_trials = int(max_trials)
        self.gamma = float(gamma)
        self.n_candidates = max(1, int(n_candidates))
        self.round_size = max(1, int(round_size))
        self.prior_weight = float(prior_weight)
        self.transfer_weight = float(transfer_weight)
        self._seed = seed
        self.rng = random.Random(seed)
        self.n_startup = int(n_startup) if n_startup is not None else min(
            10, max(4, self.max_trials // 4)
        )
        self.transfer_ramp = (
            int(transfer_ramp) if transfer_ramp is not None
            else 2 * self.n_startup
        )

        self._free = [p for p in space.params if p.name not in self.fixed]
        self._observations: List[Tuple[Dict[str, Any], float]] = []
        self._paid = 0  # budget-charged observations (own proposals only)
        self._best_config: Optional[Dict[str, Any]] = None
        self._best_time = float("inf")
        self._rounds = 0
        self.warm_started = 0
        # cross-cell transfer state (set by on_study_attach):
        self.transfer_mode = "off"
        # prior mode: sibling (config, weight) points pre-split into good/bad
        # by each sibling's OWN objective quantile — sibling times live on a
        # different cell's scale, so they must never be ranked against local
        # times, only donate density mass
        self._sibling_good: List[Tuple[Dict[str, Any], float]] = []
        self._sibling_bad: List[Tuple[Dict[str, Any], float]] = []
        # warm mode: sibling incumbents snapped into this space, closest
        # sibling first — consumed as the first startup proposals
        self._seed_configs: List[Dict[str, Any]] = []
        # surrogate training rows donated by siblings: (config, time_s,
        # namespace) — flows even with transfer="off" (model-form transfer)
        self._surrogate_sibling_rows: List[Tuple[Dict[str, Any], float, str]] = []

        self.tag = "tpe/startup"
        self.on_study_attach(history or ())

    def on_study_attach(self, history, siblings=None, transfer="off") -> None:
        """Warm-start + transfer seam (the Strategy protocol's study hook):
        ingest prior ``(config, time_s[, tag])`` observations and optional
        sibling-cell histories, then recompute the pending proposals — the
        proposal stream is a pure function of ``(seed, observations,
        siblings)``, so attaching after construction is byte-identical to
        passing everything to the constructor. Must run before the first
        ``ask``.

        ``siblings`` (:class:`~repro.core.transfer.SiblingHistory` records,
        closest first) are ingested per ``transfer``: ``"prior"`` adds every
        sibling observation to the Parzen densities with the sibling's
        distance-decayed weight, pre-split by the sibling's own good/bad
        quantile; ``"warm"`` seeds the startup batch with each sibling's
        incumbent. Either way sibling evidence is free — it never counts
        toward ``max_trials`` and never marks a config as already-proposed.
        """
        if self._outstanding:
            raise RuntimeError(
                "on_study_attach must be called before trials are in flight"
            )
        import random

        for entry in history or ():
            cfg, t = entry[0], float(entry[1])
            tag = entry[2] if len(entry) > 2 else None
            full = self._canon(cfg)
            if full is None:
                continue  # foreign-space record / violates `fixed`
            # charge own proposals (tpe-tagged cache records; untagged =
            # explicit history) against the budget; another strategy's
            # records are free evidence, not budget theft
            charged = tag is None or str(tag).startswith("tpe")
            self._record(full, t, charged=charged)
        self.warm_started = len(self._observations)
        if siblings is not None:
            self._ingest_siblings(siblings, transfer)
            self._ingest_surrogate_rows(siblings)
        self.rng = random.Random(self._seed)
        self._finished = False
        self._pending = []
        self._refill()

    def _ingest_siblings(self, siblings, transfer: str) -> None:
        self._sibling_good, self._sibling_bad = [], []
        self._seed_configs = []
        self.transfer_mode = "off"
        if transfer == "off" or not siblings:
            return
        self.transfer_mode = transfer
        seed_seen = set()
        for sib in siblings:
            w = self.transfer_weight * math.exp(-float(sib.distance))
            if w <= 1e-6:
                continue
            local: List[Tuple[Dict[str, Any], float]] = []
            for entry in sib.trials:
                full = self._canon(entry[0])
                if full is not None and math.isfinite(float(entry[1])):
                    local.append((full, float(entry[1])))
            if not local:
                continue
            if transfer == "prior":
                good, bad = self._split([(c, t, w) for c, t in local])
                self._sibling_good += good
                self._sibling_bad += bad
            else:  # warm: the sibling's incumbent seeds the startup batch
                inc = min(local, key=lambda ct: ct[1])[0]
                key = config_key(inc)
                if key not in seed_seen:
                    seed_seen.add(key)
                    self._seed_configs.append(dict(inc))

    def _ingest_surrogate_rows(self, siblings) -> None:
        """Sibling trials as surrogate training rows, kept separate from the
        Parzen densities: the surrogate channel is live whenever
        ``surrogate != off`` — including ``transfer="off"`` — because the
        per-namespace intercept makes foreign scales safe for the *model*
        where they are unsafe for the density split."""
        self._surrogate_sibling_rows = []
        if self.surrogate == "off":
            return
        for sib in siblings:
            for entry in sib.trials:
                full = self._canon(entry[0])
                t = float(entry[1])
                if full is not None and math.isfinite(t) and t > 0.0:
                    self._surrogate_sibling_rows.append((full, t, sib.namespace))

    @property
    def sibling_observations(self) -> int:
        return len(self._sibling_good) + len(self._sibling_bad)

    # ------------------------------------------------------------ bookkeeping

    def _canon(self, cfg: Dict[str, Any]) -> Optional[Dict[str, Any]]:
        """Snap a config onto this space; None if it belongs to a different
        space (doesn't cover this one's knobs — a foreign cache record must
        not collapse to the defaults and eat budget) or contradicts the
        pinned ``fixed`` values."""
        if not all(p.name in cfg for p in self.space.params):
            return None
        full = {p.name: p.snap(cfg[p.name]) for p in self.space.params}
        for k, v in self.fixed.items():
            if k in cfg and cfg[k] != v:
                return None
            full[k] = v
        return full

    def _record(self, cfg: Dict[str, Any], t: float, charged: bool = True) -> None:
        self._observations.append((cfg, t))
        if charged:
            self._paid += 1
        if t < self._best_time:
            self._best_config, self._best_time = dict(cfg), t

    # -- QueueStrategy hooks

    def _observe(self, trial: Trial) -> None:
        full = self._canon(trial.config)
        if full is not None:
            # Trial.score: non-ok trials (errors, over-deadline measurements)
            # enter the model as infeasible, same as before timeouts kept
            # their real time_s
            self._record(full, trial.score)

    def _on_batch_done(self) -> None:
        self._refill()

    def _refill(self) -> None:
        remaining = self.max_trials - self._paid
        if remaining <= 0:
            self._finished = True
            return
        # any local evidence defuses random startup; sibling prior points do
        # too, but only down to a floor of genuinely local random trials — a
        # misleading sibling (outlier cell) must not strip the cell of ALL
        # exploration of its own objective
        n_local = len(self._observations)
        if self.sibling_observations:
            floor = min(self.n_startup, max(2, self.n_startup // 3))
            n_obs = n_local + min(
                self.sibling_observations, max(0, self.n_startup - floor)
            )
        else:
            n_obs = n_local
        if n_obs < self.n_startup:
            k = min(remaining, self.n_startup - n_obs)
            self.tag = "tpe/startup"
            seen = {config_key(c) for c, _ in self._observations}
            batch: List[Dict[str, Any]] = []
            # warm transfer: sibling incumbents go first (they ARE proposals —
            # evaluated in this cell and budget-charged like any other)
            while self._seed_configs and len(batch) < k:
                cfg = self._seed_configs.pop(0)
                if config_key(cfg) in seen:
                    continue
                seen.add(config_key(cfg))
                batch.append(cfg)
            while len(batch) < k:
                cfg = self._random_config(seen)
                seen.add(config_key(cfg))
                batch.append(cfg)
            self._pending = batch
        else:
            self._rounds += 1
            self.tag = f"tpe/round{self._rounds}"
            self._pending = self._propose_round(min(remaining, self.round_size))

    # ------------------------------------------------------------- proposals

    def _random_config(self, seen) -> Dict[str, Any]:
        for _ in range(16):  # bounded novelty retries (spaces can exhaust)
            cfg = {p.name: p.sample(self.rng) for p in self._free}
            cfg.update(self.fixed)
            if config_key(cfg) not in seen:
                return cfg
        return cfg

    def _worst_finite(self) -> float:
        finite = [t for _, t in self._observations if math.isfinite(t)]
        return max(finite) if finite else 1.0

    def _split(
        self, obs: List[Tuple[Dict[str, Any], float, float]]
    ) -> Tuple[List[Tuple[Dict[str, Any], float]], List[Tuple[Dict[str, Any], float]]]:
        """Rank ``(config, time, weight)`` triples by time and split at the
        ``gamma`` quantile, keeping each observation's density weight
        attached: ``([(config, weight)...] good, [...] bad)``."""
        ranked = sorted(obs, key=lambda ct: ct[1])  # stable: insertion order ties
        n_good = max(1, min(len(ranked) - 1, int(math.ceil(self.gamma * len(ranked)))))
        return (
            [(c, w) for c, _, w in ranked[:n_good]],
            [(c, w) for c, _, w in ranked[n_good:]],
        )

    def _fit_surrogate(self) -> Optional[CostSurrogate]:
        """Fresh fit over (local observations + sibling rows); None when the
        surrogate is off or under-trained. Refit every round — the training
        set is a deterministic function of (observations, siblings), which
        keeps the proposal stream replayable."""
        if self.surrogate == "off":
            return None
        rows = [
            (c, t, self.platform)
            for c, t in self._observations
            if math.isfinite(t) and t > 0.0
        ] + self._surrogate_sibling_rows
        model = CostSurrogate(self.space).fit(rows)
        self.surrogate_rows = model.n_rows
        return model if model.ready else None

    def _propose_round(self, k: int) -> List[Dict[str, Any]]:
        """k EI-ranked proposals; each one conditions the next via a constant
        lie at the worst observed objective (in-flight configs fall into the
        bad density, so l/g repels repeats — batch diversity). Sibling prior
        points join the good/bad densities with their distance-decayed
        weights but are split by their OWN cell's quantile, never ranked
        against local times.

        Under ``surrogate="rank"`` the round generates ``k × oversample``
        lie-conditioned proposals and returns the ``k`` the cost model
        predicts fastest (stable order) — the predicted frontier. Only those
        k are ever proposed, so budget accounting and cache identity are
        byte-identical to ``off``; the surviving set is a pure function of
        (seed, observations, siblings, training set)."""
        model = self._fit_surrogate()
        n = k if model is None else k * self.surrogate_oversample
        lie = self._worst_finite()
        lies: List[Tuple[Dict[str, Any], float]] = []
        seen = {config_key(c) for c, _ in self._observations}
        out: List[Dict[str, Any]] = []
        # the sibling prior fades linearly as local evidence accumulates:
        # full strength with zero local observations, gone at transfer_ramp —
        # a misleading sibling costs early proposals, never the whole budget
        fade = max(
            0.0, 1.0 - len(self._observations) / max(self.transfer_ramp, 1)
        )
        sib_good = [(c, w * fade) for c, w in self._sibling_good if w * fade > 0]
        sib_bad = [(c, w * fade) for c, w in self._sibling_bad if w * fade > 0]
        for _ in range(n):
            local = [(c, t, 1.0) for c, t in self._observations] + \
                    [(c, t, 1.0) for c, t in lies]
            good, bad = self._split(local)
            cfg = self._sample_ei(good + sib_good, bad + sib_bad, seen)
            seen.add(config_key(cfg))
            lies.append((cfg, lie))
            out.append(cfg)
        if model is not None and len(out) > k:
            out = model.rank(out, self.platform)[:k]
        return out

    def _sample_ei(self, good, bad, seen) -> Dict[str, Any]:
        l_dens = {p.name: _density(p, [c[p.name] for c, _ in good],
                                   self.prior_weight, [w for _, w in good])
                  for p in self._free}
        g_dens = {p.name: _density(p, [c[p.name] for c, _ in bad],
                                   self.prior_weight, [w for _, w in bad])
                  for p in self._free}
        novel_best, novel_score = None, -math.inf
        for _ in range(self.n_candidates):
            cfg = {name: d.sample(self.rng) for name, d in l_dens.items()}
            cfg.update(self.fixed)
            score = sum(
                l_dens[n].logpdf(cfg[n]) - g_dens[n].logpdf(cfg[n]) for n in l_dens
            )
            if config_key(cfg) not in seen and score > novel_score:
                novel_best, novel_score = cfg, score
        if novel_best is not None:
            return novel_best
        # every candidate already observed/in-flight: fall back to exploration
        # (which itself retries for novelty before giving up)
        return self._random_config(seen)

    # ---------------------------------------------------------------- result

    def result(self) -> TPEResult:
        return TPEResult(
            best_config=dict(self._best_config or {}),
            best_time=self._best_time,
            rounds=self._rounds,
            evaluations=0,  # stamped by TrialScheduler.run
            n_observations=len(self._observations),
            warm_started=self.warm_started,
            transfer_mode=self.transfer_mode,
            sibling_observations=self.sibling_observations,
            surrogate=self.surrogate,
            surrogate_rows=self.surrogate_rows,
        )
