"""Tree-structured Parzen Estimator — the model-based strategy the ask/tell
engine was built to host (ROADMAP "Next optimizer").

TPE (Bergstra et al., 2011) inverts the usual surrogate direction: instead of
modelling p(objective | config) it splits the observations at an objective
quantile ``gamma`` into a *good* set and a *bad* set and fits one kernel
density per parameter to each — ``l(x)`` over the good configs, ``g(x)`` over
the bad. Maximizing expected improvement reduces to maximizing ``l(x)/g(x)``:
candidates are drawn from ``l`` and ranked by the density ratio.

Per-``Param`` kernels respect the space semantics:

  - ``IntParam``/``FloatParam`` — a Parzen mixture of Gaussians centred on
    the observed values plus one uniform prior component; samples are pushed
    through ``Param.snap`` so ``step`` grids and ``pow2`` snapping always
    hold. ``pow2`` params with positive bounds are modelled in log2 space
    (the natural metric for mesh factors and block sizes).
  - ``CatParam`` — a Laplace-smoothed categorical over ``choices``.

**Batched acquisition.** Proposals are generated a *round* at a time, every
round drawn before any of its results is consumed — exactly the CRS
discipline — so ``TrialScheduler.run(batch_size=n)`` keeps its thread pool
full and the proposed-config *set* is identical for any batch size (the
determinism tests assert this). Within a round, each proposal after the first
is conditioned on a **constant-liar penalty**: the already-proposed (in-
flight) configs are told a pessimistic lie (the worst observed objective), so
they join the *bad* density and the ratio ``l/g`` repels the next candidate
away from them — diversity without waiting for results.

**Warm start.** ``history`` (the tuner feeds it from the TrialScheduler's
persistent JSONL cache as ``(config, time_s, tag)`` triples) seeds the
observation set; entries the strategy itself proposed — tpe-tagged cache
records, and untagged/explicit ``(config, time_s)`` pairs — also count
toward ``max_trials``. So a re-run over a complete cache proposes nothing
(zero fresh evaluations), a re-run over a crashed session's cache resumes
with exactly the unpaid remainder of its budget, and records another
strategy left on the platform (a GSFT sweep sharing the same ``--cache``)
are free model evidence rather than silent budget theft.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler import Trial, config_key
from repro.core.space import CatParam, Param, TunableSpace
from repro.core.strategies.base import QueueStrategy, register_strategy

_SQRT_2PI = math.sqrt(2.0 * math.pi)


@dataclass
class TPEResult:
    best_config: Dict[str, Any]
    best_time: float
    rounds: int
    evaluations: int
    n_observations: int = 0
    warm_started: int = 0  # observations seeded from the persistent cache
    timeouts: int = 0
    stopped_early: bool = False


# ------------------------------------------------------------- kernel densities


class _NumericDensity:
    """Parzen estimator for an Int/Float param: a mixture of Gaussians at the
    observed values plus one uniform prior component over the bounds. ``pow2``
    params with lo >= 1 live in log2 space."""

    def __init__(self, param: Param, values: Sequence[Any], prior_weight: float = 1.0):
        self.param = param
        self.log2 = bool(getattr(param, "pow2", False)) and param.lo >= 1
        lo, hi = float(param.lo), float(param.hi)
        if self.log2:
            lo, hi = math.log2(lo), math.log2(max(hi, lo * 2.0))
        self.lo, self.hi = lo, hi
        self.width = max(hi - lo, 1e-9)
        self.points = [self._fwd(v) for v in values]
        # bandwidth shrinks as evidence accumulates, floored so late rounds
        # still explore the step/pow2 neighbourhood
        self.sigma = max(self.width / max(len(self.points), 1), self.width * 0.08)
        self.prior_weight = prior_weight
        self.total = len(self.points) + prior_weight

    def _fwd(self, v) -> float:
        v = float(v)
        return math.log2(max(v, 2.0 ** self.lo)) if self.log2 else v

    def sample(self, rng):
        r = rng.random() * self.total
        if r < self.prior_weight or not self.points:
            x = self.lo + rng.random() * self.width
        else:
            mu = self.points[int(rng.random() * len(self.points)) % len(self.points)]
            x = rng.gauss(mu, self.sigma)
        return self.param.snap(2.0 ** x if self.log2 else x)

    def logpdf(self, v) -> float:
        x = self._fwd(v)
        dens = self.prior_weight / self.width
        for mu in self.points:
            z = (x - mu) / self.sigma
            dens += math.exp(-0.5 * z * z) / (self.sigma * _SQRT_2PI)
        return math.log(dens / self.total)


class _CategoricalDensity:
    """Laplace-smoothed categorical over a CatParam's choices."""

    def __init__(self, param: CatParam, values: Sequence[Any], prior_weight: float = 1.0):
        self.param = param
        counts = {c: prior_weight for c in param.choices}
        for v in values:
            counts[param.snap(v)] += 1.0
        total = sum(counts.values())
        self.choices = list(param.choices)
        self.probs = [counts[c] / total for c in self.choices]

    def sample(self, rng):
        r = rng.random()
        acc = 0.0
        for c, p in zip(self.choices, self.probs):
            acc += p
            if r < acc:
                return c
        return self.choices[-1]

    def logpdf(self, v) -> float:
        v = self.param.snap(v)
        return math.log(self.probs[self.choices.index(v)])


def _density(param: Param, values: Sequence[Any], prior_weight: float):
    if param.numeric:
        return _NumericDensity(param, values, prior_weight)
    return _CategoricalDensity(param, values, prior_weight)


# ------------------------------------------------------------------- strategy


@register_strategy("tpe", "bayes")
class TPEStrategy(QueueStrategy):
    """Tree-structured Parzen Estimator with round-batched EI acquisition.

    Parameters
      max_trials     trial budget; own warm-start history counts toward it
      n_startup      random trials before the first model round
      gamma          good/bad split quantile (fraction of obs in the good set)
      n_candidates   EI candidates sampled from ``l`` per proposal
      round_size     proposals per model round (size the thread pool to this)
      history        prior ``(config, time_s[, tag])`` observations — own
                     (tpe-tagged or untagged) entries are budget-charged,
                     foreign-strategy entries are free model evidence
      seed           rng seed — the proposed-config stream is a pure function
                     of (seed, told results), independent of batch size
    """

    supports_history = True  # Study/tuner feed the persistent eval cache in
    budget_kwarg = "max_trials"  # Study.optimize(budget=N) maps here

    def __init__(
        self,
        space: TunableSpace,
        *,
        fixed: Optional[Dict[str, Any]] = None,
        max_trials: int = 48,
        n_startup: Optional[int] = None,
        gamma: float = 0.25,
        n_candidates: int = 24,
        round_size: int = 8,
        prior_weight: float = 1.0,
        seed: int = 0,
        history: Optional[Sequence[Tuple[Dict[str, Any], float]]] = None,
    ):
        super().__init__()
        import random

        self.space = space
        self.fixed = dict(fixed or {})
        self.max_trials = int(max_trials)
        self.gamma = float(gamma)
        self.n_candidates = max(1, int(n_candidates))
        self.round_size = max(1, int(round_size))
        self.prior_weight = float(prior_weight)
        self._seed = seed
        self.rng = random.Random(seed)
        self.n_startup = int(n_startup) if n_startup is not None else min(
            10, max(4, self.max_trials // 4)
        )

        self._free = [p for p in space.params if p.name not in self.fixed]
        self._observations: List[Tuple[Dict[str, Any], float]] = []
        self._paid = 0  # budget-charged observations (own proposals only)
        self._best_config: Optional[Dict[str, Any]] = None
        self._best_time = float("inf")
        self._rounds = 0
        self.warm_started = 0

        self.tag = "tpe/startup"
        self.on_study_attach(history or ())

    def on_study_attach(self, history) -> None:
        """Warm-start seam (the Strategy protocol's study hook): ingest prior
        ``(config, time_s[, tag])`` observations, then recompute the pending
        proposals — the proposal stream is a pure function of
        ``(seed, observations)``, so attaching history after construction is
        byte-identical to passing it to the constructor. Must run before the
        first ``ask``."""
        if self._outstanding:
            raise RuntimeError(
                "on_study_attach must be called before trials are in flight"
            )
        import random

        for entry in history or ():
            cfg, t = entry[0], float(entry[1])
            tag = entry[2] if len(entry) > 2 else None
            full = self._canon(cfg)
            if full is None:
                continue  # foreign-space record / violates `fixed`
            # charge own proposals (tpe-tagged cache records; untagged =
            # explicit history) against the budget; another strategy's
            # records are free evidence, not budget theft
            charged = tag is None or str(tag).startswith("tpe")
            self._record(full, t, charged=charged)
        self.warm_started = len(self._observations)
        self.rng = random.Random(self._seed)
        self._finished = False
        self._pending = []
        self._refill()

    # ------------------------------------------------------------ bookkeeping

    def _canon(self, cfg: Dict[str, Any]) -> Optional[Dict[str, Any]]:
        """Snap a config onto this space; None if it belongs to a different
        space (doesn't cover this one's knobs — a foreign cache record must
        not collapse to the defaults and eat budget) or contradicts the
        pinned ``fixed`` values."""
        if not all(p.name in cfg for p in self.space.params):
            return None
        full = {p.name: p.snap(cfg[p.name]) for p in self.space.params}
        for k, v in self.fixed.items():
            if k in cfg and cfg[k] != v:
                return None
            full[k] = v
        return full

    def _record(self, cfg: Dict[str, Any], t: float, charged: bool = True) -> None:
        self._observations.append((cfg, t))
        if charged:
            self._paid += 1
        if t < self._best_time:
            self._best_config, self._best_time = dict(cfg), t

    # -- QueueStrategy hooks

    def _observe(self, trial: Trial) -> None:
        full = self._canon(trial.config)
        if full is not None:
            # Trial.score: non-ok trials (errors, over-deadline measurements)
            # enter the model as infeasible, same as before timeouts kept
            # their real time_s
            self._record(full, trial.score)

    def _on_batch_done(self) -> None:
        self._refill()

    def _refill(self) -> None:
        remaining = self.max_trials - self._paid
        if remaining <= 0:
            self._finished = True
            return
        n_obs = len(self._observations)  # any evidence defuses random startup
        if n_obs < self.n_startup:
            k = min(remaining, self.n_startup - n_obs)
            self.tag = "tpe/startup"
            seen = {config_key(c) for c, _ in self._observations}
            batch: List[Dict[str, Any]] = []
            for _ in range(k):
                cfg = self._random_config(seen)
                seen.add(config_key(cfg))
                batch.append(cfg)
            self._pending = batch
        else:
            self._rounds += 1
            self.tag = f"tpe/round{self._rounds}"
            self._pending = self._propose_round(min(remaining, self.round_size))

    # ------------------------------------------------------------- proposals

    def _random_config(self, seen) -> Dict[str, Any]:
        for _ in range(16):  # bounded novelty retries (spaces can exhaust)
            cfg = {p.name: p.sample(self.rng) for p in self._free}
            cfg.update(self.fixed)
            if config_key(cfg) not in seen:
                return cfg
        return cfg

    def _worst_finite(self) -> float:
        finite = [t for _, t in self._observations if math.isfinite(t)]
        return max(finite) if finite else 1.0

    def _split(
        self, obs: List[Tuple[Dict[str, Any], float]]
    ) -> Tuple[List[Dict[str, Any]], List[Dict[str, Any]]]:
        ranked = sorted(obs, key=lambda ct: ct[1])  # stable: insertion order ties
        n_good = max(1, min(len(ranked) - 1, int(math.ceil(self.gamma * len(ranked)))))
        return [c for c, _ in ranked[:n_good]], [c for c, _ in ranked[n_good:]]

    def _propose_round(self, k: int) -> List[Dict[str, Any]]:
        """k EI-ranked proposals; each one conditions the next via a constant
        lie at the worst observed objective (in-flight configs fall into the
        bad density, so l/g repels repeats — batch diversity)."""
        lie = self._worst_finite()
        lies: List[Tuple[Dict[str, Any], float]] = []
        seen = {config_key(c) for c, _ in self._observations}
        out: List[Dict[str, Any]] = []
        for _ in range(k):
            good, bad = self._split(self._observations + lies)
            cfg = self._sample_ei(good, bad, seen)
            seen.add(config_key(cfg))
            lies.append((cfg, lie))
            out.append(cfg)
        return out

    def _sample_ei(self, good, bad, seen) -> Dict[str, Any]:
        l_dens = {p.name: _density(p, [c[p.name] for c in good], self.prior_weight)
                  for p in self._free}
        g_dens = {p.name: _density(p, [c[p.name] for c in bad], self.prior_weight)
                  for p in self._free}
        novel_best, novel_score = None, -math.inf
        for _ in range(self.n_candidates):
            cfg = {name: d.sample(self.rng) for name, d in l_dens.items()}
            cfg.update(self.fixed)
            score = sum(
                l_dens[n].logpdf(cfg[n]) - g_dens[n].logpdf(cfg[n]) for n in l_dens
            )
            if config_key(cfg) not in seen and score > novel_score:
                novel_best, novel_score = cfg, score
        if novel_best is not None:
            return novel_best
        # every candidate already observed/in-flight: fall back to exploration
        # (which itself retries for novelty before giving up)
        return self._random_config(seen)

    # ---------------------------------------------------------------- result

    def result(self) -> TPEResult:
        return TPEResult(
            best_config=dict(self._best_config or {}),
            best_time=self._best_time,
            rounds=self._rounds,
            evaluations=0,  # stamped by TrialScheduler.run
            n_observations=len(self._observations),
            warm_started=self.warm_started,
        )
