"""ASHA — Asynchronous Successive Halving (Li et al., 2018) over the
engine's fidelity axis.

The paper's sweeps (and our TPE sessions) pay full fidelity for every
config, so obviously-bad candidates burn the same wall-clock as the winner.
ASHA runs *wide* at a cheap rung and promotes only what earns it: rung
fidelities follow the geometric ladder ``min_fidelity · eta^k`` (see
:class:`~repro.core.fidelity.FidelitySchedule`), and a config at rung ``k``
is promoted to rung ``k+1`` the moment it ranks in the top ``ceil(n/eta)``
of the ``n`` rung-``k`` completions — **no round barrier**: a promotion can
dispatch while its rung peers are still running, so workers never idle
while a rung drains. That asynchrony is the whole point (and the reason the
scheduler grew a submit/poll seam): synchronous halving stalls every rung
on its slowest straggler.

Candidate generation is delegated to an *inner* proposer (``random`` by
default, ``tpe`` for model-based screening). The inner strategy only ever
sees rung-0 trials — asks map 1:1 onto rung-0 launches and only rung-0
results are told back — so its observation model stays on one consistent
time scale and promotions never distort its budget accounting.

Determinism: the promotion/proposal stream is a pure function of the inner
seed and the completion order (scores + arrival ranks); nothing reads a
clock or an unseeded rng. With one worker, completion order equals
submission order, which is what makes interrupted ASHA sessions resumable
as exact replays.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from repro.core.fidelity import FidelitySchedule
from repro.core.scheduler import INFEASIBLE, Trial, config_key
from repro.core.strategies.base import make_strategy, register_strategy


@dataclass
class AsyncJob:
    """One unit of asynchronous work: evaluate ``config`` at ``fidelity``.
    The scheduler's ``run_async`` driver hands the job back (with its Trial)
    to ``on_result`` — the strategy's state machine keys on ``rung``."""

    config: Dict[str, Any]
    fidelity: float
    rung: int
    tag: str


@dataclass
class AshaResult:
    best_config: Optional[Dict[str, Any]]
    best_time: float
    best_fidelity: float  # rung fidelity the reported best was measured at
    rungs: List[float]
    # per-rung observability (index = rung): launched counts promotions in,
    # promotions[k] = configs promoted OUT of rung k
    rung_launched: List[int]
    rung_completed: List[int]
    promotions: List[int]
    proposals: int  # distinct rung-0 configs drawn from the inner proposer
    inner: str
    eta: float
    evaluations: int = 0
    timeouts: int = 0
    stopped_early: bool = False

    def rung_table(self) -> List[Dict[str, Any]]:
        """Per-rung counters as records — what ``study.report()`` renders."""
        return [
            {
                "rung": k,
                "fidelity": f,
                "launched": self.rung_launched[k],
                "completed": self.rung_completed[k],
                "promoted": self.promotions[k],
            }
            for k, f in enumerate(self.rungs)
        ]


@register_strategy("asha")
class AshaStrategy:
    """Asynchronous successive halving over any inner proposer.

    ``max_trials`` caps *distinct rung-0 configs* (the width of the search);
    total evaluations are larger by the promotion ladder — geometrically
    dominated by the cheap rungs, which is where the wall-clock saving
    comes from.
    """

    tag = "asha"
    wants_async = True  # TrialScheduler.run routes to run_async
    supports_history = False
    supports_transfer = False
    transfer_modes: tuple = ()
    budget_kwarg = "max_trials"

    def __init__(
        self,
        space,
        *,
        fixed: Optional[Dict[str, Any]] = None,
        max_trials: int = 27,
        inner: Any = "random",
        min_fidelity: float = 1.0 / 9.0,
        max_fidelity: float = 1.0,
        eta: float = 3.0,
        seed: int = 0,
        **inner_kwargs: Any,
    ):
        self.schedule = FidelitySchedule(
            float(min_fidelity), float(max_fidelity), float(eta)
        )
        self.rungs = self.schedule.rungs()
        self.eta = float(eta)
        self.max_trials = int(max_trials)
        self.inner_name = inner if isinstance(inner, str) else type(inner).__name__
        if isinstance(inner, str):
            inner = make_strategy(
                inner, space, fixed=fixed, seed=seed,
                max_trials=self.max_trials, **inner_kwargs,
            )
        self.inner = inner

        n_rungs = len(self.rungs)
        self._configs: Dict[str, Dict[str, Any]] = {}
        # completion records per rung: (score, arrival_rank, key) — sortable;
        # arrival_rank breaks score ties deterministically (stream purity)
        self._records: List[List[tuple]] = [[] for _ in range(n_rungs)]
        self._promoted: List[set] = [set() for _ in range(n_rungs)]
        self.rung_launched = [0] * n_rungs
        self.rung_completed = [0] * n_rungs
        self.promotions = [0] * n_rungs
        self._proposed = 0
        self._inflight = 0
        self._arrival = 0
        # best per rung — result() reports the highest rung with a finite best
        self._rung_best_time = [INFEASIBLE] * n_rungs
        self._rung_best_config: List[Optional[Dict[str, Any]]] = [None] * n_rungs

    # ------------------------------------------------------------ promotion

    def _promotable(self, k: int) -> List[str]:
        """Keys at rung ``k`` currently ranked in the top ``ceil(n/eta)`` of
        its ``n`` completions, not yet promoted, with a finite score — an
        infeasible (timed-out / failed) trial never climbs the ladder."""
        recs = self._records[k]
        if not recs:
            return []
        top_n = math.ceil(len(recs) / self.eta)
        ranked = sorted(recs)
        return [
            key for score, _, key in ranked[:top_n]
            if math.isfinite(score) and key not in self._promoted[k]
        ]

    def _next_job(self) -> Optional[AsyncJob]:
        # promotions first, highest rung first: finishing a promising config
        # beats widening the base (Li et al.'s get_job order)
        for k in range(len(self.rungs) - 2, -1, -1):
            cand = self._promotable(k)
            if cand:
                key = cand[0]
                self._promoted[k].add(key)
                self.promotions[k] += 1
                rung = k + 1
                self.rung_launched[rung] += 1
                self._inflight += 1
                return AsyncJob(
                    dict(self._configs[key]), self.rungs[rung], rung,
                    f"asha/rung{rung}",
                )
        # otherwise widen rung 0 from the inner proposer
        if self._proposed < self.max_trials and not self.inner.done:
            cfgs = self.inner.ask(1)
            if cfgs:
                cfg = dict(cfgs[0])
                self._configs[config_key(cfg)] = cfg
                self._proposed += 1
                self.rung_launched[0] += 1
                self._inflight += 1
                return AsyncJob(cfg, self.rungs[0], 0, "asha/rung0")
        return None

    # -------------------------------------------------------- async protocol

    def next_jobs(self, n: int) -> List[AsyncJob]:
        jobs: List[AsyncJob] = []
        while len(jobs) < n:
            job = self._next_job()
            if job is None:
                break
            jobs.append(job)
        return jobs

    def on_result(self, job: AsyncJob, trial: Trial) -> None:
        self._inflight -= 1
        k = job.rung
        self._arrival += 1
        self._records[k].append(
            (trial.score, self._arrival, config_key(job.config))
        )
        self.rung_completed[k] += 1
        if trial.ok and trial.score < self._rung_best_time[k]:
            self._rung_best_time[k] = trial.score
            self._rung_best_config[k] = dict(job.config)
        if k == 0:
            # the inner proposer models rung-0 observations only — one
            # consistent time scale, asks and tells 1:1
            self.inner.tell([trial])

    @property
    def done(self) -> bool:
        if self._inflight > 0:
            return False  # a completion may unlock a promotion
        if any(self._promotable(k) for k in range(len(self.rungs) - 1)):
            return False
        return self._proposed >= self.max_trials or self.inner.done

    # ------------------------------------------------------------------ misc

    def ask(self, n: Optional[int] = None) -> List[Dict[str, Any]]:
        raise NotImplementedError(
            "AshaStrategy is asynchronous (wants_async=True) — drive it via "
            "TrialScheduler.run(), which routes to run_async/submit/poll"
        )

    def tell(self, trials) -> None:
        raise NotImplementedError(
            "AshaStrategy is asynchronous — results arrive via on_result"
        )

    def result(self) -> AshaResult:
        best_config, best_time, best_fidelity = None, INFEASIBLE, 0.0
        for k in range(len(self.rungs) - 1, -1, -1):
            if self._rung_best_config[k] is not None:
                best_config = self._rung_best_config[k]
                best_time = self._rung_best_time[k]
                best_fidelity = self.rungs[k]
                break
        return AshaResult(
            best_config=best_config,
            best_time=best_time,
            best_fidelity=best_fidelity,
            rungs=list(self.rungs),
            rung_launched=list(self.rung_launched),
            rung_completed=list(self.rung_completed),
            promotions=list(self.promotions),
            proposals=self._proposed,
            inner=self.inner_name,
            eta=self.eta,
        )
