"""CMPE — Configuration Manager and Performance Evaluator (paper §VII).

Back-compat facade: the implementation moved to
:class:`repro.core.scheduler.TrialScheduler`, which adds concurrent batches,
a persistent cross-session cache, per-trial timeout/retry, and early-stopping
hooks. ``CMPE`` is the serial-defaults subclass keeping the original
constructor signature and single-trial ``evaluate`` semantics:

  1. apply the candidate config to the system (the analog of rewriting
     Hadoop's XML config files and restarting the daemons),
  2. run the job and measure execution time,
  3. append every trial to a JSONL log (the paper's provision for recovering
     the optimum and tracing errors),
  4. return the execution time to the algorithm; identical configurations
     are memoized.
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional

from repro.core.scheduler import (  # noqa: F401 — re-exported legacy names
    INFEASIBLE,
    Evaluator,
    Trial,
    TrialScheduler,
    _key,
    best_from_log,
    config_hash,
    config_key,
    read_log,
)


class CMPE(TrialScheduler):
    """The paper's CMPE: a TrialScheduler pinned to serial, uncached-on-disk
    evaluation (pass ``max_workers``/``cache_path`` to opt in to the engine
    features; the ask/tell drivers do)."""

    def __init__(
        self,
        evaluator: Evaluator,
        *,
        platform: str = "train",
        log_path: Optional[Path] = None,
        clear_caches_between_trials: bool = False,
        **scheduler_kwargs,
    ):
        super().__init__(
            evaluator,
            platform=platform,
            log_path=log_path,
            clear_caches_between_trials=clear_caches_between_trials,
            **scheduler_kwargs,
        )
