"""CMPE — Configuration Manager and Performance Evaluator (paper §VII).

The abstraction layer between the search algorithms (GSFT / CRS) and the
platform. The algorithms hand the CMPE a candidate configuration; the CMPE

  1. applies it to the system (builds the RunConfig / mesh / step function —
     the analog of rewriting Hadoop's XML config files and restarting the
     daemons; "safe-mode off / delete the output dir" becomes clearing the
     jit cache so every trial is isolated),
  2. runs the job / evaluates the cell and measures execution time,
  3. appends every trial to a **log file** (JSONL: timestamp, config, time,
     evaluator detail) — the paper's provision for recovering the optimum and
     tracing errors,
  4. returns (execution_time, info) to the algorithm.

Identical configurations are memoized (the evaluators here are deterministic;
the paper re-ran jobs because cluster timings are noisy).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Protocol, Tuple

INFEASIBLE = float("inf")


class Evaluator(Protocol):
    """config dict -> (execution time in seconds, info dict)."""

    def __call__(self, config: Dict[str, Any]) -> Tuple[float, Dict[str, Any]]: ...


@dataclass
class Trial:
    config: Dict[str, Any]
    time_s: float
    info: Dict[str, Any] = field(default_factory=dict)
    wall_s: float = 0.0
    error: Optional[str] = None


def _key(config: Dict[str, Any]) -> str:
    return json.dumps(config, sort_keys=True, default=str)


class CMPE:
    def __init__(
        self,
        evaluator: Evaluator,
        *,
        platform: str = "train",
        log_path: Optional[Path] = None,
        clear_caches_between_trials: bool = False,
    ):
        self.evaluator = evaluator
        self.platform = platform
        self.log_path = Path(log_path) if log_path else None
        self.clear_caches = clear_caches_between_trials
        self.trials: List[Trial] = []
        self._memo: Dict[str, Trial] = {}
        if self.log_path:
            self.log_path.parent.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------------- api

    def evaluate(self, config: Dict[str, Any], tag: str = "") -> float:
        """Tune the platform to ``config``, run the job, return execution
        time. Logs every call."""
        key = _key(config)
        if key in self._memo:
            trial = self._memo[key]
            self._log(trial, tag=tag, cached=True)
            return trial.time_s

        if self.clear_caches:
            import jax

            jax.clear_caches()  # trial isolation (paper: config rewrite + restart)

        t0 = time.time()
        try:
            t, info = self.evaluator(config)
            trial = Trial(dict(config), float(t), info, wall_s=time.time() - t0)
        except Exception as e:  # noqa: BLE001 — a failed run is a logged trial
            trial = Trial(dict(config), INFEASIBLE, {}, wall_s=time.time() - t0,
                          error=f"{type(e).__name__}: {e}")
        self.trials.append(trial)
        self._memo[key] = trial
        self._log(trial, tag=tag, cached=False)
        return trial.time_s

    def best(self) -> Trial:
        ok = [t for t in self.trials if t.error is None]
        if not ok:
            raise RuntimeError("no successful trials")
        return min(ok, key=lambda t: t.time_s)

    @property
    def num_evaluations(self) -> int:
        return len(self.trials)

    # ------------------------------------------------------------------- log

    def _log(self, trial: Trial, tag: str, cached: bool):
        if not self.log_path:
            return
        rec = {
            "ts": time.time(),
            "platform": self.platform,
            "tag": tag,
            "cached": cached,
            "config": trial.config,
            "time_s": trial.time_s,
            "wall_s": trial.wall_s,
            "error": trial.error,
            "info": {k: v for k, v in trial.info.items() if isinstance(v, (int, float, str, bool))},
        }
        with self.log_path.open("a") as f:
            f.write(json.dumps(rec, default=str) + "\n")


def read_log(path: Path) -> List[Dict[str, Any]]:
    """Recover trials from a CMPE log file (the paper's 'analyzing the log
    file helps in finding the optimal configuration')."""
    out = []
    for line in Path(path).read_text().splitlines():
        if line.strip():
            out.append(json.loads(line))
    return out


def best_from_log(path: Path) -> Dict[str, Any]:
    recs = [r for r in read_log(path) if r.get("error") is None]
    return min(recs, key=lambda r: r["time_s"])
