"""Static feasibility analysis — reject doomed configs before they burn a
worker.

The paper's CMPE (and our strategies) pay full execution time for every
sampled config, including ones that were provably doomed before launch.
This module is the propose-time gate: a :class:`StaticPrefilter` vets a
candidate config **without executing it**, from three kinds of evidence:

  1. **Space-level validity / clamp-aliasing** — the kernel ops layer snaps
     every proposed block size to a legal value (``snap_block`` /
     ``snap_chunk`` / ``snap_d_block``); a proposal the snap would *change*
     runs byte-identically to the snapped config that is already in the
     space, so measuring it burns a worker on a duplicate. WordCount's
     ``sort_buffer_tokens > block_tokens`` clamp is the same class.
  2. **Analytic VMEM footprint** — each Pallas kernel exposes a
     ``vmem_footprint`` model next to its snap helper (tiles + scratch +
     f32 intermediates ⇒ bytes); a config whose working set exceeds the
     per-core VMEM budget faults on hardware before producing a number.
  3. **Analytic HBM residency** — train/serve roofline cells reuse
     :func:`repro.core.roofline.estimate_tpu_hbm` (on a lightweight fake
     mesh — no jax device state) plus the mesh-divisibility rule
     ``make_tuning_mesh`` would raise on.

For compiled programs there is a fourth, deeper source: AOT lowering.
:func:`aot_memory_estimate` runs ``jax.jit(fn).lower(...)`` and feeds the
HLO text through :func:`repro.core.hlo.parse_memory` — the peak-buffer
estimator the cost-surrogate roadmap item trains on. It costs a trace (not
a compile), so it is exposed as an analysis helper rather than wired into
the per-proposal hot path.

The scheduler seam: ``TrialScheduler(prefilter=...)`` calls
``prefilter(config, platform, fidelity)`` before dispatching a fresh trial;
a :class:`Rejection` becomes a ``status="infeasible_static"`` trial record
(machine-readable rule + detail, persisted, replayed on resume, never
charged a worker or counted as an evaluation) that strategies see as an
infeasible penalty. ``--prefilter static|off`` on every CLI.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

__all__ = [
    "PREFILTER_MODES",
    "Rejection",
    "StaticPrefilter",
    "VMEM_BUDGET",
    "aot_memory_estimate",
    "make_prefilter",
]

# Per-core VMEM working-set budget (bytes) the kernel footprint models are
# checked against — the ~16 MiB of a TPU v4/v5 core.
VMEM_BUDGET = 16 * 1024 ** 2

PREFILTER_MODES = ("off", "static")


@dataclass(frozen=True)
class Rejection:
    """Why a config was statically rejected, machine-readable.

    ``rule`` is the stable identifier strategies/analysis key on
    (``snap_alias`` / ``vmem_budget`` / ``hbm_budget`` /
    ``mesh_divisibility``); ``reason`` the human-readable sentence;
    ``detail`` scalar evidence (proposed vs. snapped values, estimated vs.
    budget bytes) that rides into the trial record's info dict."""

    rule: str
    reason: str
    detail: Dict[str, Any] = field(default_factory=dict)


# A prefilter is any callable with this shape; None (or make_prefilter("off"))
# disables the gate.
Prefilter = Callable[[Dict[str, Any], str, float], Optional[Rejection]]


def make_prefilter(mode: str, **kwargs: Any) -> Optional["StaticPrefilter"]:
    """Resolve a ``--prefilter`` mode string: ``"off"`` → None (no gate),
    ``"static"`` → a :class:`StaticPrefilter`."""
    if mode in (None, "off"):
        return None
    if mode == "static":
        return StaticPrefilter(**kwargs)
    raise ValueError(
        f"unknown prefilter mode {mode!r} (one of {PREFILTER_MODES})"
    )


class StaticPrefilter:
    """The ``--prefilter static`` gate: dispatches on the cell's cache
    namespace (which carries the full workload identity — kernel + dtype +
    shape dims for kernel cells, arch:shape@chips for roofline cells) and
    applies the matching rule set. Namespaces it has no model for pass
    clean: the gate only ever rejects what it can *prove* doomed."""

    def __init__(
        self,
        vmem_budget: int = VMEM_BUDGET,
        hbm_budget: Optional[int] = None,
    ):
        self.vmem_budget = int(vmem_budget)
        self.hbm_budget = hbm_budget  # None = roofline's HBM_CAP

    def __call__(
        self, config: Dict[str, Any], platform: str, fidelity: float = 1.0
    ) -> Optional[Rejection]:
        if platform.startswith("kernel/"):
            return self.check_kernel(config, platform)
        if platform == "wordcount" or platform.startswith("wordcount/"):
            return self.check_wordcount(config)
        if platform.startswith(("train/", "serve/")):
            return self.check_roofline(config, platform)
        return None

    # ------------------------------------------------------------- kernels

    def check_kernel(
        self, config: Dict[str, Any], platform: str
    ) -> Optional[Rejection]:
        """Kernel-cell rules, resolved purely from the namespace string:
        ``kernel/<kernel>.<dtype>:<shape-class>`` carries every dim the snap
        helpers and footprint models need."""
        from repro.core.kernel_tune import parse_kernel_platform
        from repro.kernels import parse_shape_class

        try:
            kernel, dtype, shape_class = parse_kernel_platform(platform)
        except ValueError:
            return None
        dims = parse_shape_class(shape_class)
        dtype_bytes = {"f32": 4, "f16": 2, "bf16": 2, "f64": 8}.get(dtype, 4)

        if kernel == "flash_attention":
            from repro.kernels.flash_attention.ops import (
                snap_block,
                vmem_footprint,
            )

            s, dh = dims.get("s", 0), dims.get("d", 0)
            for knob in ("block_q", "block_kv"):
                if knob not in config:
                    continue
                snapped = snap_block(int(config[knob]), s)
                if snapped != int(config[knob]):
                    return _alias(knob, config[knob], snapped, s)
            bq = int(config.get("block_q", 128))
            bkv = int(config.get("block_kv", 128))
            return self._vmem(vmem_footprint(bq, bkv, dh, dtype_bytes))

        if kernel == "rwkv6":
            from repro.kernels.rwkv6.ops import snap_chunk, vmem_footprint

            s, hd = dims.get("s", 0), dims.get("d", 0)
            if "chunk" in config:
                snapped = snap_chunk(int(config["chunk"]), s)
                if snapped != int(config["chunk"]):
                    return _alias("chunk", config["chunk"], snapped, s)
            return self._vmem(
                vmem_footprint(int(config.get("chunk", 64)), hd, dtype_bytes)
            )

        # ssm_scan
        from repro.kernels.ssm_scan.ops import (
            snap_chunk,
            snap_d_block,
            vmem_footprint,
        )

        s, di, n = dims.get("s", 0), dims.get("di", 0), dims.get("n", 0)
        if "chunk" in config:
            snapped = snap_chunk(int(config["chunk"]), s)
            if snapped != int(config["chunk"]):
                return _alias("chunk", config["chunk"], snapped, s)
        if "d_block" in config:
            snapped = snap_d_block(int(config["d_block"]), di)
            if snapped != int(config["d_block"]):
                return _alias("d_block", config["d_block"], snapped, di)
        return self._vmem(vmem_footprint(
            int(config.get("chunk", 128)), int(config.get("d_block", 256)),
            n, dtype_bytes,
        ))

    def _vmem(self, est_bytes: int) -> Optional[Rejection]:
        if est_bytes <= self.vmem_budget:
            return None
        return Rejection(
            rule="vmem_budget",
            reason=(
                f"estimated VMEM working set {est_bytes} B exceeds the "
                f"{self.vmem_budget} B per-core budget"
            ),
            detail={
                "vmem_est_bytes": int(est_bytes),
                "vmem_budget_bytes": int(self.vmem_budget),
            },
        )

    # ----------------------------------------------------------- wordcount

    @staticmethod
    def check_wordcount(config: Dict[str, Any]) -> Optional[Rejection]:
        """WordCount's map task clamps the sort buffer to the block
        (``buf = min(max(sort_buffer, 1), block)``) — a proposal with
        ``sort_buffer_tokens > block_tokens`` runs byte-identically to the
        clamped config already in the space."""
        if "sort_buffer_tokens" not in config or "block_tokens" not in config:
            return None
        buf, block = int(config["sort_buffer_tokens"]), int(config["block_tokens"])
        if buf <= block:
            return None
        return Rejection(
            rule="snap_alias",
            reason=(
                f"sort_buffer_tokens={buf} is clamped to block_tokens={block} "
                "at run time — the proposal aliases the clamped config"
            ),
            detail={
                "param": "sort_buffer_tokens",
                "proposed": buf,
                "effective": block,
            },
        )

    # ------------------------------------------------------ roofline cells

    def check_roofline(
        self, config: Dict[str, Any], platform: str
    ) -> Optional[Rejection]:
        """Train/serve cell rules: mesh divisibility (the factorization
        ``make_tuning_mesh`` would raise on) and the analytic per-chip HBM
        residency vs. the 16 GiB cap — computed on a fake mesh, no jax
        device state, no compile."""
        from repro.configs.archs import get_arch
        from repro.configs.base import SHAPES
        from repro.core import roofline as rl
        from repro.core.space import SPACES
        from repro.core.transfer import parse_namespace

        cell = parse_namespace(platform)
        if cell.arch is None or cell.shape is None:
            return None
        try:
            arch = get_arch(cell.arch)
            shape = SHAPES[cell.shape]
        except (KeyError, ValueError):
            return None  # not a cell this gate has a model for
        space = SPACES[cell.base]
        run = space.to_run_config(config)
        chips = int(cell.chips)
        mp = min(int(config.get(
            "mesh_model_parallel", run.mesh_model_parallel)), chips)
        if chips % mp:
            return Rejection(
                rule="mesh_divisibility",
                reason=(
                    f"mesh_model_parallel={mp} does not divide the cell's "
                    f"{chips} chips — no mesh factorization exists"
                ),
                detail={"mesh_model_parallel": mp, "chips": chips},
            )
        run = run.replace(mesh_model_parallel=mp)
        est = rl.estimate_tpu_hbm(arch, run, shape, _FakeMesh(chips, mp))
        cap = rl.HBM_CAP if self.hbm_budget is None else int(self.hbm_budget)
        total = est["total_gib"] * 1024 ** 3
        if total <= cap:
            return None
        return Rejection(
            rule="hbm_budget",
            reason=(
                f"estimated per-chip HBM {est['total_gib']:.2f} GiB exceeds "
                f"the {cap / 1024 ** 3:.0f} GiB cap — the config OOMs before "
                "producing a number"
            ),
            detail={
                "hbm_est_gib": round(float(est["total_gib"]), 3),
                "hbm_budget_gib": round(cap / 1024 ** 3, 3),
                "chips": chips,
            },
        )


class _FakeMesh:
    """The two attributes :func:`estimate_tpu_hbm` reads off a mesh
    (axis names/sizes and total device count) without constructing jax
    device state — the prefilter must stay execution-free."""

    class _Devices:
        def __init__(self, shape):
            self.shape = shape
            self.size = 1
            for d in shape:
                self.size *= d

    def __init__(self, chips: int, model_parallel: int):
        self.axis_names = ("data", "model")
        self.devices = self._Devices((chips // model_parallel, model_parallel))


def _alias(param: str, proposed: Any, effective: int, bound: int) -> Rejection:
    return Rejection(
        rule="snap_alias",
        reason=(
            f"{param}={proposed} snaps to {effective} for this shape "
            f"(bound {bound}) — the proposal aliases a config already in "
            "the space"
        ),
        detail={
            "param": param,
            "proposed": int(proposed),
            "effective": int(effective),
        },
    )


# ------------------------------------------------------------- AOT analysis


def aot_memory_estimate(fn: Callable[..., Any], *args: Any, **kwargs: Any):
    """Lower ``fn`` ahead of time and statically estimate its peak buffer
    bytes from the HLO text: ``jax.jit(fn).lower(*args)`` →
    :func:`repro.core.hlo.parse_memory`. Costs a trace, not a compile or an
    execution — the deep-analysis path for compiled (train/serve) programs.
    The learned cost surrogate's optional HLO feature channel
    (:func:`repro.core.surrogate.hlo_features`) extracts from the same
    lowered text, adding :func:`~repro.core.hlo.parse_collectives` wire
    bytes next to this peak-memory estimate.

    Returns a :class:`repro.core.hlo.MemoryEstimate`."""
    import jax

    from repro.core.hlo import parse_memory

    lowered = jax.jit(fn).lower(*args, **kwargs)
    try:
        # lowered.as_text() is StableHLO MLIR; parse_memory wants HLO text
        text = lowered.compiler_ir(dialect="hlo").as_hlo_text()
    except Exception:
        text = lowered.as_text()
    return parse_memory(text)
