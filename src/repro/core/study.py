"""Study — the persistent, resumable tuning-session object (the user-facing
API every driver now goes through).

The paper's Admin workflow is "pick a platform × algorithm, run, read the
reduction". A :class:`Study` is that workflow made durable: it owns one
storage directory (trial log, persistent evaluation cache, session manifest
with space/platform/seed provenance) and accepts any number of heterogeneous
sessions against it:

    study = Study.create("results/studies/wc")
    study.optimize("wordcount", "gsft", evaluator)       # session 1
    study.optimize("wordcount", "tpe", evaluator,        # session 2 —
                   budget=48)                            #   warm-started free
    study.report()                                       # the reduction table

Because every session shares the study's evaluation cache, a later session
replays earlier measurements for nothing, a model-based strategy (TPE) seeds
its observation history from them through the sanctioned
``Strategy.on_study_attach(history)`` seam, and an interrupted session is
re-entered with :meth:`Study.resume` paying only the unpaid remainder of its
budget.

Engine knobs (parallel workers, isolation backend, per-trial timeout,
retries, patience, batch size) live on one validated :class:`EngineConfig`
instead of a kwarg forest; ``repro.core.tuner.tune`` remains as a thin
deprecated shim over a throwaway in-memory Study.
"""
from __future__ import annotations

import dataclasses
import inspect
import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence

from repro.core.scheduler import (
    TrialScheduler,
    iter_jsonl,
    jsonl_line,
    read_cache_by_platform,
    read_log,
)
from repro.core.space import SPACES, TunableSpace
from repro.core.strategies import STRATEGIES, make_strategy
from repro.core.strategies.base import QueueStrategy
from repro.core.transfer import (
    TRANSFER_MODES,
    SiblingHistory,
    Similarity,
    default_similarity,
    parse_namespace,
)

__all__ = ["EngineConfig", "Study", "StudyCell", "TuneOutcome", "run_session"]

_ISOLATIONS = ("inline", "subprocess")


# ------------------------------------------------------------- engine config


@dataclass(frozen=True)
class EngineConfig:
    """Every TrialScheduler/driver knob, validated in one place.

    ``workers``     parallel trials per batch (thread pool / worker processes)
    ``isolation``   ``"inline"`` (threads, soft timeouts) or ``"subprocess"``
                    (worker processes, hard SIGKILL deadlines)
    ``timeout_s``   per-trial deadline; None = unlimited
    ``retries``     per-trial retries before recording a failure
    ``patience``    stop a session when the best hasn't improved in N batches
    ``batch_size``  max configs per ask() batch (None = whole phase)
    ``clear_caches`` clear jit caches before every fresh trial (serial path)
    ``pin_devices`` restrict each subprocess worker to one of N device slots
                    (env set before the worker's first jax import), so N
                    workers run N truly concurrent device trials; requires
                    ``isolation="subprocess"``
    ``prefilter``   static feasibility gate at propose time: ``"static"``
                    rejects provably-doomed configs (clamp aliases, VMEM/HBM
                    overflow) as ``infeasible_static`` records without
                    charging a worker; ``"off"`` (default) runs everything
    ``surrogate``   learned cost model over the study cache: ``"rank"``
                    pre-ranks a surrogate-capable strategy's acquisition
                    candidates at the predicted frontier (TPE over-samples,
                    the :class:`~repro.core.surrogate.CostSurrogate` keeps
                    the predicted-fastest); ``"off"`` (default) disables it.
                    Strategies without ``supports_surrogate`` ignore it
    """

    workers: int = 1
    isolation: str = "inline"
    timeout_s: Optional[float] = None
    retries: int = 0
    patience: Optional[int] = None
    batch_size: Optional[int] = None
    clear_caches: bool = False
    pin_devices: Optional[int] = None
    prefilter: str = "off"
    surrogate: str = "off"

    def __post_init__(self):
        if int(self.workers) < 1:
            raise ValueError(f"EngineConfig.workers must be >= 1, got {self.workers}")
        if self.isolation not in _ISOLATIONS:
            raise ValueError(
                f"EngineConfig.isolation must be one of {_ISOLATIONS}, "
                f"got {self.isolation!r}"
            )
        if self.timeout_s is not None and not self.timeout_s > 0:
            raise ValueError(
                f"EngineConfig.timeout_s must be positive or None, got {self.timeout_s}"
            )
        if int(self.retries) < 0:
            raise ValueError(f"EngineConfig.retries must be >= 0, got {self.retries}")
        if self.patience is not None and int(self.patience) < 1:
            raise ValueError(
                f"EngineConfig.patience must be >= 1 or None, got {self.patience}"
            )
        if self.batch_size is not None and int(self.batch_size) < 1:
            raise ValueError(
                f"EngineConfig.batch_size must be >= 1 or None, got {self.batch_size}"
            )
        if self.pin_devices is not None:
            if int(self.pin_devices) < 1:
                raise ValueError(
                    f"EngineConfig.pin_devices must be >= 1 or None, "
                    f"got {self.pin_devices}"
                )
            if self.isolation != "subprocess":
                raise ValueError(
                    "EngineConfig.pin_devices requires isolation='subprocess' "
                    "— inline threads share one jax runtime and cannot be "
                    "pinned per trial"
                )
        from repro.core.feasibility import PREFILTER_MODES

        if self.prefilter not in PREFILTER_MODES:
            raise ValueError(
                f"EngineConfig.prefilter must be one of {PREFILTER_MODES}, "
                f"got {self.prefilter!r}"
            )
        from repro.core.surrogate import SURROGATE_MODES

        if self.surrogate not in SURROGATE_MODES:
            raise ValueError(
                f"EngineConfig.surrogate must be one of {SURROGATE_MODES}, "
                f"got {self.surrogate!r}"
            )

    def scheduler_kwargs(self) -> Dict[str, Any]:
        """Kwargs for :class:`TrialScheduler` (and the ``tune`` shim)."""
        return dict(
            max_workers=self.workers,
            timeout_s=self.timeout_s,
            retries=self.retries,
            isolation=self.isolation,
            clear_caches_between_trials=self.clear_caches,
            pin_devices=self.pin_devices,
            prefilter=self.prefilter,
        )

    def run_kwargs(self) -> Dict[str, Any]:
        """Kwargs for :meth:`TrialScheduler.run`."""
        return dict(batch_size=self.batch_size, patience=self.patience)

    def replace(self, **changes: Any) -> "EngineConfig":
        return dataclasses.replace(self, **changes)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "EngineConfig":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in (d or {}).items() if k in names})


# ------------------------------------------------------------- tune outcome


@dataclass
class TuneOutcome:
    platform: str
    algorithm: str
    default_time: float
    best_time: float
    best_config: Dict[str, Any]
    evaluations: int
    detail: Any = None
    # per-SESSION deltas (not scheduler-lifetime totals): a shared multi-cell
    # or multi-session scheduler must not inflate every report
    cache_stats: Optional[Dict[str, int]] = None
    timeouts: int = 0  # trials that hit the (soft) per-trial deadline
    # proposals the static prefilter rejected without running them — their
    # own counter, never folded into evaluations or timeouts
    infeasible_static: int = 0

    @property
    def reduction_pct(self) -> float:
        """The paper's headline metric: % reduction in execution time vs. the
        all-defaults configuration."""
        if self.default_time in (0.0, float("inf")):
            return 0.0
        return 100.0 * (self.default_time - self.best_time) / self.default_time

    def summary(self) -> Dict[str, Any]:
        out = {
            "platform": self.platform,
            "algorithm": self.algorithm,
            "default_time_s": self.default_time,
            "best_time_s": self.best_time,
            "reduction_pct": round(self.reduction_pct, 2),
            "evaluations": self.evaluations,
            "timeouts": self.timeouts,
            "best_config": self.best_config,
        }
        if self.infeasible_static:
            out["infeasible_static"] = self.infeasible_static
        if self.cache_stats:
            out["cache_stats"] = self.cache_stats
        # multi-fidelity provenance: an ASHA session's per-rung counters ride
        # into sessions.jsonl so fidelity savings are auditable after the fact
        if hasattr(self.detail, "rung_table"):
            out["rungs"] = self.detail.rung_table()
            out["best_fidelity"] = self.detail.best_fidelity
        return out


# ------------------------------------------------------------ session engine


def run_session(
    scheduler: TrialScheduler,
    platform: str,
    algorithm: str,
    space: TunableSpace,
    *,
    fixed: Optional[Dict[str, Any]] = None,
    active_params: Optional[Sequence[str]] = None,
    batch_size: Optional[int] = None,
    patience: Optional[int] = None,
    siblings: Optional[Sequence[SiblingHistory]] = None,
    transfer: str = "off",
    **algo_kwargs,
) -> TuneOutcome:
    """One tuning session on an already-configured scheduler: measure the
    defaults, drive the strategy, report per-session deltas.

    This is the engine path under :meth:`Study.optimize` and the
    ``tuner.tune`` shim; share one scheduler across calls to share its memo
    and persistent cache (the multi-cell driver does).

    ``siblings``/``transfer`` is the cross-cell channel: when ``transfer``
    is not ``"off"`` and the strategy declares ``supports_transfer``, the
    sibling histories ride into ``on_study_attach`` alongside the cached
    history (``Study._run_session`` computes them via
    :meth:`Study.histories_for`; resume replays the recorded set).
    """
    if transfer not in TRANSFER_MODES:
        raise ValueError(
            f"transfer must be one of {TRANSFER_MODES}, got {transfer!r}"
        )
    factory = _factory_for(algorithm)
    # warm-start a model-based strategy from the persistent eval cache
    # *before* the defaults trial lands in it: a re-run over a complete cache
    # resumes with its full observation history and proposes nothing fresh
    attach_history = (
        getattr(factory, "supports_history", False)
        and "history" not in algo_kwargs
    )
    history = scheduler.cached_observations() if attach_history else None
    has_transfer = (
        transfer != "off"
        and bool(siblings)
        and getattr(factory, "supports_transfer", False)
    )
    # strategies that override the on_study_attach seam receive history
    # there; legacy supports_history strategies — including protocol-only
    # classes with no hook attribute at all — still get the constructor kwarg
    hook = getattr(factory, "on_study_attach", None)
    uses_hook = hook is not None and hook is not QueueStrategy.on_study_attach
    if attach_history and not uses_hook:
        algo_kwargs["history"] = history
    # a surrogate-enabled strategy predicts in this cell's namespace: the
    # session's platform is its context unless the caller pinned one
    if (
        getattr(factory, "supports_surrogate", False)
        and str(algo_kwargs.get("surrogate", "off")) != "off"
    ):
        algo_kwargs.setdefault("platform", platform)

    before = scheduler.stats_snapshot()
    defaults = {**space.defaults(), **(fixed or {})}
    # a multi-fidelity session caps out at its schedule's top rung — the
    # defaults yardstick must be measured at the SAME fidelity or the
    # reduction comparison mixes scales
    top_fidelity = (
        float(algo_kwargs.get("max_fidelity", 1.0)) if algorithm == "asha"
        else 1.0
    )
    default_time = scheduler.evaluate(
        defaults, tag="default", fidelity=top_fidelity
    )

    if algorithm in ("gsft", "grid"):
        algo_kwargs.setdefault("active_params", active_params)
    strategy = make_strategy(algorithm, space, fixed=fixed, **algo_kwargs)
    # the surrogate's training channel: sibling histories flow to a
    # surrogate-enabled strategy even with transfer="off" — the cost model
    # (not the Parzen prior) is what consumes them there
    has_surrogate = (
        bool(siblings) and getattr(strategy, "surrogate", "off") != "off"
    )
    if uses_hook and (attach_history or has_transfer or has_surrogate):
        transfer_kwargs = (
            {"siblings": list(siblings), "transfer": transfer}
            if (has_transfer or has_surrogate) else {}
        )
        strategy.on_study_attach(
            history if attach_history else (), **transfer_kwargs
        )
    result = scheduler.run(strategy, batch_size=batch_size, patience=patience)
    best_config, best_time = result.best_config, result.best_time

    # equal-fidelity incumbent rule: a best measured below the session's top
    # rung (ASHA stopped before anything reached it) is a cheaper experiment
    # on a different scale — the full-scale defaults measurement beats it by
    # fiat rather than by a meaningless comparison
    sub_fidelity = (
        getattr(result, "best_fidelity", top_fidelity) < top_fidelity
        and default_time < float("inf")
    )
    # defaults themselves might be the optimum; the log keeps everything
    if default_time < best_time or sub_fidelity:
        best_config, best_time = defaults, default_time

    after = scheduler.stats_snapshot()
    return TuneOutcome(
        platform=platform,
        algorithm=algorithm,
        default_time=default_time,
        best_time=best_time,
        best_config=best_config,
        evaluations=after["evaluations"] - before["evaluations"],
        detail=result,
        cache_stats={
            k: after[k] - before[k] for k in ("fresh", "memo_hits", "cache_hits")
        },
        timeouts=after["timeouts"] - before["timeouts"],
        infeasible_static=(
            after["infeasible_static"] - before["infeasible_static"]
        ),
    )


# ------------------------------------------------------------------- helpers


def _factory_for(algorithm: str):
    try:
        return STRATEGIES[algorithm]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {algorithm!r} (use one of {sorted(STRATEGIES)})"
        ) from None


def _space_for(name: str) -> TunableSpace:
    """Resolve a platform name to its shipped space. Cell platforms are
    namespaced ``train/arch:shape`` — the prefix names the space."""
    base = name.split("/", 1)[0]
    if base in SPACES:
        return SPACES[base]
    if base == "wordcount":
        from repro.apps.wordcount import WORDCOUNT_SPACE

        return WORDCOUNT_SPACE
    raise ValueError(
        f"no shipped space for platform {name!r} — pass space= explicitly"
    )


def _accepts_kwarg(factory: Any, name: str) -> bool:
    try:
        sig = inspect.signature(factory)
    except (TypeError, ValueError):  # builtins / exotic callables: assume yes
        return True
    params = sig.parameters.values()
    if any(p.kind is p.VAR_KEYWORD for p in params):
        return True
    return name in sig.parameters


_MISSING = object()  # serialization-failure sentinel — None is a legal value


def _jsonable(obj: Any) -> Any:
    """``obj`` if it round-trips through JSON, else ``_MISSING`` (NOT None:
    a legitimately-None kwarg must not read as a serialization failure)."""
    try:
        json.dumps(obj)
        return obj
    except (TypeError, ValueError):
        return _MISSING


def _spec_ref(evaluator: Any) -> Optional[Dict[str, Any]]:
    """JSON-able recipe for rebuilding an evaluator on resume — only when it
    carries a dotted-path :class:`~repro.core.executors.EvaluatorSpec` with
    JSON-able arguments (a pickled instance or numpy payload does not
    round-trip through the session manifest)."""
    spec = getattr(evaluator, "spec", None)
    if spec is None or not isinstance(getattr(spec, "target", None), str):
        return None
    ref = {
        "target": spec.target,
        "args": list(spec.args),
        "kwargs": dict(spec.kwargs),
        "construct": bool(spec.construct),
    }
    return ref if _jsonable(ref) is not _MISSING else None


# ---------------------------------------------------------------------- study


class Study:
    """A persistent, resumable collection of tuning sessions over one storage
    directory (``Study.create`` / ``Study.load`` / ``Study.open``), or an
    ephemeral in-memory session holder (``Study()`` — what the deprecated
    ``tune()`` shim uses).

    Storage layout under ``path``:

      - ``study.json``     manifest: version, creation time, seed, engine
      - ``cache.jsonl``    persistent evaluation cache (platform-namespaced)
      - ``trials.jsonl``   every trial of every session (the paper's log)
      - ``sessions.jsonl`` session provenance: start/done records
    """

    MANIFEST = "study.json"
    VERSION = 1

    def __init__(
        self,
        path: Optional[Path] = None,
        *,
        engine: Optional[EngineConfig] = None,
        seed: int = 0,
        cache_path: Optional[Path] = None,
        log_path: Optional[Path] = None,
    ):
        self.path = Path(path) if path else None
        self.engine = engine or EngineConfig()
        self.seed = int(seed)
        if self.path is not None:
            self.path.mkdir(parents=True, exist_ok=True)
            self.cache_path: Optional[Path] = self.path / "cache.jsonl"
            self.log_path: Optional[Path] = self.path / "trials.jsonl"
            self._sessions_path: Optional[Path] = self.path / "sessions.jsonl"
        else:  # in-memory study, optionally with explicit storage files
            self.cache_path = Path(cache_path) if cache_path else None
            self.log_path = Path(log_path) if log_path else None
            self._sessions_path = None
        self._sessions: List[Dict[str, Any]] = self._load_sessions()
        self._outcomes: List[TuneOutcome] = []
        self._cells: Dict[str, "StudyCell"] = {}
        self._open_schedulers: List[TrialScheduler] = []

    # ------------------------------------------------------------ lifecycle

    @classmethod
    def create(
        cls,
        path: Path,
        *,
        engine: Optional[EngineConfig] = None,
        seed: int = 0,
    ) -> "Study":
        """Create a new study directory (manifest + empty storage). Refuses
        to clobber an existing study — use :meth:`load` or :meth:`open`."""
        path = Path(path)
        manifest = path / cls.MANIFEST
        if manifest.exists():
            raise FileExistsError(
                f"study already exists at {path} — use Study.load()/Study.open()"
            )
        study = cls(path, engine=engine, seed=seed)
        manifest.write_text(json.dumps({
            "version": cls.VERSION,
            "created": time.time(),
            "seed": study.seed,
            "engine": study.engine.to_dict(),
        }, indent=1))
        return study

    @classmethod
    def load(cls, path: Path, *, engine: Optional[EngineConfig] = None) -> "Study":
        """Load an existing study; ``engine`` overrides the stored defaults
        for this process only (the manifest is not rewritten)."""
        path = Path(path)
        manifest = path / cls.MANIFEST
        if not manifest.exists():
            raise FileNotFoundError(
                f"no study at {path} (missing {cls.MANIFEST}) — use Study.create()"
            )
        meta = json.loads(manifest.read_text())
        return cls(
            path,
            engine=engine or EngineConfig.from_dict(meta.get("engine", {})),
            seed=int(meta.get("seed", 0)),
        )

    @classmethod
    def open(
        cls,
        path: Path,
        *,
        engine: Optional[EngineConfig] = None,
        seed: int = 0,
    ) -> "Study":
        """Load the study at ``path`` if one exists, else create it — the
        CLI's ``--study DIR`` semantics."""
        if (Path(path) / cls.MANIFEST).exists():
            return cls.load(path, engine=engine)
        return cls.create(path, engine=engine, seed=seed)

    def close(self) -> None:
        """Release every scheduler the study holds open (cell schedulers and
        their warm subprocess workers). Idempotent."""
        for sched in self._open_schedulers:
            sched.close()
        self._open_schedulers = []
        self._cells = {}

    def __enter__(self) -> "Study":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- sessions

    def optimize(
        self,
        platform: str,
        algorithm: str,
        evaluator: Any,
        *,
        space: Optional[TunableSpace] = None,
        budget: Optional[int] = None,
        seed: Optional[int] = None,
        fixed: Optional[Dict[str, Any]] = None,
        active_params: Optional[Sequence[str]] = None,
        engine: Optional[EngineConfig] = None,
        transfer: str = "off",
        similarity: Optional[Similarity] = None,
        **algo_kwargs,
    ) -> TuneOutcome:
        """Run one tuning session against the study's storage.

        ``budget`` maps onto the strategy's trial-budget knob (strategies
        declare it via ``budget_kwarg``, e.g. TPE's ``max_trials``); cached
        history the strategy itself produced counts toward it, so repeating a
        session over a complete cache proposes nothing fresh. ``seed``
        defaults to the study seed for strategies that take one.

        ``transfer`` turns on the cross-cell channel: ``"warm"`` seeds the
        strategy's initial candidates from sibling-cell incumbents,
        ``"prior"`` feeds sibling observations to TPE's densities with a
        distance-decayed weight (see :meth:`histories_for`); sibling trials
        never count toward ``budget``. ``similarity`` overrides the sibling
        distance function — cell families whose namespaces don't follow the
        train/serve arch:shape grammar (e.g. kernel cells) supply their own.
        """
        space = space or _space_for(platform)
        eng = engine or self.engine
        scheduler = self.scheduler(evaluator, platform=platform, engine=eng)
        try:
            return self._run_session(
                scheduler, platform, algorithm, space, eng,
                budget=budget, seed=seed, fixed=fixed,
                active_params=active_params, evaluator=evaluator,
                transfer=transfer, similarity=similarity,
                **algo_kwargs,
            )
        finally:
            scheduler.close()

    def histories_for(
        self,
        platform: str,
        *,
        similarity: Optional[Similarity] = None,
        max_siblings: Optional[int] = None,
        max_distance: Optional[float] = None,
    ) -> List[SiblingHistory]:
        """Sibling-cell histories for ``platform``, closest first: one
        :class:`~repro.core.transfer.SiblingHistory` per *other* cache
        namespace whose distance under ``similarity`` (default
        :func:`~repro.core.transfer.default_similarity` over arch, shape,
        chips) is finite. Grouping is by each record's **stored** namespace,
        so a ``train/a:s@512c`` chip-count variant is its own sibling, never
        folded into ``train/a:s``, and legacy unplatformed records are
        attributed to no cell at all. Only clean ``status="ok"`` records
        qualify — a sibling's timeouts and errors are not evidence."""
        if self.cache_path is None or not self.cache_path.exists():
            return []
        sim = similarity or default_similarity
        me = parse_namespace(platform)
        out: List[SiblingHistory] = []
        for ns, records in read_cache_by_platform(self.cache_path).items():
            if not ns or ns == platform:
                continue
            distance = sim(me, parse_namespace(ns))
            if distance is None or not (distance < float("inf")):
                continue
            if max_distance is not None and distance > max_distance:
                continue
            trials = tuple(
                (dict(rec["config"]), float(rec["time_s"]), rec.get("tag"))
                for rec in records.values()
                if "config" in rec and "time_s" in rec
                and rec.get("status", "ok") == "ok"
                and float(rec.get("fidelity", 1.0)) >= 1.0
            )
            if trials:
                out.append(SiblingHistory(ns, float(distance), trials))
        out.sort(key=lambda s: (s.distance, s.namespace))
        return out[:max_siblings] if max_siblings is not None else out

    def _run_session(
        self,
        scheduler: TrialScheduler,
        platform: str,
        algorithm: str,
        space: TunableSpace,
        eng: EngineConfig,
        *,
        budget: Optional[int],
        seed: Optional[int],
        fixed: Optional[Dict[str, Any]],
        active_params: Optional[Sequence[str]],
        evaluator: Any,
        resumes: Optional[int] = None,
        transfer: str = "off",
        siblings: Optional[List[SiblingHistory]] = None,
        similarity: Optional[Similarity] = None,
        **algo_kwargs,
    ) -> TuneOutcome:
        misplaced = sorted({
            "batch_size", "patience", "max_workers", "workers", "timeout_s",
            "retries", "isolation", "clear_caches", "cache_path", "log_path",
        } & set(algo_kwargs))
        if misplaced:
            raise ValueError(
                f"optimize(): {', '.join(misplaced)} are engine/storage "
                "knobs, not strategy kwargs — configure them on EngineConfig "
                "(engine=...) or the study directory"
            )
        factory = _factory_for(algorithm)
        if transfer not in TRANSFER_MODES:
            raise ValueError(
                f"transfer must be one of {TRANSFER_MODES}, got {transfer!r}"
            )
        if transfer != "off":
            modes = getattr(factory, "transfer_modes", ())
            if not getattr(factory, "supports_transfer", False) or not modes:
                raise ValueError(
                    f"algorithm {algorithm!r} does not support cross-cell "
                    "transfer (supports_transfer is not set) — run with "
                    "transfer='off'"
                )
            if transfer not in modes:
                # e.g. gsft/crs asked for "prior": downgrade to the mode the
                # strategy actually implements, and record THAT — provenance
                # must never claim a prior that was really warm seeding
                transfer = modes[-1] if "warm" not in modes else "warm"
        # the learned cost surrogate: plumb EngineConfig.surrogate (or an
        # explicit surrogate= strategy kwarg) into surrogate-capable
        # strategies, with the cell namespace as prediction context. Its
        # training set rides the sibling channel even when the Parzen
        # transfer prior is off — cross-study transfer in model form
        wants_surrogate = (
            getattr(factory, "supports_surrogate", False)
            and str(algo_kwargs.get("surrogate", eng.surrogate)) != "off"
        )
        if wants_surrogate:
            # run_session injects the namespace (its ``platform`` argument)
            # as the strategy's prediction context; only the mode rides here
            algo_kwargs.setdefault("surrogate", eng.surrogate)
        if transfer == "off" and not wants_surrogate:
            siblings = None
        elif siblings is None:  # resume passes the recorded set instead
            siblings = self.histories_for(platform, similarity=similarity)
        if budget is not None:
            budget_kwarg = getattr(factory, "budget_kwarg", None)
            if not budget_kwarg:
                raise ValueError(
                    f"algorithm {algorithm!r} does not define a budget knob — "
                    "pass its own kwargs (e.g. samples_per_param for gsft, "
                    "m/k/max_rounds for crs)"
                )
            algo_kwargs.setdefault(budget_kwarg, int(budget))
        if "seed" not in algo_kwargs and _accepts_kwarg(factory, "seed"):
            algo_kwargs["seed"] = self.seed if seed is None else int(seed)

        sid = self._next_session_id()
        # provenance that fails to round-trip through JSON is recorded as
        # DROPPED, not silently as null — resume() refuses lossy records
        # rather than re-running the session minus its constraints. That
        # includes an explicitly-passed history= (it was budget-charged
        # evidence in this session; a resume must not swap it for the cache).
        dropped = [
            k for k, v in algo_kwargs.items() if _jsonable(v) is _MISSING
        ]
        if fixed and _jsonable(dict(fixed)) is _MISSING:
            dropped.append("fixed")
        start_rec = {
            "event": "start",
            "session": sid,
            "ts": time.time(),
            "platform": platform,
            "algorithm": algorithm,
            "space": space.platform,
            "budget": budget,
            "seed": algo_kwargs.get("seed"),
            "fixed": dict(fixed) if fixed and "fixed" not in dropped else None,
            "active_params": list(active_params) if active_params else None,
            "args": {
                k: v for k, v in algo_kwargs.items()
                if _jsonable(v) is not _MISSING
            },
            "engine": eng.to_dict(),
            "log_path": str(scheduler.log_path) if scheduler.log_path else None,
            "evaluator_spec": _spec_ref(evaluator),
        }
        if siblings is not None:
            # the exact sibling set is session provenance: resume must replay
            # THESE namespaces (and these trial-count prefixes), not whatever
            # the cache holds by then — and must raise if one went missing.
            # Recorded whenever the sibling channel was open (transfer OR a
            # surrogate training set), even when the set came up empty
            start_rec["transfer"] = {
                "mode": transfer,
                "siblings": [
                    {"namespace": s.namespace, "distance": s.distance,
                     "trials": len(s.trials)}
                    for s in (siblings or [])
                ],
            }
        if dropped:
            start_rec["args_dropped"] = sorted(dropped)
        if resumes is not None:
            start_rec["resumes"] = resumes
        self._record(start_rec)

        try:
            outcome = run_session(
                scheduler, platform, algorithm, space,
                fixed=fixed, active_params=active_params,
                siblings=siblings, transfer=transfer,
                **eng.run_kwargs(), **algo_kwargs,
            )
        except Exception as e:
            # a deterministic failure (bad kwarg, broken strategy) closes the
            # session so resume() can't latch onto it forever; interruptions
            # (KeyboardInterrupt and harder) stay open — they ARE the resume
            # case
            self._record({
                "event": "failed",
                "session": sid,
                "ts": time.time(),
                "error": f"{type(e).__name__}: {e}",
            })
            raise
        self._record({
            "event": "done",
            "session": sid,
            "ts": time.time(),
            "summary": outcome.summary(),
        })
        self._outcomes.append(outcome)
        return outcome

    # ------------------------------------------------- external session seam

    _LIFECYCLE_EVENTS = ("start", "done", "failed", "cell")

    def begin_session(
        self,
        platform: str,
        algorithm: str,
        *,
        space: Optional[str] = None,
        mode: str = "offline",
        args: Optional[Dict[str, Any]] = None,
    ) -> int:
        """Open a session whose trials are produced OUTSIDE the scheduler
        engine (the online serving controller) yet journaled with the same
        provenance: a ``start`` record in ``sessions.jsonl`` carrying
        ``mode`` (``"online"`` sessions are skipped by :meth:`resume` — the
        serving driver re-enters them with the surviving baseline instead of
        replaying a strategy budget). Returns the session id; close it with
        :meth:`end_session`."""
        sid = self._next_session_id()
        self._record({
            "event": "start",
            "session": sid,
            "ts": time.time(),
            "platform": platform,
            "algorithm": algorithm,
            "space": space,
            "mode": mode,
            "args": {
                k: v for k, v in (args or {}).items()
                if _jsonable(v) is not _MISSING
            },
            "engine": self.engine.to_dict(),
            "log_path": str(self.log_path) if self.log_path else None,
        })
        return sid

    def record_session_event(
        self, session: int, event: str, fields: Optional[Dict[str, Any]] = None
    ) -> None:
        """Journal one event record against an open session (the online
        controller's guard decisions ride through here). Lifecycle event
        names are reserved for the study itself."""
        if event in self._LIFECYCLE_EVENTS:
            raise ValueError(
                f"event {event!r} is a reserved lifecycle event — "
                "begin_session/end_session own those"
            )
        self._record({
            "event": event,
            "session": int(session),
            "ts": time.time(),
            **{k: v for k, v in (fields or {}).items()
               if _jsonable(v) is not _MISSING},
        })

    def end_session(self, session: int, summary: Dict[str, Any]) -> None:
        """Close a :meth:`begin_session` session with its ``done`` summary
        (same record shape the engine path writes — :meth:`report` rows pick
        the shared keys up with no special casing)."""
        self._record({
            "event": "done",
            "session": int(session),
            "ts": time.time(),
            "summary": {
                k: v for k, v in (summary or {}).items()
                if _jsonable(v) is not _MISSING
            },
        })

    def append_trial_record(self, rec: Dict[str, Any]) -> None:
        """Append one trial-shaped record to the study's trial log — the
        seam non-scheduler trial producers (per-window online measurements)
        persist through, so :meth:`trials` and ``read_log`` see one stream.
        No-op for an in-memory study with no log file."""
        if self.log_path is None:
            return
        self.log_path.parent.mkdir(parents=True, exist_ok=True)
        with self.log_path.open("a") as f:
            f.write(jsonl_line({"ts": time.time(), **rec}) + "\n")

    def resume(
        self,
        evaluator: Any = None,
        *,
        space: Optional[TunableSpace] = None,
        engine: Optional[EngineConfig] = None,
    ) -> TuneOutcome:
        """Re-enter the most recent interrupted session (a ``start`` record
        with no matching ``done``), paying only the unpaid remainder — every
        trial the crashed session persisted replays from the cache, and a
        history-aware strategy resumes with the budget it already spent.

        Online serving sessions (``mode="online"``) are not resumable here:
        their state is a surviving baseline, not an unpaid strategy budget —
        ``repro.launch.serve --online-tune`` re-enters them via
        :func:`repro.serving.journal.surviving_baseline`.

        The evaluator is rebuilt from the session's stored
        ``EvaluatorSpec`` recipe when it has one; otherwise pass
        ``evaluator=`` explicitly.
        """
        done = {r["session"] for r in self._sessions if r["event"] == "done"}
        resumes_of = {
            r["session"]: r["resumes"] for r in self._sessions
            if r["event"] == "start" and r.get("resumes") is not None
        }
        # a resume attempt closes its target only once it actually COMPLETES
        # (a failed resume re-opens the original — its unpaid remainder is
        # still owed), and completion propagates down resume CHAINS: if
        # session 3 resumed session 2 which resumed session 1, session 3
        # finishing pays off all three
        completed = set(done)
        frontier = True
        while frontier:
            frontier = {
                target for sid, target in resumes_of.items()
                if sid in completed and target not in completed
            }
            completed |= frontier
        closed = completed | {
            r["session"] for r in self._sessions if r["event"] == "failed"
        }
        open_recs = [
            r for r in self._sessions
            if r["event"] == "start" and r["session"] not in closed
            and r.get("mode", "offline") != "online"
        ]
        if not open_recs:
            raise ValueError(
                "nothing to resume: every recorded session completed"
            )
        rec = open_recs[-1]
        if rec.get("args_dropped"):
            raise ValueError(
                f"session {rec['session']} cannot be resumed faithfully: "
                f"{', '.join(rec['args_dropped'])} did not round-trip through "
                "the session manifest (non-JSON values) — re-run optimize() "
                "with the original arguments instead"
            )
        if evaluator is None:
            ref = rec.get("evaluator_spec")
            if not ref:
                raise ValueError(
                    f"session {rec['session']} ({rec['platform']}/"
                    f"{rec['algorithm']}) stored no evaluator recipe — pass "
                    "evaluator= to resume()"
                )
            from repro.core.executors import EvaluatorSpec

            evaluator = EvaluatorSpec(
                target=ref["target"], args=tuple(ref.get("args", ())),
                kwargs=dict(ref.get("kwargs", {})),
                construct=bool(ref.get("construct", True)),
            ).resolve()
        space = space or _space_for(rec.get("space") or rec["platform"])
        eng = engine or EngineConfig.from_dict(rec.get("engine", {}))
        kwargs = dict(rec.get("args") or {})
        seed = kwargs.pop("seed", None)  # recorded post-injection; re-route
        # a transfer (or surrogate-training) session resumes with the SAME
        # sibling set it started with — rebuilt from the recorded namespaces
        # and trial-count prefixes; a sibling namespace that disappeared from
        # the cache is a hard error, never a silent no-transfer rerun. The
        # record's presence (not its mode) gates the rebuild: a surrogate
        # session stores mode="off" with a live sibling list
        stored_transfer = rec.get("transfer")
        transfer = (stored_transfer or {}).get("mode", "off")
        siblings = (
            self._siblings_from_record(rec, stored_transfer.get("siblings") or [])
            if stored_transfer is not None else None
        )
        scheduler = self.scheduler(
            evaluator, platform=rec["platform"], engine=eng,
            # a session logging to a custom file (per-cell logs) must keep
            # appending there — the remainder must not land elsewhere
            log_path=Path(rec["log_path"]) if rec.get("log_path") else None,
        )
        try:
            return self._run_session(
                scheduler, rec["platform"], rec["algorithm"], space, eng,
                budget=None, seed=seed, fixed=rec.get("fixed"),
                active_params=rec.get("active_params"), evaluator=evaluator,
                resumes=rec["session"], transfer=transfer, siblings=siblings,
                **kwargs,
            )
        finally:
            scheduler.close()

    def _siblings_from_record(
        self, rec: Dict[str, Any], stored: List[Dict[str, Any]]
    ) -> List[SiblingHistory]:
        """Rebuild a recorded sibling set from the cache: per namespace, the
        first ``trials`` clean records in cache order (the append-order
        prefix the original session saw — later sibling growth must not
        change a resumed session's prior). Missing or shrunken namespaces
        raise."""
        grouped = (
            read_cache_by_platform(self.cache_path)
            if self.cache_path is not None and self.cache_path.exists() else {}
        )
        out: List[SiblingHistory] = []
        problems: List[str] = []
        for s in stored:
            ns, want = s["namespace"], int(s["trials"])
            trials = tuple(
                (dict(r["config"]), float(r["time_s"]), r.get("tag"))
                for r in grouped.get(ns, {}).values()
                if "config" in r and "time_s" in r
                and r.get("status", "ok") == "ok"
            )[:want]
            if len(trials) < want:
                problems.append(f"{ns} ({len(trials)}/{want} records)")
                continue
            out.append(SiblingHistory(ns, float(s["distance"]), trials))
        if problems:
            raise ValueError(
                f"session {rec['session']} cannot be resumed faithfully: its "
                f"transfer prior used sibling namespaces no longer (fully) in "
                f"the cache: {', '.join(problems)} — restore the cache or "
                "re-run optimize() from scratch"
            )
        return out

    # ---------------------------------------------------------------- cells

    def has_cell(self, arch: str, shape: str) -> bool:
        """Whether :meth:`cell` already holds a handle for this cell (so a
        caller can reuse it without re-supplying setup arguments)."""
        return f"{arch}:{shape}" in self._cells

    def cell(
        self,
        arch: str,
        shape: str,
        *,
        chips: Optional[int] = None,
        evaluator: Any = None,
        log_path: Optional[Path] = None,
    ) -> "StudyCell":
        """Handle for one (arch × shape) cell of a tuning matrix. Repeated
        calls return the same handle, so the cell's sessions share one
        scheduler (probe memo and all) on top of the study-wide cache — and
        therefore a repeat call may not silently change the cell's setup:
        explicitly passed ``chips``/``evaluator``/``log_path`` that conflict
        with the existing handle's raise (its cached measurements were taken
        under the first call's setup). ``chips=None`` means "no opinion"
        (defaults to 256 on creation). The chip count is persisted with the
        study, so the guard holds ACROSS processes too: reopening a study
        with a conflicting explicit ``chips`` raises rather than silently
        replaying the other topology's cached measurements (evaluator and
        log_path conflicts are only detectable within one process)."""
        key = f"{arch}:{shape}"
        cell = self._cells.get(key)
        if cell is None:
            stored = next(
                (r for r in self._sessions
                 if r.get("event") == "cell" and r.get("cell") == key),
                None,
            )
            if stored is not None:
                if chips is not None and chips != stored["chips"]:
                    raise ValueError(
                        f"cell {key!r} was created in this study with "
                        f"chips={stored['chips']} — its cached trials were "
                        f"measured under that topology; use a separate study "
                        f"for chips={chips}"
                    )
                eff_chips = int(stored["chips"])
            else:
                eff_chips = 256 if chips is None else int(chips)
                self._record({
                    "event": "cell", "cell": key, "chips": eff_chips,
                    "ts": time.time(),
                })
            cell = self._cells[key] = StudyCell(
                self, arch, shape, chips=eff_chips,
                evaluator=evaluator, log_path=log_path,
            )
            return cell
        conflicts = []
        if chips is not None and chips != cell.chips:
            conflicts.append("chips")
        if evaluator is not None and evaluator is not cell._evaluator:
            conflicts.append("evaluator")
        if log_path is not None and log_path != cell._log_path:
            conflicts.append("log_path")
        if conflicts:
            raise ValueError(
                f"cell {key!r} already exists with different "
                f"{', '.join(conflicts)} — its cached trials were measured "
                "under the first call's setup; use a separate study (or cell "
                "name) for a different configuration"
            )
        return cell

    # ------------------------------------------------------------ accessors

    def scheduler(
        self,
        evaluator: Any,
        *,
        platform: str,
        engine: Optional[EngineConfig] = None,
        log_path: Optional[Path] = None,
    ) -> TrialScheduler:
        """A TrialScheduler wired to this study's storage — the seam for
        drivers that run strategies directly (the curated hillclimb sweep).
        The caller owns closing it (or hands it to the study via cells)."""
        eng = engine or self.engine
        return TrialScheduler(
            evaluator,
            platform=platform,
            log_path=log_path or self.log_path,
            cache_path=self.cache_path,
            **eng.scheduler_kwargs(),
        )

    def trials(self, platform: Optional[str] = None) -> List[Dict[str, Any]]:
        """Every logged trial record, optionally filtered to one platform."""
        if self.log_path is None or not self.log_path.exists():
            return []
        return read_log(self.log_path, platform=platform)

    def _candidates(self) -> List[Dict[str, Any]]:
        """Successful measurements across the study, one file read: cache
        records plus this process's outcomes (in-memory studies have no
        cache file). Sub-fidelity records (ASHA's cheap rungs) are excluded —
        a fast low-rung time is a cheaper experiment, never the study's
        best."""
        candidates: List[Dict[str, Any]] = []
        if self.cache_path is not None:
            candidates += [
                {
                    "platform": rec.get("platform"),
                    "config": rec.get("config"),
                    "time_s": float(rec["time_s"]),
                }
                for rec in iter_jsonl(self.cache_path)
                if rec.get("status", "ok") == "ok" and "time_s" in rec
                and float(rec.get("fidelity", 1.0)) >= 1.0
            ]
        for out in self._outcomes:
            candidates.append({
                "platform": out.platform,
                "config": out.best_config,
                "time_s": out.best_time,
            })
        return candidates

    def best(self, platform: Optional[str] = None) -> Dict[str, Any]:
        """Best successful measurement across the whole study (or one
        platform): ``{"platform", "config", "time_s"}``."""
        candidates = [
            c for c in self._candidates()
            if platform is None or c["platform"] == platform
        ]
        if not candidates:
            where = f" (platform={platform!r})" if platform else ""
            raise ValueError(f"no successful trials in study{where}")
        return min(candidates, key=lambda r: r["time_s"])

    def sessions(self) -> List[Dict[str, Any]]:
        """Raw session provenance records (start/done events, file order)."""
        return list(self._sessions)

    def report(self) -> Dict[str, Any]:
        """The paper's reduction table, one row per session, with
        per-session cache/evaluation deltas (never lifetime totals)."""
        done = {
            r["session"]: r for r in self._sessions if r["event"] == "done"
        }
        failed = {
            r["session"] for r in self._sessions if r["event"] == "failed"
        }
        rows = []
        platforms = set()
        for rec in self._sessions:
            if rec["event"] != "start":
                continue
            sid = rec["session"]
            platforms.add(rec["platform"])
            tr = rec.get("transfer") or {}
            row: Dict[str, Any] = {
                "session": sid,
                "platform": rec["platform"],
                "algorithm": rec["algorithm"],
                "status": ("done" if sid in done
                           else "failed" if sid in failed
                           else "interrupted"),
                "transfer": tr.get("mode", "off"),
            }
            if tr.get("mode", "off") != "off":
                row["transfer_siblings"] = len(tr.get("siblings") or [])
            srg = (rec.get("args") or {}).get("surrogate", "off")
            if srg != "off":
                row["surrogate"] = srg
                row["surrogate_siblings"] = len(tr.get("siblings") or [])
            if rec.get("resumes") is not None:
                row["resumes"] = rec["resumes"]
            if rec.get("mode", "offline") != "offline":
                row["mode"] = rec["mode"]
            if sid in done:
                s = done[sid].get("summary", {})
                for k in ("default_time_s", "best_time_s", "reduction_pct",
                          "evaluations", "timeouts", "infeasible_static",
                          "cache_stats", "rungs", "best_fidelity",
                          # online serving sessions: guard-decision counters
                          "windows", "rollbacks", "promotions", "demotions",
                          "rejections"):
                    if k in s:
                        row[k] = s[k]
            rows.append(row)
        best: Dict[str, Dict[str, Any]] = {}
        for cand in self._candidates():  # one cache read for every platform
            p = cand["platform"]
            if p in platforms and (
                p not in best or cand["time_s"] < best[p]["time_s"]
            ):
                best[p] = cand
        best = dict(sorted(best.items()))
        # perf observability: the process-wide probe-compile cache counters
        # (lazy import — report() must not pay the roofline/jax import for
        # studies that never touched a roofline evaluator)
        from repro.core.roofline import probe_cache_stats

        return {
            "study": str(self.path) if self.path else None,
            "sessions": rows,
            "best": best,
            "probe_cache": probe_cache_stats(),
        }

    # -------------------------------------------------------------- plumbing

    def _track(self, scheduler: TrialScheduler) -> None:
        self._open_schedulers.append(scheduler)

    def _next_session_id(self) -> int:
        ids = [r["session"] for r in self._sessions if "session" in r]
        return (max(ids) + 1) if ids else 1

    def _record(self, rec: Dict[str, Any]) -> None:
        self._sessions.append(rec)
        if self._sessions_path is not None:
            with self._sessions_path.open("a") as f:
                f.write(jsonl_line(rec) + "\n")

    def _load_sessions(self) -> List[Dict[str, Any]]:
        if self._sessions_path is None:
            return []
        return iter_jsonl(self._sessions_path)


# ----------------------------------------------------------------- studycell


class StudyCell:
    """One (arch × shape) cell of a tuning matrix, bound to a study.

    All of a cell's sessions share one TrialScheduler — so the roofline
    probe-compile memo survives across sessions — while the cell's trials are
    namespaced ``{train|serve}/arch:shape`` in the study-wide cache (the same
    knob dict on a different cell must never collide)."""

    def __init__(
        self,
        study: Study,
        arch: str,
        shape: str,
        *,
        chips: int = 256,
        evaluator: Any = None,
        log_path: Optional[Path] = None,
    ):
        from repro.configs.base import SHAPES

        if shape not in SHAPES:
            raise ValueError(
                f"unknown shape {shape!r} (known: {sorted(SHAPES)})"
            )
        self.study = study
        self.arch_name = arch
        self.shape_name = shape
        self.chips = int(chips)
        self.platform = "train" if SHAPES[shape].kind == "train" else "serve"
        self.space = SPACES[self.platform]
        self.platform_key = f"{self.platform}/{arch}:{shape}"
        self._evaluator = evaluator
        self._default_evaluator = evaluator is None
        self._log_path = log_path
        self._scheduler: Optional[TrialScheduler] = None
        self._engine: Optional[EngineConfig] = None

    @property
    def name(self) -> str:
        return f"{self.arch_name}:{self.shape_name}"

    def evaluator(self) -> Any:
        if self._evaluator is None:
            from repro.configs.archs import get_arch
            from repro.configs.base import SHAPES
            from repro.core.evaluators import RooflineEvaluator

            arch = get_arch(self.arch_name)
            shape = SHAPES[self.shape_name]
            if shape.name in arch.skip_shapes:
                raise ValueError(
                    f"{self.shape_name} is skipped for {self.arch_name}"
                )
            self._evaluator = RooflineEvaluator(
                arch, shape, self.space, chips=self.chips
            )
        return self._evaluator

    def scheduler(self) -> TrialScheduler:
        if self._scheduler is None:
            eng = self.study.engine
            if self._default_evaluator:
                # the roofline evaluator mutates global compiler state; match
                # the historical multi-cell discipline of clearing jit caches
                eng = eng.replace(clear_caches=True)
            self._engine = eng
            self._scheduler = self.study.scheduler(
                self.evaluator(), platform=self.platform_key, engine=eng,
                log_path=self._log_path,
            )
            self.study._track(self._scheduler)
        return self._scheduler

    def optimize(
        self,
        algorithm: str,
        *,
        budget: Optional[int] = None,
        seed: Optional[int] = None,
        fixed: Optional[Dict[str, Any]] = None,
        active_params: Optional[Sequence[str]] = None,
        transfer: str = "off",
        **algo_kwargs,
    ) -> TuneOutcome:
        """One tuning session on this cell, through its shared scheduler.
        ``transfer`` pulls sibling-cell histories from the study-wide cache
        (see :meth:`Study.histories_for`)."""
        scheduler = self.scheduler()
        assert self._engine is not None
        return self.study._run_session(
            scheduler, self.platform_key, algorithm, self.space, self._engine,
            budget=budget, seed=seed, fixed=fixed,
            active_params=active_params, evaluator=self._evaluator,
            transfer=transfer,
            **algo_kwargs,
        )
