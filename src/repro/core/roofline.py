"""Roofline analysis of compiled (arch × shape × mesh) cells — TPU v5e model.

Three terms per cell, all derived from ``.lower().compile()`` artifacts (no
execution — this container is CPU-only, v5e is the *target*):

    compute    = HLO_FLOPs_per_device   / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device   / HBM_bandwidth_per_chip
    collective = wire_bytes_per_device  / ICI_link_bandwidth

Predicted step time is ``max`` of the three (TPUs overlap DMA/ICI with MXU
compute; the dominant term is the bottleneck the §Perf loop works on).

**Trip-count correction.** ``cost_analysis()`` counts a ``while`` body once,
so a scanned L-layer model under-reports by ~L×. We therefore compile two (or
three, when microbatched) *loop-free* reduced-depth variants — 1 and 2
structural periods, with every internal scan unrolled — and solve the affine
cost model

    cost(G, M) = c0 + M·c_m + M·G·c_layer          (train, M microbatches)
    cost(G)    = c0 + G·c_layer                     (serve)

for the full depth G = num_layers / period. The *real* (scanned) artifact is
still compiled first: it proves the production program compiles, and provides
``memory_analysis()`` (per-device HBM residency) — memory numbers must come
from the real program, not the unrolled cost probes.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI, 16 GiB HBM. Cross-pod (DCI) hops are modeled at 25 GB/s.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax

from repro.configs.base import ArchConfig, RunConfig, ShapeConfig
from repro.core.hlo import CollectiveStats, parse_collectives
from repro.models import transformer as tfm

# ----------------------------------------------------------------- constants

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (intra-pod)
DCI_BW = 25e9  # bytes/s cross-pod
HBM_CAP = 16 * 1024**3  # bytes per chip


@dataclass
class CostTerms:
    """Per-device totals for one compiled program."""

    flops: float = 0.0
    bytes_accessed: float = 0.0
    collectives: CollectiveStats = field(default_factory=CollectiveStats)

    def __sub__(self, o: "CostTerms") -> "CostTerms":
        return CostTerms(
            self.flops - o.flops,
            self.bytes_accessed - o.bytes_accessed,
            CollectiveStats.combine(self.collectives, o.collectives, 1.0, -1.0),
        )

    def __add__(self, o: "CostTerms") -> "CostTerms":
        return CostTerms(
            self.flops + o.flops,
            self.bytes_accessed + o.bytes_accessed,
            CollectiveStats.combine(self.collectives, o.collectives, 1.0, 1.0),
        )

    def scaled(self, k: float) -> "CostTerms":
        return CostTerms(self.flops * k, self.bytes_accessed * k, self.collectives.scaled(k))


def extract_costs(compiled) -> CostTerms:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return CostTerms(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        collectives=parse_collectives(compiled.as_text()),
    )


@dataclass
class MemoryStats:
    argument_bytes: int = 0
    output_bytes: int = 0
    temp_bytes: int = 0
    alias_bytes: int = 0

    @property
    def peak_bytes(self) -> int:
        # donated (aliased) buffers are not double-counted
        return self.argument_bytes + self.temp_bytes + self.output_bytes - self.alias_bytes

    @property
    def fits_hbm(self) -> bool:
        return self.peak_bytes <= HBM_CAP

    def summary(self) -> Dict:
        return {
            "argument_bytes": self.argument_bytes,
            "output_bytes": self.output_bytes,
            "temp_bytes": self.temp_bytes,
            "alias_bytes": self.alias_bytes,
            "peak_bytes": self.peak_bytes,
            "peak_gib": round(self.peak_bytes / 1024**3, 3),
            "fits_hbm_16gib": self.fits_hbm,
        }


def extract_memory(compiled) -> MemoryStats:
    ma = compiled.memory_analysis()
    if ma is None:
        return MemoryStats()
    return MemoryStats(
        argument_bytes=int(getattr(ma, "argument_size_in_bytes", 0)),
        output_bytes=int(getattr(ma, "output_size_in_bytes", 0)),
        temp_bytes=int(getattr(ma, "temp_size_in_bytes", 0)),
        alias_bytes=int(getattr(ma, "alias_size_in_bytes", 0)),
    )


# ------------------------------------------------------- TPU memory estimate


def estimate_tpu_hbm(arch: ArchConfig, run: RunConfig, shape: ShapeConfig, mesh) -> Dict:
    """Analytic per-chip HBM residency on the *target* (TPU v5e, native bf16).

    ``memory_analysis()`` of the CPU executable over-reports activation
    stacks: XLA:CPU has no native bf16 compute, so every saved bf16 tensor
    gains a hoisted f32 copy for the emulated matmuls (verified in the HLO;
    see DESIGN.md). This model counts what actually resides on a TPU chip:

      params (+ grads + AdamW moments when training, dtype-aware, sharded per
      the ZeRO rules) + per-layer saved scan carries (remat policy) + KV/state
      caches + a transient working set (logits + attention/MoE blocks).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    mp = sizes.get("model", 1)
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    n_dev = mesh.devices.size
    dsize = {"float32": 4, "bfloat16": 2, "int8": 1}
    n_params = arch.param_count()

    mode = shape.kind
    b_loc = max(shape.global_batch // dp, 1)
    mb = run.microbatch_size or 0
    if mode == "train" and mb and mb < shape.global_batch:
        b_loc = max(mb // dp, 1)
    s = shape.seq_len if mode != "decode" else 1
    d = arch.d_model
    cd = dsize[run.compute_dtype]

    if mode == "train":
        p_shards = n_dev if run.zero_sharding == "fsdp" else mp
        o_shards = n_dev if run.zero_sharding in ("fsdp", "zero1") else mp
        params_b = n_params * dsize[run.param_dtype] / p_shards
        grads_b = n_params * 4 / p_shards
        opt_b = 2 * n_params * dsize[run.optimizer_moment_dtype] / o_shards
    else:
        params_b = n_params * dsize[run.weight_dtype] / n_dev
        grads_b = opt_b = 0.0

    # saved residual-stream carries across the layer scan (bf16), per remat
    from repro.models.transformer import num_groups as _ng

    saved_mult = {"full": 1.0, "dots": 4.0, "none": 12.0}[run.remat_policy]
    carries_b = 0.0
    if mode == "train":
        carries_b = _ng(arch) * b_loc * s * d * cd * saved_mult

    # caches (decode/prefill)
    cache_b = 0.0
    if mode != "train":
        kvd = dsize[run.kv_cache_dtype]
        dh = arch.resolved_head_dim
        n_attn = sum(1 for k, _ in arch.layer_kinds() if k in ("attn", "attn_local"))
        cache_tokens = shape.seq_len * shape.global_batch
        kv_shards = n_dev if shape.global_batch < dp else dp * (
            mp if (arch.num_kv_heads % mp == 0 or shape.seq_len % mp == 0) else 1
        )
        cache_b += 2 * n_attn * cache_tokens * arch.num_kv_heads * dh * kvd / kv_shards
        n_ssm = sum(1 for k, _ in arch.layer_kinds() if k in ("mamba", "rwkv"))
        if n_ssm:
            state = (
                arch.ssm_expand * d * arch.ssm_state_dim * 4
                if "mamba" in arch.block_pattern
                else d * arch.rwkv_head_dim * 4
            )
            cache_b += n_ssm * shape.global_batch * state / max(dp, 1)

    # transient working set: logits + one layer's activation blocks
    vloc = arch.padded_vocab / mp
    logits_b = (b_loc * s * vloc * (cd + 4)) if mode == "train" else (b_loc * 1 * vloc * 4)
    hq = arch.num_heads
    attn_block_b = b_loc * max(hq // mp, 1) * s * min(run.attn_block_kv, s) * 4
    ff = arch.d_ff_expert or arch.d_ff
    mlp_b = b_loc * s * max(ff // mp, ff // mp) * cd
    workset_b = logits_b + 2 * attn_block_b + 2 * mlp_b

    total = params_b + grads_b + opt_b + carries_b + cache_b + workset_b
    return {
        "params_gib": params_b / 1024**3,
        "grads_gib": grads_b / 1024**3,
        "opt_gib": opt_b / 1024**3,
        "carries_gib": carries_b / 1024**3,
        "cache_gib": cache_b / 1024**3,
        "workset_gib": workset_b / 1024**3,
        "total_gib": total / 1024**3,
        "fits_hbm_16gib": total <= HBM_CAP,
    }


# ------------------------------------------------------------------ roofline


@dataclass
class Roofline:
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops_global: float
    hlo_flops_global: float
    n_chips: int

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_step(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops_global / max(self.hlo_flops_global, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the predicted step
        time: (useful FLOPs / chips / peak) / t_step — i.e. MFU at t_step."""
        ideal = self.model_flops_global / self.n_chips / PEAK_FLOPS
        return ideal / max(self.t_step, 1e-30)

    def summary(self) -> Dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "t_step_s": self.t_step,
            "bottleneck": self.bottleneck,
            "model_flops_global": self.model_flops_global,
            "hlo_flops_global": self.hlo_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction_mfu": self.roofline_fraction,
        }


def collective_time(stats: CollectiveStats, n_pods: int) -> float:
    """Wire time: per-group-size traffic; groups of size == n_pods are DCI."""
    t = 0.0
    for g, b in stats.by_group_size.items():
        bw = DCI_BW if (n_pods > 1 and int(g) == n_pods) else ICI_BW
        t += b / bw
    return t


def model_flops(arch: ArchConfig, shape: ShapeConfig) -> float:
    """Useful FLOPs per step: 6·N_active·D (train) or 2·N_active·D (serve),
    D = tokens processed this step."""
    n_active = arch.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence + KV-cache attention reads (2·T·Hkv·Dh·Hq? —
    # count only the parameter term; attention dominates the *memory* roof)
    return 2.0 * n_active * shape.global_batch


def make_roofline(
    per_device: CostTerms,
    arch: ArchConfig,
    shape: ShapeConfig,
    mesh,
) -> Roofline:
    n_chips = mesh.devices.size
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_pods = sizes.get("pod", 1)
    return Roofline(
        t_compute=per_device.flops / PEAK_FLOPS,
        t_memory=per_device.bytes_accessed / HBM_BW,
        t_collective=collective_time(per_device.collectives, n_pods),
        model_flops_global=model_flops(arch, shape),
        hlo_flops_global=per_device.flops * n_chips,
        n_chips=n_chips,
    )


# ----------------------------------------------------- trip-count correction


def reduced_arch(arch: ArchConfig, n_periods: int) -> ArchConfig:
    period = tfm.structural_period(arch)
    return dataclasses.replace(arch, num_layers=period * n_periods)


# Cross-cell probe-compile cache: the multi-cell matrix walk (and repeated
# sessions over the same cell) hit `_compile_cost_probe` with identical
# (arch, probe RunConfig, shape, mesh) keys — every RooflineEvaluator used to
# recompile them because its memo is per-instance. The extracted CostTerms
# are pure functions of the compiled artifact, so one process-wide cache is
# safe (RooflineEvaluator is parallel_safe=False — the scheduler serializes
# access; subprocess workers each own a process-local copy).
_PROBE_COSTS: Dict[Tuple, CostTerms] = {}
_PROBE_COSTS_LOCK = threading.Lock()
_PROBE_HITS = 0
_PROBE_MISSES = 0


def _probe_cache_key(arch, probe_run, shape, mesh, make_step_fn) -> Tuple:
    # the step builder is keyed by OBJECT, not by name: two distinct
    # closures can share a __qualname__ while building different programs,
    # and the cache entry holding the reference keeps the id stable
    return (
        arch,
        probe_run,
        shape,
        tuple(mesh.axis_names),
        tuple(mesh.devices.shape),
        make_step_fn,
    )


def probe_cache_stats() -> Dict[str, int]:
    """Process-wide probe-compile cache counters: resident entries plus
    lifetime hit/miss counts — the observability hook ``study.report()``
    surfaces so fidelity/cache savings are measurable, not anecdotal."""
    return {
        "entries": len(_PROBE_COSTS),
        "hits": _PROBE_HITS,
        "misses": _PROBE_MISSES,
    }


def clear_probe_cache() -> None:
    global _PROBE_HITS, _PROBE_MISSES
    with _PROBE_COSTS_LOCK:
        _PROBE_COSTS.clear()
        _PROBE_HITS = 0
        _PROBE_MISSES = 0


def _compile_cost_probe(arch, run, shape, mesh, make_step_fn, microbatch=0) -> CostTerms:
    """Loop-free compile of a reduced cell; returns per-device costs.
    Identical probes — same (arch, probe RunConfig, shape, mesh topology,
    step builder) — are compiled once per process."""
    global _PROBE_HITS, _PROBE_MISSES
    probe_run = run.replace(scan_layers=False, microbatch_size=microbatch)
    key = _probe_cache_key(arch, probe_run, shape, mesh, make_step_fn)
    with _PROBE_COSTS_LOCK:
        hit = _PROBE_COSTS.get(key)
        if hit is not None:
            _PROBE_HITS += 1
        else:
            _PROBE_MISSES += 1
    if hit is not None:
        return hit
    bundle = make_step_fn(arch, probe_run, shape, mesh)
    compiled = bundle.lower().compile()
    costs = extract_costs(compiled)
    with _PROBE_COSTS_LOCK:
        _PROBE_COSTS[key] = costs
    return costs


def extrapolated_costs(
    arch: ArchConfig,
    run: RunConfig,
    shape: ShapeConfig,
    mesh,
    make_step_fn,
    single_probe: bool = False,
) -> Tuple[CostTerms, Dict[str, float]]:
    """Solve the affine cost model from loop-free reduced-depth probes and
    return full-depth per-device costs (+ probe timing diagnostics).

    ``single_probe=True`` is the low-fidelity path (ASHA's cheap rungs):
    only the L1 probe is compiled and the full-depth cost is the naive
    ``a1·g`` extrapolation — it overcounts the fixed per-step overhead by
    ``(g-1)·c0``, but ranks candidates well enough to screen, at one compile
    instead of two or three. It shares the L1 probe cache entry with the
    full path, so promoting a screened config pays only the missing
    probes."""
    period = tfm.structural_period(arch)
    g_full = arch.num_layers // period
    times = {}

    t0 = time.time()
    a1 = _compile_cost_probe(reduced_arch(arch, 1), run, shape, mesh, make_step_fn)
    times["probe_L1_s"] = time.time() - t0
    if g_full == 1:
        return a1, times
    if single_probe:
        times["probe_single"] = 1.0
        return a1.scaled(g_full), times

    t0 = time.time()
    a2 = _compile_cost_probe(reduced_arch(arch, 2), run, shape, mesh, make_step_fn)
    times["probe_L2_s"] = time.time() - t0

    b = shape.global_batch
    mb = run.microbatch_size or 0
    n_micro = b // mb if (shape.kind == "train" and mb and mb < b and b % mb == 0) else 1

    c_layer = a2 - a1
    if n_micro == 1:
        c0 = a1 - c_layer
        full = c0 + c_layer.scaled(g_full)
        return full, times

    # microbatched: probe (L1, M=2) for the per-microbatch overhead. Layer
    # work is token-proportional (the full batch passes through every layer
    # regardless of how it is split), so c_l does NOT scale with M — only the
    # per-microbatch accumulation overhead c_m does:
    #   cost(G, M) = c0 + M·c_m + G·c_l ; probes A=(1,1), B=(2,1), C=(1,2)
    t0 = time.time()
    a_m2 = _compile_cost_probe(
        reduced_arch(arch, 1), run, shape, mesh, make_step_fn, microbatch=b // 2
    )
    times["probe_M2_s"] = time.time() - t0
    c_l = c_layer  # B - A
    c_m = a_m2 - a1  # C - A
    c0 = a1 - c_m - c_l
    full = c0 + c_m.scaled(n_micro) + c_l.scaled(g_full)
    return full, times
