"""Tunable configuration spaces — the paper's §III "parameters" tables.

The paper curates 12 Hadoop and 11 Spark parameters (out of ~200/~180), each
with a default and a bounded range, and two value types: *continuous*
(numeric, sampled with a predefined step) and *boolean/categorical*. We mirror
that exactly for the two "platforms" of a distributed JAX framework:

  - ``train``  platform — 12 knobs (the Hadoop analog)
  - ``serve``  platform — 11 knobs (the Spark analog)

Every knob is a real ``RunConfig`` field consumed by the distribution layer
(sharding rules, step builders, kernels); none are decorative. Like the
paper's spaces, some knobs matter enormously for a given job and some are
long-tail (e.g. ``attn_block_q`` only binds on the Pallas path — the tuner
has to *discover* that, just as the paper's Table VII shows
``spark.scheduler.listenerbus`` moving nothing).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import RunConfig


@dataclass(frozen=True)
class Param:
    name: str
    default: Any

    def grid(self, num: int) -> List[Any]:
        raise NotImplementedError

    def sample(self, rng, lo=None, hi=None) -> Any:
        raise NotImplementedError

    def snap(self, value) -> Any:
        return value

    @property
    def numeric(self) -> bool:
        return False


@dataclass(frozen=True)
class IntParam(Param):
    lo: int = 0
    hi: int = 1
    step: int = 1
    pow2: bool = False  # snap to powers of two (mesh factors, block sizes)

    @property
    def numeric(self) -> bool:
        return True

    def _valid(self, v: int) -> int:
        v = int(round(v))
        v = max(self.lo, min(self.hi, v))
        if self.pow2:
            # nearest power of two within bounds
            import math

            if v <= 0:
                return max(self.lo, 1) if self.lo > 0 else 0
            p = 2 ** round(math.log2(max(v, 1)))
            return int(max(self.lo, min(self.hi, p)))
        if self.step > 1:
            v = self.lo + round((v - self.lo) / self.step) * self.step
            v = max(self.lo, min(self.hi, v))
        return int(v)

    def snap(self, value) -> int:
        return self._valid(value)

    def grid(self, num: int) -> List[int]:
        if self.pow2:
            vals, v = [], max(self.lo, 1)
            while v <= self.hi:
                vals.append(v)
                v *= 2
            if self.lo == 0:
                vals = [0] + vals
            return vals[:: max(len(vals) // num, 1)] if num < len(vals) else vals
        if num <= 1:
            return [self.default]
        step = max((self.hi - self.lo) / (num - 1), self.step)
        out, v = [], float(self.lo)
        while v <= self.hi + 1e-9:
            out.append(self._valid(v))
            v += step
        return sorted(set(out))

    def grid_between(self, lo: float, hi: float, step: float) -> List[int]:
        out, v = [], lo
        guard = 0
        while v <= hi + 1e-9 and guard < 64:
            out.append(self._valid(v))
            v += max(step, 1e-9)
            guard += 1
        return sorted(set(out))

    def sample(self, rng, lo=None, hi=None) -> int:
        lo = self.lo if lo is None else lo
        hi = self.hi if hi is None else hi
        return self._valid(lo + rng.random() * (hi - lo))


@dataclass(frozen=True)
class FloatParam(Param):
    lo: float = 0.0
    hi: float = 1.0
    step: float = 0.1

    @property
    def numeric(self) -> bool:
        return True

    def snap(self, value) -> float:
        """Clamp into bounds AND quantize to the ``step`` grid anchored at
        ``lo`` (matching ``IntParam.snap`` — the paper samples continuous
        parameters 'with a predefined step', so CRS/TPE proposals must land
        on the same grid the sweeps walk). A quantum that rounds past ``hi``
        clamps back to ``hi``."""
        v = float(max(self.lo, min(self.hi, value)))
        if self.step > 0:
            v = self.lo + round((v - self.lo) / self.step) * self.step
            v = float(max(self.lo, min(self.hi, v)))
        return v

    def grid(self, num: int) -> List[float]:
        if num <= 1:
            return [self.default]
        step = (self.hi - self.lo) / (num - 1)
        # step-quantized snapping can collapse neighbours — dedupe like IntParam
        return sorted({self.snap(self.lo + i * step) for i in range(num)})

    def grid_between(self, lo: float, hi: float, step: float) -> List[float]:
        out, v, guard = [], lo, 0
        while v <= hi + 1e-9 and guard < 64:
            out.append(self.snap(v))
            v += max(step, 1e-9)
            guard += 1
        return sorted(set(out))

    def sample(self, rng, lo=None, hi=None) -> float:
        lo = self.lo if lo is None else lo
        hi = self.hi if hi is None else hi
        return self.snap(lo + rng.random() * (hi - lo))


@dataclass(frozen=True)
class CatParam(Param):
    choices: Tuple[Any, ...] = ()

    def grid(self, num: int) -> List[Any]:
        return list(self.choices)

    def snap(self, value):
        return value if value in self.choices else self.default

    def sample(self, rng, lo=None, hi=None):
        return self.choices[int(rng.random() * len(self.choices)) % len(self.choices)]


def BoolParam(name: str, default: bool) -> CatParam:
    return CatParam(name, default, choices=(False, True))


@dataclass(frozen=True)
class TunableSpace:
    """A platform's curated knob set (paper Table I / Table II analog)."""

    platform: str
    params: Tuple[Param, ...]
    most_influential: Tuple[str, ...]  # the paper's finer-tuning set

    def __post_init__(self):
        names = [p.name for p in self.params]
        assert len(set(names)) == len(names)
        for m in self.most_influential:
            assert m in names, m

    def param(self, name: str) -> Param:
        return next(p for p in self.params if p.name == name)

    def names(self) -> List[str]:
        return [p.name for p in self.params]

    def defaults(self) -> Dict[str, Any]:
        return {p.name: p.default for p in self.params}

    def snap(self, config: Dict[str, Any]) -> Dict[str, Any]:
        return {k: self.param(k).snap(v) for k, v in config.items()}

    def to_run_config(self, config: Dict[str, Any], base: Optional[RunConfig] = None) -> RunConfig:
        base = base or RunConfig()
        fields = {f.name for f in dataclasses.fields(RunConfig)}
        overrides = {k: v for k, v in config.items() if k in fields}
        return base.replace(**overrides)


# ---------------------------------------------------------------- the spaces

# Training platform — the "Hadoop 12" (paper Table I analog).
TRAIN_SPACE = TunableSpace(
    platform="train",
    params=(
        IntParam("mesh_model_parallel", 16, lo=1, hi=64, pow2=True),
        IntParam("microbatch_size", 0, lo=0, hi=128, pow2=True),
        CatParam("remat_policy", "full", choices=("none", "dots", "full")),
        IntParam("attn_block_q", 512, lo=128, hi=2048, step=128),
        IntParam("attn_block_kv", 512, lo=128, hi=2048, step=128),
        CatParam("matmul_precision", "bf16", choices=("bf16", "f32")),
        CatParam("grad_compression", "off", choices=("off", "int8")),
        BoolParam("scan_layers", True),
        CatParam("zero_sharding", "fsdp", choices=("none", "zero1", "fsdp")),
        CatParam("collective_matmul", "ag", choices=("ag", "rs")),
        BoolParam("moe_expert_parallel", True),
        CatParam("optimizer_moment_dtype", "float32", choices=("float32", "bfloat16")),
    ),
    most_influential=("mesh_model_parallel", "microbatch_size"),
)

# Serving platform — the "Spark 11" (paper Table II analog).
SERVE_SPACE = TunableSpace(
    platform="serve",
    params=(
        IntParam("mesh_model_parallel", 16, lo=1, hi=64, pow2=True),
        CatParam("kv_cache_dtype", "bfloat16", choices=("bfloat16", "int8")),
        CatParam("kv_partition", "auto", choices=("auto", "heads", "sequence")),
        IntParam("attn_block_kv", 512, lo=128, hi=2048, step=128),
        IntParam("attn_block_q", 512, lo=128, hi=2048, step=128),
        CatParam("weight_dtype", "bfloat16", choices=("bfloat16", "int8")),
        CatParam("matmul_precision", "bf16", choices=("bf16", "f32")),
        BoolParam("scan_layers", True),
        BoolParam("moe_expert_parallel", True),
        CatParam("collective_matmul", "ag", choices=("ag", "rs")),
        CatParam("embed_impl", "gather", choices=("gather", "one_hot")),
    ),
    most_influential=("mesh_model_parallel", "attn_block_kv"),
)

SPACES = {"train": TRAIN_SPACE, "serve": SERVE_SPACE}
