"""Evaluator backends for the CMPE — the "run the job, measure time" step.

Three interchangeable implementations of the ``Evaluator`` protocol:

  - ``WalltimeEvaluator`` — actually executes a jitted job on the local
    devices and measures wall-clock time. This is the paper-faithful path
    (their trials ran WordCount on the cluster); used for the WordCount
    reproduction and CPU-sized LM jobs, and it is what you would run
    unchanged on a real v5e pod.
  - ``RooflineEvaluator`` — AOT: builds the (arch × shape) step under the
    candidate config on a tuner-chosen mesh, compiles the loop-free probes,
    and returns the roofline-predicted step time max(compute, memory,
    collective). Infeasible configs (estimated HBM overflow on the target
    chip) are penalized. This is the evaluator for the production-mesh cells
    in this CPU-only container.
  - ``FunctionEvaluator`` — wraps a plain function (unit tests / synthetic
    objectives with known optima).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

from repro.compat import set_mesh as compat_set_mesh
from repro.configs.base import ArchConfig, RunConfig, ShapeConfig
from repro.core import roofline as rl
from repro.core.space import TunableSpace


@dataclass
class FunctionEvaluator:
    """Wraps a plain function. Picklable whenever ``fn`` is a module-level
    function — which makes it subprocess-isolatable as-is; for closures and
    lambdas attach an :class:`~repro.core.executors.EvaluatorSpec` via
    ``spec`` instead."""

    fn: Callable[[Dict[str, Any]], float]
    spec: Optional[Any] = None  # EvaluatorSpec for subprocess workers

    def __call__(self, config: Dict[str, Any]) -> Tuple[float, Dict[str, Any]]:
        return float(self.fn(config)), {}


@dataclass
class WalltimeEvaluator:
    """builder(config) -> zero-arg callable running one full job; we time the
    best of ``repeats`` runs after one warmup (compile) run.

    ``parallel_safe`` is True: the TrialScheduler may fan a batch of these
    over its thread pool (the paper's trials are independent jobs). Beware
    that concurrent trials on one oversubscribed host contend for cores —
    size ``max_workers`` to the machine, as you would cluster slots."""

    builder: Callable[[Dict[str, Any]], Callable[[], Any]]
    repeats: int = 3
    parallel_safe: bool = True
    spec: Optional[Any] = None  # EvaluatorSpec — builders are usually closures

    def __call__(self, config: Dict[str, Any]) -> Tuple[float, Dict[str, Any]]:
        job = self.builder(config)
        job()  # warmup / compile
        best = float("inf")
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            job()
            best = min(best, time.perf_counter() - t0)
        return best, {"repeats": self.repeats}


@dataclass
class RooflineEvaluator:
    """AOT probe-compile + roofline. ``parallel_safe`` is False — probe
    compilation mutates global XLA state, so the TrialScheduler keeps roofline
    batches serial. Batch speed comes from the **probe-compile memo** instead:
    distinct knob configs that resolve to the same (RunConfig × mesh) — knobs
    the RunConfig doesn't consume, clamped mesh factors — reuse the compiled
    probes and cost nothing beyond a dict lookup."""

    arch: ArchConfig
    shape: ShapeConfig
    space: TunableSpace
    base_run: Optional[RunConfig] = None
    chips: int = 256
    multi_pod: bool = False
    memory_penalty: str = "soft"  # soft | inf
    parallel_safe: bool = False
    spec: Optional[Any] = None  # EvaluatorSpec for subprocess workers

    def __post_init__(self):
        self._probe_memo: Dict[Tuple[Any, int], Tuple[float, Dict[str, Any]]] = {}

    def __getstate__(self):
        # subprocess isolation pickles the evaluator into each worker —
        # compiled probes must never cross a process boundary
        state = self.__dict__.copy()
        state["_probe_memo"] = {}
        return state

    def __call__(self, config: Dict[str, Any]) -> Tuple[float, Dict[str, Any]]:
        run = self.space.to_run_config(config, self.base_run)
        mp = min(int(config.get("mesh_model_parallel", run.mesh_model_parallel)), self.chips)
        run = run.replace(mesh_model_parallel=mp)

        memo_key = (run, mp)
        hit = self._probe_memo.get(memo_key)
        if hit is not None:
            t, info = hit
            return t, {**info, "probe_compile_reused": True}
        t, info = self._evaluate(run, mp)
        self._probe_memo[memo_key] = (t, info)
        return t, info

    def _evaluate(self, run: RunConfig, mp: int) -> Tuple[float, Dict[str, Any]]:
        from repro.distributed.steps import make_step
        from repro.launch.mesh import make_tuning_mesh

        mesh = make_tuning_mesh(mp, chips=self.chips, multi_pod=self.multi_pod)

        with compat_set_mesh(mesh):
            per_dev, probe_times = rl.extrapolated_costs(
                self.arch, run, self.shape, mesh, make_step
            )
            roof = rl.make_roofline(per_dev, self.arch, self.shape, mesh)
        t = roof.t_step

        est = rl.estimate_tpu_hbm(self.arch, run, self.shape, mesh)
        info: Dict[str, Any] = {**roof.summary(), "hbm_est_gib": est["total_gib"]}
        if not est["fits_hbm_16gib"]:
            if self.memory_penalty == "inf":
                return float("inf"), info
            over = est["total_gib"] / (rl.HBM_CAP / 1024**3)
            t = t * (1.0 + over)  # soft penalty steers the search back inside
            info["hbm_penalized"] = True
        return t, info
