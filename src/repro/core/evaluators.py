"""Evaluator backends for the CMPE — the "run the job, measure time" step.

Three interchangeable implementations of the ``Evaluator`` protocol:

  - ``WalltimeEvaluator`` — actually executes a jitted job on the local
    devices and measures wall-clock time. This is the paper-faithful path
    (their trials ran WordCount on the cluster); used for the WordCount
    reproduction and CPU-sized LM jobs, and it is what you would run
    unchanged on a real v5e pod.
  - ``RooflineEvaluator`` — AOT: builds the (arch × shape) step under the
    candidate config on a tuner-chosen mesh, compiles the loop-free probes,
    and returns the roofline-predicted step time max(compute, memory,
    collective). Infeasible configs (estimated HBM overflow on the target
    chip) are penalized. This is the evaluator for the production-mesh cells
    in this CPU-only container.
  - ``FunctionEvaluator`` — wraps a plain function (unit tests / synthetic
    objectives with known optima).
"""
from __future__ import annotations

import inspect
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

from repro.compat import set_mesh as compat_set_mesh
from repro.configs.base import ArchConfig, RunConfig, ShapeConfig
from repro.core import roofline as rl
from repro.core.space import TunableSpace


def _accepts_fidelity(fn: Callable[..., Any]) -> bool:
    """Whether ``fn`` genuinely handles a ``fidelity=`` kwarg.

    A bare ``**kwargs`` does NOT qualify: such a callable would silently
    swallow the kwarg, run the full-size job, and get cached (and ranked by
    ASHA) under a low-fidelity key as if it were the scaled one. Only an
    explicit ``fidelity`` parameter counts — or the opt-in attribute
    ``accepts_fidelity = True`` for wrappers that forward ``**kwargs`` to
    something that really consumes it."""
    if getattr(fn, "accepts_fidelity", False):
        return True
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):  # builtins / C callables
        return False
    for p in sig.parameters.values():
        if p.name == "fidelity" and p.kind in (
            inspect.Parameter.POSITIONAL_OR_KEYWORD,
            inspect.Parameter.KEYWORD_ONLY,
        ):
            return True
    return False


def _block_until_ready(value: Any) -> None:
    """Force JAX async dispatch to finish before the clock is read.

    Jitted jobs return as soon as the work is *enqueued*; timing the bare
    call measures dispatch, not execution. Tolerates ``None`` and arbitrary
    non-array returns (jax.block_until_ready tree-maps leaves and skips
    objects without a ``block_until_ready`` method), and degrades to a no-op
    when jax isn't importable so pure-Python jobs still time fine."""
    if value is None:
        return
    try:
        import jax
    except ImportError:
        return
    jax.block_until_ready(value)


@dataclass
class FunctionEvaluator:
    """Wraps a plain function. Picklable whenever ``fn`` is a module-level
    function — which makes it subprocess-isolatable as-is; for closures and
    lambdas attach an :class:`~repro.core.executors.EvaluatorSpec` via
    ``spec`` instead.

    If ``fn`` accepts a ``fidelity=`` kwarg the evaluator declares
    ``supports_fidelity`` and forwards the rung fraction — the seam the
    synthetic multi-fidelity objectives in the ASHA tests ride on. A plain
    single-argument ``fn`` never sees the kwarg."""

    fn: Callable[[Dict[str, Any]], float]
    spec: Optional[Any] = None  # EvaluatorSpec for subprocess workers
    parallel_safe: bool = True  # wrapped fns are independent pure calls

    def __post_init__(self):
        self.supports_fidelity = _accepts_fidelity(self.fn)

    def __call__(
        self, config: Dict[str, Any], fidelity: float = 1.0
    ) -> Tuple[float, Dict[str, Any]]:
        if fidelity < 1.0 and self.supports_fidelity:
            return float(self.fn(config, fidelity=fidelity)), {}
        return float(self.fn(config)), {}


@dataclass
class WalltimeEvaluator:
    """builder(config) -> zero-arg callable running one full job; we time the
    best of ``repeats`` runs after one warmup (compile) run.

    ``parallel_safe`` is True: the TrialScheduler may fan a batch of these
    over its thread pool (the paper's trials are independent jobs). Beware
    that concurrent trials on one oversubscribed host contend for cores —
    size ``max_workers`` to the machine, as you would cluster slots.

    Fidelity: a sub-fidelity trial measures fewer repeats
    (``max(1, round(repeats × f))`` — measure-step fidelity), and a builder
    that accepts ``fidelity=`` additionally gets the rung fraction to scale
    the job itself (input-scale fidelity — e.g. WordCount on a corpus
    prefix). The measured time is then the low-rung job's real time, which
    is exactly what ASHA ranks within a rung."""

    builder: Callable[[Dict[str, Any]], Callable[[], Any]]
    repeats: int = 3
    parallel_safe: bool = True
    spec: Optional[Any] = None  # EvaluatorSpec — builders are usually closures
    supports_fidelity = True

    def __post_init__(self):
        self._builder_takes_fidelity = _accepts_fidelity(self.builder)

    def __call__(
        self, config: Dict[str, Any], fidelity: float = 1.0
    ) -> Tuple[float, Dict[str, Any]]:
        if fidelity < 1.0 and self._builder_takes_fidelity:
            job = self.builder(config, fidelity=fidelity)
        else:
            job = self.builder(config)
        repeats = self.repeats
        if fidelity < 1.0:
            repeats = max(1, int(round(self.repeats * fidelity)))
        _block_until_ready(job())  # warmup / compile — wait it out too
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            _block_until_ready(job())
            best = min(best, time.perf_counter() - t0)
        info: Dict[str, Any] = {"repeats": repeats}
        if fidelity < 1.0:
            info["fidelity"] = fidelity
        return best, info


@dataclass
class RooflineEvaluator:
    """AOT probe-compile + roofline. ``parallel_safe`` is False — probe
    compilation mutates global XLA state, so the TrialScheduler keeps roofline
    batches serial. Batch speed comes from the **probe-compile memo** instead:
    distinct knob configs that resolve to the same (RunConfig × mesh) — knobs
    the RunConfig doesn't consume, clamped mesh factors — reuse the compiled
    probes and cost nothing beyond a dict lookup."""

    arch: ArchConfig
    shape: ShapeConfig
    space: TunableSpace
    base_run: Optional[RunConfig] = None
    chips: int = 256
    multi_pod: bool = False
    memory_penalty: str = "soft"  # soft | inf
    parallel_safe: bool = False
    spec: Optional[Any] = None  # EvaluatorSpec for subprocess workers
    # probe-depth fidelity: a sub-fidelity call compiles only the single L1
    # probe and extrapolates (skips the L2/M2 probes the affine cost model
    # needs) — roughly 1/2 to 1/3 of the compile cost per fresh config
    supports_fidelity = True

    def __post_init__(self):
        self._probe_memo: Dict[
            Tuple[Any, int, bool], Tuple[float, Dict[str, Any]]
        ] = {}

    def __getstate__(self):
        # subprocess isolation pickles the evaluator into each worker —
        # compiled probes must never cross a process boundary
        state = self.__dict__.copy()
        state["_probe_memo"] = {}
        return state

    def __call__(
        self, config: Dict[str, Any], fidelity: float = 1.0
    ) -> Tuple[float, Dict[str, Any]]:
        run = self.space.to_run_config(config, self.base_run)
        mp = min(int(config.get("mesh_model_parallel", run.mesh_model_parallel)), self.chips)
        run = run.replace(mesh_model_parallel=mp)

        full = fidelity >= 1.0
        # fidelity is part of the memo identity — a cheap single-probe
        # estimate must never replay as the full extrapolation
        memo_key = (run, mp, full)
        hit = self._probe_memo.get(memo_key)
        if hit is not None:
            t, info = hit
            return t, {**info, "probe_compile_reused": True}
        t, info = self._evaluate(run, mp, full)
        self._probe_memo[memo_key] = (t, info)
        return t, info

    def _evaluate(
        self, run: RunConfig, mp: int, full: bool = True
    ) -> Tuple[float, Dict[str, Any]]:
        from repro.distributed.steps import make_step
        from repro.launch.mesh import make_tuning_mesh

        mesh = make_tuning_mesh(mp, chips=self.chips, multi_pod=self.multi_pod)

        with compat_set_mesh(mesh):
            per_dev, probe_times = rl.extrapolated_costs(
                self.arch, run, self.shape, mesh, make_step,
                single_probe=not full,
            )
            roof = rl.make_roofline(per_dev, self.arch, self.shape, mesh)
        t = roof.t_step

        est = rl.estimate_tpu_hbm(self.arch, run, self.shape, mesh)
        info: Dict[str, Any] = {**roof.summary(), "hbm_est_gib": est["total_gib"]}
        if not full:
            info["probe_single"] = True  # cheap L1-only extrapolation
        if not est["fits_hbm_16gib"]:
            if self.memory_penalty == "inf":
                return float("inf"), info
            over = est["total_gib"] / (rl.HBM_CAP / 1024**3)
            t = t * (1.0 + over)  # soft penalty steers the search back inside
            info["hbm_penalized"] = True
        return t, info
