"""Cross-cell transfer: sibling histories, cell similarity, config snapping.

A :class:`~repro.core.study.Study` that has tuned ``train/mamba:1x8`` holds
evidence that should accelerate ``train/mamba:2x8`` — the same observation
that drives learning-based tuners (Bao, arXiv:1808.06008) and the online
transfer setting of arXiv:2309.01901. The per-cell platform namespacing that
keeps cells from *corrupting* each other's caches also keeps that evidence
out; this module is the sanctioned way back in:

  - :func:`parse_namespace` decodes the ``{train|serve}/arch:shape[@Nc]``
    cache namespaces (PR-4 keying) into a structured :class:`CellKey`,
  - :func:`default_similarity` scores two cells by (arch, shape, chips)
    distance — pluggable: ``Study.histories_for(similarity=...)`` takes any
    ``(CellKey, CellKey) -> float`` (``inf`` = never a sibling),
  - :class:`SiblingHistory` is what ``histories_for`` returns and what the
    ``Strategy.on_study_attach(history, siblings=...)`` channel carries,
  - :func:`snap_into_space` lands a sibling cell's config inside another
    cell's :class:`~repro.core.space.TunableSpace` — in-bounds, on-grid,
    idempotent (the property tests enforce all three).

Transfer modes (the ``--transfer`` CLI flag / ``Study.optimize(transfer=)``):

  ``off``    no sibling channel (the default — cells tune from scratch)
  ``warm``   sibling *incumbents* seed the strategy's initial candidate set
             (cheap; gsft/crs use this, tpe seeds its startup batch)
  ``prior``  sibling *observations* enter TPE's Parzen densities with a
             distance-decayed weight; they never count toward ``max_trials``
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core.space import TunableSpace

__all__ = [
    "TRANSFER_MODES",
    "CellKey",
    "SiblingHistory",
    "Similarity",
    "default_similarity",
    "parse_namespace",
    "snap_into_space",
    "warm_seed_configs",
]

TRANSFER_MODES = ("off", "warm", "prior")

DEFAULT_CHIPS = 256  # namespaces only carry @Nc when non-default (PR-4)


@dataclass(frozen=True)
class CellKey:
    """Structured identity of one cache namespace: ``base`` is the space
    name (``train``/``serve``/``wordcount``), arch/shape the cell coordinates
    (None for un-celled namespaces like plain ``wordcount``), chips the
    topology (default 256 — the ``@Nc`` suffix is only present otherwise)."""

    base: str
    arch: Optional[str] = None
    shape: Optional[str] = None
    chips: int = DEFAULT_CHIPS


def parse_namespace(namespace: str) -> CellKey:
    """Decode a cache namespace into a :class:`CellKey`.

    Accepts every namespace shape the drivers write: ``train``,
    ``wordcount/variant``, ``train/arch:shape``, ``train/arch:shape@512c``.
    """
    base, sep, cell = namespace.partition("/")
    if not sep:
        return CellKey(base=base)
    chips = DEFAULT_CHIPS
    if "@" in cell:
        cell, _, suffix = cell.rpartition("@")
        digits = suffix[:-1] if suffix.endswith("c") else suffix
        try:
            chips = int(digits)
        except ValueError:
            cell = f"{cell}@{suffix}"  # not a chips suffix; keep it in the name
    arch, colon, shape = cell.partition(":")
    return CellKey(
        base=base,
        arch=arch or None,
        shape=(shape or None) if colon else None,
        chips=chips,
    )


def _shape_distance(a: Optional[str], b: Optional[str]) -> float:
    """Distance between two shape names: 0 for identical, a log-scaled
    sequence/batch gap (+ a kind-mismatch step) for known shapes, a flat
    step when either side is unknown."""
    if a == b:
        return 0.0
    if a is None or b is None:
        return 0.5
    from repro.configs.base import SHAPES

    sa, sb = SHAPES.get(a), SHAPES.get(b)
    if sa is None or sb is None:
        return 1.0
    d = 0.0 if sa.kind == sb.kind else 1.0
    d += abs(math.log2(sa.seq_len) - math.log2(sb.seq_len)) * 0.25
    d += abs(math.log2(sa.global_batch) - math.log2(sb.global_batch)) * 0.25
    return d


def default_similarity(a: CellKey, b: CellKey) -> float:
    """Distance between two cells; smaller = more similar, ``inf`` = never a
    sibling. Different base platforms are incomparable (their spaces differ);
    otherwise arch identity dominates, then shape geometry, then topology."""
    if a.base != b.base:
        return math.inf
    d = 0.0
    if a.arch != b.arch:
        d += 1.0
    d += _shape_distance(a.shape, b.shape)
    d += abs(math.log2(max(a.chips, 1)) - math.log2(max(b.chips, 1))) * 0.25
    return d


Similarity = Callable[[CellKey, CellKey], float]


@dataclass(frozen=True)
class SiblingHistory:
    """One sibling cell's evidence: its cache namespace, its similarity
    distance to the receiving cell, and its ``(config, time_s, tag)`` trial
    triples in cache (first-write) order — the order is load-bearing: resume
    replays a recorded *prefix* of it to reproduce the original sibling set.
    """

    namespace: str
    distance: float
    trials: Tuple[Tuple[Dict[str, Any], float, Any], ...]

    @property
    def weight(self) -> float:
        """Distance-decayed influence in [0, 1]: ``exp(-distance)``."""
        return math.exp(-float(self.distance))

    def incumbent(self) -> Optional[Dict[str, Any]]:
        """The sibling's best finite-time config (None when it has none)."""
        best_cfg, best_t = None, math.inf
        for cfg, t, _tag in self.trials:
            if math.isfinite(t) and t < best_t:
                best_cfg, best_t = cfg, t
        return dict(best_cfg) if best_cfg is not None else None


def snap_into_space(space: TunableSpace, config: Dict[str, Any]) -> Dict[str, Any]:
    """Land a (possibly foreign) config inside ``space``: every param of the
    space gets a value — the config's own where present, the space default
    otherwise — snapped in-bounds and on-grid through ``Param.snap``, with
    keys the space doesn't know dropped. Defaults are snapped too (a shipped
    default may sit off its own step grid, e.g. wordcount's ``io_sort_mb``
    100 on a 32-step grid), so the result is always a ``snap`` fixed point
    and the function is idempotent."""
    return {
        p.name: p.snap(config[p.name] if p.name in config else p.default)
        for p in space.params
    }


def warm_seed_configs(space, fixed, siblings, existing):
    """The shared ``warm`` seeding step (gsft/crs): each sibling's incumbent,
    snapped into ``space`` with ``fixed`` re-applied, deduped against
    ``existing`` pending configs and each other — in sibling (closest-first)
    order."""
    from repro.core.scheduler import config_key

    seen = {config_key(c) for c in existing}
    seeds = []
    for sib in siblings:
        inc = sib.incumbent()
        if inc is None:
            continue
        cfg = {**snap_into_space(space, inc), **(fixed or {})}
        key = config_key(cfg)
        if key not in seen:
            seen.add(key)
            seeds.append(cfg)
    return seeds
