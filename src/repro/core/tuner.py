"""Admin facade (paper Figure I) — **deprecated shim**.

``tune()`` predates the :class:`repro.core.study.Study` API and survives as a
thin wrapper: one call builds a throwaway in-memory Study (or, given an
explicit ``scheduler``, runs the shared session engine directly) and returns
the same :class:`TuneOutcome`. New code should hold a Study instead — it
keeps the evaluation cache, trial log, and session provenance in one place
and can resume interrupted sessions::

    study = Study.open("results/studies/my-study")
    study.optimize(platform, algorithm, evaluator, budget=48)

The engine knobs accepted here (``max_workers``/``timeout_s``/``retries``/
``isolation``/``batch_size``/``patience``/``clear_caches_between_trials``)
map 1:1 onto :class:`repro.core.study.EngineConfig` — see the README's
migration table.
"""
from __future__ import annotations

import warnings
from pathlib import Path
from typing import Any, Dict, Optional, Sequence

from repro.core.scheduler import Evaluator, TrialScheduler
from repro.core.space import SPACES, TunableSpace
from repro.core.study import (  # noqa: F401 — TuneOutcome re-exported here
    EngineConfig,
    Study,
    TuneOutcome,
    run_session,
)


def tune(
    platform: str,
    algorithm: str,
    evaluator: Evaluator,
    *,
    space: Optional[TunableSpace] = None,
    log_path: Optional[Path] = None,
    fixed: Optional[Dict[str, Any]] = None,
    active_params: Optional[Sequence[str]] = None,
    clear_caches_between_trials: bool = False,
    max_workers: int = 1,
    cache_path: Optional[Path] = None,
    batch_size: Optional[int] = None,
    patience: Optional[int] = None,
    timeout_s: Optional[float] = None,
    retries: int = 0,
    isolation: str = "inline",
    scheduler: Optional[TrialScheduler] = None,
    **algo_kwargs,
) -> TuneOutcome:
    """Run one tuning session (the Admin's 'select algorithm × platform').

    .. deprecated:: PR 4
        ``tune()`` is a shim over a throwaway :class:`Study`. Prefer
        ``Study.open(dir).optimize(...)`` — it persists the cache/log/session
        provenance together and supports ``resume()``/``report()``.

    Pass ``scheduler`` to share one engine (and its memo + persistent cache)
    across several sessions. Engine knobs and ``scheduler`` are mutually
    exclusive: a conflicting combination raises instead of silently ignoring
    the knobs."""
    warnings.warn(
        "tune() is deprecated — use repro.core.study.Study "
        "(Study.open(dir).optimize(...)) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    space = space or SPACES[platform]
    if scheduler is not None:
        ignored = [
            name for name, off_default in (
                ("max_workers", max_workers != 1),
                ("timeout_s", timeout_s is not None),
                ("retries", retries != 0),
                ("cache_path", cache_path is not None),
                ("isolation", isolation != "inline"),
                ("log_path", log_path is not None),
                ("clear_caches_between_trials", clear_caches_between_trials),
            ) if off_default
        ]
        if ignored:
            raise ValueError(
                f"tune(): {', '.join(ignored)} would be silently ignored when "
                "an explicit scheduler is passed — configure them on the "
                "TrialScheduler instead"
            )
        return run_session(
            scheduler, platform, algorithm, space,
            fixed=fixed, active_params=active_params,
            batch_size=batch_size, patience=patience,
            **algo_kwargs,
        )

    engine = EngineConfig(
        workers=max_workers,
        isolation=isolation,
        timeout_s=timeout_s,
        retries=retries,
        patience=patience,
        batch_size=batch_size,
        clear_caches=clear_caches_between_trials,
    )
    study = Study(engine=engine, cache_path=cache_path, log_path=log_path)
    with study:
        return study.optimize(
            platform, algorithm, evaluator,
            space=space, fixed=fixed, active_params=active_params,
            **algo_kwargs,
        )
