"""Admin facade (paper Figure I): pick a platform and an algorithm, run the
tuning, get the best configuration + the reduction vs. the all-defaults run.

Every algorithm — gsft, crs, hillclimb, tpe, and whatever registers next — runs
through the same ask/tell ``Strategy`` + ``TrialScheduler`` engine, so the
engine knobs (``max_workers`` parallel batches, ``cache_path`` persistent
evaluation cache, ``patience`` pruning, per-trial ``timeout_s``/``retries``)
apply uniformly.
"""
from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, Optional, Sequence

from repro.core.scheduler import Evaluator, TrialScheduler
from repro.core.space import SPACES, TunableSpace
from repro.core.strategies import STRATEGIES, make_strategy


@dataclass
class TuneOutcome:
    platform: str
    algorithm: str
    default_time: float
    best_time: float
    best_config: Dict[str, Any]
    evaluations: int
    detail: Any = None
    cache_stats: Optional[Dict[str, int]] = None
    timeouts: int = 0  # trials that hit the (soft) per-trial deadline

    @property
    def reduction_pct(self) -> float:
        """The paper's headline metric: % reduction in execution time vs. the
        all-defaults configuration."""
        if self.default_time in (0.0, float("inf")):
            return 0.0
        return 100.0 * (self.default_time - self.best_time) / self.default_time

    def summary(self) -> Dict[str, Any]:
        out = {
            "platform": self.platform,
            "algorithm": self.algorithm,
            "default_time_s": self.default_time,
            "best_time_s": self.best_time,
            "reduction_pct": round(self.reduction_pct, 2),
            "evaluations": self.evaluations,
            "timeouts": self.timeouts,
            "best_config": self.best_config,
        }
        if self.cache_stats:
            out["cache_stats"] = self.cache_stats
        return out


def tune(
    platform: str,
    algorithm: str,
    evaluator: Evaluator,
    *,
    space: Optional[TunableSpace] = None,
    log_path: Optional[Path] = None,
    fixed: Optional[Dict[str, Any]] = None,
    active_params: Optional[Sequence[str]] = None,
    clear_caches_between_trials: bool = False,
    max_workers: int = 1,
    cache_path: Optional[Path] = None,
    batch_size: Optional[int] = None,
    patience: Optional[int] = None,
    timeout_s: Optional[float] = None,
    retries: int = 0,
    isolation: str = "inline",
    scheduler: Optional[TrialScheduler] = None,
    **algo_kwargs,
) -> TuneOutcome:
    """Run one tuning session (the Admin's 'select algorithm × platform').

    Pass ``scheduler`` to share one engine (and its memo + persistent cache)
    across several sessions — the multi-cell driver does. Engine knobs and
    ``scheduler`` are mutually exclusive: a conflicting combination raises
    instead of silently ignoring the knobs."""
    space = space or SPACES[platform]
    if scheduler is not None:
        ignored = [
            name for name, off_default in (
                ("max_workers", max_workers != 1),
                ("timeout_s", timeout_s is not None),
                ("retries", retries != 0),
                ("cache_path", cache_path is not None),
                ("isolation", isolation != "inline"),
                ("log_path", log_path is not None),
                ("clear_caches_between_trials", clear_caches_between_trials),
            ) if off_default
        ]
        if ignored:
            raise ValueError(
                f"tune(): {', '.join(ignored)} would be silently ignored when "
                "an explicit scheduler is passed — configure them on the "
                "TrialScheduler instead"
            )
    created_scheduler = scheduler is None
    if created_scheduler:
        scheduler = TrialScheduler(
            evaluator,
            platform=platform,
            log_path=log_path,
            clear_caches_between_trials=clear_caches_between_trials,
            max_workers=max_workers,
            cache_path=cache_path,
            timeout_s=timeout_s,
            retries=retries,
            isolation=isolation,
        )

    if algorithm not in STRATEGIES:
        raise ValueError(
            f"unknown algorithm {algorithm!r} (use one of {sorted(STRATEGIES)})"
        )
    # warm-start a model-based strategy (TPE) from the persistent eval cache
    # *before* the defaults trial lands in it: a re-run over a complete cache
    # resumes with its full observation history and proposes nothing fresh
    if (
        getattr(STRATEGIES[algorithm], "supports_history", False)
        and "history" not in algo_kwargs
    ):
        algo_kwargs["history"] = scheduler.cached_observations()

    # per-run accounting: deltas against the scheduler's lifetime counters,
    # so a shared multi-cell scheduler doesn't inflate every cell's report
    evals_before = scheduler.num_evaluations
    timeouts_before = scheduler.timeout_trials
    try:
        defaults = {**space.defaults(), **(fixed or {})}
        default_time = scheduler.evaluate(defaults, tag="default")

        if algorithm in ("gsft", "grid"):
            algo_kwargs.setdefault("active_params", active_params)
        strategy = make_strategy(algorithm, space, fixed=fixed, **algo_kwargs)
        result = scheduler.run(strategy, batch_size=batch_size, patience=patience)
        best_config, best_time = result.best_config, result.best_time

        # defaults themselves might be the optimum; the log keeps everything
        if default_time < best_time:
            best_config, best_time = defaults, default_time

        return TuneOutcome(
            platform=platform,
            algorithm=algorithm,
            default_time=default_time,
            best_time=best_time,
            best_config=best_config,
            evaluations=scheduler.num_evaluations - evals_before,
            detail=result,
            cache_stats=scheduler.cache_stats(),
            timeouts=scheduler.timeout_trials - timeouts_before,
        )
    finally:
        if created_scheduler:
            scheduler.close()  # reap warm subprocess workers; inline: no-op
