"""Admin facade (paper Figure I): pick a platform and an algorithm, run the
tuning, get the best configuration + the reduction vs. the all-defaults run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, Optional, Sequence

from repro.core.cmpe import CMPE, Evaluator
from repro.core.crs import controlled_random_search
from repro.core.grid_finer import grid_search_finer_tuning
from repro.core.space import SPACES, TunableSpace


@dataclass
class TuneOutcome:
    platform: str
    algorithm: str
    default_time: float
    best_time: float
    best_config: Dict[str, Any]
    evaluations: int
    detail: Any = None

    @property
    def reduction_pct(self) -> float:
        """The paper's headline metric: % reduction in execution time vs. the
        all-defaults configuration."""
        if self.default_time in (0.0, float("inf")):
            return 0.0
        return 100.0 * (self.default_time - self.best_time) / self.default_time

    def summary(self) -> Dict[str, Any]:
        return {
            "platform": self.platform,
            "algorithm": self.algorithm,
            "default_time_s": self.default_time,
            "best_time_s": self.best_time,
            "reduction_pct": round(self.reduction_pct, 2),
            "evaluations": self.evaluations,
            "best_config": self.best_config,
        }


def tune(
    platform: str,
    algorithm: str,
    evaluator: Evaluator,
    *,
    space: Optional[TunableSpace] = None,
    log_path: Optional[Path] = None,
    fixed: Optional[Dict[str, Any]] = None,
    active_params: Optional[Sequence[str]] = None,
    clear_caches_between_trials: bool = False,
    **algo_kwargs,
) -> TuneOutcome:
    """Run one tuning session (the Admin's 'select algorithm × platform')."""
    space = space or SPACES[platform]
    cmpe = CMPE(
        evaluator,
        platform=platform,
        log_path=log_path,
        clear_caches_between_trials=clear_caches_between_trials,
    )

    defaults = {**space.defaults(), **(fixed or {})}
    default_time = cmpe.evaluate(defaults, tag="default")

    if algorithm in ("gsft", "grid"):
        result = grid_search_finer_tuning(
            space, cmpe, fixed=fixed, active_params=active_params, **algo_kwargs
        )
        best_config, best_time = result.best_config, result.best_time
    elif algorithm == "crs":
        result = controlled_random_search(space, cmpe, fixed=fixed, **algo_kwargs)
        best_config, best_time = result.best_config, result.best_time
    else:
        raise ValueError(f"unknown algorithm {algorithm!r} (use 'gsft' or 'crs')")

    # defaults themselves might be the optimum; the log keeps everything
    if default_time < best_time:
        best_config, best_time = defaults, default_time

    return TuneOutcome(
        platform=platform,
        algorithm=algorithm,
        default_time=default_time,
        best_time=best_time,
        best_config=best_config,
        evaluations=cmpe.num_evaluations,
        detail=result,
    )
