"""Learned cost surrogate: ridge regression over the Study cache.

The static half of the ROADMAP's cost-surrogate item shipped in PR 8
(``--prefilter static``: reject configs whose AOT-estimated peak bytes
exceed HBM, zero devices touched). This module is the learned half, after
Bao's learning-based tuner (PAPERS.md, arXiv:1808.06008): a regression
model trained on *measured* trials predicts wall time for *unmeasured*
configs, and TPE uses it to pre-rank its acquisition candidates — each
model round over-samples proposals, the surrogate re-ranks them, and only
the predicted frontier is evaluated (``--surrogate rank``).

Design constraints, in priority order:

  - **Dependency-free and deterministic.** Pure-Python ridge regression
    (Gaussian elimination, no numpy in the fit path) so the proposal
    stream stays a pure function of (seed, observations, siblings,
    training set) — the PR 5 purity tests extend to ``--surrogate rank``.
  - **Cross-cell by construction.** Training rows carry their cache
    namespace; a per-namespace intercept column absorbs each cell's scale
    offset (wc:2m is ~2x wc:1m at every config), so sibling cells donate
    *config-effect* evidence without their absolute times poisoning the
    local ranking. This is the PR 5 transfer machinery in model form:
    siblings arrive through ``Study.histories_for`` even when the Parzen
    ``--transfer`` prior is off.
  - **Log-space everywhere.** The target is ``log(time_s)`` (config
    effects on runtime are multiplicative), pow2 knobs are encoded in
    log2 space (matching TPE's ``_NumericDensity`` metric), and cell
    geometry enters as log2 chips/seq_len/global_batch from the parsed
    :class:`~repro.core.transfer.CellKey`.

The surrogate never touches budget accounting: training is free (it reads
observations the scheduler already paid for), and ranking only *reorders*
candidates within a round — it neither proposes nor suppresses
evaluations, so ``--surrogate rank`` and ``off`` spend identical budgets.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.space import Param, TunableSpace
from repro.core.transfer import parse_namespace

__all__ = [
    "SURROGATE_MODES",
    "CostSurrogate",
    "encode_config",
    "cell_features",
    "hlo_features",
]

SURROGATE_MODES = ("off", "rank")

# Fewest usable rows before the model trusts itself; below this, ``fit``
# leaves the surrogate un-ready and TPE falls back to plain EI order.
MIN_TRAIN = 8


def _log2_metric(param: Param) -> bool:
    """Same rule as TPE's ``_NumericDensity``: pow2 knobs with positive
    bounds live in log2 space."""
    return bool(getattr(param, "pow2", False)) and getattr(param, "lo", 0) >= 1


def encode_config(space: TunableSpace, config: Dict[str, Any]) -> Dict[str, float]:
    """One config -> named numeric features. Numeric knobs become one
    column each (log2 for pow2 knobs), categorical/bool knobs one-hot over
    their declared choices. Missing knobs fall back to the space default so
    foreign-but-compatible cache records still encode."""
    feats: Dict[str, float] = {}
    for p in space.params:
        v = config.get(p.name, p.default)
        if p.numeric:
            x = float(v)
            if _log2_metric(p):
                x = math.log2(max(x, 1.0))
            feats[f"cfg:{p.name}"] = x
        else:
            feats[f"cfg:{p.name}={p.snap(v)!r}"] = 1.0
    return feats


def cell_features(namespace: str) -> Dict[str, float]:
    """Shape-geometry features from a cache namespace via
    :func:`~repro.core.transfer.parse_namespace`: log2 topology always,
    log2 seq/batch + kind one-hot when the shape is a known
    ``configs.base.SHAPES`` cell. Unknown shapes contribute geometry only
    through the per-namespace intercept the model adds separately."""
    key = parse_namespace(namespace)
    feats = {"geo:log2_chips": math.log2(max(key.chips, 1))}
    if key.shape is not None:
        from repro.configs.base import SHAPES

        shape = SHAPES.get(key.shape)
        if shape is not None:
            feats["geo:log2_seq"] = math.log2(shape.seq_len)
            feats["geo:log2_batch"] = math.log2(shape.global_batch)
            feats[f"geo:kind={shape.kind}"] = 1.0
    return feats


def hlo_features(fn: Callable, *args, **kwargs) -> Dict[str, float]:
    """Optional static-analysis features for one lowered program: peak
    memory from :func:`hlo.parse_memory` and wire traffic from
    :func:`hlo.parse_collectives`, both in log2 bytes (zero traffic -> 0).
    Costs one AOT lowering, no compile, no devices — the same trick (and
    the same HLO-text extraction) as the PR 8 static prefilter's
    :func:`~repro.core.feasibility.aot_memory_estimate`. Feed the result
    through ``CostSurrogate``'s ``extra_features`` hook."""
    import jax

    from repro.core.hlo import parse_collectives, parse_memory

    lowered = jax.jit(fn).lower(*args, **kwargs)
    try:
        # lowered.as_text() is StableHLO MLIR; the parsers want HLO text
        text = lowered.compiler_ir(dialect="hlo").as_hlo_text()
    except Exception:
        text = lowered.as_text()
    mem = parse_memory(text)
    coll = parse_collectives(text)
    return {
        "hlo:log2_peak_bytes": math.log2(max(mem.peak_bytes, 1)),
        "hlo:log2_wire_bytes": math.log2(max(coll.wire_bytes, 1.0)),
        "hlo:collectives": float(coll.count),
    }


def _solve(a: List[List[float]], b: List[float]) -> List[float]:
    """Gaussian elimination with partial pivoting on the (symmetric
    positive-definite, thanks to the ridge) normal equations."""
    n = len(b)
    m = [row[:] + [b[i]] for i, row in enumerate(a)]
    for col in range(n):
        piv = max(range(col, n), key=lambda r: abs(m[r][col]))
        if abs(m[piv][col]) < 1e-12:
            continue  # degenerate column; its weight stays 0
        m[col], m[piv] = m[piv], m[col]
        inv = 1.0 / m[col][col]
        for r in range(col + 1, n):
            f = m[r][col] * inv
            if f:
                for c in range(col, n + 1):
                    m[r][c] -= f * m[col][c]
    x = [0.0] * n
    for r in range(n - 1, -1, -1):
        if abs(m[r][r]) < 1e-12:
            continue
        s = m[r][n] - sum(m[r][c] * x[c] for c in range(r + 1, n))
        x[r] = s / m[r][r]
    return x


class CostSurrogate:
    """Ridge regression ``log(time_s) ~ config + cell geometry [+ HLO]``.

    ``fit`` takes ``(config, time_s, namespace)`` rows — the local cell's
    observations plus any sibling cells' — and is a no-op (``ready`` stays
    False) below ``min_train`` usable rows, so early rounds degrade to
    plain TPE rather than rank on noise. Everything is deterministic:
    feature columns are sorted by name, ties in ``rank`` keep input order.
    """

    def __init__(
        self,
        space: TunableSpace,
        *,
        l2: float = 1.0,
        min_train: int = MIN_TRAIN,
        extra_features: Optional[Callable[[Dict[str, Any]], Dict[str, float]]] = None,
    ):
        self.space = space
        self.l2 = float(l2)
        self.min_train = int(min_train)
        self.extra_features = extra_features
        self.ready = False
        self.n_rows = 0
        self._keys: List[str] = []
        self._mean: List[float] = []
        self._scale: List[float] = []
        self._w: List[float] = []
        self._y_mean = 0.0

    def _featurize(self, config: Dict[str, Any], namespace: str) -> Dict[str, float]:
        feats = encode_config(self.space, config)
        feats.update(cell_features(namespace))
        if namespace:
            # per-cell fixed effect: absorbs each cell's absolute scale so
            # siblings teach config *effects*, not their own magnitudes
            feats[f"ns:{namespace}"] = 1.0
        if self.extra_features is not None:
            for k, v in self.extra_features(config).items():
                feats[str(k)] = float(v)
        return feats

    def fit(
        self, rows: Sequence[Tuple[Dict[str, Any], float, str]]
    ) -> "CostSurrogate":
        usable = [
            (cfg, float(t), str(ns))
            for cfg, t, ns in rows
            if math.isfinite(float(t)) and float(t) > 0.0
        ]
        self.ready = False
        self.n_rows = len(usable)
        if len(usable) < self.min_train:
            return self
        feats = [self._featurize(cfg, ns) for cfg, _, ns in usable]
        self._keys = sorted({k for f in feats for k in f})
        d = len(self._keys)
        x = [[f.get(k, 0.0) for k in self._keys] for f in feats]
        y = [math.log(t) for _, t, _ in usable]
        n = len(x)
        self._mean = [sum(col) / n for col in zip(*x)]
        self._scale = []
        for j in range(d):
            var = sum((row[j] - self._mean[j]) ** 2 for row in x) / n
            self._scale.append(math.sqrt(var) if var > 1e-12 else 1.0)
        z = [
            [(row[j] - self._mean[j]) / self._scale[j] for j in range(d)]
            for row in x
        ]
        self._y_mean = sum(y) / n
        yc = [v - self._y_mean for v in y]
        # normal equations with ridge: (Z'Z + l2*I) w = Z'y
        a = [[0.0] * d for _ in range(d)]
        for row in z:
            for j in range(d):
                rj = row[j]
                if rj:
                    arow = a[j]
                    for k in range(j, d):
                        arow[k] += rj * row[k]
        for j in range(d):
            for k in range(j):
                a[j][k] = a[k][j]
            a[j][j] += self.l2
        b = [
            sum(z[i][j] * yc[i] for i in range(n)) for j in range(d)
        ]
        self._w = _solve(a, b)
        self.ready = True
        return self

    def predict(self, config: Dict[str, Any], namespace: str = "") -> float:
        """Predicted ``log(time_s)`` — comparable across configs of one
        cell (absolute accuracy is not the contract; ranking is)."""
        if not self.ready:
            raise RuntimeError("CostSurrogate.predict before a successful fit")
        f = self._featurize(config, namespace)
        return self._y_mean + sum(
            self._w[j] * (f.get(k, 0.0) - self._mean[j]) / self._scale[j]
            for j, k in enumerate(self._keys)
        )

    def rank(
        self, configs: Sequence[Dict[str, Any]], namespace: str = ""
    ) -> List[Dict[str, Any]]:
        """Configs sorted fastest-predicted-first; stable, so equal
        predictions keep the acquisition order they arrived in."""
        if not self.ready:
            return list(configs)
        scored = [(self.predict(c, namespace), i) for i, c in enumerate(configs)]
        return [configs[i] for _, i in sorted(scored, key=lambda si: (si[0], si[1]))]
