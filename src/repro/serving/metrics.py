"""Streaming serving metrics: per-window latency quantiles and throughput.

The serving driver feeds one latency sample per decoded token into a
:class:`DecodeWindowMonitor`; at window boundaries the monitor emits a
:class:`WindowStats` (p50/p99 over the window's sliding reservoir, mean,
tokens/s) that the :class:`~repro.serving.controller.OnlineController` makes
guard decisions on.

Time never enters this module directly (the ``serving-injected-clock`` lint
rule bans wall-clock reads package-wide): the monitor takes an injectable
``clock=`` callable. With ``clock=None`` a window's wall time is the sum of
its recorded latencies — exactly right for simulations, where the "latency"
samples are scripted and a real clock would destroy determinism. The real
driver injects ``time.perf_counter``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, List, Optional, Sequence

__all__ = ["DecodeWindowMonitor", "WindowStats", "quantile"]


def quantile(values: Sequence[float], q: float) -> float:
    """Linear-interpolation quantile over ``values`` (need not be sorted).

    Deterministic and dependency-free (no numpy): ``q`` in [0, 1] maps onto
    rank ``q * (n - 1)`` of the sorted sample with linear interpolation
    between neighbouring order statistics — the same convention as
    ``numpy.quantile``'s default."""
    if not values:
        raise ValueError("quantile of an empty sample")
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"q must be in [0, 1], got {q}")
    ordered = sorted(values)
    pos = q * (len(ordered) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(ordered) - 1)
    frac = pos - lo
    return ordered[lo] * (1.0 - frac) + ordered[hi] * frac


@dataclass(frozen=True)
class WindowStats:
    """One decode window's measured behaviour — what guard decisions rank.

    ``p50``/``p99``/``mean``/``max`` are per-token decode latencies in
    seconds over the window's reservoir; ``tokens_per_s`` is the window's
    throughput; ``wall_s`` its wall time (clock delta when a clock is
    injected, sum of latencies otherwise)."""

    window: int
    count: int
    p50: float
    p99: float
    mean: float
    max: float
    tokens_per_s: float
    wall_s: float

    def to_dict(self) -> dict:
        return {
            "window": self.window,
            "count": self.count,
            "p50_s": self.p50,
            "p99_s": self.p99,
            "mean_s": self.mean,
            "max_s": self.max,
            "tokens_per_s": self.tokens_per_s,
            "wall_s": self.wall_s,
        }


class DecodeWindowMonitor:
    """Sliding-window latency/throughput monitor for the decode loop.

    Usage per window::

        monitor.begin_window()
        for each decoded token:
            monitor.record(latency_s, tokens=batch)
        stats = monitor.end_window()

    The per-window reservoir keeps at most ``max_samples`` latencies (oldest
    evicted first — a bounded sliding window, so a pathological window can
    never grow memory without bound); ``history`` retains the last
    ``history_windows`` WindowStats for aggregate reporting."""

    def __init__(
        self,
        clock: Optional[Callable[[], float]] = None,
        max_samples: int = 4096,
        history_windows: int = 64,
    ):
        if int(max_samples) < 1:
            raise ValueError(f"max_samples must be >= 1, got {max_samples}")
        self.clock = clock
        self.max_samples = int(max_samples)
        self.history: Deque[WindowStats] = deque(maxlen=int(history_windows))
        self._samples: Deque[float] = deque(maxlen=self.max_samples)
        self._window = 0
        self._tokens = 0
        self._t_start: Optional[float] = None
        self._open = False

    def begin_window(self) -> None:
        if self._open:
            raise RuntimeError("begin_window() called twice without end_window()")
        self._samples.clear()
        self._tokens = 0
        self._t_start = self.clock() if self.clock is not None else None
        self._open = True

    def record(self, latency_s: float, tokens: int = 1) -> None:
        """One decode-step observation: ``latency_s`` for ``tokens`` new
        tokens (a batched step emits batch-many tokens in one step)."""
        if not self._open:
            raise RuntimeError("record() outside begin_window()/end_window()")
        if latency_s < 0:
            raise ValueError(f"negative latency {latency_s}")
        self._samples.append(float(latency_s))
        self._tokens += int(tokens)

    def end_window(self) -> WindowStats:
        if not self._open:
            raise RuntimeError("end_window() without begin_window()")
        if not self._samples:
            raise RuntimeError("end_window() on a window with no samples")
        samples: List[float] = list(self._samples)
        if self.clock is not None and self._t_start is not None:
            wall = self.clock() - self._t_start
        else:
            wall = sum(samples)
        stats = WindowStats(
            window=self._window,
            count=len(samples),
            p50=quantile(samples, 0.50),
            p99=quantile(samples, 0.99),
            mean=sum(samples) / len(samples),
            max=max(samples),
            tokens_per_s=self._tokens / wall if wall > 0 else 0.0,
            wall_s=wall,
        )
        self.history.append(stats)
        self._window += 1
        self._open = False
        return stats

    def aggregate(self, last_n: Optional[int] = None) -> Optional[WindowStats]:
        """Pooled stats over the last ``last_n`` retained windows (all
        retained windows when None); None when no window has completed.
        Quantiles are weighted by window sample counts via per-window
        (p50, p99) pooling — an *approximation* (exact pooling would need
        the raw samples, which the sliding reservoir has dropped), good
        enough for end-of-run reporting, never used by guard decisions."""
        windows = list(self.history)
        if last_n is not None:
            windows = windows[-int(last_n):]
        if not windows:
            return None
        count = sum(w.count for w in windows)
        wall = sum(w.wall_s for w in windows)
        tokens = sum(w.tokens_per_s * w.wall_s for w in windows)
        return WindowStats(
            window=windows[-1].window,
            count=count,
            p50=quantile([w.p50 for w in windows], 0.50),
            p99=quantile([w.p99 for w in windows], 0.99),
            mean=sum(w.mean * w.count for w in windows) / count,
            max=max(w.max for w in windows),
            tokens_per_s=tokens / wall if wall > 0 else 0.0,
            wall_s=wall,
        )
