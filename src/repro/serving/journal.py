"""OnlineJournal — guard decisions and window stats into Study storage.

An online session must be as auditable as an offline one: the ``start`` /
``done`` records land in ``sessions.jsonl`` through the Study's public
session seam (so ``Study.report()`` shows online rows alongside offline
sessions with no special casing), every guard decision (probation start,
static rejection, rollback, promotion, demotion — each carrying the bound
value and the window stats it was made on) is an event record against that
session, and every served window writes a trial-shaped record into
``trials.jsonl`` (``source="online"``, ``time_s`` = the window's p99).

:func:`surviving_baseline` is the resume path: an interrupted online run has
no unpaid strategy budget to replay — its state is *which config holds the
baseline slice* — so ``serve.py --online-tune`` re-reads the journal and
starts the next session from the last promoted baseline.

No wall-clock reads here (``serving-injected-clock``): timestamps are
stamped by the Study's own record writers.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.serving.controller import GuardConfig, WindowPlan
from repro.serving.metrics import WindowStats

__all__ = ["OnlineJournal", "surviving_baseline"]

# the sessions.jsonl event name guard decisions are journaled under
GUARD_EVENT = "guard"


class OnlineJournal:
    """The controller-facing journal: ``window(plan, stats)`` per served
    window, ``decision(kind, **fields)`` per guard decision, ``finish``
    to close the session with its summary."""

    def __init__(
        self,
        study: Any,
        platform: str,
        *,
        algorithm: str,
        guard: GuardConfig,
        baseline: Dict[str, Any],
        strategy_args: Optional[Dict[str, Any]] = None,
    ):
        self.study = study
        self.platform = platform
        self.session = study.begin_session(
            platform,
            algorithm,
            space="serve",
            mode="online",
            args={
                "guard": guard.to_dict(),
                "baseline": dict(baseline),
                **(strategy_args or {}),
            },
        )

    def window(self, plan: WindowPlan, stats: WindowStats) -> None:
        """One served window into the trial log: ``time_s`` is the window's
        p99 (the quantity guard decisions rank), the full window stats ride
        in ``info`` along with which slice served it."""
        self.study.append_trial_record({
            "platform": self.platform,
            "tag": f"online/{plan.slice}",
            "cached": False,
            "config": dict(plan.config),
            "time_s": stats.p99,
            "wall_s": stats.wall_s,
            "error": None,
            "status": "ok",
            "source": "online",
            "info": {
                **stats.to_dict(),
                "slice": plan.slice,
                "candidate": plan.candidate_id,
            },
        })

    def decision(self, kind: str, **fields: Any) -> None:
        self.study.record_session_event(
            self.session, GUARD_EVENT, {"kind": kind, **fields}
        )

    def finish(self, summary: Dict[str, Any]) -> None:
        self.study.end_session(self.session, summary)


def surviving_baseline(
    study: Any, platform: str
) -> Optional[Dict[str, Any]]:
    """The baseline config an interrupted (or completed) online run left
    holding the majority slice for ``platform`` — the config the next
    ``--online-tune`` session must start from.

    Walks the session journal in file order: each online ``start`` record's
    recorded baseline, superseded by every ``promote`` decision within that
    platform's online sessions. Returns None when the study has no online
    history for the platform (the caller falls back to defaults or
    ``--tuned-config``)."""
    online_sessions: set = set()
    baseline: Optional[Dict[str, Any]] = None
    for rec in study.sessions():
        event = rec.get("event")
        if (
            event == "start"
            and rec.get("mode") == "online"
            and rec.get("platform") == platform
        ):
            online_sessions.add(rec.get("session"))
            start_baseline = (rec.get("args") or {}).get("baseline")
            if start_baseline:
                baseline = dict(start_baseline)
        elif (
            event == GUARD_EVENT
            and rec.get("kind") == "promote"
            and rec.get("session") in online_sessions
            and rec.get("config")
        ):
            baseline = dict(rec["config"])
    return baseline
