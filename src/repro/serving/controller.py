"""OnlineController — safety-bounded live tuning over decode windows.

The offline engine evaluates candidate configs against a fixed workload; in
serving there is no second copy of production to experiment on. Following the
online-tuning setting of arXiv:2309.01901, the controller partitions decode
windows into traffic slices:

  - the **incumbent (baseline)** config always serves the majority slice —
    structurally: candidate windows occur at most once per round of
    ``ceil(1 / slice_frac)`` windows, and ``slice_frac < 0.5`` is validated,
    so at every prefix of the run baseline windows strictly outnumber
    candidate windows;
  - **one candidate at a time** (proposed by any registered ask/tell
    strategy, vetted by the static prefilter before it ever serves traffic)
    serves the probation slice;
  - the moment a candidate window's p99 regresses past
    ``safety_p99 × baseline_p99`` the candidate is **rolled back** and told
    to the strategy as a penalty observation (``Trial.score`` = infeasible);
  - a candidate that survives ``probation_windows`` candidate windows with a
    measured improvement (median probation p99 at least ``promote_margin``
    below the baseline reference) is **promoted** to the new baseline; one
    that survives without improving is demoted — told to the strategy as an
    honest (non-penalty) observation.

Determinism contract: the controller reads no clock and draws no randomness
of its own — the decision stream is a pure function of (strategy seed,
observed WindowStats sequence). The ``serving-injected-clock`` lint rule
enforces the clock half package-wide.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional

from repro.core.scheduler import INFEASIBLE, Trial
from repro.core.space import TunableSpace
from repro.core.transfer import snap_into_space
from repro.serving.metrics import WindowStats, quantile

__all__ = ["GuardConfig", "OnlineController", "WindowPlan"]


@dataclass(frozen=True)
class GuardConfig:
    """The safety envelope, validated in one place.

    ``safety_p99``        rollback bound: a candidate window whose p99
                          exceeds ``safety_p99 × baseline_p99`` is rolled
                          back immediately (must be > 1)
    ``slice_frac``        fraction of decode windows the candidate may serve;
                          must be in (0, 0.5) so the baseline holds a strict
                          majority by construction
    ``probation_windows`` candidate windows a candidate must survive before
                          the promote/demote decision — the rollback budget:
                          a regressing candidate serves at most this many
                          windows before it is gone
    ``baseline_window``   how many recent baseline windows feed the rolling
                          baseline p99 reference (median — robust to one
                          noisy window)
    ``promote_margin``    fractional p99 improvement required to promote
                          (0.03 = 3% better than baseline; guards against
                          promoting noise)
    ``warmup_windows``    baseline-only windows before the first candidate
                          may serve (the reference must exist before anything
                          is judged against it)
    """

    safety_p99: float = 1.25
    slice_frac: float = 0.2
    probation_windows: int = 3
    baseline_window: int = 8
    promote_margin: float = 0.03
    warmup_windows: int = 2

    def __post_init__(self):
        if not self.safety_p99 > 1.0:
            raise ValueError(
                f"safety_p99 must be > 1 (a bound at or below the baseline "
                f"would roll back healthy candidates), got {self.safety_p99}"
            )
        if not 0.0 < self.slice_frac < 0.5:
            raise ValueError(
                f"slice_frac must be in (0, 0.5) — the baseline must hold a "
                f"strict majority of traffic, got {self.slice_frac}"
            )
        if int(self.probation_windows) < 1:
            raise ValueError(
                f"probation_windows must be >= 1, got {self.probation_windows}"
            )
        if int(self.baseline_window) < 1:
            raise ValueError(
                f"baseline_window must be >= 1, got {self.baseline_window}"
            )
        if not 0.0 <= self.promote_margin < 1.0:
            raise ValueError(
                f"promote_margin must be in [0, 1), got {self.promote_margin}"
            )
        if int(self.warmup_windows) < 1:
            raise ValueError(
                f"warmup_windows must be >= 1, got {self.warmup_windows}"
            )

    @property
    def round_length(self) -> int:
        """Windows per scheduling round; the last window of each round is
        the (at most one) candidate slot. ``slice_frac < 0.5`` makes this
        >= 3, so every round is majority-baseline."""
        return max(3, int(math.ceil(1.0 / self.slice_frac)))

    def to_dict(self) -> Dict[str, Any]:
        return {
            "safety_p99": self.safety_p99,
            "slice_frac": self.slice_frac,
            "probation_windows": self.probation_windows,
            "baseline_window": self.baseline_window,
            "promote_margin": self.promote_margin,
            "warmup_windows": self.warmup_windows,
        }


@dataclass(frozen=True)
class WindowPlan:
    """What the serving loop should do for one decode window."""

    window: int
    slice: str  # "baseline" | "candidate"
    config: Dict[str, Any]
    candidate_id: Optional[int] = None  # stable id of the probing candidate


@dataclass
class _Candidate:
    cid: int
    config: Dict[str, Any]
    probation_p99: List[float] = field(default_factory=list)


class OnlineController:
    """The control loop: call :meth:`next_window` before serving each decode
    window, serve it under the returned plan's config, then feed the
    measured :class:`WindowStats` back through :meth:`observe`.

    ``journal`` (optional) receives every window record and guard decision —
    see :class:`repro.serving.journal.OnlineJournal`; any object with
    ``window(plan, stats)`` / ``decision(kind, **fields)`` methods works.
    ``prefilter`` (optional) is the PR-8 static gate: proposals it rejects
    are journaled and told to the strategy as ``infeasible_static`` penalty
    observations without ever serving traffic.
    """

    # cap on consecutive strategy proposals vetted per candidate slot — a
    # strategy stuck proposing statically-infeasible configs must not spin
    # the window loop forever
    MAX_VETS_PER_SLOT = 16

    def __init__(
        self,
        space: TunableSpace,
        strategy: Any,
        baseline: Dict[str, Any],
        *,
        guard: Optional[GuardConfig] = None,
        journal: Optional[Any] = None,
        prefilter: Optional[Any] = None,
        platform: str = "serve",
    ):
        self.space = space
        self.strategy = strategy
        self.guard = guard or GuardConfig()
        self.journal = journal
        self.prefilter = prefilter
        self.platform = platform
        # every config the controller ever serves or judges lives on the
        # space's grid — an off-grid baseline would be a config the tuner
        # could never re-propose or compare against
        self.baseline = snap_into_space(space, baseline)
        self.baseline_start = dict(self.baseline)
        self._baseline_p99: Deque[float] = deque(
            maxlen=int(self.guard.baseline_window)
        )
        self._candidate: Optional[_Candidate] = None
        self._next_cid = 1
        self._expected_window = 0
        self._pending_plan: Optional[WindowPlan] = None
        self.windows_baseline = 0
        self.windows_candidate = 0
        self.rollbacks = 0
        self.promotions = 0
        self.demotions = 0
        self.rejections = 0
        self.start_p99: Optional[float] = None  # first post-warmup reference

    # ------------------------------------------------------------- planning

    @property
    def windows_total(self) -> int:
        return self.windows_baseline + self.windows_candidate

    @property
    def baseline_p99(self) -> Optional[float]:
        """Rolling baseline reference: median p99 of the recent baseline
        windows (None until one exists)."""
        if not self._baseline_p99:
            return None
        return quantile(list(self._baseline_p99), 0.5)

    def next_window(self) -> WindowPlan:
        """Plan the next decode window. At most one window may be planned
        ahead; :meth:`observe` must consume the plan before the next call."""
        if self._pending_plan is not None:
            raise RuntimeError(
                "next_window() called again before observe() consumed the "
                f"plan for window {self._pending_plan.window}"
            )
        w = self._expected_window
        plan = WindowPlan(w, "baseline", dict(self.baseline))
        if self._candidate_slot(w):
            if self._candidate is None:
                self._acquire_candidate()
            if self._candidate is not None:
                plan = WindowPlan(
                    w, "candidate", dict(self._candidate.config),
                    candidate_id=self._candidate.cid,
                )
        self._pending_plan = plan
        return plan

    def _candidate_slot(self, window: int) -> bool:
        """Deterministic traffic partition: the last window of each round is
        the candidate slot (so every round starts with baseline windows and
        the baseline majority holds at every prefix of the run); the first
        ``warmup_windows`` windows are always baseline — the rollback
        reference must exist before anything is judged against it."""
        if window < self.guard.warmup_windows or self.baseline_p99 is None:
            return False
        return window % self.guard.round_length == self.guard.round_length - 1

    def _acquire_candidate(self) -> None:
        """Pull the next strategy proposal, snapped into the space and vetted
        by the static prefilter; rejected proposals are penalty-told (the
        strategy steers away) and never serve traffic."""
        for _ in range(self.MAX_VETS_PER_SLOT):
            if getattr(self.strategy, "done", False):
                return
            asked = self.strategy.ask(1)
            if not asked:
                return
            config = snap_into_space(self.space, asked[0])
            rejection = (
                self.prefilter(config, self.platform, 1.0)
                if self.prefilter is not None else None
            )
            if rejection is None:
                cid = self._next_cid
                self._next_cid += 1
                self._candidate = _Candidate(cid, config)
                self._decision(
                    "probation_start", candidate=cid, config=config,
                    baseline_p99=self.baseline_p99,
                    bound=self.guard.safety_p99,
                    probation_windows=self.guard.probation_windows,
                )
                return
            self.rejections += 1
            self._decision(
                "reject_static", config=config, rule=rejection.rule,
                reason=rejection.reason,
            )
            self.strategy.tell([Trial(
                dict(config), INFEASIBLE,
                {"prefilter_rule": rejection.rule, **rejection.detail},
                error=f"InfeasibleStatic[{rejection.rule}]: {rejection.reason}",
                status="infeasible_static", source="prefilter",
            )])

    # ------------------------------------------------------------ observing

    def observe(self, plan: WindowPlan, stats: WindowStats) -> None:
        """Feed one served window's measurement back; guard decisions
        (rollback / promote / demote) happen here, immediately."""
        if self._pending_plan is None or plan.window != self._pending_plan.window:
            raise RuntimeError(
                f"observe() got window {plan.window}, expected plan "
                f"{self._pending_plan.window if self._pending_plan else None}"
            )
        self._pending_plan = None
        self._expected_window += 1
        if self.journal is not None:
            self.journal.window(plan, stats)
        if plan.slice == "baseline":
            self.windows_baseline += 1
            self._baseline_p99.append(stats.p99)
            warm = min(self.guard.warmup_windows, self.guard.baseline_window)
            if self.start_p99 is None and len(self._baseline_p99) >= warm:
                self.start_p99 = self.baseline_p99
            return
        self.windows_candidate += 1
        cand = self._candidate
        if cand is None or plan.candidate_id != cand.cid:
            raise RuntimeError(
                f"observe() for candidate {plan.candidate_id} but the active "
                f"candidate is {cand.cid if cand else None}"
            )
        ref = self.baseline_p99
        assert ref is not None  # candidate slots require a reference
        bound = self.guard.safety_p99 * ref
        if stats.p99 > bound:
            self._rollback(cand, stats, ref, bound)
            return
        cand.probation_p99.append(stats.p99)
        if len(cand.probation_p99) >= self.guard.probation_windows:
            self._resolve_probation(cand, ref)

    def _rollback(
        self, cand: _Candidate, stats: WindowStats, ref: float, bound: float
    ) -> None:
        self.rollbacks += 1
        self._candidate = None
        self._decision(
            "rollback", candidate=cand.cid, config=cand.config,
            p99=stats.p99, baseline_p99=ref, bound=bound,
            windows_served=len(cand.probation_p99) + 1,
        )
        # penalty observation: the measurement is real (time_s keeps it for
        # analysis) but the strategy ranks on Trial.score, which is
        # infeasible for any non-ok status — TPE/CRS steer away
        self.strategy.tell([Trial(
            dict(cand.config), float(stats.p99), {"baseline_p99": ref},
            error=(
                f"RollbackGuard: candidate p99 {stats.p99:.6g}s exceeded "
                f"{self.guard.safety_p99:g}x baseline ({bound:.6g}s)"
            ),
            status="rollback",
        )])

    def _resolve_probation(self, cand: _Candidate, ref: float) -> None:
        cand_p99 = quantile(cand.probation_p99, 0.5)
        self._candidate = None
        if cand_p99 <= ref * (1.0 - self.guard.promote_margin):
            self.promotions += 1
            self.baseline = dict(cand.config)
            # the probation measurements WERE baseline-config measurements
            # from this moment on — seed the new reference from them instead
            # of judging the next candidate against the dethroned config
            self._baseline_p99.clear()
            self._baseline_p99.extend(cand.probation_p99)
            self._decision(
                "promote", candidate=cand.cid, config=cand.config,
                candidate_p99=cand_p99, baseline_p99=ref,
                margin=self.guard.promote_margin,
            )
        else:
            self.demotions += 1
            self._decision(
                "demote", candidate=cand.cid, config=cand.config,
                candidate_p99=cand_p99, baseline_p99=ref,
            )
        # either way the probation produced an honest full measurement
        self.strategy.tell([Trial(
            dict(cand.config), float(cand_p99), {"baseline_p99": ref},
        )])

    # ------------------------------------------------------------ reporting

    def _decision(self, kind: str, **fields: Any) -> None:
        if self.journal is not None:
            self.journal.decision(kind, **fields)

    def summary(self) -> Dict[str, Any]:
        """Session summary in the offline TuneOutcome vocabulary, so an
        online session's ``done`` record reads like any other in
        ``Study.report()``: ``default_time_s`` is the starting baseline's
        post-warmup p99, ``best_time_s`` the final baseline's rolling p99,
        ``evaluations`` the resolved candidate probations."""
        final_p99 = self.baseline_p99
        default = self.start_p99 if self.start_p99 is not None else float("inf")
        best = final_p99 if final_p99 is not None else default
        reduction = (
            100.0 * (default - best) / default
            if default not in (0.0, float("inf")) else 0.0
        )
        return {
            "platform": self.platform,
            "algorithm": getattr(self.strategy, "tag", "online"),
            "default_time_s": default,
            "best_time_s": best,
            "reduction_pct": round(reduction, 2),
            "evaluations": self.rollbacks + self.promotions + self.demotions,
            "windows": self.windows_total,
            "windows_baseline": self.windows_baseline,
            "windows_candidate": self.windows_candidate,
            "rollbacks": self.rollbacks,
            "promotions": self.promotions,
            "demotions": self.demotions,
            "rejections": self.rejections,
            "baseline_start": dict(self.baseline_start),
            "best_config": dict(self.baseline),
            "guard": self.guard.to_dict(),
        }
