"""Online serving tuner — a safety-bounded control loop over batched decode.

The offline tuner (the paper's workflow) measures candidate configs against a
fixed workload; this subsystem tunes *live*, the way arXiv:2309.01901 tunes
Spark against production traffic:

  - :mod:`repro.serving.metrics` — streaming per-window latency/throughput
    monitoring (p50/p99 over a sliding reservoir, injectable clock so
    simulations are deterministic),
  - :mod:`repro.serving.controller` — the :class:`OnlineController`: the
    incumbent (baseline) config always holds the majority traffic slice; one
    strategy-proposed candidate at a time serves a bounded probation slice
    and is rolled back the moment its windowed p99 regresses past the safety
    bound, or promoted to the new baseline when it survives with a measured
    improvement,
  - :mod:`repro.serving.journal` — every guard decision journaled into Study
    storage (``sessions.jsonl``/``trials.jsonl``) with the same provenance as
    offline sessions; an interrupted run resumes with the surviving baseline,
  - :mod:`repro.serving.traffic` — scripted synthetic traffic (phase shifts,
    injected regressions) driving the CI smokes and the simulation suite.

Invariant (enforced by ``tools/reprolint.py`` rule ``serving-injected-clock``):
no module in this package reads the wall clock directly — time enters only
through injected ``clock=`` callables, so every decision stream is a pure
function of (seed, trace).
"""
from repro.serving.controller import (
    GuardConfig,
    OnlineController,
    WindowPlan,
)
from repro.serving.journal import OnlineJournal, surviving_baseline
from repro.serving.metrics import (
    DecodeWindowMonitor,
    WindowStats,
    quantile,
)
from repro.serving.traffic import (
    TRACES,
    SyntheticServeModel,
    TrafficPhase,
    scripted_trace,
)

__all__ = [
    "DecodeWindowMonitor",
    "GuardConfig",
    "OnlineController",
    "OnlineJournal",
    "SyntheticServeModel",
    "TRACES",
    "TrafficPhase",
    "WindowPlan",
    "WindowStats",
    "quantile",
    "scripted_trace",
    "surviving_baseline",
]
