"""Scripted synthetic traffic for the online tuner.

Real serving traffic shifts under the tuner's feet — prompt lengths drift,
batch sizes change, and the config that was optimal for the old mix regresses
on the new one. This module scripts those dynamics so the simulation suite
and the CI smokes can assert guard behaviour exactly:

  - a **trace** is a sequence of :class:`TrafficPhase` records; each phase
    fixes the workload mix (prompt length, batch) and the *ground-truth
    optimum* (``ideal_block_kv``, ``ideal_kv_dtype``) for its duration;
  - :class:`SyntheticServeModel` turns (window index, config, slice) into a
    deterministic per-token latency list: configs near the phase optimum are
    fast, distance is charged as ``amp * 0.25 * |log2(bkv) - log2(ideal)|``
    plus a flat penalty for the wrong KV-cache dtype, and a seeded
    per-window jitter + tail sample keep p99 honestly above p50;
  - the ``regression`` trace injects a ``spike`` multiplier on every window
    served by a non-baseline slice — "any change regresses here" — which the
    safety guard must catch within the probation budget.

All randomness is ``random.Random`` seeded from integers only (string seeds
would be PYTHONHASHSEED-dependent), keyed per (seed, window) and independent
of the config — so the full decision stream of a simulated run is a pure
function of (seed, trace), which the simulation suite asserts by replay.
No wall-clock reads (``serving-injected-clock``): latencies are scripted.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

__all__ = ["TRACES", "SyntheticServeModel", "TrafficPhase", "scripted_trace"]


@dataclass(frozen=True)
class TrafficPhase:
    """One stretch of workload with a fixed ground-truth optimum.

    ``windows``        decode windows the phase lasts (the final phase
                       extends indefinitely if the run is longer)
    ``prompt_len``     prompt tokens per request — longer prompts cost more
    ``batch``          requests per decode step (tokens per step)
    ``ideal_block_kv`` the ``attn_block_kv`` value that is optimal here
    ``ideal_kv_dtype`` the ``kv_cache_dtype`` that is optimal here
    ``amp``            how hard config distance is punished (0 = all configs
                       equal — the "flat" trace)
    ``spike``          latency multiplier applied to windows served by a
                       non-baseline slice (an injected regression: any
                       config change during this phase goes bad)
    """

    name: str
    windows: int
    prompt_len: int
    batch: int
    ideal_block_kv: int = 512
    ideal_kv_dtype: str = "bfloat16"
    amp: float = 1.0
    spike: float = 1.0


# Named traces the CLI (--traffic) and CI smokes run.
#
#   flat        one phase, amp=0: every config performs identically up to
#               jitter — the guard must fire zero rollbacks.
#   regression  defaults are already optimal and every candidate slice is
#               spiked 1.6x — each candidate's first window breaches the
#               1.25x bound, so rollback must land within one probation
#               window and the baseline must never be displaced.
#   drift       phase 1 favours the defaults; phase 2 shifts to short
#               prompts where attn_block_kv=128 + int8 KV cache win — the
#               controller must promote a measurably better baseline.
TRACES: Dict[str, Tuple[TrafficPhase, ...]] = {
    "flat": (
        TrafficPhase("steady", windows=64, prompt_len=512, batch=8, amp=0.0),
    ),
    "regression": (
        TrafficPhase(
            "poisoned", windows=64, prompt_len=512, batch=8,
            amp=1.0, spike=1.6,
        ),
    ),
    "drift": (
        TrafficPhase("long-prompts", windows=16, prompt_len=2048, batch=8),
        TrafficPhase(
            "short-prompts", windows=96, prompt_len=256, batch=16,
            ideal_block_kv=128, ideal_kv_dtype="int8", amp=2.0,
        ),
    ),
}


def scripted_trace(name: str) -> Tuple[TrafficPhase, ...]:
    try:
        return TRACES[name]
    except KeyError:
        raise ValueError(
            f"unknown trace {name!r}; known: {sorted(TRACES)}"
        ) from None


class SyntheticServeModel:
    """Deterministic latency generator over a scripted trace.

    ``latencies(window, config, slice_name)`` returns the per-decode-step
    latency list for one window: the config's phase cost (see module
    docstring), a seeded multiplicative jitter drawn per window (identical
    whichever config serves the window — decisions depend on the config,
    never on which random numbers it happened to draw), and one tail sample
    so every window's p99 sits visibly above its p50.
    """

    # decode steps simulated per window — enough samples for a stable
    # p50/p99 spread without slowing the CI smokes
    STEPS_PER_WINDOW = 24
    JITTER = 0.01      # +/- multiplicative body noise
    TAIL = 1.12        # tail-sample multiplier (keeps p99 > p50)
    DTYPE_PENALTY = 1.10  # cost of serving with the wrong KV-cache dtype

    def __init__(self, trace: Tuple[TrafficPhase, ...], seed: int = 0):
        if not trace:
            raise ValueError("trace must have at least one phase")
        self.trace = tuple(trace)
        self.seed = int(seed)

    @property
    def total_windows(self) -> int:
        return sum(p.windows for p in self.trace)

    def phase_at(self, window: int) -> TrafficPhase:
        """The phase governing ``window``; the last phase extends forever so
        a run longer than the script stays in the final regime."""
        if window < 0:
            raise ValueError(f"negative window {window}")
        offset = 0
        for phase in self.trace:
            offset += phase.windows
            if window < offset:
                return phase
        return self.trace[-1]

    def cost(self, config: Dict[str, Any], phase: TrafficPhase) -> float:
        """Noise-free per-step latency for ``config`` under ``phase``."""
        base = 0.004 * (1.0 + phase.prompt_len / 2048.0)
        bkv = int(config.get("attn_block_kv", 512))
        dist = abs(math.log2(bkv) - math.log2(phase.ideal_block_kv))
        cost = base * (1.0 + phase.amp * 0.25 * dist)
        if config.get("kv_cache_dtype", "bfloat16") != phase.ideal_kv_dtype:
            cost *= self.DTYPE_PENALTY
        if config.get("matmul_precision", "bf16") == "f32":
            cost *= 1.02
        return cost

    def latencies(
        self, window: int, config: Dict[str, Any], slice_name: str
    ) -> List[float]:
        phase = self.phase_at(window)
        cost = self.cost(config, phase)
        if slice_name != "baseline":
            cost *= phase.spike
        # integer-keyed seeding: string/tuple-of-string seeds would vary
        # with PYTHONHASHSEED across processes
        rng = random.Random(self.seed * 1_000_003 + window)
        out = [
            cost * (1.0 + rng.uniform(-self.JITTER, self.JITTER))
            for _ in range(self.STEPS_PER_WINDOW - 1)
        ]
        out.append(cost * self.TAIL * (1.0 + rng.uniform(0.0, self.JITTER)))
        return out
