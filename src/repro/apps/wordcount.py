"""WordCount — the paper's benchmark job, as a JAX map/reduce pipeline.

The paper's experiments tune Hadoop/Spark running WordCount on a 1 GB corpus
and measure wall-clock execution time. This module reproduces that experiment
design *with measured wall time* on the local devices: a token corpus is
split into map tasks (``lax.map`` over chunks), each map task bincounts its
blocks, optional map-side "compression" narrows the shuffle payload, and the
reduce phase tree-merges the per-task partial counts over vocabulary shards.

Every knob mirrors a Table-I parameter (analog noted inline). As in the
paper, several knobs are *long-tail* on this platform (e.g. the parallel-task
caps don't bind on a single host) — the tuner has to discover which matter.
The dominant knob is ``replication`` (default 3, like ``dfs.replication``):
the job re-reads the corpus once per replica, so tuned=1 recovers ~2/3 of the
runtime — the same shape as the paper's Table IV finding.

On a multi-device mesh the map tasks are additionally sharded over the
``data`` axis with a ``psum`` shuffle (``shard_map``), which is the faithful
distributed geometry; on one CPU device it degrades to the sequential case.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.space import BoolParam, CatParam, FloatParam, IntParam, TunableSpace

VOCAB = 8192

# The 12 knobs, mirroring the paper's Table I (analog in comments).
WORDCOUNT_SPACE = TunableSpace(
    platform="wordcount",
    params=(
        IntParam("num_map_tasks", 2, lo=2, hi=32, step=1, pow2=True),        # mapreduce.job.maps
        IntParam("block_tokens", 32768, lo=4096, hi=262144, pow2=True),      # dfs.blocksize
        IntParam("map_tasks_max", 2, lo=2, hi=128, pow2=True),               # tasktracker.map.tasks.maximum (no-op on 1 host)
        FloatParam("slowstart", 0.05, lo=0.025, hi=0.9, step=0.025),         # reduce.slowstart.completedmaps (no-op: single phase)
        BoolParam("map_output_compress", False),                              # map.output.compress
        IntParam("num_reduces", 1, lo=1, hi=4, step=1),                       # mapreduce.job.reduces
        IntParam("sort_buffer_tokens", 8192, lo=2048, hi=65536, pow2=True),   # task.io.sort.mb
        IntParam("sort_factor", 10, lo=5, hi=80, step=5),                     # task.io.sort.factor
        IntParam("replication", 3, lo=1, hi=3, step=1),                       # dfs.replication
        IntParam("reduce_tasks_max", 2, lo=2, hi=128, pow2=True),             # tasktracker.reduce.tasks.maximum (no-op)
        IntParam("jvm_numtasks", 1, lo=1, hi=1024, pow2=True),                # job.jvm.numtasks (no-op)
        IntParam("io_sort_mb", 100, lo=32, hi=128, step=32),                  # task.io.sort.mb (MB knob kept for table parity)
    ),
    most_influential=("replication", "block_tokens"),
)


def make_corpus(num_tokens: int = 1 << 21, vocab: int = VOCAB, seed: int = 0) -> jnp.ndarray:
    """Deterministic zipfian-ish corpus (the '1 GB dataset')."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(vocab, size=num_tokens, p=probs).astype(np.int32)
    return jnp.asarray(toks)


def _bincount_blocks(chunk: jnp.ndarray, block: int, sort_buffer: int, vocab: int):
    """Map task: count words in ``chunk``, reading it block by block and
    scattering each block through a bounded 'sort buffer'."""
    n = chunk.shape[0]
    block = min(block, n)
    n_blocks = n // block

    def one_block(blk):
        buf = min(max(int(sort_buffer), 1), block)
        segs = blk.reshape(block // buf, buf) if block % buf == 0 else blk[None, :]

        def seg_count(carry, seg):
            return carry.at[seg].add(1), None

        counts, _ = jax.lax.scan(seg_count, jnp.zeros((vocab,), jnp.int32), segs)
        return counts

    blocks = chunk[: n_blocks * block].reshape(n_blocks, block)
    counts = jax.lax.map(one_block, blocks).sum(axis=0)
    rem = chunk[n_blocks * block:]
    if rem.size:
        counts = counts.at[rem].add(1)
    return counts


def _tree_merge(partials: jnp.ndarray, fan_in: int) -> jnp.ndarray:
    """Reduce phase: merge per-task counts ``fan_in`` streams at a time
    (io.sort.factor analog)."""
    while partials.shape[0] > 1:
        m = partials.shape[0]
        f = max(2, min(fan_in, m))
        pad = (-m) % f
        if pad:
            partials = jnp.pad(partials, ((0, pad), (0, 0)))
        partials = partials.reshape(-1, f, partials.shape[-1]).sum(axis=1)
    return partials[0]


def build_wordcount(
    config: Dict[str, Any],
    corpus: jnp.ndarray,
    *,
    vocab: int = VOCAB,
    mesh=None,
    fidelity: float = 1.0,
) -> Callable[[], jnp.ndarray]:
    """Compile the WordCount job under ``config``; returns a zero-arg runner
    (what the CMPE's WalltimeEvaluator times).

    ``fidelity < 1`` is input-scale fidelity: the job runs on the leading
    ``fidelity`` fraction of the corpus — the paper's workload shrunk, not a
    different workload — so an ASHA rung-0 probe costs a fraction of the
    full measured trial while preserving the knobs' relative effects
    (replication still re-reads the prefix, block/sort knobs still shape the
    same map tasks)."""
    cfg = WORDCOUNT_SPACE.snap({**WORDCOUNT_SPACE.defaults(), **config})
    if fidelity < 1.0:
        # keep at least one token per map task so the chunking below stays
        # well-formed at extreme rungs
        n_keep = max(int(cfg["num_map_tasks"]),
                     int(corpus.shape[0] * max(fidelity, 0.0)))
        corpus = corpus[:n_keep]
    n_map = int(cfg["num_map_tasks"])
    block = int(cfg["block_tokens"])
    sortbuf = int(cfg["sort_buffer_tokens"])
    fan_in = int(cfg["sort_factor"])
    n_red = int(cfg["num_reduces"])
    reps = int(cfg["replication"])
    compress = bool(cfg["map_output_compress"])

    n = corpus.shape[0] - corpus.shape[0] % n_map

    def job(tokens):
        chunks = tokens[:n].reshape(n_map, -1)

        def map_task(chunk):
            counts = _bincount_blocks(chunk, block, sortbuf, vocab)
            if compress:
                # map-side combine + narrow the shuffle payload
                counts = jnp.minimum(counts, 2**15 - 1).astype(jnp.int16)
            return counts

        total = jnp.zeros((vocab,), jnp.int32)
        for r in range(reps):  # dfs.replication: the job re-reads each replica
            # each replica is a rotated view of the corpus (same multiset, so
            # the result is unchanged) — a distinct read that XLA cannot CSE
            # into the first one, faithfully costing the extra replica I/O
            rep_chunks = jnp.roll(chunks, r, axis=1) if r else chunks
            partials = jax.lax.map(map_task, rep_chunks).astype(jnp.int32)
            # reduce phase over vocabulary shards (last shard takes the
            # remainder when num_reduces does not divide the vocabulary —
            # found by the hypothesis correctness property)
            vshard = vocab // n_red
            bounds = [(i * vshard, (i + 1) * vshard if i < n_red - 1 else vocab)
                      for i in range(n_red)]
            merged = [
                _tree_merge(partials[:, lo:hi], fan_in) for lo, hi in bounds
            ]
            total = total + jnp.concatenate(merged)
        return total // reps

    jitted = jax.jit(job)

    def runner():
        return jax.block_until_ready(jitted(corpus))

    return runner


def wordcount_reference(corpus: np.ndarray, vocab: int = VOCAB) -> np.ndarray:
    return np.bincount(np.asarray(corpus), minlength=vocab).astype(np.int32)


def make_evaluator(corpus=None, repeats: int = 2):
    """WalltimeEvaluator wired to WordCount (paper-faithful measured loop).

    The attached ``spec`` lets subprocess workers rebuild this evaluator by
    importing this module — the builder closure itself can't be pickled. A
    custom corpus travels inside the spec as a plain numpy array."""
    from repro.core.evaluators import WalltimeEvaluator
    from repro.core.executors import EvaluatorSpec

    spec_kwargs: Dict[str, Any] = {"repeats": repeats}
    if corpus is not None:
        spec_kwargs["corpus"] = np.asarray(corpus)
    corpus = corpus if corpus is not None else make_corpus()
    return WalltimeEvaluator(
        builder=lambda cfg, fidelity=1.0: build_wordcount(
            cfg, corpus, fidelity=fidelity
        ),
        repeats=repeats,
        spec=EvaluatorSpec.factory(
            "repro.apps.wordcount:make_evaluator", **spec_kwargs
        ),
    )
