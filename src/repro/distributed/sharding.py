"""Logical-axis → mesh-axis rules, driven by the tunable RunConfig.

This module is where the execution-layer knobs (the paper's "configuration
parameters") become concrete GSPMD shardings:

  - ``mesh_model_parallel``   — model-axis size (the mesh itself, see launch.mesh)
  - ``zero_sharding``         — none | zero1 (opt-state over data) | fsdp (params too)
  - ``collective_matmul``     — ag (Megatron TP) | rs (sequence-parallel residual)
  - ``moe_expert_parallel``   — experts over model axis (EP) vs expert-FF TP
  - ``kv_partition`` / ``attn_partition`` — heads vs sequence partitioning

Every rule degrades gracefully: an axis is only mapped when the concrete
dimension is divisible by the mesh-axis size (checked in the shard closure),
so one rule set serves all 10 architectures, including awkward cases like
whisper's 6 heads or gemma3's single KV head.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax

from repro.configs.base import ArchConfig, RunConfig, ShapeConfig, resolve_kv_partition


def mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_rules(
    arch: ArchConfig,
    run: RunConfig,
    shape: ShapeConfig,
    mesh,
) -> Dict[str, Any]:
    """Logical-axis rules for one (arch × shape × mesh × run) cell."""
    sizes = mesh_axis_sizes(mesh)
    mp = sizes.get("model", 1)
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    b_axes = batch_axes(mesh)
    dh = arch.resolved_head_dim
    mode = shape.kind

    heads_ok = arch.num_heads % mp == 0
    kv_part = resolve_kv_partition(arch, run, mp)
    # serve: weights are always fully (2D) sharded — a 398B bf16 checkpoint
    # does not fit 16-way; the per-layer all-gather is the price (tunable via
    # mesh_model_parallel)
    fsdp = (run.zero_sharding == "fsdp") if mode == "train" else True
    seq_par = (
        run.collective_matmul == "rs"
        and mode != "decode"
        and shape.seq_len % mp == 0
    )
    batch_ok = shape.global_batch % dp == 0

    rules: Dict[str, Any] = {
        # ---- parameters -------------------------------------------------
        "vocab": "model",
        "embed": "data" if fsdp else None,
        "ff": "model",
        "heads_out": "model",
        "kv_out": "model" if (arch.num_kv_heads * dh) % mp == 0 else None,
        "heads": "model" if heads_ok else None,
        "embed_out": "model",
        "inner": "model",
        "expert": "model" if run.moe_expert_parallel else None,
        "ff_expert": None if run.moe_expert_parallel else "model",
        # ---- activations -------------------------------------------------
        "act_batch": b_axes if batch_ok else None,
        "act_seq": "model" if seq_par else None,
        "act_heads": "model" if heads_ok else None,
        "act_embed": None,
        # flattened (B·S) token dim of the MoE dispatch: follows the batch
        "act_tokens": b_axes if batch_ok else None,
        # ---- kv / state caches -------------------------------------------
        "kv_heads": "model" if kv_part == "heads" else None,
        "kv_seq": "model" if kv_part == "sequence" else None,
        # helper metadata for the shard closure
        "_sizes": sizes,
    }

    # long-context single-sequence decode: batch can't shard; spread the KV
    # timeline over every chip instead.
    if mode == "decode" and not batch_ok and kv_part == "sequence":
        rules["kv_seq"] = b_axes + ("model",)
    return rules


def opt_state_rules(rules: Dict[str, Any], run: RunConfig) -> Dict[str, Any]:
    """ZeRO-1: optimizer moments additionally sharded over the data axis along
    the d_model ("embed") dimension present in every projection weight."""
    if run.zero_sharding not in ("zero1", "fsdp"):
        return rules
    out = dict(rules)
    out["embed"] = "data"
    return out


def batch_partition_specs(arch: ArchConfig, shape: ShapeConfig, mesh, run: RunConfig):
    """PartitionSpec tree matching Model.input_specs(shape)."""
    from jax.sharding import PartitionSpec as P

    sizes = mesh_axis_sizes(mesh)
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    b_ax = batch_axes(mesh) if shape.global_batch % dp == 0 else None
    specs = {}
    if shape.kind == "train":
        specs["tokens"] = P(b_ax, None)
        specs["labels"] = P(b_ax, None)
    elif shape.kind == "prefill":
        specs["tokens"] = P(b_ax, None)
    else:
        specs["tokens"] = P(b_ax, None)
        specs["cache_len"] = P()
    if shape.kind != "decode":
        if arch.frontend == "vision":
            specs["patches"] = P(b_ax, None, None)
        elif arch.frontend == "audio":
            specs["frames"] = P(b_ax, None, None)
    return specs
