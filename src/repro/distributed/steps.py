"""Distributed step builders: (arch × shape × mesh × RunConfig) → jit-able
train / prefill / decode steps with full input/output sharding trees.

Every builder returns a ``StepBundle`` carrying the abstract inputs
(ShapeDtypeStructs — no allocation) and the sharding trees, so the same
bundle serves three consumers:

  - the **dry-run** (``bundle.lower(mesh)`` → compile → memory/cost analysis),
  - the **tuner's roofline evaluator** (same artifacts, knobs varied),
  - **real execution** (examples / smoke tests pass concrete arrays).

The paper's knobs enter here: microbatch gradient accumulation
(``microbatch_size``), remat policy (inside the stack scan), ZeRO sharding of
optimizer state, int8 cross-pod gradient compression (partial-manual
``shard_map`` over the ``pod`` axis), and the activation-sharding strategy.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size as compat_axis_size
from repro.compat import shard_map as compat_shard_map
from repro.configs.base import ArchConfig, RunConfig, ShapeConfig
from repro.distributed.sharding import (
    batch_partition_specs,
    make_rules,
    mesh_axis_sizes,
    opt_state_rules,
)
from repro.models.model import Model
from repro.optim import compression
from repro.optim.adamw import (
    AdamWConfig,
    abstract_opt_state,
    adamw_update,
    clip_by_global_norm,
    init_opt_state,
)
from repro.optim.schedules import warmup_cosine


@dataclass
class StepBundle:
    name: str
    fn: Callable
    abstract_inputs: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    model: Model
    rules: Dict[str, Any]
    mesh: Any = None

    def jit(self, donate: bool = True):
        from repro.compat import concrete_shardings

        return jax.jit(
            self.fn,
            in_shardings=concrete_shardings(self.in_shardings, self.mesh),
            out_shardings=concrete_shardings(self.out_shardings, self.mesh),
            donate_argnums=self.donate_argnums if donate else (),
        )

    def lower(self):
        return self.jit().lower(*self.abstract_inputs)

    def compile(self):
        return self.lower().compile()

    def place(self, mesh, *args):
        """device_put concrete inputs onto their declared shardings."""
        from jax.sharding import NamedSharding, PartitionSpec

        def put(tree, ps):
            return jax.tree.map(
                lambda x, p: jax.device_put(x, NamedSharding(mesh, p)),
                tree,
                ps,
                is_leaf=lambda x: x is None,
            )

        return tuple(put(a, p) for a, p in zip(args, self.in_shardings))


def _effective_run(run: RunConfig) -> RunConfig:
    """Resolve derived knobs (matmul precision → compute dtype)."""
    if run.matmul_precision == "f32" and run.compute_dtype != "float32":
        run = run.replace(compute_dtype="float32")
    return run


def _adamw_cfg(run: RunConfig) -> AdamWConfig:
    return AdamWConfig(moment_dtype=run.optimizer_moment_dtype)


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def make_train_step(
    arch: ArchConfig, run: RunConfig, shape: ShapeConfig, mesh
) -> StepBundle:
    run = _effective_run(run)
    sizes = mesh_axis_sizes(mesh)
    n_pod = sizes.get("pod", 1)
    compress = run.grad_compression == "int8" and n_pod > 1
    if compress:
        # scatter-free embedding bwd: XLA's scatter partitioner cannot handle
        # the transposed device groups of a partial-manual shard_map region
        run = run.replace(embed_impl="one_hot")
    model = Model(arch, run)
    rules = make_rules(arch, run, shape, mesh)
    opt_rules = opt_state_rules(rules, run)
    cfg = _adamw_cfg(run)

    param_ps = model.param_partition_specs(rules)
    opt_param_ps = model.param_partition_specs(opt_rules)
    batch_ps = batch_partition_specs(arch, shape, mesh, run)

    b = shape.global_batch
    mb = run.microbatch_size or 0
    n_micro = 1
    if mb and mb < b and b % mb == 0:
        n_micro = b // mb

    # ---- rules inside the compression shard_map: the pod axis is manual
    pod_local_shape = dataclasses.replace(shape, global_batch=b // n_pod)
    if compress:
        inner_rules = dict(make_rules(arch, run, pod_local_shape, mesh))
        inner_rules["act_batch"] = (
            ("data",) if (b // n_pod) % sizes.get("data", 1) == 0 else None
        )
        inner_sizes = dict(sizes)
        inner_sizes.pop("pod", None)
        inner_rules["_sizes"] = inner_sizes
    else:
        inner_rules = rules

    def mean_loss(params, batch):
        loss, metrics = model.loss(params, batch, rules=inner_rules)
        return loss, metrics

    grad_fn = jax.value_and_grad(mean_loss, has_aux=True)

    def grads_over_batch(params, batch):
        """Possibly microbatched loss+grad (mean over the whole batch)."""
        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def reshape(x):
            return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])

        micro = jax.tree.map(reshape, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb_batch):
            acc, loss_acc = carry
            (loss, _), grads = grad_fn(params, mb_batch)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / n_micro, acc, grads)
            return (acc, loss_acc + loss / n_micro), None

        (grads, loss), _ = jax.lax.scan(
            body, (zeros, 0.0), micro, unroll=not run.scan_layers
        )
        return loss, {"ce": loss, "aux": jnp.zeros(())}, grads

    def apply_update(state, grads, loss, metrics, new_err=None):
        grads, gnorm = clip_by_global_norm(grads, run.gradient_clip)
        lr = warmup_cosine(state["step"], peak_lr=run.learning_rate)
        new_params, new_opt = adamw_update(
            grads, state["opt"], state["params"], state["step"], lr, cfg
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        if "err" in state:
            new_state["err"] = new_err if new_err is not None else state["err"]
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr, **metrics}
        return new_state, out_metrics

    if not compress:

        def train_step(state, batch):
            loss, metrics, grads = grads_over_batch(state["params"], batch)
            return apply_update(state, grads, loss, metrics)

    elif not hasattr(jax, "shard_map"):
        # Legacy-jax fallback: XLA's SPMD partitioner on jaxlib 0.4.x cannot
        # handle the partial-manual (pod-manual, data/model-auto) shard_map
        # region below. Express the same computation in pure GSPMD instead:
        # chunk the batch into an explicit pod-sharded leading dim, vmap the
        # per-pod grads (each pod computes only its own chunk), and reduce the
        # int8-quantized chunks with an int32 sum over the pod-sharded dim —
        # which XLA lowers to the same narrow cross-pod all-reduce.
        from jax.sharding import NamedSharding

        def train_step(state, batch):
            params = state["params"]

            def chunk(x):
                if x.ndim == 0:
                    return x
                c = x.reshape((n_pod, x.shape[0] // n_pod) + x.shape[1:])
                spec = P(*(("pod",) + (None,) * (c.ndim - 1)))
                return jax.lax.with_sharding_constraint(c, NamedSharding(mesh, spec))

            batch_c = jax.tree.map(chunk, batch)

            def per_pod(mb):
                return grads_over_batch(params, mb)

            loss_p, metrics_p, grads_p = jax.vmap(per_pod)(batch_c)
            synced, new_err = compression.compress_sum_chunked_tree(
                grads_p, state["err"]
            )
            loss = loss_p.mean()
            metrics = jax.tree.map(lambda m: m.mean(0), metrics_p)
            return apply_update(state, synced, loss, metrics, new_err)

    else:
        # Partial-manual shard_map over the pod axis: pod-local grads, int8
        # error-feedback all-reduce across pods, everything else GSPMD.
        def pod_body(params, err, batch):
            loss, metrics, grads = grads_over_batch(params, batch)
            synced, new_err = compression.compress_psum_pod_tree(grads, err)
            n = compat_axis_size("pod")
            loss = jax.lax.psum(loss, "pod") / n
            metrics = jax.tree.map(lambda m: jax.lax.psum(m, "pod") / n, metrics)
            return loss, metrics, synced, new_err

        replicate = lambda tree: jax.tree.map(lambda _: P(), tree)
        # pod-manual in_specs: batch leaves split over pod on dim 0; scalars whole
        pod_batch_specs = {
            k: (P() if v.ndim == 0 else P(*(("pod",) + (None,) * (v.ndim - 1))))
            for k, v in Model(arch, run).input_specs(shape).items()
        }
        metrics_specs = {"ce": P(), "aux": P()}

        def train_step(state, batch):
            params = state["params"]
            body = compat_shard_map(
                pod_body,
                mesh=mesh,
                in_specs=(replicate(params), replicate(state["err"]), pod_batch_specs),
                out_specs=(P(), metrics_specs, replicate(params), replicate(params)),
                axis_names={"pod"},
                check_vma=False,
            )
            loss, metrics, grads, new_err = body(params, state["err"], batch)
            return apply_update(state, grads, loss, metrics, new_err)

    # ---- abstract inputs + shardings
    params_abs = model.abstract_params()
    state_abs = {
        "params": params_abs,
        "opt": abstract_opt_state(params_abs, cfg),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    state_ps = {
        "params": param_ps,
        "opt": {"mu": opt_param_ps, "nu": opt_param_ps},
        "step": P(),
    }
    if compress:
        if hasattr(jax, "shard_map"):
            state_abs["err"] = compression.abstract_error_state(params_abs)
            state_ps["err"] = param_ps
        else:  # chunked fallback keeps one residual per pod: [n_pod, *param]
            state_abs["err"] = compression.abstract_chunked_error_state(
                params_abs, n_pod
            )
            state_ps["err"] = jax.tree.map(lambda _: P("pod"), params_abs)
    batch_abs = model.input_specs(shape)

    metrics_ps = {"loss": P(), "grad_norm": P(), "lr": P(), "ce": P(), "aux": P()}
    return StepBundle(
        name=f"train:{arch.name}:{shape.name}",
        fn=train_step,
        abstract_inputs=(state_abs, batch_abs),
        in_shardings=(state_ps, batch_ps),
        out_shardings=(state_ps, metrics_ps),
        donate_argnums=(0,),
        model=model,
        rules=rules,
        mesh=mesh,
    )


def init_train_state(bundle: StepBundle, rng=None):
    """Real initial state (smoke tests / examples)."""
    model = bundle.model
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    params = model.init_params(rng)
    cfg = _adamw_cfg(model.run)
    state = {
        "params": params,
        "opt": init_opt_state(params, cfg),
        "step": jnp.zeros((), jnp.int32),
    }
    if "err" in bundle.abstract_inputs[0]:
        err_abs = bundle.abstract_inputs[0]["err"]
        state["err"] = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), err_abs)
    return state


# ---------------------------------------------------------------------------
# Serve
# ---------------------------------------------------------------------------


def make_prefill_step(
    arch: ArchConfig, run: RunConfig, shape: ShapeConfig, mesh
) -> StepBundle:
    run = _effective_run(run)
    run = run.replace(param_dtype=run.weight_dtype)  # serve: no f32 masters
    model = Model(arch, run)
    rules = make_rules(arch, run, shape, mesh)
    param_ps = model.param_partition_specs(rules)
    batch_ps = batch_partition_specs(arch, shape, mesh, run)
    cache_ps = model.cache_partition_specs(
        rules, shape.global_batch, model.cache_capacity(shape)
    )

    def prefill_step(params, batch):
        return model.prefill(params, batch, rules=rules)

    logits_ps = P(rules["act_batch"], "model")
    return StepBundle(
        name=f"prefill:{arch.name}:{shape.name}",
        fn=prefill_step,
        abstract_inputs=(model.abstract_params(), model.input_specs(shape)),
        in_shardings=(param_ps, batch_ps),
        out_shardings=(logits_ps, cache_ps),
        donate_argnums=(),
        model=model,
        rules=rules,
        mesh=mesh,
    )


def make_decode_step(
    arch: ArchConfig, run: RunConfig, shape: ShapeConfig, mesh
) -> StepBundle:
    run = _effective_run(run)
    run = run.replace(param_dtype=run.weight_dtype)  # serve: no f32 masters
    model = Model(arch, run)
    rules = make_rules(arch, run, shape, mesh)
    param_ps = model.param_partition_specs(rules)
    batch_ps = batch_partition_specs(arch, shape, mesh, run)
    cache_ps = model.cache_partition_specs(
        rules, shape.global_batch, model.cache_capacity(shape)
    )

    def decode_step(params, caches, batch):
        return model.decode_step(params, caches, batch, rules=rules)

    cache_abs = model.cache_abstract(shape.global_batch, model.cache_capacity(shape))
    logits_ps = P(rules["act_batch"], "model")
    return StepBundle(
        name=f"decode:{arch.name}:{shape.name}",
        fn=decode_step,
        abstract_inputs=(model.abstract_params(), cache_abs, model.input_specs(shape)),
        in_shardings=(param_ps, cache_ps, batch_ps),
        out_shardings=(logits_ps, cache_ps),
        donate_argnums=(1,),
        model=model,
        rules=rules,
        mesh=mesh,
    )


def make_step(arch: ArchConfig, run: RunConfig, shape: ShapeConfig, mesh) -> StepBundle:
    if shape.kind == "train":
        return make_train_step(arch, run, shape, mesh)
    if shape.kind == "prefill":
        return make_prefill_step(arch, run, shape, mesh)
    return make_decode_step(arch, run, shape, mesh)
