"""Model facade: ``ArchConfig × RunConfig → init / loss / prefill / decode``.

Everything the launcher, tuner, and dry-run need from a model:

  - ``param_specs()``      — declarative PSpec tree (shapes + logical axes)
  - ``abstract_params()``  — ShapeDtypeStruct tree (AOT dry-run, no allocation)
  - ``init_params(rng)``   — real arrays (smoke tests / examples)
  - ``loss(params, batch)``        — train-mode forward + CE loss
  - ``prefill(params, batch)``     — full-sequence forward, emits caches
  - ``decode_step(params, caches, batch)`` — one-token step against caches
  - ``input_specs(shape)`` / ``cache_abstract(...)`` — dry-run stand-ins

The model is sharding-agnostic: it calls ``ctx.shard(x, logical_axes)`` at
layer boundaries and the caller provides the logical→mesh rules (see
``repro.distributed.sharding``). With ``rules=None`` every constraint is a
no-op, so the same code runs on one CPU device.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RunConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.models.layers import (
    PSpec,
    abstract_params,
    cross_entropy,
    init_params,
    partition_specs,
    rms_norm,
    rms_norm_specs,
    softcap,
)

AUX_LOSS_WEIGHT = 0.01


def _noop_shard(x, axes):
    return x


def make_shard_fn(rules: Optional[Dict[str, Any]]):
    if rules is None:
        return _noop_shard
    from jax.sharding import PartitionSpec as P

    sizes = rules.get("_sizes", {})

    def axis_product(r) -> int:
        names = (r,) if isinstance(r, str) else tuple(r)
        n = 1
        for name in names:
            n *= sizes.get(name, 1)
        return n

    def shard(x, axes):
        mesh_axes = []
        used = set()
        for i, a in enumerate(axes):
            r = rules.get(a) if a is not None else None
            if r is not None and x.shape[i] % axis_product(r) != 0:
                r = None  # dimension not divisible: leave unconstrained
            if r is not None:
                names = (r,) if isinstance(r, str) else tuple(r)
                if any(n in used for n in names):
                    r = None  # a mesh axis may shard only one dim (e.g. seq-
                else:        # parallel residual + head-sharded qkv)
                    used.update(names)
            mesh_axes.append(r)
        return jax.lax.with_sharding_constraint(x, P(*mesh_axes))

    return shard


@dataclass
class Model:
    arch: ArchConfig
    run: RunConfig

    # ------------------------------------------------------------------ params

    def param_specs(self) -> Dict[str, Any]:
        arch = self.arch
        d = arch.d_model
        specs: Dict[str, Any] = {
            "embed": PSpec((arch.padded_vocab, d), ("vocab", "embed"), init="small_normal"),
            "stack": tfm.stack_specs(arch),
            "final_norm": rms_norm_specs(d),
        }
        if not arch.tie_embeddings:
            specs["unembed"] = PSpec((arch.padded_vocab, d), ("vocab", "embed"), init="small_normal")
        if arch.encoder_layers:
            specs["encoder"] = tfm.encoder_stack_specs(arch)
            specs["enc_final_norm"] = rms_norm_specs(d)
        return specs

    def abstract_params(self, dtype=None):
        return abstract_params(self.param_specs(), jnp.dtype(dtype or self.run.param_dtype))

    def init_params(self, rng, dtype=None):
        return init_params(self.param_specs(), rng, jnp.dtype(dtype or self.run.param_dtype))

    def param_partition_specs(self, rules: Dict[str, Any]):
        return partition_specs(self.param_specs(), rules)

    # ------------------------------------------------------------------ caches

    def cache_capacity(self, shape: ShapeConfig) -> int:
        return shape.seq_len

    def cache_specs(self, batch: int, capacity: int) -> Dict[str, Any]:
        return tfm.cache_specs(self.arch, batch, capacity, self.run)

    def cache_abstract(self, batch: int, capacity: int):
        spec_tree = self.cache_specs(batch, capacity)
        dtypes = tfm.cache_dtypes(self.arch, self.run, spec_tree)
        return jax.tree.map(
            lambda s, dt: jax.ShapeDtypeStruct(s.shape, dt),
            spec_tree,
            dtypes,
            is_leaf=lambda x: isinstance(x, PSpec),
        )

    def cache_init(self, batch: int, capacity: int):
        spec_tree = self.cache_specs(batch, capacity)
        dtypes = tfm.cache_dtypes(self.arch, self.run, spec_tree)
        return jax.tree.map(
            lambda s, dt: jnp.ones(s.shape, dt) if s.init == "ones" else jnp.zeros(s.shape, dt),
            spec_tree,
            dtypes,
            is_leaf=lambda x: isinstance(x, PSpec),
        )

    def cache_partition_specs(self, rules: Dict[str, Any], batch: int, capacity: int):
        return partition_specs(self.cache_specs(batch, capacity), rules)

    # ----------------------------------------------------------------- forward

    def _embed_inputs(self, params, batch, ctx: tfm.Ctx):
        """Token embeddings + modality-frontend substitution."""
        arch = self.arch
        cd = ctx.compute_dtype
        tokens = batch["tokens"]
        if self.run.embed_impl == "one_hot" and ctx.mode == "train":
            # iota one-hot matmul: the vocab axis stays sharded and the
            # backward pass is a matmul (no scatter-add into the table).
            onehot = jax.nn.one_hot(tokens, arch.padded_vocab, dtype=cd)
            x = jnp.einsum("bsv,vd->bsd", onehot, params["embed"].astype(cd))
        else:
            x = params["embed"].astype(cd)[tokens]
        x = x * jnp.asarray(arch.d_model, cd) ** 0.5 if arch.tie_embeddings else x
        if arch.frontend == "vision" and "patches" in batch:
            p = batch["patches"].astype(cd)  # (B, P, D) precomputed (stub)
            x = jax.lax.dynamic_update_slice(x, p, (0, 0, 0))
        return x

    def _encode(self, params, batch, ctx: tfm.Ctx):
        frames = batch["frames"].astype(ctx.compute_dtype)  # (B, F, D) stub
        pos = tfm.sinusoidal_positions(frames.shape[1], self.arch.d_model, frames.dtype)
        enc = tfm.apply_encoder(params["encoder"], frames + pos[None], ctx)
        return rms_norm(enc, params["enc_final_norm"], self.arch.norm_eps)

    def _logits(self, params, x, ctx: tfm.Ctx):
        """Logits stay in compute dtype (bf16): the CE converts to f32 inside
        its (fusable) reductions, avoiding a materialized f32 (B,S,V) buffer."""
        arch = self.arch
        table = params["embed"] if arch.tie_embeddings else params["unembed"]
        logits = jnp.einsum("bsd,vd->bsv", x, table.astype(ctx.compute_dtype))
        return softcap(logits, arch.final_logit_softcap)

    def _cast_params(self, params, ctx: tfm.Ctx):
        """Pre-cast the whole tree to compute dtype ONCE, outside the layer
        scan. With FSDP/2D-sharded weights this moves the per-layer weight
        all-gathers from f32 masters to bf16 — half the wire bytes of the
        dominant collective term in FSDP training (§Perf iteration 3)."""
        cd = ctx.compute_dtype

        def cast(w):
            # int8 serving weights keep their per-layer (fused) dequant; only
            # wider floats are narrowed upfront
            if jnp.issubdtype(w.dtype, jnp.floating) and jnp.dtype(w.dtype).itemsize > cd.itemsize:
                return w.astype(cd)
            return w

        return jax.tree.map(cast, params)

    def _backbone(self, params, x, ctx: tfm.Ctx, caches=None):
        x = ctx.shard(x, ("act_batch", "act_seq", "act_embed"))
        x, aux, new_caches = tfm.apply_stack(params["stack"], x, ctx, caches=caches)
        x = rms_norm(x, params["final_norm"], self.arch.norm_eps)
        return x, aux, new_caches

    def _make_ctx(self, mode: str, positions, rules, cache_len=None, enc_out=None,
                  interpret=False) -> tfm.Ctx:
        return tfm.Ctx(
            arch=self.arch, run=self.run, mode=mode, positions=positions,
            shard=make_shard_fn(rules), cache_len=cache_len, enc_out=enc_out,
            interpret=interpret,
        )

    # ------------------------------------------------------------------- train

    def loss(self, params, batch, *, rules=None, interpret=False):
        """batch: tokens (B,S), labels (B,S), [patches|frames]. Returns
        (loss, metrics)."""
        arch = self.arch
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        ctx = self._make_ctx("train", positions, rules, interpret=interpret)
        params = self._cast_params(params, ctx)
        enc_out = None
        if arch.encoder_layers:
            enc_out = self._encode(params, batch, ctx)
            ctx.enc_out = enc_out
        x = self._embed_inputs(params, batch, ctx)
        x, aux, _ = self._backbone(params, x, ctx)
        logits = self._logits(params, x, ctx)
        labels = batch["labels"]
        if arch.frontend == "vision":
            # vision positions carry no next-token target
            labels = jnp.where(positions < arch.frontend_seq, -1, labels)
        ce = cross_entropy(logits, labels, arch.vocab_size)
        loss = ce + AUX_LOSS_WEIGHT * aux
        return loss, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------------- serve

    def prefill(self, params, batch, *, rules=None, interpret=False):
        """Full-sequence forward; returns (last-token logits (B, V), caches)."""
        arch = self.arch
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        ctx = self._make_ctx("prefill", positions, rules, interpret=interpret)
        params = self._cast_params(params, ctx)
        if arch.encoder_layers:
            ctx.enc_out = self._encode(params, batch, ctx)
        x = self._embed_inputs(params, batch, ctx)
        x, _, caches = self._backbone(params, x, ctx)
        logits = self._logits(params, x[:, -1:, :], ctx)
        return logits[:, 0], caches

    def decode_step(self, params, caches, batch, *, rules=None, interpret=False):
        """One decode step. batch: tokens (B,1), cache_len scalar int32.
        Returns (logits (B, V), new caches)."""
        tokens = batch["tokens"]
        b = tokens.shape[0]
        cache_len = batch["cache_len"]
        positions = jnp.broadcast_to(cache_len[None, None], (b, 1)).astype(jnp.int32)
        ctx = self._make_ctx("decode", positions, rules, cache_len=cache_len,
                             interpret=interpret)
        params = self._cast_params(params, ctx)
        x = self._embed_inputs(params, batch, ctx)
        x, _, new_caches = self._backbone(params, x, ctx, caches=caches)
        logits = self._logits(params, x, ctx)
        return logits[:, 0], new_caches

    # ----------------------------------------------------------------- dry-run

    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        arch = self.arch
        b, s = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct
        if shape.kind == "train":
            batch = {
                "tokens": tok((b, s), jnp.int32),
                "labels": tok((b, s), jnp.int32),
            }
        elif shape.kind == "prefill":
            batch = {"tokens": tok((b, s), jnp.int32)}
        else:  # decode
            batch = {
                "tokens": tok((b, 1), jnp.int32),
                "cache_len": tok((), jnp.int32),
            }
        if shape.kind != "decode":
            if arch.frontend == "vision":
                batch["patches"] = tok((b, arch.frontend_seq, arch.d_model), jnp.bfloat16)
            elif arch.frontend == "audio":
                batch["frames"] = tok((b, arch.frontend_seq, arch.d_model), jnp.bfloat16)
        return batch

    def make_inputs(self, shape: ShapeConfig, rng=None):
        """Real (synthetic) inputs matching ``input_specs`` (smoke tests)."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        specs = self.input_specs(shape)
        out = {}
        for name, sds in specs.items():
            rng, sub = jax.random.split(rng)
            if name in ("tokens", "labels"):
                out[name] = jax.random.randint(sub, sds.shape, 0, self.arch.vocab_size, jnp.int32)
            elif name == "cache_len":
                out[name] = jnp.asarray(shape.seq_len - 1, jnp.int32)
            else:
                out[name] = 0.02 * jax.random.normal(sub, sds.shape, jnp.float32)
        return out
