"""Parameter-spec machinery and basic layers (norm, rope, MLP, embedding).

Parameters are declared as ``PSpec`` leaves (shape + logical axes + init) so the
same declaration yields (a) ``jax.ShapeDtypeStruct`` trees for AOT dry-runs with
no allocation, (b) real initialized arrays for smoke tests / examples, and
(c) ``PartitionSpec`` trees via logical→mesh axis rules.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class PSpec:
    """Declarative parameter leaf."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis names (len == len(shape))
    init: str = "normal"  # normal | zeros | ones | small_normal
    scale: float = 0.0  # 0 -> 1/sqrt(fan_in) for normal

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def abstract_params(tree, dtype) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        tree,
        is_leaf=lambda x: isinstance(x, PSpec),
    )


def init_params(tree, rng, dtype) -> Any:
    leaves, treedef = jax.tree.flatten(tree, is_leaf=lambda x: isinstance(x, PSpec))
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for spec, r in zip(leaves, rngs):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dtype))
        else:
            fan_in = spec.shape[0] if spec.shape else 1
            scale = spec.scale or (1.0 / max(fan_in, 1)) ** 0.5
            if spec.init == "small_normal":
                scale = 0.02
            out.append(scale * jax.random.normal(r, spec.shape, dtype))
    return jax.tree.unflatten(treedef, out)


def partition_specs(tree, rules: Dict[str, Any]) -> Any:
    """Map logical axes to mesh axes. ``rules[name]`` is a mesh axis (str),
    tuple of mesh axes, or None. Axes whose dimension is not divisible by the
    mapped mesh-axis size (``rules["_sizes"]``) fall back to replication."""
    sizes = rules.get("_sizes", {})

    def axis_product(r) -> int:
        names = (r,) if isinstance(r, str) else tuple(r)
        return int(jnp.prod(jnp.asarray([sizes.get(n, 1) for n in names]))) if names else 1

    def one(spec: PSpec) -> P:
        out = []
        for dim, a in zip(spec.shape, spec.axes):
            r = rules.get(a) if a is not None else None
            if r is not None and dim % axis_product(r) != 0:
                r = None
            out.append(r)
        return P(*out)

    return jax.tree.map(one, tree, is_leaf=lambda x: isinstance(x, PSpec))


def logical_sharding_constraint(x, axes: Tuple[Optional[str], ...], rules):
    spec = P(*[rules.get(a) if a is not None else None for a in axes])
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def rms_norm_specs(d: int) -> PSpec:
    # stored as a zero-centered scale (gemma convention); init zeros == identity
    return PSpec((d,), ("embed",), init="zeros")


def rotary_embedding(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    angles = angles[..., None, :]  # (..., S, 1, half) broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def gated_mlp_specs(d: int, ff: int) -> Dict[str, PSpec]:
    return {
        "wi_gate": PSpec((d, ff), ("embed", "ff")),
        "wi_up": PSpec((d, ff), ("embed", "ff")),
        "wo": PSpec((ff, d), ("ff", "embed")),
    }


def gated_mlp(params, x, compute_dtype):
    """SwiGLU MLP. x: (B, S, D)."""
    g = jnp.einsum("bsd,df->bsf", x, params["wi_gate"].astype(compute_dtype))
    u = jnp.einsum("bsd,df->bsf", x, params["wi_up"].astype(compute_dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(compute_dtype))


def embed_specs(vocab: int, d: int) -> PSpec:
    return PSpec((vocab, d), ("vocab", "embed"), init="small_normal")


def embed_lookup(table, tokens, compute_dtype):
    return table.astype(compute_dtype)[tokens]


def unembed(x, table, compute_dtype, cap: float = 0.0):
    logits = jnp.einsum("bsd,vd->bsv", x, table.astype(compute_dtype))
    logits = softcap(logits.astype(jnp.float32), cap)
    return logits


def cross_entropy(logits, labels, vocab_size: int):
    """logits: (B, S, Vpad) any float dtype (converted to f32 inside the
    reductions, which XLA fuses — no materialized f32 copy); labels int32
    (B, S). Ignores padded vocab tail and label = -1 positions."""
    logits = logits.astype(jnp.float32)
    vpad = logits.shape[-1]
    if vpad > vocab_size:
        # where + iota (not scatter) so the masking partitions cleanly when the
        # vocab axis is sharded over the model axis.
        vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(vocab_ids < vocab_size, logits, -1e9)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # select-and-reduce rather than take_along_axis: the backward pass is then
    # an elementwise select instead of a scatter, which both partitions better
    # under GSPMD and avoids XLA's scatter-partitioner edge cases inside
    # partial-manual shard_map regions.
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    onehot = vocab_ids == labels[..., None]
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    mask = (labels >= 0).astype(logits.dtype)
    return jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
