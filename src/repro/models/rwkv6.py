"""RWKV6 (Finch) block: time-mix with data-dependent decay + channel-mix.

The training/prefill path uses the chunked linear-attention formulation
(GLA-style): within a chunk, decay products are factored into the queries and
keys so intra-chunk attention is a plain masked matmul; across chunks a
(B, H, K, V) state is carried by ``lax.scan``. The same math backs the Pallas
kernel in ``repro.kernels.rwkv6``. Decode is the exact single-step recurrence.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import PSpec, rms_norm

LORA_DIM = 64


def rwkv_specs(arch: ArchConfig) -> Dict[str, PSpec]:
    d = arch.d_model
    h = d // arch.rwkv_head_dim
    ff = arch.d_ff
    return {
        "tmix": {
            "mu": PSpec((5, d), (None, "embed"), init="zeros"),  # r,k,v,g,w shift mixes
            "w_r": PSpec((d, d), ("embed", "heads_out")),
            "w_k": PSpec((d, d), ("embed", "heads_out")),
            "w_v": PSpec((d, d), ("embed", "heads_out")),
            "w_g": PSpec((d, d), ("embed", "heads_out")),
            "w_o": PSpec((d, d), ("heads_out", "embed")),
            "w0": PSpec((d,), ("embed",), init="zeros"),
            "w_lora_a": PSpec((d, LORA_DIM), ("embed", None), init="small_normal"),
            "w_lora_b": PSpec((LORA_DIM, d), (None, "embed"), init="zeros"),
            "u": PSpec((h, arch.rwkv_head_dim), ("heads", None), init="zeros"),
            "ln_x": PSpec((d,), ("embed",), init="zeros"),  # per-head group norm
        },
        "cmix": {
            "mu": PSpec((2, d), (None, "embed"), init="zeros"),  # k, r
            "w_k": PSpec((d, ff), ("embed", "ff")),
            "w_v": PSpec((ff, d), ("ff", "embed")),
            "w_r": PSpec((d, d), ("embed", "embed_out")),
        },
    }


def _shift(x, prev):
    """Token shift: x[:, t] -> x[:, t-1]; position 0 takes ``prev`` (B, D)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _decay(p, xw):
    """Data-dependent per-channel decay w_t in (0,1); returns log(w_t)."""
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"].astype(jnp.float32))
    dd = lora @ p["w_lora_b"].astype(jnp.float32)
    return -jnp.exp(p["w0"].astype(jnp.float32) + dd)  # log w = -exp(...) < 0


def time_mix(p, x, prev_x, state, arch: ArchConfig, chunk: int = 64,
             unroll: bool = False):
    """x: (B, S, D); prev_x: (B, D) shift state; state: (B, H, K, V) wkv state.
    Returns (out, new_prev_x, new_state)."""
    b, s, d = x.shape
    hd = arch.rwkv_head_dim
    h = d // hd
    xs = _shift(x, prev_x)
    mu = p["mu"].astype(x.dtype)  # (5, D)
    mix = lambda i: x + mu[i] * (xs - x)
    cd = x.dtype
    r = (mix(0) @ p["w_r"].astype(cd)).reshape(b, s, h, hd)
    k = (mix(1) @ p["w_k"].astype(cd)).reshape(b, s, h, hd)
    v = (mix(2) @ p["w_v"].astype(cd)).reshape(b, s, h, hd)
    g = mix(3) @ p["w_g"].astype(cd)
    logw = _decay(p["tmix_alias"] if "tmix_alias" in p else p, mix(4)).reshape(b, s, h, hd)
    u = p["u"].astype(jnp.float32)

    if s == 1:
        out, new_state = _decode_step(r, k, v, logw, u, state)
        return _output(p, out, g, arch), x[:, -1], new_state

    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        r, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (r, k, v))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))

    rc = r.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 3, 2, 4)  # (N,B,H,C,K)
    kc = k.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    lw = logw.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 3, 2, 4).astype(jnp.float32)

    def body(S, blk):
        rb, kb, vb, lwb = blk  # (B,H,C,K/V)
        lcum = jnp.cumsum(lwb, axis=2)  # inclusive log-decay products
        ltot = lcum[:, :, -1:, :]
        # factor decays into q/k: q' = r ⊙ exp(lcum_{t-1}); k' = k ⊙ exp(-lcum_τ)
        q_f = rb.astype(jnp.float32) * jnp.exp(lcum - lwb)
        k_f = kb.astype(jnp.float32) * jnp.exp(-lcum)
        scores = jnp.einsum("bhck,bhdk->bhcd", q_f, k_f)  # (B,H,C,C)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        scores = jnp.where(tri, scores, 0.0)
        # diagonal bonus term: r_t · (u ⊙ k_t)
        diag = jnp.einsum("bhck,bhck->bhc", rb.astype(jnp.float32) * u[None, :, None, :], kb.astype(jnp.float32))
        o_intra = jnp.einsum("bhcd,bhdv->bhcv", scores, vb.astype(jnp.float32))
        o_intra += diag[..., None] * vb.astype(jnp.float32)
        o_inter = jnp.einsum("bhck,bhkv->bhcv", q_f, S)
        # state update: S' = diag(exp ltot) S + Σ (k ⊙ exp(ltot - lcum)) v^T
        k_s = kb.astype(jnp.float32) * jnp.exp(ltot - lcum)
        S_new = jnp.exp(ltot).transpose(0, 1, 3, 2) * S + jnp.einsum(
            "bhck,bhcv->bhkv", k_s, vb.astype(jnp.float32)
        )
        return S_new, (o_intra + o_inter).astype(x.dtype)

    state, outs = jax.lax.scan(
        body, state.astype(jnp.float32), (rc, kc, vc, lw), unroll=unroll
    )
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, n_chunks * chunk, h, hd)[:, :s]
    return _output(p, out, g, arch), x[:, -1], state


def _decode_step(r, k, v, logw, u, state):
    """Single-token recurrence. r/k/v/logw: (B,1,H,K); state: (B,H,K,V)."""
    r0, k0, v0 = (t[:, 0].astype(jnp.float32) for t in (r, k, v))
    lw = logw[:, 0].astype(jnp.float32)
    state = state.astype(jnp.float32)
    att = state + (u[None] * k0)[..., None] * v0[:, :, None, :]
    out = jnp.einsum("bhk,bhkv->bhv", r0, att)[:, None]  # (B,1,H,V)
    new_state = jnp.exp(lw)[..., None] * state + k0[..., None] * v0[:, :, None, :]
    return out, new_state


def _output(p, out, g, arch: ArchConfig):
    b, s = out.shape[:2]
    d = arch.d_model
    hd = arch.rwkv_head_dim
    # per-head group norm
    o = out.reshape(b, s, d // hd, hd).astype(jnp.float32)
    o = o * jax.lax.rsqrt(jnp.mean(jnp.square(o), -1, keepdims=True) + 1e-5)
    o = o.reshape(b, s, d) * (1.0 + p["ln_x"].astype(jnp.float32))
    o = o.astype(g.dtype) * jax.nn.silu(g)
    return o @ p["w_o"].astype(g.dtype)


def channel_mix(p, x, prev_x):
    """RWKV channel mix. Returns (out, new_prev_x)."""
    xs = _shift(x, prev_x)
    mu = p["mu"].astype(x.dtype)
    xk = x + mu[0] * (xs - x)
    xr = x + mu[1] * (xs - x)
    k = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(x.dtype)))
    kv = k @ p["w_v"].astype(x.dtype)
    return jax.nn.sigmoid(xr @ p["w_r"].astype(x.dtype)) * kv, x[:, -1]


def init_rwkv_state(arch: ArchConfig, batch: int, dtype=jnp.float32):
    d = arch.d_model
    hd = arch.rwkv_head_dim
    return {
        "wkv": jnp.zeros((batch, d // hd, hd, hd), jnp.float32),
        "shift_t": jnp.zeros((batch, d), dtype),
        "shift_c": jnp.zeros((batch, d), dtype),
    }
