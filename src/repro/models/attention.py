"""GQA attention: flash-style chunked XLA path + Pallas kernel dispatch.

The XLA path is a blockwise online-softmax implementation written with
``lax.scan`` over KV blocks, so that (a) peak memory stays O(S·block_kv) rather
than O(S·T) — required for the 32k prefill dry-runs — and (b) the tunable
``attn_block_kv`` knob is meaningful on both paths. The Pallas path (TPU
target) lives in ``repro.kernels.flash_attention``.

GQA is realised by repeating K/V to the full query-head count *inside each KV
block*, so all activation tensors carry a flat head axis that is divisible by
the model-parallel degree whenever ``num_heads`` is (the (Hkv, G) factored
layout cannot be sharded 16-way when both factors are < 16, e.g. qwen2's
8 × 8). ``window`` may be a traced per-layer scalar (≤ 0 means full context),
which lets local/global alternating stacks (gemma2/gemma3) share one scanned
layer body.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

NEG_INF = -1e30

Window = Union[int, jnp.ndarray]


def _is_static_zero(window: Window) -> bool:
    return isinstance(window, (int, float)) and window == 0


def _softcap(s, cap: float):
    if not cap:
        return s
    return cap * jnp.tanh(s / cap)


def _mask(qpos, kpos, *, causal: bool, window: Window, kv_length):
    """qpos: (B,1,S,1); kpos: (1,1,1,T) -> bool (B,1,S,T)."""
    mask = jnp.ones(jnp.broadcast_shapes(qpos.shape, kpos.shape), bool)
    if causal:
        mask &= kpos <= qpos
    if not _is_static_zero(window):
        w = jnp.asarray(window)
        mask &= (qpos - kpos < w) | (w <= 0)
    if kv_length is not None:
        mask &= kpos < kv_length[:, None, None, None]
    return mask


def attention(
    q,
    k,
    v,
    *,
    q_positions,
    kv_length: Optional[jnp.ndarray] = None,
    causal: bool = True,
    window: Window = 0,
    softcap_val: float = 0.0,
    block_kv: int = 512,
    impl: str = "xla",
    interpret: bool = False,
    unroll: bool = False,
):
    """Grouped-query attention.

    q: (B, S, Hq, Dh); k, v: (B, T, Hkv, Dh). ``q_positions``: (B, S) global
    positions of the queries (supports decode with cache offset).
    ``kv_length``: optional (B,) valid KV prefix length (decode caches).
    ``window``: 0 = full; > 0 = sliding window; may be a traced scalar
    (then ≤ 0 means full). Returns (B, S, Hq, Dh).
    """
    b, s, hq, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = dh**-0.5
    qs = q * scale

    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        return fa_ops.flash_attention(
            qs,
            k,
            v,
            q_positions=q_positions,
            kv_length=kv_length,
            causal=causal,
            window=window,
            softcap_val=softcap_val,
            block_kv=block_kv,
            interpret=interpret,
        )

    def expand(x):  # (B, T', Hkv, Dh) -> (B, T', Hq, Dh)
        if g == 1:
            return x
        return jnp.repeat(x, g, axis=2)

    qpos = q_positions[:, None, :, None]  # (B,1,S,1)

    if s == 1 or t <= block_kv:
        # Decode / short context: single-shot masked attention (linear in T).
        kf, vf = expand(k), expand(v)
        scores = jnp.einsum("bshd,bthd->bhst", qs, kf)
        scores = _softcap(scores, softcap_val)
        kpos = jnp.arange(t)[None, None, None, :]
        m = _mask(qpos, kpos, causal=causal, window=window, kv_length=kv_length)
        scores = jnp.where(m, scores.astype(jnp.float32), NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhst,bthd->bshd", probs, vf)
        return out

    # Blockwise online-softmax over KV blocks.
    n_blocks = -(-t // block_kv)
    pad = n_blocks * block_kv - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, n_blocks, block_kv, hkv, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, n_blocks, block_kv, hkv, dh).transpose(1, 0, 2, 3, 4)

    def body(carry, blk):
        m_run, l_run, acc = carry
        kblk, vblk, idx = blk  # (B, block, Hkv, Dh)
        kf, vf = expand(kblk), expand(vblk)
        scores = jnp.einsum("bshd,bthd->bhst", qs, kf)  # (B,Hq,S,block)
        scores = _softcap(scores, softcap_val)
        kpos = idx * block_kv + jnp.arange(block_kv)[None, None, None, :]
        msk = (kpos < t) & _mask(
            qpos, kpos, causal=causal, window=window, kv_length=kv_length
        )
        scores = jnp.where(msk, scores.astype(jnp.float32), NEG_INF)
        m_blk = jnp.max(scores, axis=-1)
        m_new = jnp.maximum(m_run, m_blk)
        corr = jnp.exp(m_run - m_new)
        p = jnp.exp(scores - m_new[..., None])  # (B,Hq,S,block)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhst,bthd->bhsd", p.astype(q.dtype), vf)
        acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, s), jnp.float32)
    acc0 = jnp.zeros((b, hq, s, dh), jnp.float32)
    (m_run, l_run, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kb, vb, jnp.arange(n_blocks)), unroll=unroll
    )
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]  # (B,Hq,S,Dh)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention_reference(q, k, v, *, q_positions, kv_length=None, causal=True,
                        window=0, softcap_val=0.0):
    """Naive O(S·T) oracle used by tests."""
    b, s, hq, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q * dh**-0.5, k)
    scores = _softcap(scores, softcap_val).astype(jnp.float32)
    kpos = jnp.arange(t)[None, None, None, :]
    qpos = q_positions[:, None, :, None]
    m = _mask(qpos, kpos, causal=causal, window=window, kv_length=kv_length)
    scores = jnp.where(m, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)
