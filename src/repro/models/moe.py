"""Top-k routed Mixture-of-Experts with capacity-bounded scatter dispatch.

Dispatch is scatter/gather based (not the one-hot einsum formulation): positions
inside each expert's capacity buffer come from a cumulative sum over the token
axis, tokens beyond capacity are dropped. With experts sharded over the
``model``/``expert`` mesh axis and tokens over ``data``, XLA SPMD lowers the
scatter/gather into the expected all-to-all exchange.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import PSpec


def moe_specs(arch: ArchConfig) -> Dict[str, PSpec]:
    d = arch.d_model
    ff = arch.d_ff_expert or arch.d_ff
    e = arch.num_experts
    return {
        "router": PSpec((d, e), ("embed", None), init="small_normal"),
        "wi_gate": PSpec((e, d, ff), ("expert", "embed", "ff_expert")),
        "wi_up": PSpec((e, d, ff), ("expert", "embed", "ff_expert")),
        "wo": PSpec((e, ff, d), ("expert", "ff_expert", "embed")),
    }


def moe_apply(params, x, arch: ArchConfig, compute_dtype, shard=None):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar).

    ``shard(x, logical_axes)`` pins the dispatch tensors: without explicit
    constraints GSPMD's propagation through the scatter falls back to
    "replicate everything" (XLA warns about involuntary full
    rematerialization), turning the token exchange into full all-gathers —
    the dominant collective cost of MoE cells at baseline (§Perf)."""
    b, s, d = x.shape
    n = b * s
    e, k = arch.num_experts, arch.experts_per_token
    shard = shard or (lambda t, axes: t)
    xf = x.reshape(n, d)
    xf = shard(xf, ("act_tokens", "act_embed"))

    logits = jnp.einsum(
        "nd,de->ne", xf.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (N, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (N, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch-style).
    density = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32), axis=0
    )
    aux_loss = e * jnp.sum(density * jnp.mean(probs, axis=0))

    capacity = max(int(arch.moe_capacity_factor * n * k / e), 1)

    # Position of each (token, slot) inside its expert buffer.
    onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.int32)  # (N, k, E)
    flat = onehot.reshape(n * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=0) - flat  # (N*k, E) exclusive
    pos = jnp.sum(pos_in_expert * flat, axis=-1)  # (N*k,)
    eid = expert_ids.reshape(n * k)
    keep = pos < capacity
    gate_flat = jnp.where(keep, gate_vals.reshape(n * k), 0.0)
    pos = jnp.where(keep, pos, capacity)  # dropped tokens write to a spill row

    # Dispatch: scatter tokens into (E, C+1, D) buffers (+1 spill row).
    src = jnp.repeat(xf, k, axis=0).astype(compute_dtype)  # (N*k, D)
    src = shard(src, ("act_tokens", "act_embed"))
    buf = jnp.zeros((e, capacity + 1, d), compute_dtype)
    buf = shard(buf, ("expert", None, "act_embed"))
    buf = buf.at[eid, pos].add(src)
    buf = shard(buf[:, :capacity], ("expert", None, "act_embed"))

    # Expert computation (gated MLP), batched over experts.
    g = jnp.einsum("ecd,edf->ecf", buf, params["wi_gate"].astype(compute_dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["wi_up"].astype(compute_dtype))
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(compute_dtype))
    out_buf = shard(out_buf, ("expert", None, "act_embed"))
    out_buf = jnp.pad(out_buf, ((0, 0), (0, 1), (0, 0)))  # restore spill row (zeros)

    # Combine: gather each slot's output, weight by gate, sum over k slots.
    gathered = out_buf[eid, pos]  # (N*k, D)
    combined = gathered * gate_flat[:, None].astype(compute_dtype)
    out = combined.reshape(n, k, d).sum(axis=1)
    return out.reshape(b, s, d), aux_loss
