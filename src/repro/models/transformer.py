"""Generic layer-stack machinery shared by all 10 assigned architectures.

A model is a stack of ``num_layers`` layers. Layers are described by
``LayerDesc`` (mixer kind + MoE flag + cross-attention flag). The stack is
executed as a ``lax.scan`` over *structural groups*: the shortest repeating
unit of structurally distinct layers (e.g. jamba's [attn, mamba×7] with MoE on
odd layers → period 8; llama4's dense/MoE alternation → period 2; plain dense
stacks → period 1). Within a group the (few) layers are unrolled; across
groups parameters/caches are stacked along a leading axis and scanned, keeping
the HLO size O(period) instead of O(num_layers).

Attention locality (gemma2/gemma3 local:global patterns) is NOT structural:
the sliding-window size is a per-layer *value* (a scanned int32 array, ≤ 0
meaning full attention), so local and global layers share one traced body.

Three modes:
  - ``train``   — full sequence, no caches.
  - ``prefill`` — full sequence, emits per-layer caches (KV / SSM / RWKV).
  - ``decode``  — single token, consumes + re-emits caches.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RunConfig
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.attention import attention
from repro.models.layers import (
    PSpec,
    gated_mlp,
    gated_mlp_specs,
    rms_norm,
    rms_norm_specs,
    rotary_embedding,
)


# ---------------------------------------------------------------------------
# Layer descriptors / structural periods
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerDesc:
    kind: str  # attn | mamba | rwkv
    is_moe: bool
    cross: bool = False  # decoder layer with cross-attention (enc-dec archs)


def layer_descs(arch: ArchConfig) -> Tuple[LayerDesc, ...]:
    cross = arch.encoder_layers > 0
    out = []
    for kind, is_moe in arch.layer_kinds():
        k = "attn" if kind in ("attn", "attn_local") else kind
        out.append(LayerDesc(k, is_moe, cross))
    return tuple(out)


def structural_period(arch: ArchConfig) -> int:
    """Shortest repeating unit of *structurally distinct* layers."""
    descs = layer_descs(arch)
    n = len(descs)
    for p in range(1, n + 1):
        if n % p == 0 and all(descs[i] == descs[i % p] for i in range(n)):
            return p
    return n


def num_groups(arch: ArchConfig) -> int:
    return arch.num_layers // structural_period(arch)


def windows_array(arch: ArchConfig) -> jnp.ndarray:
    """(num_layers,) per-layer sliding window; 0 = full attention."""
    wins = []
    for i in range(arch.num_layers):
        kind = arch.block_pattern[i % len(arch.block_pattern)]
        wins.append(arch.sliding_window if kind == "attn_local" else 0)
    return jnp.asarray(wins, jnp.int32)


def has_dynamic_window(arch: ArchConfig) -> bool:
    return any(k == "attn_local" for k in arch.block_pattern)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def attn_specs(arch: ArchConfig, cross: bool = False) -> Dict[str, PSpec]:
    d = arch.d_model
    dh = arch.resolved_head_dim
    hq, hkv = arch.num_heads, arch.num_kv_heads
    prefix = "c" if cross else ""
    specs = {
        prefix + "wq": PSpec((d, hq * dh), ("embed", "heads_out")),
        prefix + "wk": PSpec((d, hkv * dh), ("embed", "kv_out")),
        prefix + "wv": PSpec((d, hkv * dh), ("embed", "kv_out")),
        prefix + "wo": PSpec((hq * dh, d), ("heads_out", "embed")),
    }
    if arch.qkv_bias and not cross:
        specs[prefix + "bq"] = PSpec((hq * dh,), ("heads_out",), init="zeros")
        specs[prefix + "bk"] = PSpec((hkv * dh,), ("kv_out",), init="zeros")
        specs[prefix + "bv"] = PSpec((hkv * dh,), ("kv_out",), init="zeros")
    return specs


def layer_specs(arch: ArchConfig, desc: LayerDesc) -> Dict[str, Any]:
    d = arch.d_model
    if desc.kind == "rwkv":
        specs = rwkv_mod.rwkv_specs(arch)
        specs["ln1"] = rms_norm_specs(d)
        specs["ln2"] = rms_norm_specs(d)
        return specs
    specs: Dict[str, Any] = {"ln1": rms_norm_specs(d), "ln2": rms_norm_specs(d)}
    if desc.kind == "attn":
        specs["attn"] = attn_specs(arch)
        if desc.cross:
            specs["xattn"] = attn_specs(arch, cross=True)
            specs["lnx"] = rms_norm_specs(d)
    elif desc.kind == "mamba":
        specs["mamba"] = mamba_mod.mamba_specs(arch)
    else:
        raise ValueError(desc.kind)
    if desc.is_moe:
        specs["moe"] = moe_mod.moe_specs(arch)
    else:
        specs["mlp"] = gated_mlp_specs(d, arch.d_ff)
    return specs


def _stack_tree(tree, n: int):
    return jax.tree.map(
        lambda s: PSpec((n,) + s.shape, (None,) + s.axes, s.init, s.scale),
        tree,
        is_leaf=lambda x: isinstance(x, PSpec),
    )


def stack_specs(arch: ArchConfig) -> Dict[str, Any]:
    """Stacked decoder stack params: {"l{j}": specs} × num_groups."""
    period = structural_period(arch)
    assert arch.num_layers % period == 0, (arch.name, arch.num_layers, period)
    descs = layer_descs(arch)[:period]
    group = {f"l{j}": layer_specs(arch, descs[j]) for j in range(period)}
    return _stack_tree(group, num_groups(arch))


def encoder_stack_specs(arch: ArchConfig) -> Dict[str, Any]:
    """Whisper-style encoder: plain non-causal attention layers."""
    desc = LayerDesc("attn", False, False)
    group = {"l0": layer_specs(arch, desc)}
    return _stack_tree(group, arch.encoder_layers)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def layer_cache_specs(
    arch: ArchConfig, desc: LayerDesc, batch: int, capacity: int, run: RunConfig
) -> Dict[str, PSpec]:
    """Cache leaves for one layer (un-stacked)."""
    dh = arch.resolved_head_dim
    hkv = arch.num_kv_heads
    if desc.kind == "attn":
        cache = {
            "k": PSpec((batch, capacity, hkv, dh), ("act_batch", "kv_seq", "kv_heads", None), init="zeros"),
            "v": PSpec((batch, capacity, hkv, dh), ("act_batch", "kv_seq", "kv_heads", None), init="zeros"),
        }
        if run.kv_cache_dtype == "int8":
            cache["ks"] = PSpec((batch, capacity, hkv), ("act_batch", "kv_seq", "kv_heads"), init="ones")
            cache["vs"] = PSpec((batch, capacity, hkv), ("act_batch", "kv_seq", "kv_heads"), init="ones")
        if desc.cross:
            f = arch.frontend_seq
            cache["ck"] = PSpec((batch, f, hkv, dh), ("act_batch", None, "kv_heads", None), init="zeros")
            cache["cv"] = PSpec((batch, f, hkv, dh), ("act_batch", None, "kv_heads", None), init="zeros")
        return cache
    if desc.kind == "mamba":
        di = arch.ssm_expand * arch.d_model
        return {
            "conv": PSpec((batch, arch.ssm_conv_width - 1, di), ("act_batch", None, "inner"), init="zeros"),
            "ssm": PSpec((batch, di, arch.ssm_state_dim), ("act_batch", "inner", None), init="zeros"),
        }
    if desc.kind == "rwkv":
        d = arch.d_model
        hd = arch.rwkv_head_dim
        return {
            "wkv": PSpec((batch, d // hd, hd, hd), ("act_batch", "heads", None, None), init="zeros"),
            "shift_t": PSpec((batch, d), ("act_batch", "act_embed"), init="zeros"),
            "shift_c": PSpec((batch, d), ("act_batch", "act_embed"), init="zeros"),
        }
    raise ValueError(desc.kind)


def cache_specs(arch: ArchConfig, batch: int, capacity: int, run: RunConfig) -> Dict[str, Any]:
    period = structural_period(arch)
    descs = layer_descs(arch)[:period]
    group = {
        f"l{j}": layer_cache_specs(arch, descs[j], batch, capacity, run)
        for j in range(period)
    }
    return _stack_tree(group, num_groups(arch))


def cache_dtypes(arch: ArchConfig, run: RunConfig, tree) -> Any:
    """Per-leaf dtype for a cache tree: KV in kv_cache_dtype, scales/SSM f32,
    shift states in compute dtype."""

    def leaf_dtype(path_leaf):
        path, _ = path_leaf
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v", "ck", "cv"):
            return jnp.int8 if run.kv_cache_dtype == "int8" else jnp.bfloat16
        if name in ("ks", "vs", "ssm", "wkv"):
            return jnp.float32
        return jnp.dtype(run.compute_dtype)

    paths = jax.tree_util.tree_flatten_with_path(tree, is_leaf=lambda x: isinstance(x, PSpec))[0]
    dtypes = [leaf_dtype(pl) for pl in paths]
    treedef = jax.tree.structure(tree, is_leaf=lambda x: isinstance(x, PSpec))
    return jax.tree.unflatten(treedef, dtypes)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


@dataclass
class Ctx:
    """Per-call context threaded through the stack."""

    arch: ArchConfig
    run: RunConfig
    mode: str  # train | prefill | decode
    positions: jnp.ndarray  # (B, S) global positions of the current tokens
    shard: Callable[[jnp.ndarray, Tuple[Optional[str], ...]], jnp.ndarray]
    cache_len: Optional[jnp.ndarray] = None  # scalar int32; valid prefix length
    enc_out: Optional[jnp.ndarray] = None  # (B, F, D) encoder output
    interpret: bool = False

    @property
    def compute_dtype(self):
        return jnp.dtype(self.run.compute_dtype)


def _quantize_kv(x):
    """(B,S,H,Dh) -> int8 values + (B,S,H) f32 scales."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(x.astype(jnp.float32) / scale[..., None]).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _attn_sublayer(p, h, ctx: Ctx, *, window, cache, prefix="", cross=False,
                   causal=True):
    """h: normed input (B,S,D). Returns (out (B,S,D), new_cache)."""
    arch, run = ctx.arch, ctx.run
    b, s, d = h.shape
    dh = arch.resolved_head_dim
    hq, hkv = arch.num_heads, arch.num_kv_heads
    cd = ctx.compute_dtype

    def proj(name, x_in, n_h):
        w = p[prefix + name].astype(cd)
        y = jnp.einsum("bsd,de->bse", x_in, w)
        bias = p.get(prefix + "b" + name[-1])
        if bias is not None:
            y = y + bias.astype(cd)
        return y.reshape(b, -1, n_h, dh)

    q = proj("wq", h, hq)
    q = ctx.shard(q, ("act_batch", "act_seq", "act_heads", None))
    new_cache = dict(cache) if cache is not None else None

    if cross:
        # Cross-attention over the (fixed) encoder sequence: K/V computed from
        # the encoder output at train/prefill time and cached for decode.
        if ctx.mode == "decode":
            k = cache["ck"].astype(cd)
            v = cache["cv"].astype(cd)
        else:
            enc = ctx.enc_out.astype(cd)
            k = proj("wk", enc, hkv)
            v = proj("wv", enc, hkv)
            if new_cache is not None:
                new_cache["ck"] = k.astype(jnp.bfloat16)
                new_cache["cv"] = v.astype(jnp.bfloat16)
        out = attention(
            q, k, v, q_positions=ctx.positions, kv_length=None, causal=False,
            window=0, softcap_val=0.0, block_kv=run.attn_block_kv, impl="xla",
            interpret=ctx.interpret,
        )
        out = ctx.shard(out, ("act_batch", "act_seq", "act_heads", None))
        out = jnp.einsum("bse,ed->bsd", out.reshape(b, s, hq * dh),
                         p[prefix + "wo"].astype(cd))
        return out, new_cache

    k = proj("wk", h, hkv)
    v = proj("wv", h, hkv)
    q = rotary_embedding(q, ctx.positions, arch.rope_theta)
    k = rotary_embedding(k, ctx.positions, arch.rope_theta)
    k = ctx.shard(k, ("act_batch", "act_seq", "kv_heads", None))
    v = ctx.shard(v, ("act_batch", "act_seq", "kv_heads", None))

    k_scale = v_scale = None
    kv_len = None
    if ctx.mode == "decode":
        # Insert the new token's K/V at position cache_len, attend over prefix.
        pos = ctx.cache_len
        if run.kv_cache_dtype == "int8":
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            new_cache["k"] = jax.lax.dynamic_update_slice(cache["k"], kq, (0, pos, 0, 0))
            new_cache["v"] = jax.lax.dynamic_update_slice(cache["v"], vq, (0, pos, 0, 0))
            new_cache["ks"] = jax.lax.dynamic_update_slice(cache["ks"], ks, (0, pos, 0))
            new_cache["vs"] = jax.lax.dynamic_update_slice(cache["vs"], vs, (0, pos, 0))
            k_scale, v_scale = new_cache["ks"], new_cache["vs"]
        else:
            new_cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0)
            )
            new_cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0)
            )
        k_use, v_use = new_cache["k"], new_cache["v"]
        kv_len = jnp.full((b,), pos + 1, jnp.int32)
    else:
        if ctx.mode == "prefill":
            if run.kv_cache_dtype == "int8":
                kq, ks = _quantize_kv(k)
                vq, vs = _quantize_kv(v)
                new_cache = {"k": kq, "v": vq, "ks": ks, "vs": vs}
            else:
                new_cache = {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}
        k_use, v_use = k, v

    if k_scale is not None:
        k_use = _dequantize_kv(k_use, k_scale, cd)
        v_use = _dequantize_kv(v_use, v_scale, cd)
    elif k_use.dtype != cd:
        k_use = k_use.astype(cd)
        v_use = v_use.astype(cd)

    out = attention(
        q, k_use, v_use, q_positions=ctx.positions, kv_length=kv_len,
        causal=causal, window=window, softcap_val=arch.attn_logit_softcap,
        block_kv=run.attn_block_kv,
        impl=run.attention_impl if ctx.mode != "decode" else "xla",
        interpret=ctx.interpret, unroll=not run.scan_layers,
    )
    out = ctx.shard(out, ("act_batch", "act_seq", "act_heads", None))
    out = jnp.einsum("bse,ed->bsd", out.reshape(b, s, hq * dh), p[prefix + "wo"].astype(cd))
    return out, new_cache


def _ffn_sublayer(p, h, desc: LayerDesc, ctx: Ctx):
    """Returns (out, aux_loss)."""
    if desc.is_moe:
        out, aux = moe_mod.moe_apply(
            p["moe"], h, ctx.arch, ctx.compute_dtype, shard=ctx.shard
        )
        return out, aux
    return gated_mlp(p["mlp"], h, ctx.compute_dtype), 0.0


def apply_layer(p, x, desc: LayerDesc, ctx: Ctx, *, window, cache):
    """Pre-norm residual layer. Returns (x, aux_loss, new_cache)."""
    arch = ctx.arch
    eps = arch.norm_eps
    aux = 0.0
    if desc.kind == "rwkv":
        if cache is None:
            b = x.shape[0]
            d = arch.d_model
            hd = arch.rwkv_head_dim
            cache = {
                "wkv": jnp.zeros((b, d // hd, hd, hd), jnp.float32),
                "shift_t": jnp.zeros((b, d), x.dtype),
                "shift_c": jnp.zeros((b, d), x.dtype),
            }
        h = rms_norm(x, p["ln1"], eps)
        out, new_shift_t, new_wkv = rwkv_mod.time_mix(
            p["tmix"], h, cache["shift_t"].astype(x.dtype), cache["wkv"], arch,
            chunk=min(ctx.run.attn_block_kv, max(x.shape[1], 16)),
            unroll=not ctx.run.scan_layers,
        )
        x = x + out
        h2 = rms_norm(x, p["ln2"], eps)
        out2, new_shift_c = rwkv_mod.channel_mix(p["cmix"], h2, cache["shift_c"].astype(x.dtype))
        x = x + out2
        new_cache = {"wkv": new_wkv, "shift_t": new_shift_t.astype(cache["shift_t"].dtype),
                     "shift_c": new_shift_c.astype(cache["shift_c"].dtype)}
        return x, aux, (new_cache if ctx.mode != "train" else None)

    if desc.kind == "mamba":
        h = rms_norm(x, p["ln1"], eps)
        if ctx.mode == "decode":
            out, new_cache = mamba_mod.mamba_decode_step(p["mamba"], h, cache, arch)
        else:
            out, new_cache = mamba_mod.mamba_forward(
                p["mamba"], h, arch, return_cache=(ctx.mode == "prefill")
            )
        x = x + out
    else:
        h = rms_norm(x, p["ln1"], eps)
        out, new_cache = _attn_sublayer(p["attn"], h, ctx, window=window, cache=cache)
        x = x + out
        if desc.cross:
            hx = rms_norm(x, p["lnx"], eps)
            # cross K/V ride in the same per-layer cache dict
            merged = new_cache if new_cache is not None else (dict(cache) if cache is not None else None)
            outx, new_cache = _attn_sublayer(
                p["xattn"], hx, ctx, window=0, cache=merged, prefix="c", cross=True
            )
            x = x + outx

    h = rms_norm(x, p["ln2"], eps)
    out, aux = _ffn_sublayer(p, h, desc, ctx)
    x = x + out
    x = ctx.shard(x, ("act_batch", "act_seq", "act_embed"))
    return x, aux, (new_cache if ctx.mode != "train" else None)


def _remat_policy(name: str):
    pols = jax.checkpoint_policies
    return {
        "none": pols.everything_saveable,
        "dots": pols.dots_with_no_batch_dims_saveable,
        "full": pols.nothing_saveable,
    }[name]


def apply_stack(params, x, ctx: Ctx, *, caches=None, windows=None):
    """Run the scanned group stack.

    params: stacked stack params; caches: stacked cache tree (decode) or None;
    windows: (num_layers,) int32 or None. Returns (x, aux_loss, new_caches).
    """
    arch = ctx.arch
    period = structural_period(arch)
    n_grp = num_groups(arch)
    descs = layer_descs(arch)[:period]
    dyn_window = has_dynamic_window(arch)
    if windows is None:
        windows = windows_array(arch)
    win_grp = windows.reshape(n_grp, period)

    def group_body(x_in, gparams, gwin, gcache):
        new_gcache = {}
        aux_total = 0.0
        for j, desc in enumerate(descs):
            lcache = gcache.get(f"l{j}") if gcache is not None else None
            w = gwin[j] if dyn_window else 0
            x_in, aux, nc = apply_layer(
                gparams[f"l{j}"], x_in, desc, ctx, window=w, cache=lcache
            )
            aux_total = aux_total + aux
            if nc is not None:
                new_gcache[f"l{j}"] = nc
        return x_in, aux_total, (new_gcache or None)

    if ctx.run.scan_layers and n_grp > 1:
        def body(carry, scanned):
            x_c, aux_c = carry
            gparams, gwin, gcache = scanned
            x_c, aux, nc = group_body(x_c, gparams, gwin, gcache)
            return (x_c, aux_c + aux), nc

        if ctx.mode == "train":
            body = jax.checkpoint(body, policy=_remat_policy(ctx.run.remat_policy), prevent_cse=True)
        xs = (params, win_grp, caches)
        (x, aux), new_caches = jax.lax.scan(body, (x, 0.0), xs)
        return x, aux, new_caches

    # Unrolled path (exact per-layer cost analysis; scan_layers=False).
    body_fn = group_body
    if ctx.mode == "train":
        body_fn = jax.checkpoint(
            group_body, policy=_remat_policy(ctx.run.remat_policy), prevent_cse=True
        )
    aux_total = 0.0
    new_caches = []
    for gi in range(n_grp):
        gparams = jax.tree.map(lambda a: a[gi], params)
        gcache = jax.tree.map(lambda a: a[gi], caches) if caches is not None else None
        x, aux, nc = body_fn(x, gparams, win_grp[gi], gcache)
        aux_total = aux_total + aux
        new_caches.append(nc)
    if new_caches and new_caches[0] is not None:
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
    else:
        new_caches = None
    return x, aux_total, new_caches


def apply_encoder(params, x, ctx: Ctx):
    """Whisper-style bidirectional encoder over frame embeddings (B, F, D)."""
    arch = ctx.arch
    desc = LayerDesc("attn", False, False)
    b, f, _ = x.shape
    enc_ctx = Ctx(
        arch=arch, run=ctx.run, mode="train",
        positions=jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32)[None], (b, f)),
        shard=ctx.shard, interpret=ctx.interpret,
    )

    def body(carry, gparams):
        h = rms_norm(carry, gparams["l0"]["ln1"], arch.norm_eps)
        out, _ = _attn_sublayer(
            gparams["l0"]["attn"], h, enc_ctx, window=0, cache=None, causal=False
        )
        carry = carry + out
        h2 = rms_norm(carry, gparams["l0"]["ln2"], arch.norm_eps)
        carry = carry + gated_mlp(gparams["l0"]["mlp"], h2, enc_ctx.compute_dtype)
        return carry, None

    x, _ = jax.lax.scan(body, x, params, unroll=not ctx.run.scan_layers)
    return x


def sinusoidal_positions(seq: int, d: int, dtype) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1).astype(dtype)
