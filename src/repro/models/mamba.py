"""Mamba (S6) selective-SSM block, as used by Jamba's SSM layers.

Training/prefill path: depthwise causal conv + ``lax.associative_scan`` over
time for the diagonal state recurrence (log-depth, while-loop-free, so AOT cost
analysis is exact). The inner dimension is sharded over the ``model`` axis
(column-parallel in_proj / row-parallel out_proj), which keeps the scan local
to each device. Decode path: exact single-step recurrence against a carried
(conv window, ssm state) cache. The Pallas kernel in ``repro.kernels.ssm_scan``
implements the single-pass time-blocked version targeted at TPU VMEM.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import PSpec


def mamba_specs(arch: ArchConfig) -> Dict[str, PSpec]:
    d = arch.d_model
    di = arch.ssm_expand * d
    n = arch.ssm_state_dim
    dt_rank = max(d // 16, 1)
    return {
        "in_proj": PSpec((d, 2 * di), ("embed", "inner")),
        "conv_w": PSpec((arch.ssm_conv_width, di), (None, "inner"), init="small_normal"),
        "conv_b": PSpec((di,), ("inner",), init="zeros"),
        "x_proj": PSpec((di, dt_rank + 2 * n), ("inner", None), init="small_normal"),
        "dt_proj": PSpec((dt_rank, di), (None, "inner"), init="small_normal"),
        "dt_bias": PSpec((di,), ("inner",), init="zeros"),
        "a_log": PSpec((di, n), ("inner", None), init="zeros"),
        "d_skip": PSpec((di,), ("inner",), init="ones"),
        "out_proj": PSpec((di, d), ("inner", "embed")),
    }


def _ssm_inputs(p, x, arch: ArchConfig):
    """Common projections. x: (B, S, D) -> (xz pieces, dt, B_t, C_t, A)."""
    n = arch.ssm_state_dim
    dt_rank = max(arch.d_model // 16, 1)
    cd = x.dtype
    xz = x @ p["in_proj"].astype(cd)  # (B, S, 2*di)
    di = xz.shape[-1] // 2
    xin, z = xz[..., :di], xz[..., di:]
    bcdt = None  # computed after conv
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, N), negative
    return xin, z, a, dt_rank, n


def mamba_forward(p, x, arch: ArchConfig, return_cache: bool = False):
    """Full-sequence path. x: (B, S, D) -> (out (B, S, D), cache | None)."""
    b, s, _ = x.shape
    xin, z, a, dt_rank, n = _ssm_inputs(p, x, arch)
    di = xin.shape[-1]
    cd = x.dtype

    # Depthwise causal conv over time.
    kw = arch.ssm_conv_width
    xpad = jnp.pad(xin, ((0, 0), (kw - 1, 0), (0, 0)))
    conv = sum(
        xpad[:, i : i + s, :] * p["conv_w"].astype(cd)[i] for i in range(kw)
    ) + p["conv_b"].astype(cd)
    u = jax.nn.silu(conv)  # (B, S, di)

    bcdt = u @ p["x_proj"].astype(cd)  # (B, S, dt_rank + 2N)
    dt = jax.nn.softplus(
        bcdt[..., :dt_rank] @ p["dt_proj"].astype(cd) + p["dt_bias"].astype(cd)
    ).astype(jnp.float32)  # (B, S, di)
    b_t = bcdt[..., dt_rank : dt_rank + n].astype(jnp.float32)  # (B, S, N)
    c_t = bcdt[..., dt_rank + n :].astype(jnp.float32)

    # Diagonal recurrence h_t = da_t ⊙ h_{t-1} + (dt u)_t B_t via associative scan.
    da = jnp.exp(dt[..., None] * a[None, None])  # (B, S, di, N)
    dbu = (dt * u.astype(jnp.float32))[..., None] * b_t[:, :, None, :]  # (B,S,di,N)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (da, dbu), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, c_t)  # (B, S, di)
    y = y + u.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y.astype(cd) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(cd)
    if not return_cache:
        return out, None
    kw = arch.ssm_conv_width
    cache = {"conv": xin[:, s - (kw - 1):, :], "ssm": h[:, -1]}
    return out, cache


def mamba_decode_step(p, x, cache, arch: ArchConfig):
    """Single-token recurrence. x: (B, 1, D); cache: {conv (B,kw-1,di),
    ssm (B,di,N)}. Returns (out (B,1,D), new cache)."""
    b = x.shape[0]
    xin, z, a, dt_rank, n = _ssm_inputs(p, x, arch)
    di = xin.shape[-1]
    cd = x.dtype
    kw = arch.ssm_conv_width

    window = jnp.concatenate([cache["conv"], xin], axis=1)  # (B, kw, di)
    conv = jnp.einsum("bkd,kd->bd", window, p["conv_w"].astype(cd)) + p["conv_b"].astype(cd)
    u = jax.nn.silu(conv)  # (B, di)

    bcdt = u @ p["x_proj"].astype(cd)
    dt = jax.nn.softplus(
        bcdt[..., :dt_rank] @ p["dt_proj"].astype(cd) + p["dt_bias"].astype(cd)
    ).astype(jnp.float32)  # (B, di)
    b_t = bcdt[..., dt_rank : dt_rank + n].astype(jnp.float32)
    c_t = bcdt[..., dt_rank + n :].astype(jnp.float32)

    da = jnp.exp(dt[..., None] * a[None])  # (B, di, N)
    h = da * cache["ssm"] + (dt * u.astype(jnp.float32))[..., None] * b_t[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c_t)
    y = y + u.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = (y.astype(cd) * jax.nn.silu(z[:, 0]))[:, None]
    out = y @ p["out_proj"].astype(cd)
    new_cache = {"conv": window[:, 1:], "ssm": h}
    return out.reshape(b, 1, -1), new_cache


def init_mamba_cache(arch: ArchConfig, batch: int, dtype=jnp.bfloat16):
    di = arch.ssm_expand * arch.d_model
    return {
        "conv": jnp.zeros((batch, arch.ssm_conv_width - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, arch.ssm_state_dim), jnp.float32),
    }
