"""Deterministic synthetic LM data pipeline with per-host sharding and
background prefetch.

Production shape: each host materializes only its slice of the global batch
(``host_slice``), assembles a globally-sharded ``jax.Array`` from the local
shards, and a prefetch thread keeps ``prefetch_depth`` batches in flight so
the accelerator never waits on the host. The corpus is a seeded zipfian
stream, so every run (and every restart — see ``state_dict``) is bit-exact
reproducible; a restart resumes from the same step's batch.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass
class PipelineConfig:
    seed: int = 0
    prefetch_depth: int = 2
    zipf_a: float = 1.2


class SyntheticLMPipeline:
    """Deterministic token stream → sharded train batches."""

    def __init__(self, arch: ArchConfig, shape: ShapeConfig, cfg: PipelineConfig = PipelineConfig(),
                 mesh=None, batch_sharding=None):
        self.arch = arch
        self.shape = shape
        self.cfg = cfg
        self.mesh = mesh
        self.batch_sharding = batch_sharding
        self.step = 0
        self.n_hosts = jax.process_count()
        self.host_id = jax.process_index()

    # ------------------------------------------------------------- batches

    def _host_batch(self, step: int) -> Dict[str, np.ndarray]:
        """The slice of the global batch owned by this host, derived purely
        from (seed, step, host) — no cross-host coordination needed."""
        b, s = self.shape.global_batch, self.shape.seq_len
        per_host = max(b // self.n_hosts, 1)
        rng = np.random.default_rng((self.cfg.seed, step, self.host_id))
        # zipf via inverse-cdf on a fixed rank table (cheap + deterministic)
        u = rng.random((per_host, s + 1))
        ranks = u ** (-1.0 / (self.cfg.zipf_a - 1.0))
        ranks = np.nan_to_num(ranks, posinf=float(self.arch.vocab_size))
        toks = np.minimum(ranks, self.arch.vocab_size - 1).astype(np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.arch.frontend == "vision":
            batch["patches"] = rng.standard_normal(
                (per_host, self.arch.frontend_seq, self.arch.d_model), dtype=np.float32
            ) * 0.02
        elif self.arch.frontend == "audio":
            batch["frames"] = rng.standard_normal(
                (per_host, self.arch.frontend_seq, self.arch.d_model), dtype=np.float32
            ) * 0.02
        return batch

    def _to_device(self, host_batch: Dict[str, np.ndarray]):
        if self.mesh is None or self.batch_sharding is None:
            return {k: jnp.asarray(v) for k, v in host_batch.items()}
        from jax.sharding import NamedSharding

        out = {}
        for k, v in host_batch.items():
            sh = NamedSharding(self.mesh, self.batch_sharding[k])
            out[k] = jax.device_put(v, sh)
        return out

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        q: "queue.Queue" = queue.Queue(maxsize=self.cfg.prefetch_depth)
        stop = threading.Event()

        def producer():
            step = self.step
            while not stop.is_set():
                try:
                    q.put(self._host_batch(step), timeout=0.1)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                host_batch = q.get()
                self.step += 1
                yield self._to_device(host_batch)
        finally:
            stop.set()

    def take(self, n: int):
        it = iter(self)
        for _ in range(n):
            yield next(it)

    # ------------------------------------------------------------ restarts

    def state_dict(self) -> Dict[str, Any]:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, state: Dict[str, Any]):
        self.step = int(state["step"])
