"""The 10 assigned architectures (full + smoke variants) and the registry.

Full configs follow the assignment sheet exactly (layers / d_model / heads /
kv heads / d_ff / vocab / family-specific structure). Smoke variants keep the
same *family structure* (same block/MoE patterns, same period) at toy size so
one train/serve step runs on a single CPU device.

``skip_shapes`` records the cells that are architecturally inapplicable
(documented in DESIGN.md §6): ``long_500k`` runs only for the SSM/hybrid
archs (rwkv6, jamba); whisper's decoder shapes are structurally exercised but
``long_500k`` is skipped (enc-dec, quadratic decoder).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig

_SKIP_LONG = ("long_500k",)

FULL: Dict[str, ArchConfig] = {}
SMOKE: Dict[str, ArchConfig] = {}


def _register(full: ArchConfig, smoke: ArchConfig):
    FULL[full.name] = full
    assert smoke.name == full.name
    SMOKE[full.name] = smoke


# --------------------------------------------------------------------- vlm
# InternVL2-26B: InternViT frontend (stub patch embeddings) + InternLM2-20B
# backbone. [arXiv:2404.16821]
_register(
    ArchConfig(
        name="internvl2-26b", family="vlm", num_layers=48, d_model=6144,
        num_heads=48, num_kv_heads=8, d_ff=16384, vocab_size=92553,
        rope_theta=1e6, frontend="vision", frontend_seq=1025,
        skip_shapes=_SKIP_LONG,
    ),
    ArchConfig(
        name="internvl2-26b", family="vlm", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
        frontend="vision", frontend_seq=9, skip_shapes=_SKIP_LONG,
    ),
)

# --------------------------------------------------------------------- ssm
# RWKV-6 "Finch" 7B: attention-free, data-dependent decay. [arXiv:2404.05892]
_register(
    ArchConfig(
        name="rwkv6-7b", family="ssm", num_layers=32, d_model=4096,
        num_heads=64, num_kv_heads=64, d_ff=14336, vocab_size=65536,
        block_pattern=("rwkv",), rwkv_head_dim=64,
    ),
    ArchConfig(
        name="rwkv6-7b", family="ssm", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
        block_pattern=("rwkv",), rwkv_head_dim=16,
    ),
)

# ------------------------------------------------------------------- dense
# Llama-3.2-1B. [hf:meta-llama/Llama-3.2-1B]
_register(
    ArchConfig(
        name="llama3.2-1b", family="dense", num_layers=16, d_model=2048,
        num_heads=32, num_kv_heads=8, d_ff=8192, vocab_size=128256,
        rope_theta=500000.0, tie_embeddings=True, skip_shapes=_SKIP_LONG,
    ),
    ArchConfig(
        name="llama3.2-1b", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
        tie_embeddings=True, skip_shapes=_SKIP_LONG,
    ),
)

# Gemma-2 9B: 1:1 local(4096):global alternation, logit softcaps, head_dim
# 256 ≠ d/H. [arXiv:2408.00118]
_register(
    ArchConfig(
        name="gemma2-9b", family="dense", num_layers=42, d_model=3584,
        num_heads=16, num_kv_heads=8, d_ff=14336, vocab_size=256000,
        head_dim=256, block_pattern=("attn_local", "attn"), sliding_window=4096,
        attn_logit_softcap=50.0, final_logit_softcap=30.0, tie_embeddings=True,
        skip_shapes=_SKIP_LONG,
    ),
    ArchConfig(
        name="gemma2-9b", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512, head_dim=32,
        block_pattern=("attn_local", "attn"), sliding_window=8,
        attn_logit_softcap=50.0, final_logit_softcap=30.0, tie_embeddings=True,
        skip_shapes=_SKIP_LONG,
    ),
)

# Qwen2-72B: GQA + QKV bias. [arXiv:2407.10671]
_register(
    ArchConfig(
        name="qwen2-72b", family="dense", num_layers=80, d_model=8192,
        num_heads=64, num_kv_heads=8, d_ff=29568, vocab_size=152064,
        qkv_bias=True, rope_theta=1e6, skip_shapes=_SKIP_LONG,
    ),
    ArchConfig(
        name="qwen2-72b", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
        qkv_bias=True, skip_shapes=_SKIP_LONG,
    ),
)

# Gemma-3 1B: 5:1 local(512):global, MQA (kv=1), 262k vocab.
# [hf:google/gemma-3-1b-pt]
_register(
    ArchConfig(
        name="gemma3-1b", family="dense", num_layers=26, d_model=1152,
        num_heads=4, num_kv_heads=1, d_ff=6912, vocab_size=262144,
        head_dim=256,
        block_pattern=("attn_local",) * 5 + ("attn",), sliding_window=512,
        rope_theta=1e6, tie_embeddings=True, skip_shapes=_SKIP_LONG,
    ),
    ArchConfig(
        name="gemma3-1b", family="dense", num_layers=6, d_model=64,
        num_heads=4, num_kv_heads=1, d_ff=128, vocab_size=512, head_dim=32,
        block_pattern=("attn_local",) * 5 + ("attn",), sliding_window=8,
        tie_embeddings=True, skip_shapes=_SKIP_LONG,
    ),
)

# --------------------------------------------------------------------- moe
# Llama-4 Maverick 400B-A17B: 128 experts top-1, dense/MoE interleave.
# [hf:meta-llama/Llama-4-Scout-17B-16E (family)]
_register(
    ArchConfig(
        name="llama4-maverick-400b-a17b", family="moe", num_layers=48,
        d_model=5120, num_heads=40, num_kv_heads=8, d_ff=8192,
        vocab_size=202048, rope_theta=500000.0,
        num_experts=128, experts_per_token=1, moe_pattern=(False, True),
        skip_shapes=_SKIP_LONG,
    ),
    ArchConfig(
        name="llama4-maverick-400b-a17b", family="moe", num_layers=2,
        d_model=64, num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
        num_experts=4, experts_per_token=1, moe_pattern=(False, True),
        skip_shapes=_SKIP_LONG,
    ),
)

# Phi-3.5-MoE 42B-A6.6B: 16 experts top-2, every layer MoE.
# [hf:microsoft/Phi-3.5-MoE-instruct]
_register(
    ArchConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe", num_layers=32,
        d_model=4096, num_heads=32, num_kv_heads=8, d_ff=6400,
        vocab_size=32064,
        num_experts=16, experts_per_token=2, moe_pattern=(True,),
        skip_shapes=_SKIP_LONG,
    ),
    ArchConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe", num_layers=2,
        d_model=64, num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
        num_experts=4, experts_per_token=2, moe_pattern=(True,),
        skip_shapes=_SKIP_LONG,
    ),
)

# ------------------------------------------------------------------ hybrid
# Jamba-1.5-Large: 1:7 attn:mamba interleave, MoE every other layer (16e
# top-2). [arXiv:2403.19887]
_register(
    ArchConfig(
        name="jamba-1.5-large-398b", family="hybrid", num_layers=72,
        d_model=8192, num_heads=64, num_kv_heads=8, d_ff=24576,
        vocab_size=65536,
        block_pattern=("attn",) + ("mamba",) * 7, moe_pattern=(False, True),
        num_experts=16, experts_per_token=2,
        ssm_state_dim=16, ssm_conv_width=4, ssm_expand=2,
    ),
    ArchConfig(
        name="jamba-1.5-large-398b", family="hybrid", num_layers=8,
        d_model=64, num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
        block_pattern=("attn",) + ("mamba",) * 7, moe_pattern=(False, True),
        num_experts=4, experts_per_token=2,
        ssm_state_dim=4, ssm_conv_width=4, ssm_expand=2,
    ),
)

# ------------------------------------------------------------------- audio
# Whisper-tiny: enc-dec; conv frontend is a stub that provides (B, 1500, 384)
# frame embeddings. [arXiv:2212.04356]
_register(
    ArchConfig(
        name="whisper-tiny", family="audio", num_layers=4, d_model=384,
        num_heads=6, num_kv_heads=6, d_ff=1536, vocab_size=51865,
        encoder_layers=4, frontend="audio", frontend_seq=1500,
        skip_shapes=_SKIP_LONG,
    ),
    ArchConfig(
        name="whisper-tiny", family="audio", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
        encoder_layers=2, frontend="audio", frontend_seq=12,
        skip_shapes=_SKIP_LONG,
    ),
)

ARCH_NAMES = tuple(FULL.keys())


def get_arch(name: str, smoke: bool = False) -> ArchConfig:
    table = SMOKE if smoke else FULL
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(table)}")
    return table[name]


def applicable_shapes(arch: ArchConfig, shapes=None):
    from repro.configs.base import SHAPES

    shapes = shapes or SHAPES
    return {k: v for k, v in shapes.items() if k not in arch.skip_shapes}
