"""Configuration dataclasses for architectures, input shapes, and execution.

Three layers of configuration, mirroring the paper's separation between the
*job* (what runs) and the *tunable platform parameters* (how it runs):

  - ``ArchConfig``  — the model architecture (fixed per assigned arch).
  - ``ShapeConfig`` — the input shape cell (train_4k / prefill_32k / ...).
  - ``RunConfig``   — the execution-layer knobs; this is the search space the
    paper's tuning algorithms (GSFT / CRS) operate on.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple

VOCAB_PAD_MULTIPLE = 256  # vocab tables are padded so the 16-way model axis divides


def pad_vocab(v: int) -> int:
    return ((v + VOCAB_PAD_MULTIPLE - 1) // VOCAB_PAD_MULTIPLE) * VOCAB_PAD_MULTIPLE


@dataclass(frozen=True)
class ArchConfig:
    """Architecture definition. ``block_pattern`` / ``moe_pattern`` are cyclic
    per-layer patterns (cycled up to ``num_layers``)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # Per-layer cyclic patterns.
    block_pattern: Tuple[str, ...] = ("attn",)  # attn | attn_local | mamba | rwkv
    moe_pattern: Tuple[bool, ...] = (False,)

    # Attention details.
    sliding_window: int = 4096  # used by attn_local entries
    qkv_bias: bool = False
    attn_logit_softcap: float = 0.0  # 0 disables
    final_logit_softcap: float = 0.0
    rope_theta: float = 10000.0

    # MoE.
    num_experts: int = 0
    experts_per_token: int = 0
    d_ff_expert: int = 0  # 0 -> d_ff
    moe_capacity_factor: float = 1.25

    # SSM (mamba) / RWKV dims.
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    rwkv_head_dim: int = 64

    # Encoder/decoder + modality frontend stubs.
    encoder_layers: int = 0  # >0 => encoder-decoder; num_layers is the decoder
    frontend: Optional[str] = None  # vision | audio
    frontend_seq: int = 0  # patches / frames provided by the (stub) frontend

    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # Which shape cells are inapplicable for this arch (documented in DESIGN.md).
    skip_shapes: Tuple[str, ...] = ()

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    def layer_kinds(self) -> Tuple[Tuple[str, bool], ...]:
        """Per-layer (kind, is_moe) for all num_layers layers."""
        out = []
        for i in range(self.num_layers):
            kind = self.block_pattern[i % len(self.block_pattern)]
            is_moe = bool(self.moe_pattern[i % len(self.moe_pattern)]) and self.num_experts > 0
            out.append((kind, is_moe))
        return tuple(out)

    @property
    def period(self) -> int:
        """Length of the repeating layer-pattern unit (for scan-over-periods)."""
        p = _lcm(len(self.block_pattern), len(self.moe_pattern))
        return min(p, self.num_layers)

    def param_count(self) -> int:
        """Analytic parameter count (unpadded vocab)."""
        d, dh = self.d_model, self.resolved_head_dim
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for kind, is_moe in self.layer_kinds():
            if kind in ("attn", "attn_local"):
                total += d * self.num_heads * dh * 2  # q, o
                total += d * self.num_kv_heads * dh * 2  # k, v
                if self.qkv_bias:
                    total += (self.num_heads + 2 * self.num_kv_heads) * dh
            elif kind == "mamba":
                di = self.ssm_expand * d
                n = self.ssm_state_dim
                total += d * di * 2  # in_proj (x, gate)
                total += di * self.ssm_conv_width
                total += di * (2 * n + 1) + di  # B,C,dt proj + dt bias (low-rank-ish)
                total += di * n + di  # A, D
                total += di * d  # out proj
            elif kind == "rwkv":
                total += d * d * 5  # r,k,v,g,o (time mix)
                total += d * 2 + 64 * d * 2  # decay lora-ish
            if kind != "rwkv":
                ff = (self.d_ff_expert or self.d_ff) if is_moe else self.d_ff
                n_ff = self.num_experts if is_moe else 1
                total += n_ff * 3 * d * ff  # gated MLP
                if is_moe:
                    total += d * self.num_experts  # router
            else:
                total += 2 * d * self.d_ff  # rwkv channel mix (k, v) + recept.
                total += d * d
            total += 2 * d  # norms
        if self.encoder_layers:
            # encoder self-attn+mlp, decoder cross-attn (approx: same block cost)
            per_attn_layer = d * self.num_heads * dh * 2 + d * self.num_kv_heads * dh * 2 + 3 * d * self.d_ff + 2 * d
            total += self.encoder_layers * per_attn_layer
            total += self.num_layers * (d * self.num_heads * dh * 2 + d * self.num_kv_heads * dh * 2 + d)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.num_experts == 0:
            return self.param_count()
        d = self.d_model
        ff = self.d_ff_expert or self.d_ff
        total = self.param_count()
        n_moe_layers = sum(1 for _, m in self.layer_kinds() if m)
        inactive = n_moe_layers * (self.num_experts - self.experts_per_token) * 3 * d * ff
        return total - inactive


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


# The four assigned LM shape cells.
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    """Execution-layer configuration — the tunable space (paper §III analog).

    Training knobs (12, the "Hadoop side") and serving knobs (11, the "Spark
    side") share this dataclass; ``repro.core.space`` declares which fields are
    exposed to each platform with defaults + bounded ranges.
    """

    # --- training knobs ---
    mesh_model_parallel: int = 16       # ICI model-axis size (data = chips // model)
    microbatch_size: int = 0            # 0 = no gradient accumulation
    remat_policy: str = "full"          # none | dots | full
    attn_block_q: int = 512
    attn_block_kv: int = 512
    matmul_precision: str = "bf16"      # bf16 | f32 (activation/accum dtype policy)
    grad_compression: str = "off"       # off | int8 (cross-pod error-feedback)
    scan_layers: bool = True            # False = unrolled (exact cost analysis)
    zero_sharding: str = "fsdp"         # none | zero1 | fsdp
    collective_matmul: str = "ag"       # ag (Megatron) | rs (sequence-parallel residual)
    moe_expert_parallel: bool = True    # True = EP (experts over model axis); False = expert-TP
    optimizer_moment_dtype: str = "float32"  # float32 | bfloat16

    # --- serving knobs ---
    kv_cache_dtype: str = "bfloat16"    # bfloat16 | int8
    prefill_chunk: int = 0              # 0 = single-shot prefill
    decode_batch_partition: str = "data"  # data | model | both
    kv_partition: str = "auto"          # auto | heads | sequence
    weight_dtype: str = "bfloat16"      # bfloat16 | int8 (serving weights)
    max_concurrent_decodes: int = 0     # 0 = batch size (serving scheduler bound)

    # --- structural (not tuned; set per environment) ---
    attention_impl: str = "xla"         # xla | pallas
    embed_impl: str = "gather"          # gather | one_hot (matmul; scatter-free bwd)
    attn_partition: str = "auto"        # auto | heads | sequence | replicated
    param_dtype: str = "float32"        # master weights
    compute_dtype: str = "bfloat16"
    gradient_clip: float = 1.0
    learning_rate: float = 3e-4

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


def resolve_attn_partition(arch: ArchConfig, run: RunConfig, model_parallel: int) -> str:
    """heads-TP when divisible, else sequence-parallel attention."""
    if run.attn_partition != "auto":
        return run.attn_partition
    if arch.num_heads % max(model_parallel, 1) == 0:
        return "heads"
    return "sequence"


def resolve_kv_partition(arch: ArchConfig, run: RunConfig, model_parallel: int) -> str:
    if run.kv_partition != "auto":
        return run.kv_partition
    if arch.num_kv_heads % max(model_parallel, 1) == 0:
        return "heads"
    return "sequence"
