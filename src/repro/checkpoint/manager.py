"""Sharded checkpointing: async save with atomic publish, restore with
re-sharding onto a (possibly different) mesh, keep-N garbage collection.

Layout:
    <dir>/step_00000420/
        manifest.json        — tree structure, per-leaf shape/dtype, step
        <leaf-key>.npy       — one file per pytree leaf
    <dir>/step_00000420.tmp/ …   (atomically renamed on completion)

Async mode hands the (host-gathered) arrays to a writer thread so the train
loop resumes immediately; ``wait()`` joins before the next save or exit.
Restore takes a sharding tree and ``device_put``s each leaf — this is what
elastic re-scaling uses to move a checkpoint onto a *different* mesh
factorization (see ``repro.ft.elastic``).
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


class CheckpointManager:
    def __init__(self, directory, *, keep_n: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---------------------------------------------------------------- save

    def save(self, step: int, state: Any, *, blocking: Optional[bool] = None):
        """Snapshot ``state`` at ``step``. Non-blocking by default: arrays are
        fetched to host, then written + published by a background thread."""
        self.wait()
        leaves_with_paths = jax.tree_util.tree_flatten_with_path(state)[0]
        host_leaves = [(
            _leaf_key(path), np.asarray(leaf)
        ) for path, leaf in leaves_with_paths]
        manifest = {
            "step": int(step),
            "time": time.time(),
            "leaves": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in host_leaves
            },
        }

        def write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for k, v in host_leaves:
                np.save(tmp / f"{k}.npy", v)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)  # atomic publish
            self._gc()

        if blocking if blocking is not None else not self.async_save:
            write()
        else:
            def guarded():
                try:
                    write()
                except BaseException as e:  # surfaced on next wait()
                    self._error = e

            self._thread = threading.Thread(target=guarded, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------- restore

    def steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp") and (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like: Any, *, step: Optional[int] = None, shardings: Any = None,
                mesh=None) -> Any:
        """Rebuild the ``like``-structured state from disk. ``shardings``
        (PartitionSpec tree) + ``mesh`` re-shard each leaf — pass the NEW
        mesh's specs to restore onto a different topology."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        src = self.dir / f"step_{step:08d}"
        paths = jax.tree_util.tree_flatten_with_path(like)[0]
        treedef = jax.tree_util.tree_structure(like)
        spec_leaves = None
        if shardings is not None:
            spec_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: x is None or not isinstance(x, (dict, list, tuple))
            )
        out = []
        for i, (path, leaf) in enumerate(paths):
            arr = np.load(src / f"{_leaf_key(path)}.npy")
            if spec_leaves is not None and mesh is not None:
                from jax.sharding import NamedSharding

                arr = jax.device_put(arr, NamedSharding(mesh, spec_leaves[i]))
            else:
                arr = jax.device_put(arr)
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------------ gc

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
