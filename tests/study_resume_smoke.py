"""Study resume smoke (the CI ``study-resume`` job — not a pytest module).

Scenario: start a Study tuning run in a child process, SIGINT it mid-batch,
then ``Study.resume()`` in this process and assert that the total paid
evaluations (trials persisted before the kill + fresh trials paid by the
resume) equal those of a single uninterrupted run — i.e. an interruption
loses nothing and double-pays nothing.

    PYTHONPATH=src python tests/study_resume_smoke.py
"""
import json
import os
import signal
import subprocess
import sys
import tempfile
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.core import Study  # noqa: E402
from repro.core.evaluators import FunctionEvaluator  # noqa: E402
from repro.core.scheduler import iter_jsonl  # noqa: E402

CRS_KW = dict(m=6, k=2, max_rounds=3, seed=11)


def objective(cfg):
    return (10.0
            + abs(cfg["mesh_model_parallel"] - 8) * 0.5
            + abs((cfg["microbatch_size"] or 256) - 32) * 0.02)


def slow_objective(cfg):
    time.sleep(0.15)  # wide SIGINT window per trial
    return objective(cfg)


def run_child(study_dir: str) -> int:
    study = Study.open(Path(study_dir))
    study.optimize("train", "crs", FunctionEvaluator(slow_objective), **CRS_KW)
    return 0


def paid_records(cache: Path) -> int:
    """Complete (parseable) persisted trial records — the evaluations the
    interrupted session already paid for. iter_jsonl applies the engine's
    own torn-tail tolerance, so a record torn by the SIGINT is not counted
    (it is not replayable either)."""
    return len(iter_jsonl(cache))


def main() -> int:
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        return run_child(sys.argv[2])

    tmp = Path(tempfile.mkdtemp(prefix="study-resume-smoke-"))
    study_dir = tmp / "study"

    # reference: the same seeded session, never interrupted, fresh study
    ref = Study.create(tmp / "ref").optimize(
        "train", "crs", FunctionEvaluator(objective), **CRS_KW)
    ref_total = ref.cache_stats["fresh"]
    assert ref_total > 6, f"reference run too small to interrupt ({ref_total})"

    # interrupted run: SIGINT the child once >= 4 trials are persisted
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    child = subprocess.Popen(
        [sys.executable, __file__, "--child", str(study_dir)], env=env)
    cache = study_dir / "cache.jsonl"
    deadline = time.time() + 120
    while time.time() < deadline:
        if paid_records(cache) >= 4:
            break
        if child.poll() is not None:
            raise SystemExit("child finished before it could be interrupted")
        time.sleep(0.02)
    child.send_signal(signal.SIGINT)
    child.wait(timeout=60)
    assert child.returncode != 0, "child should have died from the SIGINT"

    paid_before = paid_records(cache)
    assert 0 < paid_before < ref_total, (paid_before, ref_total)

    # resume: replays everything already paid, pays only the remainder
    study = Study.load(study_dir)
    out = study.resume(evaluator=FunctionEvaluator(objective))
    assert out.cache_stats["cache_hits"] == paid_before, (
        out.cache_stats, paid_before)
    assert out.cache_stats["fresh"] == ref_total - paid_before, (
        out.cache_stats, ref_total, paid_before)
    assert out.best_config == ref.best_config
    assert out.best_time == ref.best_time

    print(json.dumps({
        "reference_evaluations": ref_total,
        "paid_before_sigint": paid_before,
        "resume_fresh": out.cache_stats["fresh"],
        "resume_replayed": out.cache_stats["cache_hits"],
        "best_time_s": out.best_time,
    }, indent=1))
    print("OK: interrupted-then-resumed total == single uninterrupted run")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
