"""RFC 8259 strictness of the JSONL persistence layer: Python's ``json``
serializes ``inf``/``nan`` floats as bare ``Infinity``/``NaN`` tokens that
only round-trip because ``json.loads`` is lenient. The writers sanitize
non-finite floats to string sentinels (``jsonl_line``) and ``iter_jsonl``
restores them — so every persisted line parses under a strict RFC parser
(the regression: PR 9's infinite-p99 windows and score=inf records)."""
import json
import math

import pytest

from repro.core.evaluators import FunctionEvaluator
from repro.core.scheduler import (
    TrialScheduler,
    iter_jsonl,
    jsonl_line,
    restore_nonfinite,
    sanitize_nonfinite,
)


def _strict_loads(line):
    def _reject(token):
        raise ValueError(f"non-RFC constant {token!r}")

    return json.loads(line, parse_constant=_reject)


def test_infinite_time_survives_strict_round_trip(tmp_path):
    rec = {
        "config": {"a": 1},
        "time_s": float("inf"),
        "nested": {"p99": float("-inf"), "vals": [1.0, float("nan")]},
    }
    line = jsonl_line(rec)
    parsed = _strict_loads(line)  # raises if any bare Infinity/NaN leaked
    restored = restore_nonfinite(parsed)
    assert restored["time_s"] == math.inf
    assert restored["nested"]["p99"] == -math.inf
    assert math.isnan(restored["nested"]["vals"][1])
    # and through the file-level reader
    path = tmp_path / "cache.jsonl"
    path.write_text(line + "\n")
    [row] = iter_jsonl(path)
    assert row["time_s"] == math.inf


def test_sentinel_strings_round_trip_as_floats_not_strings():
    assert sanitize_nonfinite(float("inf")) == "Infinity"
    assert sanitize_nonfinite(float("-inf")) == "-Infinity"
    assert sanitize_nonfinite(float("nan")) == "NaN"
    # tuples sanitize like lists (JSON has no tuple)
    assert sanitize_nonfinite((1.0, float("inf"))) == [1.0, "Infinity"]
    # restore is exactly inverse on the sentinels, identity elsewhere
    assert restore_nonfinite("Infinity") == math.inf
    assert restore_nonfinite("Infinityy") == "Infinityy"
    assert restore_nonfinite({"x": ["NaN"]})["x"][0] != restore_nonfinite("x")


def test_legacy_bare_infinity_lines_still_decode(tmp_path):
    # records written before the sanitizer carry bare tokens; the lenient
    # stdlib parse inside iter_jsonl must keep accepting them
    path = tmp_path / "legacy.jsonl"
    path.write_text('{"time_s": Infinity, "score": NaN}\n')
    [row] = iter_jsonl(path)
    assert row["time_s"] == math.inf
    assert math.isnan(row["score"])


def test_scheduler_cache_lines_are_strict_json(tmp_path):
    # end to end: a trial whose measurement comes back infinite must land in
    # cache.jsonl and the trial log as strict-parseable lines
    cache = tmp_path / "cache.jsonl"
    log = tmp_path / "log.jsonl"
    sched = TrialScheduler(
        FunctionEvaluator(lambda c: float("inf")),
        cache_path=cache, log_path=log,
    )
    sched.evaluate({"mesh_model_parallel": 8}, tag="t")
    sched.close()
    for path in (cache, log):
        lines = [l for l in path.read_text().splitlines() if l.strip()]
        assert lines
        for line in lines:
            _strict_loads(line)
    # and the warm-start reader hands the inf back as a float
    rows = iter_jsonl(cache)
    assert any(r.get("time_s") == math.inf for r in rows)
