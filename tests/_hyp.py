"""Optional-hypothesis shim: property tests degrade to a clean skip when
hypothesis is not installed (install the ``dev`` extra: ``pip install -e
.[dev]``) instead of erroring the whole module at collection."""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # plain zero-arg stand-in: no functools.wraps, or pytest would
            # read the wrapped signature and demand fixtures for its params
            def skipper():
                pytest.skip("hypothesis not installed — pip install -e .[dev]")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every strategy call
        returns an inert placeholder (the test body never runs)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
