"""Edge cases for the HLO text analyzers (repro.core.hlo): empty modules,
malformed shape strings, CollectiveStats.combine weighting, and the
parse_memory peak-buffer estimator the static feasibility gate's AOT path
feeds on."""
import numpy as np
import pytest

from repro.core.hlo import (
    CollectiveStats,
    MemoryEstimate,
    parse_collectives,
    parse_memory,
)


# ---------------------------------------------------------------- empty text


def test_parse_collectives_empty_text():
    stats = parse_collectives("")
    assert stats.count == 0
    assert stats.wire_bytes == 0.0
    assert dict(stats.by_op) == {}


def test_parse_memory_empty_text():
    est = parse_memory("")
    assert est == MemoryEstimate()
    assert est.peak_bytes == 0
    assert est.op_count == 0


def test_parse_memory_non_hlo_garbage():
    # prose / MLIR-ish text with no `%name = shape op(` lines parses to zero
    est = parse_memory("func.func @main(%arg0: tensor<4xf32>) {\n  return\n}")
    assert est.peak_bytes == 0


# ----------------------------------------------------------- malformed shapes


def test_malformed_shape_contributes_zero_bytes():
    # dtype not in DTYPE_BYTES (token types) and missing dims both yield 0
    text = "\n".join([
        "ENTRY main {",
        "  a.1 = token[] after-all()",
        "  b.2 = f32[bogus] weird-op(a.1)",          # non-numeric dims: no match
        "  c.3 = f32[4,4]{1,0} add(a.1, a.1)",       # well-formed: 64 B temp
        "}",
    ])
    est = parse_memory(text)
    assert est.max_temp_bytes == 64
    # the token[] line parses as an op with zero bytes
    assert est.total_temp_bytes == 64


def test_parse_collectives_ignores_malformed_groups():
    # a collective with no replica_groups defaults to group size 1 (zero wire)
    text = "  ar.1 = f32[8]{0} all-reduce(p.0), to_apply=add\n"
    stats = parse_collectives(text)
    assert stats.count == 1
    assert stats.wire_bytes == 0.0  # 2*(g-1)/g with g=1


# ------------------------------------------------------ combine() weighting


def _stats(op: str, g: int, result_bytes: int) -> CollectiveStats:
    s = CollectiveStats()
    s.add(op, g, result_bytes)
    return s


def test_combine_weights_wire_bytes_but_not_counts():
    a = _stats("all-gather", 4, 1024)   # wire = 3/4 * 1024 = 768
    b = _stats("all-gather", 4, 2048)   # wire = 3/4 * 2048 = 1536
    out = CollectiveStats.combine(a, b, wa=2.0, wb=0.5)
    assert out.wire_bytes == pytest.approx(2.0 * 768 + 0.5 * 1536)
    assert out.by_op["all-gather"] == pytest.approx(out.wire_bytes)
    assert out.by_group_size[4] == pytest.approx(out.wire_bytes)
    # counts are occurrence counts — never scaled by the weights
    assert out.count == 2
    assert out.counts_by_op["all-gather"] == 2


def test_combine_negative_weight_is_subtraction():
    a = _stats("all-reduce", 2, 1000)   # wire = 2*(1/2)*1000 = 1000
    out = CollectiveStats.combine(a, a, wa=1.0, wb=-1.0)
    assert out.wire_bytes == pytest.approx(0.0)
    assert out.count == 2  # still two observations


def test_combine_empty_is_identity_on_wire_bytes():
    a = _stats("reduce-scatter", 4, 100)  # wire = 3 * 100
    out = CollectiveStats.combine(a, CollectiveStats())
    assert out.wire_bytes == pytest.approx(a.wire_bytes)
    assert out.count == a.count


# -------------------------------------------------------------- parse_memory


SYNTHETIC_HLO = """\
HloModule test, entry_computation_layout={(f32[64,64]{1,0})->f32[64]{0}}

ENTRY main.5 {
  Arg_0.1 = f32[64,64]{1,0} parameter(0)
  exp.2 = f32[64,64]{1,0} exponential(Arg_0.1)
  c.3 = f32[] constant(0)
  ROOT reduce.4 = f32[64]{0} reduce(exp.2, c.3), dimensions={1}
}
"""


def test_parse_memory_synthetic_module():
    est = parse_memory(SYNTHETIC_HLO)
    assert est.param_bytes == 64 * 64 * 4
    assert est.output_bytes == 64 * 4
    assert est.max_temp_bytes == 64 * 64 * 4  # the exponential intermediate
    assert est.peak_bytes == est.param_bytes + est.output_bytes + est.max_temp_bytes
    assert est.op_count == 4


def test_parse_memory_max_vs_total_temp():
    text = "\n".join([
        "ENTRY m {",
        "  p.1 = f32[8]{0} parameter(0)",
        "  a.2 = f32[1024]{0} broadcast(p.1)",
        "  b.3 = f32[16]{0} slice(a.2)",
        "  ROOT r.4 = f32[16]{0} negate(b.3)",
        "}",
    ])
    est = parse_memory(text)
    assert est.max_temp_bytes == 1024 * 4
    assert est.total_temp_bytes == 1024 * 4 + 16 * 4


def test_parse_memory_on_real_lowering():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.core.feasibility import aot_memory_estimate

    x = np.zeros((32, 32), np.float32)
    est = aot_memory_estimate(lambda a, b: jnp.dot(a, b).sum(), x, x)
    assert est.param_bytes >= 2 * 32 * 32 * 4
    assert est.max_temp_bytes >= 32 * 32 * 4  # the dot product intermediate
    assert est.peak_bytes > 0
    assert est.op_count > 0


def test_parse_memory_monotone_in_input_size():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.core.feasibility import aot_memory_estimate

    def f(a):
        return jnp.tanh(a) @ jnp.tanh(a).T

    small = aot_memory_estimate(f, np.zeros((16, 16), np.float32))
    big = aot_memory_estimate(f, np.zeros((128, 128), np.float32))
    assert big.peak_bytes > small.peak_bytes


# ------------------------------------------------- async start/done pairs


def test_async_pair_counts_once_with_sync_bytes():
    # an async all-gather is a start/done pair; the -start's printed shape
    # is the tuple (operand, result) — the pair must contribute exactly the
    # sync op's count and wire bytes, not operand+result and not 2 ops
    sync = (
        "  ag.1 = f32[256,64]{1,0} all-gather(p.0), dimensions={0}, "
        "replica_groups=[2,4]<=[8]\n"
    )
    async_pair = "\n".join([
        "  ag-start.1 = (f32[64,64], f32[256,64]) all-gather-start(p.0), "
        "dimensions={0}, replica_groups=[2,4]<=[8]",
        "  ag-done.1 = f32[256,64]{1,0} all-gather-done(ag-start.1)",
        "",
    ])
    s_sync = parse_collectives(sync)
    s_async = parse_collectives(async_pair)
    assert s_sync.count == 1
    assert s_async.count == 1
    assert s_async.wire_bytes == s_sync.wire_bytes > 0
    assert dict(s_async.by_op) == dict(s_sync.by_op)


def test_async_allreduce_plain_start_shape():
    # all-reduce-start prints a plain array shape (result == operand); the
    # done line must still be skipped rather than double-counted
    sync = (
        "  ar.1 = f32[128]{0} all-reduce(p.0), to_apply=add, "
        "replica_groups=[1,8]<=[8]\n"
    )
    async_pair = "\n".join([
        "  ar-start.1 = f32[128]{0} all-reduce-start(p.0), to_apply=add, "
        "replica_groups=[1,8]<=[8]",
        "  ar-done.1 = f32[128]{0} all-reduce-done(ar-start.1)",
        "",
    ])
    s_sync = parse_collectives(sync)
    s_async = parse_collectives(async_pair)
    assert s_async.count == s_sync.count == 1
    assert s_async.wire_bytes == s_sync.wire_bytes > 0
