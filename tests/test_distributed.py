"""Distributed behaviour on a fake 8-device world (subprocess: these tests
must not pollute the main process's single-device view).

Covers: (2,2,2) pod×data×model train execution, gradient-compression path
(numerics vs uncompressed + int8 wire in HLO), serve bundles, sharding-rule
divisibility fallbacks, and the production-mesh function itself.
"""
import pytest


def test_train_step_multi_pod_exec(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro.configs.base import ShapeConfig, RunConfig
from repro.configs.archs import get_arch
from repro.distributed.steps import make_step, init_train_state
from repro.compat import set_mesh
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(model_parallel=2, pod=2)
arch = get_arch("llama3.2-1b", smoke=True)
shape = ShapeConfig("t", 32, 8, "train")
with set_mesh(mesh):
    b = make_step(arch, RunConfig(mesh_model_parallel=2), shape, mesh)
    state = init_train_state(b)
    batch = b.model.make_inputs(shape)
    state, batch = b.place(mesh, state, batch)
    fn = b.jit()
    l0 = None
    for i in range(4):
        state, m = fn(state, batch)
        l0 = l0 if l0 is not None else float(m["loss"])
    assert float(m["loss"]) < l0, (float(m["loss"]), l0)
print("TRAIN_OK")
""")
    assert "TRAIN_OK" in out


def test_grad_compression_matches_uncompressed(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro.configs.base import ShapeConfig, RunConfig
from repro.configs.archs import get_arch
from repro.distributed.steps import make_step, init_train_state
from repro.compat import set_mesh
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(model_parallel=2, pod=2)
arch = get_arch("llama3.2-1b", smoke=True)
shape = ShapeConfig("t", 32, 8, "train")
losses = {}
for comp in ["off", "int8"]:
    with set_mesh(mesh):
        b = make_step(arch, RunConfig(mesh_model_parallel=2, grad_compression=comp), shape, mesh)
        state = init_train_state(b, jax.random.PRNGKey(0))
        batch = b.model.make_inputs(shape, jax.random.PRNGKey(1))
        state, batch = b.place(mesh, state, batch)
        fn = b.jit()
        for i in range(3):
            state, m = fn(state, batch)
        losses[comp] = float(m["loss"])
        if comp == "int8":
            txt = b.lower().compile().as_text()
            n_int = sum(1 for l in txt.splitlines() if "all-reduce" in l and ("s32[" in l or "s8[" in l))
            assert n_int > 0, "no int8/int32 cross-pod all-reduce in HLO"
rel = abs(losses["off"] - losses["int8"]) / abs(losses["off"])
assert rel < 0.02, losses  # error feedback keeps trajectories close
print("COMPRESS_OK", losses)
""")
    assert "COMPRESS_OK" in out


def test_serve_bundles_with_awkward_heads(subproc):
    """gemma3 (kv=1) and whisper (6 heads) on model_parallel=4: the rules must
    fall back (sequence-partition KV / replicate heads) and still execute."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.configs.base import ShapeConfig, RunConfig
from repro.configs.archs import get_arch
from repro.distributed.steps import make_prefill_step, make_decode_step
from repro.compat import set_mesh
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(model_parallel=4)
for name in ["gemma3-1b", "whisper-tiny"]:
    arch = get_arch(name, smoke=True)
    run = RunConfig(mesh_model_parallel=4)
    with set_mesh(mesh):
        pre = make_prefill_step(arch, run, ShapeConfig("p", 32, 4, "prefill"), mesh)
        params = pre.model.init_params(jax.random.PRNGKey(0))
        batch = pre.model.make_inputs(ShapeConfig("p", 32, 4, "prefill"))
        params, batch = pre.place(mesh, params, batch)
        logits, caches = pre.jit()(params, batch)
        assert bool(jnp.all(jnp.isfinite(logits))), name
print("SERVE_OK")
""")
    assert "SERVE_OK" in out


def test_production_mesh_shapes(subproc):
    out = subproc("""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert m1.devices.shape == (16, 16) and m1.axis_names == ("data", "model")
m2 = make_production_mesh(multi_pod=True)
assert m2.devices.shape == (2, 16, 16) and m2.axis_names == ("pod", "data", "model")
print("MESH_OK")
""", devices=512)
    assert "MESH_OK" in out


def test_dryrun_cell_end_to_end(subproc):
    """One full dry-run cell (lower+compile+roofline) inside the 512-device
    world — the integration test for deliverable (e)."""
    out = subproc("""
from repro.launch.dryrun import run_cell
cell = run_cell("llama3.2-1b", "decode_32k", with_probes=True, verbose=False)
assert cell["compile_ok"]
assert cell["roofline"]["t_step_s"] > 0
assert cell["memory"]["peak_gib"] > 0
assert cell["tpu_hbm_estimate"]["fits_hbm_16gib"]
print("CELL_OK", cell["roofline"]["bottleneck"])
""", devices=512)
    assert "CELL_OK" in out
