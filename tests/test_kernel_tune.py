"""Kernel autotuning workload: the KernelEvaluator (numerics gate, fidelity,
spec round-trip), device-pinned subprocess workers, the tuned-table
round-trip into the public kernel entry points, snap idempotency, and the
honest-walltime / fidelity-detection regressions that rode along.

Worker-side functions must be module-level: the spawn start method ships
them to workers by pickle-by-reference.
"""
import json
import os
import pickle
import time

import pytest

from repro.core.evaluators import (
    FunctionEvaluator,
    WalltimeEvaluator,
    _accepts_fidelity,
)
from repro.core.executors import (
    EvaluatorSpec,
    SubprocessBackend,
    _apply_pin_guard,
    _device_pin_env,
)
from repro.core.kernel_tune import (
    DEFAULT_SHAPES,
    KERNEL_NAMES,
    KERNEL_SPACES,
    KernelEvaluator,
    kernel_platform_key,
    kernel_similarity,
    make_kernel_evaluator,
    parse_kernel_platform,
    shape_class_for,
    tuned_entry,
    write_tuned_entries,
)
from repro.core.scheduler import TrialScheduler
from repro.core.study import EngineConfig
from repro.core.transfer import parse_namespace
from repro.kernels import (
    TUNED_TABLE_ENV,
    invalidate_tuned_table_cache,
    load_tuned_table,
    shape_class_distance,
    table_key,
    tuned_config,
)


# ------------------------------------------------------- evaluator protocol


def test_kernel_evaluator_ok_path_returns_finite_time():
    ev = make_kernel_evaluator("rwkv6", (1, 64, 2, 16), repeats=1)
    t, info = ev(KERNEL_SPACES["rwkv6"].defaults())
    assert t < float("inf")
    assert info["kernel"] == "rwkv6"
    assert info["shape_class"] == "b1s64h2d16"
    assert info["max_rel_err"] < ev.tolerance
    assert "numerics_mismatch" not in info


def test_kernel_evaluator_numerics_gate_blocks_fast_wrong_variants():
    """A variant outside tolerance must return the infeasible penalty, not a
    timing — a fast-but-wrong block config can never become the incumbent."""
    ev = make_kernel_evaluator("rwkv6", (1, 64, 2, 16), repeats=1,
                               tolerance=0.0)  # nothing passes a zero gate
    t, info = ev(KERNEL_SPACES["rwkv6"].defaults())
    assert t == KernelEvaluator.INFEASIBLE
    assert info["numerics_mismatch"] is True
    assert "repeats" not in info  # gated BEFORE any timed run


def test_kernel_evaluator_fidelity_scales_repeats():
    ev = make_kernel_evaluator("rwkv6", (1, 64, 2, 16), repeats=4)
    _, full = ev(KERNEL_SPACES["rwkv6"].defaults())
    _, half = ev(KERNEL_SPACES["rwkv6"].defaults(), fidelity=0.5)
    assert full["repeats"] == 4 and "fidelity" not in full
    assert half["repeats"] == 2 and half["fidelity"] == 0.5
    assert ev.supports_fidelity and not ev.parallel_safe


def test_kernel_evaluator_oversize_blocks_snap_not_crash():
    """Proposals beyond the (padded) sequence are legal: the ops-layer snap
    clamps them, so the search space never produces a hard failure."""
    ev = make_kernel_evaluator("flash_attention", (1, 200, 2, 2, 64),
                               repeats=1)
    t, info = ev({"block_q": 1024, "block_kv": 1024})
    assert t < float("inf") and "numerics_mismatch" not in info


def test_kernel_evaluator_spec_round_trips_through_pickle():
    """Subprocess workers rebuild the evaluator from its dotted-path spec;
    device arrays must never ride along in the pickle."""
    ev = make_kernel_evaluator("ssm_scan", (1, 64, 32, 8), repeats=2, seed=7)
    ev._materialize()
    clone = pickle.loads(pickle.dumps(ev))
    assert clone._data is None  # arrays dropped at the process boundary
    assert clone.shape == ev.shape and clone.seed == 7

    rebuilt = ev.spec.resolve()
    assert isinstance(rebuilt, KernelEvaluator)
    assert (rebuilt.kernel, rebuilt.shape, rebuilt.repeats) == (
        "ssm_scan", (1, 64, 32, 8), 2)


def test_kernel_evaluator_rejects_bad_kernel_and_rank():
    with pytest.raises(ValueError, match="unknown kernel"):
        KernelEvaluator("conv2d", (1, 2, 3, 4))
    with pytest.raises(ValueError, match="dims"):
        KernelEvaluator("flash_attention", (1, 256, 4, 64))  # rank 4, not 5


# ----------------------------------------------- cells, namespace, transfer


def test_kernel_platform_key_round_trips_and_parses_as_cell():
    for kernel in KERNEL_NAMES:
        shape = DEFAULT_SHAPES[kernel][0]
        key = kernel_platform_key(kernel, "f32", shape_class_for(kernel, shape))
        assert parse_kernel_platform(key) == (
            kernel, "f32", shape_class_for(kernel, shape))
        cell = parse_namespace(key)
        assert cell.base == "kernel"
        assert cell.arch == f"{kernel}.f32"
    with pytest.raises(ValueError):
        parse_kernel_platform("wordcount")


def test_kernel_similarity_within_kernel_finite_across_infinite():
    flash_256 = parse_namespace(kernel_platform_key(
        "flash_attention", "f32", "b2s256h4k2d64"))
    flash_512 = parse_namespace(kernel_platform_key(
        "flash_attention", "f32", "b2s512h4k2d64"))
    rwkv = parse_namespace(kernel_platform_key("rwkv6", "f32", "b2s256h4d64"))
    flash_bf16 = parse_namespace(kernel_platform_key(
        "flash_attention", "bf16", "b2s256h4k2d64"))
    assert kernel_similarity(flash_256, flash_512) == 1.0  # one octave in s
    assert kernel_similarity(flash_256, flash_256) == 0.0
    assert kernel_similarity(flash_256, rwkv) == float("inf")
    assert kernel_similarity(flash_256, flash_bf16) == float("inf")


def test_shape_class_distance_dim_alphabets_must_match():
    assert shape_class_distance("b2s256h4d64", "b2s512h4d64") == 1.0
    assert shape_class_distance("b2s256h4d64", "b2s256di64n8") == float("inf")


# --------------------------------------------------- tuned table round-trip


def test_tuned_table_write_then_kernels_pick_it_up(tmp_path, monkeypatch):
    """A Study-tuned incumbent written to the table is consulted at call
    time by the public entry point when no explicit blocks are passed."""
    table = tmp_path / "tuned_table.json"
    write_tuned_entries(tuned_entry(
        "rwkv6", "f32", "b1s96h2d32", {"chunk": 16, "junk_knob": 9},
        time_s=0.01, source="test"), table)
    doc = json.loads(table.read_text())
    assert doc["version"] == 1
    rec = doc["entries"]["rwkv6|f32|b1s96h2d32"]
    assert rec["config"] == {"chunk": 16}  # knobs outside the space filtered

    monkeypatch.setenv(TUNED_TABLE_ENV, str(table))
    invalidate_tuned_table_cache()
    try:
        # exact hit, nearest same-kernel fallback, cross-kernel miss
        assert tuned_config("rwkv6", "f32", "b1s96h2d32") == {"chunk": 16}
        assert tuned_config("rwkv6", "f32", "b1s192h2d32") == {"chunk": 16}
        assert tuned_config("ssm_scan", "f32", "b1s96di2n32") is None

        import jax.numpy as jnp
        from unittest import mock

        from repro.kernels.rwkv6 import ops as rwkv_ops

        r = jnp.zeros((1, 96, 2, 32), jnp.float32)
        u = jnp.zeros((2, 32), jnp.float32)
        with mock.patch.object(rwkv_ops, "wkv6_chunked",
                               wraps=rwkv_ops.wkv6_chunked) as spy:
            rwkv_ops.wkv6(r, r, r, -jnp.ones_like(r), u, interpret=True)
            assert spy.call_args.kwargs["chunk"] == 16  # tuned value
            rwkv_ops.wkv6(r, r, r, -jnp.ones_like(r), u, chunk=64,
                          interpret=True)
            assert spy.call_args.kwargs["chunk"] == 64  # explicit arg wins
    finally:
        invalidate_tuned_table_cache()


def test_corrupt_tuned_table_warns_and_falls_back(tmp_path):
    bad = tmp_path / "tuned_table.json"
    bad.write_text("{not json")
    with pytest.warns(RuntimeWarning, match="tuned"):
        assert load_tuned_table(bad) == {}
    assert tuned_config("rwkv6", "f32", "b1s96h2d32", path=bad) is None


def test_missing_tuned_table_is_silently_empty(tmp_path):
    assert load_tuned_table(tmp_path / "nope.json") == {}


def test_write_tuned_entries_merges_and_invalidates(tmp_path):
    table = tmp_path / "t.json"
    write_tuned_entries(tuned_entry(
        "rwkv6", "f32", "b1s64h2d16", {"chunk": 32}, 0.1, "a"), table)
    assert tuned_config("rwkv6", "f32", "b1s64h2d16", path=table) == {
        "chunk": 32}
    # second write merges (old key survives) and the cache sees the update
    write_tuned_entries(tuned_entry(
        "rwkv6", "f32", "b1s64h2d16", {"chunk": 64}, 0.05, "b"), table)
    assert tuned_config("rwkv6", "f32", "b1s64h2d16", path=table) == {
        "chunk": 64}
    assert set(load_tuned_table(table)) == {table_key(
        "rwkv6", "f32", "b1s64h2d16")}


def test_shipped_tuned_table_is_valid_and_covers_all_kernels():
    """The checked-in artifact must load and carry an incumbent for every
    kernel (the acceptance round-trip the CI smoke exercises)."""
    invalidate_tuned_table_cache()
    entries = load_tuned_table()
    kernels = {key.split("|")[0] for key in entries}
    assert kernels == set(KERNEL_NAMES)
    for rec in entries.values():
        assert rec["config"] and rec["time_s"] > 0


# -------------------------------------------------------- snap idempotency


def test_snap_block_idempotent_and_clamps_to_padded_length():
    from repro.kernels.flash_attention.ops import snap_block

    # 128-snap first, then clamp to the 128-PADDED sequence — never below
    assert snap_block(100, 512) == 128      # floor at one MXU tile
    assert snap_block(512, 512) == 512
    assert snap_block(1024, 256) == 256     # clamped to padded s
    assert snap_block(256, 200) == 256      # padded(200)=256: NOT de-aligned
    assert snap_block(300, 512) == 256      # down-snap to a 128 multiple
    for block in (1, 100, 128, 200, 256, 1024):
        for s in (64, 200, 256, 512):
            once = snap_block(block, s)
            assert snap_block(once, s) == once
            assert once % 128 == 0


def test_snap_chunk_idempotent_both_kernels():
    from repro.kernels.rwkv6.ops import snap_chunk as rwkv_snap
    from repro.kernels.ssm_scan.ops import snap_chunk as ssm_snap

    for snap in (rwkv_snap, ssm_snap):
        assert snap(256, 160) == 160  # clamp to T
        assert snap(64, 160) == 64
        assert snap(0, 160) == 1
        for chunk in (1, 16, 64, 256):
            for s in (7, 96, 160, 512):
                once = snap(chunk, s)
                assert snap(once, s) == once and 1 <= once <= s


def test_snap_d_block_idempotent_and_divides():
    from repro.kernels.ssm_scan.ops import snap_d_block

    assert snap_d_block(1024, 64) == 64
    assert snap_d_block(128, 96) == 32  # halves until it divides
    for d_block in (16, 48, 256, 1024):
        for di in (32, 64, 96):
            once = snap_d_block(d_block, di)
            assert snap_d_block(once, di) == once
            assert di % once == 0


# ------------------------------------------------ satellite: device pinning


def test_pin_env_narrows_existing_cuda_list(monkeypatch):
    monkeypatch.setenv("CUDA_VISIBLE_DEVICES", "3, 5,7")
    assert _device_pin_env(1, 3) == {"CUDA_VISIBLE_DEVICES": "5"}
    assert _device_pin_env(4, 3) == {"CUDA_VISIBLE_DEVICES": "5"}  # wraps


def test_pin_env_gpu_platform_uses_slot_index(monkeypatch):
    monkeypatch.delenv("CUDA_VISIBLE_DEVICES", raising=False)
    monkeypatch.setenv("JAX_PLATFORMS", "cuda")
    assert _device_pin_env(2, 4) == {"CUDA_VISIBLE_DEVICES": "2"}


def test_pin_env_tpu_bounds_one_chip_per_process(monkeypatch):
    monkeypatch.delenv("CUDA_VISIBLE_DEVICES", raising=False)
    monkeypatch.setenv("JAX_PLATFORMS", "tpu")
    env = _device_pin_env(3, 4)
    assert env["TPU_VISIBLE_CHIPS"] == "3"
    assert env["TPU_PROCESS_BOUNDS"] == "1,1,1"


def test_pin_env_cpu_fallback_strips_inherited_device_count(monkeypatch):
    monkeypatch.delenv("CUDA_VISIBLE_DEVICES", raising=False)
    monkeypatch.delenv("JAX_PLATFORMS", raising=False)
    monkeypatch.delenv("TPU_WORKER_ID", raising=False)
    monkeypatch.setenv(
        "XLA_FLAGS",
        "--xla_foo=1 --xla_force_host_platform_device_count=512")
    env = _device_pin_env(0, 2)
    assert env["JAX_PLATFORMS"] == "cpu"
    assert "device_count=512" not in env["XLA_FLAGS"]
    assert "--xla_force_host_platform_device_count=1" in env["XLA_FLAGS"]
    assert "--xla_foo=1" in env["XLA_FLAGS"]  # unrelated flags survive


def test_pin_guard_passes_without_jax_or_pin():
    assert _apply_pin_guard(None) is None
    assert _apply_pin_guard({}) is None


def _pin_probe(cfg):
    """Worker-side: 1.0 iff the CPU pin env took before this process ran."""
    ok = (os.environ.get("JAX_PLATFORMS") == "cpu"
          and "--xla_force_host_platform_device_count=1"
          in os.environ.get("XLA_FLAGS", ""))
    return 1.0 if ok else 0.0


def make_pin_probe_evaluator():
    return FunctionEvaluator(_pin_probe)


def test_pinned_workers_see_pin_env_and_distinct_slots():
    backend = SubprocessBackend(
        spec=EvaluatorSpec.factory("test_kernel_tune:make_pin_probe_evaluator"),
        pin_devices=2,
    )
    with TrialScheduler(FunctionEvaluator(_pin_probe), backend=backend,
                        max_workers=2) as sched:
        trials = sched.evaluate_batch([{"x": i} for i in range(4)])
        slots = {w.pin_slot for w in backend._workers}
    assert [t.time_s for t in trials] == [1.0] * 4  # env inside every worker
    assert slots == {0, 1}  # round-robin over distinct device slots


def test_unpinned_workers_do_not_get_pin_env():
    backend = SubprocessBackend(
        spec=EvaluatorSpec.factory("test_kernel_tune:make_pin_probe_evaluator"),
    )
    with TrialScheduler(FunctionEvaluator(_pin_probe), backend=backend,
                        max_workers=1) as sched:
        trial = sched.evaluate_batch([{"x": 0}])[0]
    assert trial.time_s == 0.0  # no pin requested -> env untouched


def test_pin_devices_validation():
    with pytest.raises(ValueError, match="positive"):
        SubprocessBackend(pin_devices=0)
    with pytest.raises(ValueError, match="subprocess"):
        TrialScheduler(FunctionEvaluator(_pin_probe), pin_devices=2)
    with pytest.raises(ValueError, match="subprocess"):
        EngineConfig(pin_devices=2)
    with pytest.raises(ValueError, match="pin_devices"):
        EngineConfig(isolation="subprocess", pin_devices=0)
    cfg = EngineConfig(isolation="subprocess", pin_devices=2)
    assert cfg.scheduler_kwargs()["pin_devices"] == 2


# ---------------------------- satellite: honest async walltime measurement


class _LazyResult:
    """Mimics a jax array mid-flight: the work only 'finishes' when someone
    blocks on it."""

    def __init__(self, delay_s):
        self.delay_s = delay_s

    def block_until_ready(self):
        time.sleep(self.delay_s)
        return self


def test_walltime_evaluator_blocks_on_async_results():
    """Async dispatch returns immediately; an evaluator that doesn't block
    times the enqueue (~0s) instead of the work. The measured time must
    include the materialization delay."""
    delay = 0.05
    ev = WalltimeEvaluator(lambda cfg: (lambda: _LazyResult(delay)), repeats=1)
    t, _ = ev({})
    assert t >= delay * 0.9, t


def test_walltime_evaluator_tolerates_none_and_scalar_returns():
    t_none, _ = WalltimeEvaluator(lambda cfg: (lambda: None), repeats=1)({})
    t_scalar, _ = WalltimeEvaluator(lambda cfg: (lambda: 42.0), repeats=1)({})
    assert t_none < 1.0 and t_scalar < 1.0


# ------------------------------- satellite: fidelity detection regression


def test_accepts_fidelity_rejects_bare_var_keyword():
    """**kwargs would silently swallow fidelity=, run the full job, and get
    ranked by ASHA under a low-fidelity key — it must NOT qualify."""

    def swallows_everything(cfg, **kwargs):
        return 1.0

    def explicit(cfg, fidelity=1.0):
        return 1.0

    def keyword_only(cfg, *, fidelity):
        return 1.0

    def plain(cfg):
        return 1.0

    assert not _accepts_fidelity(swallows_everything)
    assert _accepts_fidelity(explicit)
    assert _accepts_fidelity(keyword_only)
    assert not _accepts_fidelity(plain)
    assert not _accepts_fidelity(len)  # C callable: no signature, no crash


def test_accepts_fidelity_opt_in_attribute_for_forwarding_wrappers():
    def wrapper(cfg, **kwargs):
        return 1.0

    wrapper.accepts_fidelity = True
    assert _accepts_fidelity(wrapper)
    assert FunctionEvaluator(wrapper).supports_fidelity


def test_function_evaluator_never_leaks_fidelity_into_plain_fn():
    seen = []

    def plain(cfg):
        seen.append(cfg)
        return 1.0

    ev = FunctionEvaluator(plain)
    assert not ev.supports_fidelity
    ev({"x": 1}, fidelity=0.25)  # swallowed by the evaluator, not the fn
    assert seen == [{"x": 1}]
