"""Data pipeline: determinism, restart-resume, label alignment, prefetch."""
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import get_arch
from repro.configs.base import ShapeConfig
from repro.data.pipeline import PipelineConfig, SyntheticLMPipeline

ARCH = get_arch("llama3.2-1b", smoke=True)
SHAPE = ShapeConfig("t", 16, 4, "train")


def _batches(pipeline, n):
    return list(pipeline.take(n))


def test_deterministic_across_instances():
    a = _batches(SyntheticLMPipeline(ARCH, SHAPE, PipelineConfig(seed=7)), 3)
    b = _batches(SyntheticLMPipeline(ARCH, SHAPE, PipelineConfig(seed=7)), 3)
    for x, y in zip(a, b):
        assert jnp.array_equal(x["tokens"], y["tokens"])
        assert jnp.array_equal(x["labels"], y["labels"])


def test_different_seeds_differ():
    a = _batches(SyntheticLMPipeline(ARCH, SHAPE, PipelineConfig(seed=1)), 1)[0]
    b = _batches(SyntheticLMPipeline(ARCH, SHAPE, PipelineConfig(seed=2)), 1)[0]
    assert not jnp.array_equal(a["tokens"], b["tokens"])


def test_restart_resume_reproduces_stream():
    p = SyntheticLMPipeline(ARCH, SHAPE, PipelineConfig(seed=3))
    full = _batches(p, 5)
    q = SyntheticLMPipeline(ARCH, SHAPE, PipelineConfig(seed=3))
    q.load_state_dict({"step": 3, "seed": 3})
    resumed = _batches(q, 2)
    for x, y in zip(full[3:], resumed):
        assert jnp.array_equal(x["tokens"], y["tokens"])


def test_labels_are_shifted_tokens():
    b = _batches(SyntheticLMPipeline(ARCH, SHAPE, PipelineConfig(seed=0)), 1)[0]
    # tokens[t+1] == labels[t] for the shared positions (same underlying stream)
    assert jnp.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert b["tokens"].dtype == jnp.int32
    assert int(b["tokens"].max()) < ARCH.vocab_size


def test_frontend_inputs_present():
    vlm = get_arch("internvl2-26b", smoke=True)
    b = _batches(SyntheticLMPipeline(vlm, SHAPE, PipelineConfig()), 1)[0]
    assert b["patches"].shape == (4, vlm.frontend_seq, vlm.d_model)
