"""Per-architecture smoke tests: reduced config, one real train / prefill /
decode step on CPU, asserting output shapes and finiteness (deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.archs import ARCH_NAMES, FULL, SMOKE, get_arch
from repro.configs.base import RunConfig, ShapeConfig
from repro.models.model import Model
from repro.models.transformer import structural_period

RUN = RunConfig()
TRAIN = ShapeConfig("t", 32, 2, "train")
PREFILL = ShapeConfig("p", 32, 2, "prefill")


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_smoke(name):
    arch = get_arch(name, smoke=True)
    m = Model(arch, RUN)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = m.make_inputs(TRAIN)
    loss, metrics = jax.jit(lambda p, b: m.loss(p, b))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), (name, loss)
    assert jnp.isfinite(metrics["ce"])


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_and_decode_smoke(name):
    arch = get_arch(name, smoke=True)
    m = Model(arch, RUN)
    params = m.init_params(jax.random.PRNGKey(0))
    logits, caches = jax.jit(lambda p, b: m.prefill(p, b))(params, m.make_inputs(PREFILL))
    assert logits.shape == (2, arch.padded_vocab)
    assert jnp.all(jnp.isfinite(logits)), name
    batch = {"tokens": jnp.ones((2, 1), jnp.int32),
             "cache_len": jnp.asarray(31, jnp.int32)}
    dlogits, new_caches = jax.jit(lambda p, c, b: m.decode_step(p, c, b))(params, caches, batch)
    assert dlogits.shape == (2, arch.padded_vocab)
    assert jnp.all(jnp.isfinite(dlogits)), name
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_matches_assignment(name):
    """The FULL configs carry the assignment sheet's numbers exactly."""
    arch = FULL[name]
    sheet = {
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "rwkv6-7b": (32, 4096, None, None, 14336, 65536),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    }[name]
    layers, d, hq, hkv, ff, vocab = sheet
    assert arch.num_layers == layers
    assert arch.d_model == d
    if hq is not None:
        assert arch.num_heads == hq and arch.num_kv_heads == hkv
    assert arch.d_ff == ff and arch.vocab_size == vocab
    # structural coherence: the scan decomposition must tile the stack
    assert arch.num_layers % structural_period(arch) == 0


def test_moe_configs():
    a = FULL["llama4-maverick-400b-a17b"]
    assert a.num_experts == 128 and a.experts_per_token == 1
    b = FULL["phi3.5-moe-42b-a6.6b"]
    assert b.num_experts == 16 and b.experts_per_token == 2
    j = FULL["jamba-1.5-large-398b"]
    assert j.num_experts == 16 and j.experts_per_token == 2
    # jamba interleave: 1 attn : 7 mamba
    kinds = [k for k, _ in j.layer_kinds()]
    assert kinds[:8] == ["attn"] + ["mamba"] * 7


def test_param_counts_in_range():
    """Analytic parameter counts land near the marketing sizes."""
    approx = {
        "qwen2-72b": (65e9, 80e9),
        "llama3.2-1b": (1.0e9, 1.6e9),
        "gemma2-9b": (8e9, 11e9),
        "jamba-1.5-large-398b": (350e9, 440e9),
        "llama4-maverick-400b-a17b": (350e9, 450e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        "rwkv6-7b": (6e9, 9e9),
        "gemma3-1b": (0.8e9, 1.5e9),
    }
    for name, (lo, hi) in approx.items():
        n = FULL[name].param_count()
        assert lo <= n <= hi, (name, f"{n:.3g}")


def test_active_params_moe():
    a = FULL["llama4-maverick-400b-a17b"]
    assert a.active_param_count() < 0.12 * a.param_count()
    p = FULL["phi3.5-moe-42b-a6.6b"]
    assert 0.1 < p.active_param_count() / p.param_count() < 0.35
