"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret=True
executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv6.ops import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref
from repro.kernels.ssm_scan.ops import selective_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref


# ------------------------------------------------------------ flash attention


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("b,s,hq,hkv,dh,causal,window,cap,bq,bkv", [
    (2, 256, 4, 2, 64, True, 0, 0.0, 128, 128),
    (1, 384, 4, 1, 128, True, 128, 50.0, 128, 128),
    (2, 256, 8, 8, 64, False, 0, 0.0, 128, 256),
    (1, 200, 4, 2, 64, True, 0, 0.0, 128, 128),   # padded tail
    (1, 512, 2, 2, 64, True, 0, 0.0, 256, 128),   # asymmetric tiles
])
def test_flash_attention_sweep(dtype, tol, b, s, hq, hkv, dh, causal, window, cap, bq, bkv):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype)
    out = flash_attention(
        q.astype(jnp.float32) * dh**-0.5, k, v, causal=causal, window=window,
        softcap_val=cap, block_q=bq, block_kv=bkv, interpret=True,
    )
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=cap)
    err = jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))
    assert err < tol, float(err)


def _space_edges(kernel):
    """(min, max) knob configs at the TunableSpace bounds — exactly what the
    tuner's pow2 grids can propose at their extremes."""
    from repro.core.kernel_tune import KERNEL_SPACES

    space = KERNEL_SPACES[kernel]
    los = {p.name: p.lo for p in space.params}
    his = {p.name: p.hi for p in space.params}
    return [space.snap(los), space.snap(his)]


@pytest.mark.parametrize("config", _space_edges("flash_attention"))
@pytest.mark.parametrize("b,s,hq,hkv,dh", [
    (1, 200, 2, 2, 64),   # non-dividing: padded tail under every block size
    (1, 256, 2, 2, 64),
])
def test_flash_attention_parity_at_space_edges(config, b, s, hq, hkv, dh):
    """Every proposal the tuner's grid can emit — min/max blocks, blocks far
    beyond the sequence — must stay numerically exact through the public
    entry point's snap/clamp."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (b, s, hq, dh)) * dh**-0.5
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    out = flash_attention(q, k, v, causal=True, interpret=True, **config)
    ref = attention_ref(q, k, v, causal=True, scale=1.0)
    assert jnp.max(jnp.abs(out - ref)) < 1e-5


@pytest.mark.parametrize("config", _space_edges("rwkv6"))
def test_wkv6_parity_at_space_edges(config):
    b, s, h, hd = 1, 48, 2, 32  # chunk hi=64 > s: clamp-to-T must handle it
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    r, k, v = (0.5 * jax.random.normal(ks[i], (b, s, h, hd)) for i in range(3))
    logw = -jnp.exp(0.3 * jax.random.normal(ks[3], (b, s, h, hd)))
    u = 0.3 * jax.random.normal(ks[4], (h, hd))
    out = wkv6(r, k, v, logw, u, interpret=True, **config)
    ref = wkv6_ref(r, k, v, logw, u)
    rel = jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9)
    assert rel < 1e-4, float(rel)


@pytest.mark.parametrize("config", _space_edges("ssm_scan"))
def test_ssm_scan_parity_at_space_edges(config):
    b, s, di, n = 1, 100, 48, 8  # s non-dividing, d_block hi=1024 > di
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, di)))
    u = jax.random.normal(ks[1], (b, s, di))
    bt = jax.random.normal(ks[2], (b, s, n))
    ct = jax.random.normal(ks[3], (b, s, n))
    a = -jnp.exp(0.3 * jax.random.normal(ks[4], (di, n)))
    y = selective_scan(dt, u, bt, ct, a, interpret=True, **config)
    ref = ssm_scan_ref(dt, u, bt, ct, a)
    rel = jnp.max(jnp.abs(y - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9)
    assert rel < 1e-4, float(rel)


def test_flash_attention_rejects_traced_window():
    q = jnp.zeros((1, 128, 2, 64))
    with pytest.raises(ValueError):
        jax.jit(lambda w: flash_attention(q, q, q, window=w, interpret=True))(
            jnp.asarray(4)
        )


# ---------------------------------------------------------------------- wkv6


@pytest.mark.parametrize("chunk", [32, 64])
@pytest.mark.parametrize("b,s,h,hd", [(2, 160, 3, 32), (1, 64, 2, 64), (1, 130, 1, 16)])
def test_wkv6_sweep(chunk, b, s, h, hd):
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r, k, v = (0.5 * jax.random.normal(ks[i], (b, s, h, hd)) for i in range(3))
    logw = -jnp.exp(0.3 * jax.random.normal(ks[3], (b, s, h, hd)))
    u = 0.3 * jax.random.normal(ks[4], (h, hd))
    out = wkv6(r, k, v, logw, u, chunk=chunk, interpret=True)
    ref = wkv6_ref(r, k, v, logw, u)
    rel = jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9)
    assert rel < 1e-4, float(rel)


def test_wkv6_matches_model_path():
    """The kernel math must agree with the chunked lax.scan used inside
    repro.models.rwkv6.time_mix (same factorization)."""
    from repro.configs.archs import get_arch
    from repro.models import rwkv6 as model_rwkv

    arch = get_arch("rwkv6-7b", smoke=True)
    b, s, d = 2, 96, arch.d_model
    h, hd = d // arch.rwkv_head_dim, arch.rwkv_head_dim
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    r, k, v = (0.5 * jax.random.normal(ks[i], (b, s, h, hd)) for i in range(3))
    logw = -jnp.exp(0.3 * jax.random.normal(ks[3], (b, s, h, hd)))
    u = 0.3 * jax.random.normal(ks[4], (h, hd))
    out_kernel = wkv6(r, k, v, logw, u, chunk=32, interpret=True)
    out_ref = wkv6_ref(r, k, v, logw, u)
    assert jnp.max(jnp.abs(out_kernel - out_ref)) / (jnp.max(jnp.abs(out_ref)) + 1e-9) < 1e-4


# ------------------------------------------------------------------ ssm scan


@pytest.mark.parametrize("chunk,dblk", [(32, 32), (64, 16)])
@pytest.mark.parametrize("b,s,di,n", [(2, 100, 64, 8), (1, 64, 32, 16)])
def test_ssm_scan_sweep(chunk, dblk, b, s, di, n):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, di)))
    u = jax.random.normal(ks[1], (b, s, di))
    bt = jax.random.normal(ks[2], (b, s, n))
    ct = jax.random.normal(ks[3], (b, s, n))
    a = -jnp.exp(0.3 * jax.random.normal(ks[4], (di, n)))
    y = selective_scan(dt, u, bt, ct, a, chunk=chunk, d_block=dblk, interpret=True)
    ref = ssm_scan_ref(dt, u, bt, ct, a)
    rel = jnp.max(jnp.abs(y - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9)
    assert rel < 1e-4, float(rel)
