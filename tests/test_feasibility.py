"""The static feasibility gate (repro.core.feasibility): per-space rejection
rules, the TrialScheduler prefilter seam (rejections recorded / persisted /
replayed, never charged a worker or counted as an evaluation), Study-level
accounting, and property tests over the kernel footprint models."""
import json
from pathlib import Path

import pytest

from repro.core import EngineConfig, Study
from repro.core.evaluators import FunctionEvaluator
from repro.core.feasibility import (
    PREFILTER_MODES,
    Rejection,
    StaticPrefilter,
    VMEM_BUDGET,
    make_prefilter,
)
from repro.core.scheduler import TrialScheduler

from _hyp import HAVE_HYPOTHESIS, given, settings, st

FLASH_PLAT = "kernel/flash_attention.f32:b2s256h4k2d64"
RWKV_PLAT = "kernel/rwkv6.f32:b1s48h2d32"
SSM_PLAT = "kernel/ssm_scan.f32:b1s128di64n8"


def flash_time(config):
    return 1.0 + config["block_q"] / 1e5 + config["block_kv"] / 1e5, {}


# ------------------------------------------------------------ make_prefilter


def test_make_prefilter_modes():
    assert make_prefilter("off") is None
    assert make_prefilter(None) is None
    assert isinstance(make_prefilter("static"), StaticPrefilter)
    with pytest.raises(ValueError):
        make_prefilter("bogus")
    assert set(PREFILTER_MODES) == {"off", "static"}


def test_engine_config_validates_prefilter():
    assert EngineConfig(prefilter="static").prefilter == "static"
    with pytest.raises(ValueError):
        EngineConfig(prefilter="bogus")


# ------------------------------------------------------------- kernel rules


def test_flash_snap_alias_rejected():
    pf = StaticPrefilter()
    r = pf({"block_q": 1024, "block_kv": 128}, FLASH_PLAT)
    assert isinstance(r, Rejection)
    assert r.rule == "snap_alias"
    assert r.detail["param"] == "block_q"
    assert r.detail["proposed"] == 1024
    assert r.detail["effective"] == 256  # snapped to the padded seq


def test_flash_legal_config_passes():
    pf = StaticPrefilter()
    assert pf({"block_q": 128, "block_kv": 256}, FLASH_PLAT) is None


def test_rwkv6_chunk_alias_rejected():
    pf = StaticPrefilter()
    r = pf({"chunk": 64}, RWKV_PLAT)  # T=48 < 64 -> clamps
    assert r is not None and r.rule == "snap_alias"
    assert pf({"chunk": 32}, RWKV_PLAT) is None


def test_ssm_d_block_alias_rejected():
    pf = StaticPrefilter()
    r = pf({"chunk": 64, "d_block": 1024}, SSM_PLAT)  # di=64 -> halves to 64
    assert r is not None and r.rule == "snap_alias"
    assert r.detail["param"] == "d_block"
    r2 = pf({"chunk": 256, "d_block": 64}, SSM_PLAT)  # s=128 -> chunk clamps
    assert r2 is not None and r2.detail["param"] == "chunk"
    assert pf({"chunk": 64, "d_block": 64}, SSM_PLAT) is None


def test_vmem_budget_rejection():
    # a tiny budget makes even the minimal legal config overflow
    pf = StaticPrefilter(vmem_budget=1024)
    r = pf({"block_q": 128, "block_kv": 128}, FLASH_PLAT)
    assert r is not None and r.rule == "vmem_budget"
    assert r.detail["vmem_est_bytes"] > r.detail["vmem_budget_bytes"] == 1024


def test_unknown_platform_passes_clean():
    pf = StaticPrefilter()
    assert pf({"anything": 1}, "mystery/unknown:cell") is None
    assert pf({"block_q": 10 ** 9}, "kernel/not-a-kernel") is None


# ---------------------------------------------------------- wordcount rules


def test_wordcount_sort_buffer_clamp_alias():
    pf = StaticPrefilter()
    r = pf({"block_tokens": 4096, "sort_buffer_tokens": 65536}, "wordcount")
    assert r is not None and r.rule == "snap_alias"
    assert r.detail == {
        "param": "sort_buffer_tokens", "proposed": 65536, "effective": 4096,
    }
    assert pf({"block_tokens": 65536, "sort_buffer_tokens": 4096},
              "wordcount") is None


# ----------------------------------------------------------- roofline rules


def test_mesh_divisibility_rejection():
    pf = StaticPrefilter()
    r = pf({"mesh_model_parallel": 3}, "train/llama3.2-1b:train_4k")
    assert r is not None and r.rule == "mesh_divisibility"
    assert r.detail == {"mesh_model_parallel": 3, "chips": 256}
    assert pf({"mesh_model_parallel": 8}, "train/llama3.2-1b:train_4k") is None


def test_hbm_budget_rejection_on_tiny_topology():
    pf = StaticPrefilter()
    # a 72B model with no model parallelism on 4 chips cannot fit 16 GiB
    r = pf({"mesh_model_parallel": 1}, "train/qwen2-72b:train_4k@4c")
    assert r is not None and r.rule == "hbm_budget"
    assert r.detail["hbm_est_gib"] > r.detail["hbm_budget_gib"]


def test_roofline_unknown_cell_passes():
    pf = StaticPrefilter()
    assert pf({"mesh_model_parallel": 3}, "train/not-an-arch:train_4k") is None


# -------------------------------------------------- scheduler prefilter seam


def test_scheduler_rejects_without_calling_evaluator(tmp_path):
    calls = []

    def ev(config):
        calls.append(dict(config))
        return flash_time(config)

    s = TrialScheduler(ev, platform=FLASH_PLAT,
                       cache_path=tmp_path / "c.jsonl", prefilter="static")
    trials = s.evaluate_batch([
        {"block_q": 128, "block_kv": 128},
        {"block_q": 1024, "block_kv": 128},  # snap alias -> rejected
    ])
    ok = [t for t in trials if t.ok]
    rejected = [t for t in trials if t.status == "infeasible_static"]
    assert len(ok) == 1 and len(rejected) == 1
    # the doomed config never reached the evaluator
    assert calls == [{"block_q": 128, "block_kv": 128}]
    r = rejected[0]
    assert r.source == "prefilter"
    assert r.info["prefilter_rule"] == "snap_alias"
    assert "InfeasibleStatic[snap_alias]" in r.error
    assert r.wall_s == 0.0
    assert r.score == float("inf")  # strategies see an infeasible penalty


def test_scheduler_accounting_excludes_rejections(tmp_path):
    s = TrialScheduler(lambda c: flash_time(c), platform=FLASH_PLAT,
                       prefilter="static")
    s.evaluate_batch([
        {"block_q": 128, "block_kv": 128},
        {"block_q": 1024, "block_kv": 128},
        {"block_q": 1024, "block_kv": 1024},
    ])
    stats = s.stats_snapshot()
    assert stats["infeasible_static"] == 2
    assert stats["evaluations"] == 1  # rejections never count as evaluations
    assert stats["fresh"] == 1
    assert stats["timeouts"] == 0 and stats["errors"] == 0


def test_rejection_replays_from_cache_on_resume(tmp_path):
    cache = tmp_path / "c.jsonl"
    s1 = TrialScheduler(lambda c: flash_time(c), platform=FLASH_PLAT,
                        cache_path=cache, prefilter="static")
    s1.evaluate_batch([{"block_q": 1024, "block_kv": 128}])

    s2 = TrialScheduler(lambda c: flash_time(c), platform=FLASH_PLAT,
                        cache_path=cache, prefilter="static")
    [t] = s2.evaluate_batch([{"block_q": 1024, "block_kv": 128}])
    assert t.status == "infeasible_static"
    assert t.source == "cache"
    stats = s2.stats_snapshot()
    assert stats["fresh"] == 0
    assert stats["cache_hits"] == 1
    assert stats["infeasible_static"] == 1
    assert stats["evaluations"] == 0


def test_gate_off_run_measures_stored_rejections_for_real(tmp_path):
    """A --prefilter off session must never inherit another session's static
    rejection from a shared cache — it measures the config for real."""
    cache = tmp_path / "c.jsonl"
    s1 = TrialScheduler(lambda c: flash_time(c), platform=FLASH_PLAT,
                        cache_path=cache, prefilter="static")
    s1.evaluate_batch([{"block_q": 1024, "block_kv": 128}])

    s2 = TrialScheduler(lambda c: flash_time(c), platform=FLASH_PLAT,
                        cache_path=cache)  # no prefilter
    [t] = s2.evaluate_batch([{"block_q": 1024, "block_kv": 128}])
    assert t.ok and t.source == "fresh"
    assert s2.stats_snapshot()["infeasible_static"] == 0


def test_submit_path_rejects_too(tmp_path):
    s = TrialScheduler(lambda c: flash_time(c), platform=FLASH_PLAT,
                       prefilter="static")
    ticket = s.submit({"block_q": 1024, "block_kv": 128})
    done = s.poll()
    assert [(t, trial.status) for t, trial in done] == \
        [(ticket, "infeasible_static")]
    assert s.stats_snapshot()["evaluations"] == 0


# -------------------------------------------------------- study accounting


def test_study_outcome_reports_infeasible_static(tmp_path):
    study = Study(engine=EngineConfig(prefilter="static"),
                  cache_path=tmp_path / "cache.jsonl")
    from repro.apps.wordcount import WORDCOUNT_SPACE

    def wc_time(config):
        return 1.0 + config["block_tokens"] / 1e6, {}

    with study:
        outcome = study.optimize(
            "wordcount", "random", wc_time,
            space=WORDCOUNT_SPACE, budget=24, seed=3,
        )
    s = outcome.summary()
    # the random walk over the space proposes at least one clamp alias
    assert outcome.infeasible_static >= 1
    assert s["infeasible_static"] == outcome.infeasible_static
    # evaluations never include rejected proposals: the counter tracks only
    # configs that were actually measured (or replayed)
    assert s["evaluations"] <= 24
    assert s["evaluations"] == outcome.cache_stats["fresh"] + \
        outcome.cache_stats["memo_hits"] + outcome.cache_stats["cache_hits"]


def test_outcome_summary_omits_zero_counter(tmp_path):
    study = Study(engine=EngineConfig(),  # prefilter off
                  cache_path=tmp_path / "cache.jsonl")
    with study:
        outcome = study.optimize(
            "wordcount", "random",
            lambda c: (1.0 + c["block_tokens"] / 1e6, {}),
            space=__import__("repro.apps.wordcount",
                             fromlist=["WORDCOUNT_SPACE"]).WORDCOUNT_SPACE,
            budget=6, seed=3,
        )
    assert outcome.infeasible_static == 0
    assert "infeasible_static" not in outcome.summary()


# ---------------------------------------------------------- property tests


@settings(max_examples=60, deadline=None)
@given(
    bq=st.sampled_from([128, 256, 512, 1024]),
    bkv=st.sampled_from([128, 256, 512, 1024]),
    dh=st.sampled_from([32, 64, 128]),
)
def test_flash_footprint_monotone_in_blocks(bq, bkv, dh):
    from repro.kernels.flash_attention.ops import vmem_footprint

    base = vmem_footprint(bq, bkv, dh)
    assert base > 0
    assert vmem_footprint(bq * 2, bkv, dh) > base
    assert vmem_footprint(bq, bkv * 2, dh) > base
    assert vmem_footprint(bq, bkv, dh * 2) > base


@settings(max_examples=60, deadline=None)
@given(
    chunk=st.sampled_from([16, 32, 64, 128]),
    hd=st.sampled_from([32, 64]),
)
def test_rwkv6_footprint_monotone(chunk, hd):
    from repro.kernels.rwkv6.ops import vmem_footprint

    assert vmem_footprint(chunk * 2, hd) > vmem_footprint(chunk, hd) > 0
    assert vmem_footprint(chunk, hd * 2) > vmem_footprint(chunk, hd)


@settings(max_examples=60, deadline=None)
@given(
    chunk=st.sampled_from([16, 64, 256]),
    d_block=st.sampled_from([16, 64, 256, 1024]),
    n=st.sampled_from([8, 16]),
)
def test_ssm_footprint_monotone(chunk, d_block, n):
    from repro.kernels.ssm_scan.ops import vmem_footprint

    base = vmem_footprint(chunk, d_block, n)
    assert base > 0
    assert vmem_footprint(chunk * 2, d_block, n) > base
    assert vmem_footprint(chunk, d_block * 2, n) > base


@settings(max_examples=40, deadline=None)
@given(
    bq=st.sampled_from([128, 256]),
    bkv=st.sampled_from([128, 256]),
)
def test_snap_idempotent_flash_configs_accepted(bq, bkv):
    """Any config the snap helpers leave unchanged (for the cell's shape)
    must pass the gate with a finite footprint under the real budget."""
    from repro.kernels.flash_attention.ops import snap_block, vmem_footprint

    s = 256  # FLASH_PLAT's sequence length
    assert snap_block(bq, s) == bq and snap_block(bkv, s) == bkv
    assert 0 < vmem_footprint(bq, bkv, 64) <= VMEM_BUDGET
    assert StaticPrefilter()({"block_q": bq, "block_kv": bkv},
                             FLASH_PLAT) is None


def test_shipped_tuned_table_effective_configs_pass_gate():
    """Soundness against shipped results: the gate may brand a raw table
    entry a snap-alias (the table stores pre-snap incumbents), but the
    *effective* (snapped) config it aliases must always pass — the gate
    never rejects a config that actually ran and won its cell."""
    from repro.kernels import DEFAULT_TABLE_PATH

    table = json.loads(Path(DEFAULT_TABLE_PATH).read_text())
    pf = StaticPrefilter()
    assert table["entries"], "shipped tuned table is empty"
    for key, entry in table["entries"].items():
        kernel, dtype, shape_class = key.split("|")
        platform = f"kernel/{kernel}.{dtype}:{shape_class}"
        config = dict(entry["config"])
        for _ in range(8):  # follow alias chains to the effective config
            r = pf(config, platform)
            if r is None:
                break
            assert r.rule == "snap_alias", (
                f"{key}: shipped incumbent rejected by {r.rule}: {r.reason}"
            )
            config[r.detail["param"]] = r.detail["effective"]
        else:
            pytest.fail(f"{key}: alias chain did not converge")
        assert pf(config, platform) is None
