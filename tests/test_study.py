"""The Study API: create/load/open lifecycle, EngineConfig validation,
optimize sessions, multi-session warm start through on_study_attach,
interrupted-session resume (pays only the unpaid remainder), per-session
delta accounting, cells, and the report/reduction table."""
import json
import threading
from pathlib import Path

import pytest

from repro.core import (
    TRAIN_SPACE,
    EngineConfig,
    Study,
    tune,
)
from repro.core.evaluators import FunctionEvaluator
from repro.core.executors import EvaluatorSpec


def quad_objective(cfg):
    t = 10.0
    t += abs(cfg["mesh_model_parallel"] - 8) * 0.5
    t += abs((cfg["microbatch_size"] or 256) - 32) * 0.02
    t += {"none": 2.0, "dots": 0.0, "full": 1.0}[cfg["remat_policy"]]
    return t


def make_quad_evaluator():
    """Module-level factory — resume() rebuilds evaluators from specs that
    point here by dotted path."""
    return FunctionEvaluator(quad_objective)


class KillAfter:
    """Deterministic objective that simulates the session being killed
    (SIGINT) on the (n+1)-th fresh evaluation."""

    def __init__(self, n, fn=quad_objective):
        self.n = n
        self.fn = fn
        self.calls = 0
        self._lock = threading.Lock()

    def __call__(self, config):
        with self._lock:
            if self.calls >= self.n:
                raise KeyboardInterrupt
            self.calls += 1
        return float(self.fn(config)), {}


class Counting:
    def __init__(self, fn=quad_objective):
        self.fn = fn
        self.calls = 0
        self._lock = threading.Lock()

    def __call__(self, config):
        with self._lock:
            self.calls += 1
        return float(self.fn(config)), {}


CRS_KW = dict(m=8, k=3, max_rounds=3, seed=5)
GSFT_KW = dict(active_params=["mesh_model_parallel", "remat_policy"],
               samples_per_param=4)


# ------------------------------------------------------------ lifecycle


def test_create_writes_manifest_and_load_roundtrips(tmp_path):
    eng = EngineConfig(workers=4, timeout_s=30.0, patience=2)
    study = Study.create(tmp_path / "s", engine=eng, seed=7)
    assert (tmp_path / "s" / "study.json").exists()

    loaded = Study.load(tmp_path / "s")
    assert loaded.engine == eng
    assert loaded.seed == 7
    assert loaded.cache_path == tmp_path / "s" / "cache.jsonl"
    assert loaded.log_path == tmp_path / "s" / "trials.jsonl"


def test_create_refuses_to_clobber_existing_study(tmp_path):
    Study.create(tmp_path / "s")
    with pytest.raises(FileExistsError, match="already exists"):
        Study.create(tmp_path / "s")


def test_load_missing_study_raises(tmp_path):
    with pytest.raises(FileNotFoundError, match="no study"):
        Study.load(tmp_path / "nope")


def test_open_creates_then_loads(tmp_path):
    a = Study.open(tmp_path / "s", seed=3)
    assert a.seed == 3
    b = Study.open(tmp_path / "s")  # second open loads, not clobbers
    assert b.seed == 3


def test_engine_config_validated_in_one_place():
    with pytest.raises(ValueError, match="workers"):
        EngineConfig(workers=0)
    with pytest.raises(ValueError, match="isolation"):
        EngineConfig(isolation="threads")
    with pytest.raises(ValueError, match="timeout_s"):
        EngineConfig(timeout_s=-1.0)
    with pytest.raises(ValueError, match="retries"):
        EngineConfig(retries=-1)
    with pytest.raises(ValueError, match="patience"):
        EngineConfig(patience=0)
    with pytest.raises(ValueError, match="batch_size"):
        EngineConfig(batch_size=0)


# ------------------------------------------------------------- optimize


def test_optimize_finds_optimum_and_records_session(tmp_path):
    study = Study.create(tmp_path / "s")
    out = study.optimize("train", "gsft", FunctionEvaluator(quad_objective),
                         **GSFT_KW)
    assert out.best_config["mesh_model_parallel"] == 8
    assert out.best_config["remat_policy"] == "dots"
    assert out.reduction_pct > 0
    # session provenance persisted: one start + one done record
    recs = [json.loads(l) for l in
            (tmp_path / "s" / "sessions.jsonl").read_text().splitlines()]
    assert [r["event"] for r in recs] == ["start", "done"]
    assert recs[0]["platform"] == "train" and recs[0]["algorithm"] == "gsft"
    assert recs[0]["space"] == "train"
    assert recs[1]["summary"]["best_config"] == out.best_config


def test_warm_rerun_of_same_session_is_free(tmp_path):
    s1 = Study.create(tmp_path / "s")
    cold = s1.optimize("train", "gsft", FunctionEvaluator(quad_objective),
                       **GSFT_KW)
    ev = Counting()
    s2 = Study.load(tmp_path / "s")
    warm = s2.optimize("train", "gsft", ev, **GSFT_KW)
    assert ev.calls == 0
    assert warm.cache_stats["fresh"] == 0
    assert warm.cache_stats["cache_hits"] > 0
    assert warm.best_config == cold.best_config
    assert warm.best_time == cold.best_time


def test_budget_maps_onto_strategy_budget_kwarg(tmp_path):
    study = Study.create(tmp_path / "s")
    out = study.optimize("train", "tpe", FunctionEvaluator(quad_objective),
                         budget=10, seed=0)
    # budget = tpe max_trials; +1 for the defaults trial tune always measures
    assert out.evaluations <= 11
    assert out.detail.n_observations >= 10
    with pytest.raises(ValueError, match="budget knob"):
        study.optimize("train", "gsft", FunctionEvaluator(quad_objective),
                       budget=10, **GSFT_KW)


def test_multi_session_history_warm_starts_tpe_for_free(tmp_path):
    """Session 2 (TPE) must seed its model from session 1's (GSFT) records
    through on_study_attach — free evidence, not budget theft."""
    study = Study.create(tmp_path / "s")
    g = study.optimize("train", "gsft", FunctionEvaluator(quad_objective),
                       **GSFT_KW)
    t = study.optimize("train", "tpe", FunctionEvaluator(quad_objective),
                       budget=8, seed=0)
    assert t.detail.warm_started >= g.evaluations  # gsft records ingested
    assert t.evaluations > 0  # ...but tpe still paid its own budget
    # session 3: repeat of session 2 — its own records now fill the budget
    ev = Counting()
    t2 = study.optimize("train", "tpe", ev, budget=8, seed=0)
    assert ev.calls == 0
    assert t2.cache_stats["fresh"] == 0
    assert t2.best_time == t.best_time


def test_on_study_attach_hook_receives_cached_history(tmp_path):
    """The sanctioned seam: a strategy that overrides on_study_attach gets
    the study's cached observations instead of a constructor kwarg."""
    from repro.core.strategies.base import QueueStrategy, register_strategy

    seen = {}

    @register_strategy("_attach_probe")
    class AttachProbe(QueueStrategy):
        tag = "probe"
        supports_history = True

        def __init__(self, space, *, fixed=None):
            super().__init__()

        def on_study_attach(self, history):
            seen["history"] = list(history)

        def _observe(self, trial):
            pass

        def result(self):
            from repro.core.strategies.tpe import TPEResult

            return TPEResult(best_config={}, best_time=float("inf"),
                             rounds=0, evaluations=0)

    try:
        study = Study.create(tmp_path / "s")
        study.optimize("train", "gsft", FunctionEvaluator(quad_objective),
                       **GSFT_KW)
        study.optimize("train", "_attach_probe",
                       FunctionEvaluator(quad_objective))
        assert seen["history"], "hook never received the cached history"
        cfg, time_s, tag = seen["history"][0]
        assert "mesh_model_parallel" in cfg and time_s > 0
    finally:
        from repro.core.strategies.base import STRATEGIES

        STRATEGIES.pop("_attach_probe", None)


# --------------------------------------------------------------- resume


def test_resume_pays_only_the_unpaid_remainder(tmp_path):
    # reference: the same session, never interrupted
    ref = Study.create(tmp_path / "ref").optimize(
        "train", "crs", FunctionEvaluator(quad_objective), **CRS_KW)
    total = ref.cache_stats["fresh"]

    study = Study.create(tmp_path / "s")
    killed = 6
    with pytest.raises(KeyboardInterrupt):
        study.optimize("train", "crs", KillAfter(killed), **CRS_KW)

    resumed = Study.load(tmp_path / "s")
    ev = Counting()
    out = resumed.resume(evaluator=ev)
    assert ev.calls == total - killed  # only the remainder is paid
    assert out.cache_stats["cache_hits"] == killed
    assert out.best_config == ref.best_config
    assert out.best_time == ref.best_time
    # the interrupted session is now closed: nothing further to resume
    with pytest.raises(ValueError, match="nothing to resume"):
        resumed.resume(evaluator=Counting())


def test_resume_rebuilds_evaluator_from_stored_spec(tmp_path):
    study = Study.create(tmp_path / "s")
    killer = KillAfter(4)
    killer.spec = EvaluatorSpec.factory("test_study:make_quad_evaluator")
    with pytest.raises(KeyboardInterrupt):
        study.optimize("train", "crs", killer, **CRS_KW)

    out = Study.load(tmp_path / "s").resume()  # no evaluator passed
    ref = Study.create(tmp_path / "ref").optimize(
        "train", "crs", FunctionEvaluator(quad_objective), **CRS_KW)
    assert out.best_config == ref.best_config
    assert out.best_time == ref.best_time


def test_resume_without_spec_or_evaluator_raises(tmp_path):
    study = Study.create(tmp_path / "s")
    with pytest.raises(KeyboardInterrupt):
        study.optimize("train", "crs", KillAfter(3), **CRS_KW)
    with pytest.raises(ValueError, match="no evaluator recipe"):
        Study.load(tmp_path / "s").resume()


def test_failed_resume_reopens_the_interrupted_session(tmp_path):
    """A resume attempt that itself FAILS (event=failed — e.g. version skew
    broke the recorded strategy args) must not close the original session:
    its unpaid remainder is still owed to a later, fixed resume."""
    study = Study.create(tmp_path / "s")
    with pytest.raises(KeyboardInterrupt):
        study.optimize("train", "crs", KillAfter(4), **CRS_KW)  # session 1

    # a resume attempt that died deterministically: start(resumes=1) + failed
    study2 = Study.load(tmp_path / "s")
    study2._record({"event": "start", "session": 2, "ts": 0.0,
                    "platform": "train", "algorithm": "crs", "space": "train",
                    "args": dict(CRS_KW), "engine": {}, "resumes": 1})
    study2._record({"event": "failed", "session": 2, "ts": 0.0,
                    "error": "RuntimeError: wrong environment"})

    # session 1 is open again: resume targets it, not "nothing to resume"
    out = Study.load(tmp_path / "s").resume(evaluator=Counting())
    ref = Study.create(tmp_path / "ref").optimize(
        "train", "crs", FunctionEvaluator(quad_objective), **CRS_KW)
    assert out.best_config == ref.best_config


def test_cli_open_study_honors_stored_engine(tmp_path):
    """Opening an existing study from a CLI with engine flags at their
    defaults must keep the study's stored EngineConfig; an explicit flag
    overlays ONLY its own field, never resetting the other stored knobs."""
    from argparse import Namespace

    from repro.launch.tune import engine_config, open_study

    stored = EngineConfig(workers=4, timeout_s=120.0)
    Study.create(tmp_path / "s", engine=stored)
    untyped = Namespace(study=tmp_path / "s", jobs=None, isolation=None,
                        trial_timeout=None, retries=None, patience=None,
                        batch=None, cache=None, log=None)
    assert open_study(untyped, engine_config(untyped)).engine == stored
    # ...an explicit flag wins for its field but doesn't clobber the rest
    explicit = Namespace(**{**vars(untyped), "jobs": 8})
    merged = open_study(explicit, engine_config(explicit)).engine
    assert merged.workers == 8
    assert merged.timeout_s == 120.0  # stored knob survives the override
    # an explicitly-typed default value is a real override too (--jobs 1)
    reset = Namespace(**{**vars(untyped), "jobs": 1})
    assert open_study(reset, engine_config(reset)).engine.workers == 1


def test_resume_replays_an_explicit_history(tmp_path):
    """history= passed to the original session is recorded provenance: the
    resumed session must re-use it, not swap in cache-derived history."""
    import random

    rng = random.Random(0)
    external = [({p.name: p.sample(rng) for p in TRAIN_SPACE.params},
                 50.0 + i) for i in range(3)]
    study = Study.create(tmp_path / "s")
    with pytest.raises(KeyboardInterrupt):
        study.optimize("train", "tpe", KillAfter(4), budget=10, seed=0,
                       history=external)
    start = Study.load(tmp_path / "s").sessions()[0]
    assert len(start["args"]["history"]) == 3  # recorded, not dropped
    out = Study.load(tmp_path / "s").resume(evaluator=Counting())
    # warm start = the 3 external observations + the 4 persisted trials is
    # NOT what the constructor sees — explicit history wins, so the resumed
    # strategy was seeded with exactly the recorded 3
    assert out.detail.warm_started == 3


def test_resume_works_with_none_valued_kwargs(tmp_path):
    """None-valued kwargs (the CLI passes n_startup=None by default) are
    legal JSON and must not be misread as unserializable — the headline
    SIGINT-resume path has to work for a stock CLI TPE session."""
    study = Study.create(tmp_path / "s")
    with pytest.raises(KeyboardInterrupt):
        study.optimize("train", "tpe", KillAfter(5), budget=12,
                       n_startup=None, round_size=8, seed=0)
    start = Study.load(tmp_path / "s").sessions()[0]
    assert "args_dropped" not in start
    assert start["args"]["n_startup"] is None
    out = Study.load(tmp_path / "s").resume(evaluator=Counting())
    assert out.detail.warm_started == 5  # cached trials seeded the model


def test_cell_chips_guard_survives_process_restart(tmp_path):
    """The chip count is persisted with the study: reopening it with a
    conflicting explicit chips must raise, not silently replay the other
    topology's cached measurements; chips=None adopts the stored value."""
    study = Study.create(tmp_path / "s")
    study.cell("llama3.2-1b", "train_4k", chips=512,
               evaluator=FunctionEvaluator(quad_objective))

    reopened = Study.load(tmp_path / "s")  # fresh process: _cells is empty
    with pytest.raises(ValueError, match="chips=512"):
        reopened.cell("llama3.2-1b", "train_4k", chips=256,
                      evaluator=FunctionEvaluator(quad_objective))
    adopted = reopened.cell("llama3.2-1b", "train_4k",
                            evaluator=FunctionEvaluator(quad_objective))
    assert adopted.chips == 512  # no opinion -> stored topology


def test_legacy_history_kwarg_strategy_without_hook_attribute(tmp_path):
    """A protocol-only strategy (no QueueStrategy base, no on_study_attach
    attribute) with supports_history=True must receive history through its
    constructor — the promised legacy seam."""
    from repro.core.strategies.base import STRATEGIES, register_strategy

    seen = {}

    @register_strategy("_legacy_probe")
    class LegacyProbe:  # implements the Strategy protocol directly
        tag = "legacy"
        supports_history = True
        done = True

        def __init__(self, space, *, fixed=None, history=None):
            seen["history"] = list(history or ())

        def ask(self, n=None):
            return []

        def tell(self, trials):
            pass

        def result(self):
            from repro.core.strategies.tpe import TPEResult

            return TPEResult(best_config={}, best_time=float("inf"),
                             rounds=0, evaluations=0)

    try:
        study = Study.create(tmp_path / "s")
        study.optimize("train", "gsft", FunctionEvaluator(quad_objective),
                       **GSFT_KW)
        study.optimize("train", "_legacy_probe",
                       FunctionEvaluator(quad_objective))
        assert seen["history"], "constructor never received the history"
    finally:
        STRATEGIES.pop("_legacy_probe", None)


def test_read_log_missing_path_raises():
    from repro.core.scheduler import read_log

    with pytest.raises(FileNotFoundError):
        read_log(Path("/nonexistent/typo.jsonl"))


def test_optimize_rejects_engine_kwargs_with_clear_error(tmp_path):
    """Engine knobs passed as strategy kwargs (the old tune() surface) get a
    ValueError pointing at EngineConfig, not a confusing TypeError."""
    study = Study.create(tmp_path / "s")
    with pytest.raises(ValueError, match="batch_size.*EngineConfig"):
        study.optimize("train", "gsft", FunctionEvaluator(quad_objective),
                       batch_size=2, **GSFT_KW)
    with pytest.raises(ValueError, match="max_workers.*EngineConfig"):
        study.optimize("train", "gsft", FunctionEvaluator(quad_objective),
                       max_workers=4, **GSFT_KW)


def test_resume_keeps_the_sessions_custom_log_path(tmp_path):
    """A session logging to a custom file (per-cell logs) must keep
    appending there on resume — not silently divert to trials.jsonl."""
    from repro.core.scheduler import read_log

    custom_log = tmp_path / "cell.jsonl"
    study = Study.create(tmp_path / "s")
    cell = study.cell("llama3.2-1b", "train_4k", evaluator=KillAfter(3),
                      log_path=custom_log)
    with pytest.raises(KeyboardInterrupt):
        cell.optimize("crs", **CRS_KW)
    study.close()
    before = len(read_log(custom_log))

    out = Study.load(tmp_path / "s").resume(evaluator=Counting())
    assert out.evaluations > 0
    assert len(read_log(custom_log)) > before  # remainder landed in the file


def test_resume_with_nothing_open_raises(tmp_path):
    study = Study.create(tmp_path / "s")
    study.optimize("train", "gsft", FunctionEvaluator(quad_objective),
                   **GSFT_KW)
    with pytest.raises(ValueError, match="nothing to resume"):
        study.resume(evaluator=Counting())


def test_resume_chain_completion_closes_every_link(tmp_path):
    """Session 3 resumes session 2 which resumed session 1: session 3
    completing pays off the whole chain — nothing is left to resume."""
    study = Study.create(tmp_path / "s")
    with pytest.raises(KeyboardInterrupt):
        study.optimize("train", "crs", KillAfter(3), **CRS_KW)  # session 1
    with pytest.raises(KeyboardInterrupt):
        Study.load(tmp_path / "s").resume(
            evaluator=KillAfter(3))  # session 2, also interrupted
    Study.load(tmp_path / "s").resume(evaluator=Counting())  # session 3: done
    with pytest.raises(ValueError, match="nothing to resume"):
        Study.load(tmp_path / "s").resume(evaluator=Counting())


# ------------------------------------------------------ report / accessors


def test_report_is_the_per_session_reduction_table(tmp_path):
    study = Study.create(tmp_path / "s")
    study.optimize("train", "gsft", FunctionEvaluator(quad_objective),
                   **GSFT_KW)
    study.optimize("train", "crs", FunctionEvaluator(quad_objective), **CRS_KW)
    rep = Study.load(tmp_path / "s").report()  # report survives reload
    assert len(rep["sessions"]) == 2
    assert [r["algorithm"] for r in rep["sessions"]] == ["gsft", "crs"]
    for row in rep["sessions"]:
        assert row["status"] == "done"
        assert row["reduction_pct"] > 0
        assert "cache_stats" in row
    assert rep["best"]["train"]["time_s"] <= min(
        r["best_time_s"] for r in rep["sessions"])


def test_report_marks_interrupted_sessions(tmp_path):
    study = Study.create(tmp_path / "s")
    with pytest.raises(KeyboardInterrupt):
        study.optimize("train", "crs", KillAfter(3), **CRS_KW)
    rep = study.report()
    assert rep["sessions"][0]["status"] == "interrupted"


def test_best_and_trials_filter_by_platform(tmp_path):
    study = Study.create(tmp_path / "s")
    study.optimize("train", "gsft", FunctionEvaluator(quad_objective),
                   **GSFT_KW)
    best = study.best(platform="train")
    assert best["time_s"] == study.best()["time_s"]
    assert best["config"]["mesh_model_parallel"] == 8
    assert study.trials(platform="train")
    assert study.trials(platform="serve") == []
    with pytest.raises(ValueError, match="no successful trials"):
        study.best(platform="serve")


# ----------------------------------------------------------------- cells


def test_cell_sessions_share_scheduler_and_report_deltas(tmp_path):
    """Satellite: a second session on the same (shared) scheduler must report
    ITS OWN cache/evaluation deltas, not scheduler-lifetime totals."""
    study = Study.create(tmp_path / "s")
    cell = study.cell("llama3.2-1b", "train_4k",
                      evaluator=FunctionEvaluator(quad_objective))
    assert study.cell("llama3.2-1b", "train_4k") is cell  # one handle per cell

    a = cell.optimize("gsft", active_params=["mesh_model_parallel"],
                      samples_per_param=3)
    b = cell.optimize("gsft", active_params=["microbatch_size"],
                      samples_per_param=3)
    sched = cell.scheduler()
    # per-session deltas sum to the lifetime totals — no inflation
    assert a.cache_stats["fresh"] + b.cache_stats["fresh"] == sched.fresh_evaluations
    assert b.cache_stats["fresh"] < sched.fresh_evaluations
    # session b re-measured the defaults on the shared scheduler => memo hit
    assert b.cache_stats["memo_hits"] >= 1
    assert a.evaluations + b.evaluations == sched.num_evaluations
    study.close()


def test_cell_repeat_call_with_conflicting_setup_raises(tmp_path):
    """The cached measurements were taken under the first call's setup — a
    repeat cell() may not silently swap chips/evaluator/log_path."""
    study = Study.create(tmp_path / "s")
    study.cell("llama3.2-1b", "train_4k",
               evaluator=FunctionEvaluator(quad_objective))
    with pytest.raises(ValueError, match="different chips"):
        study.cell("llama3.2-1b", "train_4k", chips=512)
    with pytest.raises(ValueError, match="different evaluator"):
        study.cell("llama3.2-1b", "train_4k",
                   evaluator=FunctionEvaluator(lambda c: 1.0))
    # an explicit chips request conflicting with a non-default cell raises
    # too (no "default = no opinion" loophole)
    study.cell("qwen2-72b", "train_4k", chips=512,
               evaluator=FunctionEvaluator(quad_objective))
    with pytest.raises(ValueError, match="different chips"):
        study.cell("qwen2-72b", "train_4k", chips=256)
    assert study.has_cell("qwen2-72b", "train_4k")
    assert not study.has_cell("qwen2-72b", "decode_32k")


def test_failed_session_is_closed_and_does_not_block_resume(tmp_path):
    """A deterministic failure (bad kwarg) must close its session record so
    resume() still reaches the genuinely interrupted session before it."""
    study = Study.create(tmp_path / "s")
    with pytest.raises(KeyboardInterrupt):
        study.optimize("train", "crs", KillAfter(4), **CRS_KW)  # session 1
    with pytest.raises(TypeError):
        study.optimize("train", "crs", Counting(),
                       bogus_kwarg=3, **CRS_KW)  # session 2: fails instantly
    study2 = Study.load(tmp_path / "s")
    assert [r["status"] for r in study2.report()["sessions"]] == [
        "interrupted", "failed"]
    out = study2.resume(evaluator=Counting())  # resumes session 1, not 2
    ref = Study.create(tmp_path / "ref").optimize(
        "train", "crs", FunctionEvaluator(quad_objective), **CRS_KW)
    assert out.best_config == ref.best_config


def test_resume_refuses_lossy_session_record(tmp_path):
    """Constraints that failed to round-trip through the session manifest
    (non-JSON values) must block resume, not be silently dropped."""
    study = Study.create(tmp_path / "s")
    with pytest.raises(KeyboardInterrupt):
        study.optimize("train", "crs", KillAfter(3),
                       fixed={"remat_policy": object()}, **CRS_KW)
    with pytest.raises(ValueError, match="did not round-trip"):
        Study.load(tmp_path / "s").resume(evaluator=Counting())


def test_cells_namespace_the_shared_cache(tmp_path):
    """The same knob dict on two different cells must never collide."""
    study = Study.create(tmp_path / "s")
    slow = study.cell("llama3.2-1b", "train_4k",
                      evaluator=FunctionEvaluator(lambda c: 5.0))
    fast = study.cell("qwen2-72b", "train_4k",
                      evaluator=FunctionEvaluator(lambda c: 1.0))
    a = slow.optimize("gsft", active_params=["mesh_model_parallel"],
                      samples_per_param=2)
    b = fast.optimize("gsft", active_params=["mesh_model_parallel"],
                      samples_per_param=2)
    assert a.best_time == 5.0 and b.best_time == 1.0
    study.close()


# ------------------------------------------------------------- tune shim


def test_tune_shim_matches_study_optimize(tmp_path):
    with pytest.warns(DeprecationWarning, match="tune\\(\\) is deprecated"):
        shim = tune("train", "gsft", FunctionEvaluator(quad_objective),
                    cache_path=tmp_path / "shim.jsonl", **GSFT_KW)
    study_out = Study.create(tmp_path / "s").optimize(
        "train", "gsft", FunctionEvaluator(quad_objective), **GSFT_KW)
    assert shim.best_config == study_out.best_config
    assert shim.best_time == study_out.best_time
    assert shim.evaluations == study_out.evaluations
    assert shim.cache_stats == study_out.cache_stats
