"""launch/multicell.py end-to-end over a tiny FunctionEvaluator matrix:
a cold run through a Study directory, then a --study re-run that must perform
ZERO fresh evaluations and land on identical incumbents per cell."""
import threading

import pytest

from repro.core import Study
from repro.launch.multicell import cell_platform, tune_cells

CELLS = ["llama3.2-1b:train_4k", "llama3.2-1b:decode_32k"]


class CountingCellEvaluator:
    """Deterministic per-cell objective (cell-dependent optimum) that counts
    fresh evaluator invocations thread-safely."""

    def __init__(self, arch, shape, platform):
        # distinct optima per cell so cross-cell cache collisions would show
        self.target = 8 if shape == "train_4k" else 16
        self.base = 5.0 if platform == "train" else 3.0
        self.calls = 0
        self._lock = threading.Lock()

    def __call__(self, config):
        with self._lock:
            self.calls += 1
        return self.base + abs(config["mesh_model_parallel"] - self.target) * 0.25, {}


def _factory(counters):
    # our evaluator already returns (time, info) tuples, so hand the instance
    # to the scheduler directly instead of wrapping it in FunctionEvaluator
    def factory(arch, shape, space, platform):
        ev = CountingCellEvaluator(arch, shape, platform)
        counters[f"{arch}:{shape}"] = ev
        return ev

    return factory


def test_multicell_cold_then_study_rerun_is_free(tmp_path):
    study_dir = tmp_path / "study"

    # cold run: every trial is fresh
    cold_counters = {}
    with Study.open(study_dir) as study:
        cold = tune_cells(
            CELLS, algorithm="gsft", study=study,
            evaluator_factory=_factory(cold_counters), samples_per_param=2,
        )
    assert set(cold) == set(CELLS)
    for cell in CELLS:
        assert cold_counters[cell].calls > 0
        assert cold[cell].cache_stats["fresh"] == cold_counters[cell].calls

    # --study re-run: zero fresh evaluations, identical incumbents per cell
    warm_counters = {}
    with Study.open(study_dir) as study:
        warm = tune_cells(
            CELLS, algorithm="gsft", study=study,
            evaluator_factory=_factory(warm_counters), samples_per_param=2,
        )
    for cell in CELLS:
        assert warm_counters[cell].calls == 0, cell
        assert warm[cell].cache_stats["fresh"] == 0
        assert warm[cell].cache_stats["cache_hits"] > 0
        assert warm[cell].best_config == cold[cell].best_config
        assert warm[cell].best_time == cold[cell].best_time


def test_multicell_cells_do_not_collide_in_shared_cache(tmp_path):
    """Same knob dicts, different cells: per-cell platform namespacing must
    keep their records (and incumbents) apart."""
    counters = {}
    with Study.open(tmp_path / "study") as study:
        out = tune_cells(
            CELLS, algorithm="gsft", study=study,
            evaluator_factory=_factory(counters), samples_per_param=2,
        )
    train_cell, decode_cell = CELLS
    assert out[train_cell].platform == "train/llama3.2-1b:train_4k"
    assert out[decode_cell].platform == "serve/llama3.2-1b:decode_32k"
    # distinct per-cell objectives => distinct best times (no cache bleed)
    assert out[train_cell].best_time != out[decode_cell].best_time


def test_multicell_second_algorithm_pass_reuses_cells(tmp_path):
    """A second tune_cells pass over the same open study (the warm-start
    workflow) must reuse the cell handles, not rebuild evaluators or trip
    the cell-conflict guard."""
    counters = {}
    with Study.open(tmp_path / "study") as study:
        first = tune_cells(CELLS, algorithm="gsft", study=study,
                           evaluator_factory=_factory(counters),
                           samples_per_param=2)
        calls_after_first = {c: counters[c].calls for c in CELLS}
        second = tune_cells(CELLS, algorithm="crs", study=study,
                            evaluator_factory=_factory({}),  # must NOT be used
                            m=4, k=2, max_rounds=1, seed=0)
    for cell in CELLS:
        # the first pass's evaluator served both sessions (shared scheduler)
        assert counters[cell].calls > calls_after_first[cell]
        assert second[cell].algorithm == "crs"
        assert first[cell].platform == second[cell].platform


def test_multicell_duplicate_cells_in_one_invocation(tmp_path):
    counters = {}
    with Study.open(tmp_path / "study") as study:
        out = tune_cells([CELLS[0], CELLS[0]], algorithm="gsft", study=study,
                         evaluator_factory=_factory(counters),
                         samples_per_param=2)
    assert set(out) == {CELLS[0]}  # second entry replays the same sessions


def test_multicell_rejects_engine_kwargs_with_explicit_study(tmp_path):
    """Engine knobs alongside an explicit study must raise (they would be
    silently ignored) — the same guard tune() has for explicit schedulers."""
    with Study.open(tmp_path / "study") as study:
        with pytest.raises(ValueError, match="jobs.*ignored"):
            tune_cells(CELLS, study=study, jobs=8)
        with pytest.raises(ValueError, match="isolation, trial_timeout"):
            tune_cells(CELLS, study=study, isolation="subprocess",
                       trial_timeout=120.0)


def test_multicell_rejects_malformed_cells(tmp_path):
    with pytest.raises(SystemExit, match="expected ARCH:SHAPE"):
        tune_cells(["llama3.2-1b"], cache_path=tmp_path / "c.jsonl")
    with pytest.raises(SystemExit, match="unknown shape"):
        tune_cells(["llama3.2-1b:bogus_shape"], cache_path=tmp_path / "c.jsonl")


def test_cell_platform_maps_shape_kind():
    assert cell_platform("train_4k") == "train"
    assert cell_platform("decode_32k") == "serve"


def test_roofline_platform_key_namespaces_topology():
    """Runs against a non-default chip count must not share cache records
    with the default topology's."""
    from repro.launch.tune import roofline_platform_key

    default = roofline_platform_key("train", "qwen2-72b", "train_4k", 256)
    other = roofline_platform_key("train", "qwen2-72b", "train_4k", 512)
    assert default == "train/qwen2-72b:train_4k"
    assert other != default and "512" in other
