"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests and
benchmarks must see the real single CPU device; multi-device tests spawn
subprocesses that set --xla_force_host_platform_device_count themselves."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"


@pytest.fixture(scope="session")
def repo_root() -> Path:
    return REPO


def run_subprocess_devices(code: str, devices: int = 8, timeout: int = 600):
    """Run ``code`` in a fresh python with N fake devices; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\nstdout:\n{proc.stdout}\n"
            f"stderr:\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess_devices
