"""ASHA resume smoke (the CI ``asha`` job — not a pytest module).

Scenario: start an ASHA Study session in a child process, SIGINT it
mid-rung, then ``Study.resume()`` in this process and assert the resumed
session pays only the unpaid remainder — every rung trial the interrupted
session persisted replays from the cache (at its recorded fidelity), and
the incumbent matches a single uninterrupted run. With one worker the
completion order equals the submission order, so the promotion stream is an
exact replay.

    PYTHONPATH=src python tests/asha_resume_smoke.py
"""
import json
import os
import signal
import subprocess
import sys
import tempfile
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.core import Study  # noqa: E402
from repro.core.evaluators import FunctionEvaluator  # noqa: E402
from repro.core.scheduler import iter_jsonl  # noqa: E402

ASHA_KW = dict(budget=9, inner="random", eta=3.0, min_fidelity=1.0 / 9.0,
               seed=11)


def objective(cfg, fidelity=1.0):
    t = (10.0
         + abs(cfg["mesh_model_parallel"] - 8) * 0.5
         + abs((cfg["microbatch_size"] or 256) - 32) * 0.02)
    # mild fidelity noise: cheap rungs rank roughly, not exactly
    if fidelity < 1.0:
        t += 0.3 * (1.0 - fidelity) * (hashkey(cfg) % 5)
    return t


def hashkey(cfg):
    return sum(ord(c) for c in json.dumps(cfg, sort_keys=True, default=str))


def slow_objective(cfg, fidelity=1.0):
    time.sleep(0.15)  # wide SIGINT window per trial
    return objective(cfg, fidelity)


def run_child(study_dir: str) -> int:
    study = Study.open(Path(study_dir))
    study.optimize("train", "asha", FunctionEvaluator(slow_objective),
                   **ASHA_KW)
    return 0


def paid_records(cache: Path) -> int:
    return len(iter_jsonl(cache))


def main() -> int:
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        return run_child(sys.argv[2])

    tmp = Path(tempfile.mkdtemp(prefix="asha-resume-smoke-"))
    study_dir = tmp / "study"

    # reference: the same seeded ASHA session, never interrupted
    ref = Study.create(tmp / "ref").optimize(
        "train", "asha", FunctionEvaluator(objective), **ASHA_KW)
    ref_total = ref.cache_stats["fresh"]
    ref_rungs = ref.summary()["rungs"]
    assert ref_total > 6, f"reference run too small to interrupt ({ref_total})"
    assert sum(r["promoted"] for r in ref_rungs) > 0, ref_rungs

    # interrupted run: SIGINT the child once >= 4 rung trials are persisted
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    child = subprocess.Popen(
        [sys.executable, __file__, "--child", str(study_dir)], env=env)
    cache = study_dir / "cache.jsonl"
    deadline = time.time() + 120
    while time.time() < deadline:
        if paid_records(cache) >= 4:
            break
        if child.poll() is not None:
            raise SystemExit("child finished before it could be interrupted")
        time.sleep(0.02)
    child.send_signal(signal.SIGINT)
    child.wait(timeout=60)
    assert child.returncode != 0, "child should have died from the SIGINT"

    paid_before = paid_records(cache)
    assert 0 < paid_before < ref_total, (paid_before, ref_total)

    # resume: replays every paid rung trial, pays only the remainder
    study = Study.load(study_dir)
    out = study.resume(evaluator=FunctionEvaluator(objective))
    assert out.cache_stats["cache_hits"] == paid_before, (
        out.cache_stats, paid_before)
    assert out.cache_stats["fresh"] == ref_total - paid_before, (
        out.cache_stats, ref_total, paid_before)
    assert out.best_config == ref.best_config
    assert out.best_time == ref.best_time
    assert out.summary()["rungs"] == ref_rungs, (
        out.summary()["rungs"], ref_rungs)

    print(json.dumps({
        "reference_evaluations": ref_total,
        "paid_before_sigint": paid_before,
        "resume_fresh": out.cache_stats["fresh"],
        "resume_replayed": out.cache_stats["cache_hits"],
        "rungs": ref_rungs,
        "best_time_s": out.best_time,
    }, indent=1))
    print("OK: interrupted ASHA session resumed as an exact replay")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
