"""Roofline machinery: HLO collective parser (property-based), wire-byte
model, cost extrapolation algebra, TPU memory estimator."""
import pytest
from _hyp import given, settings, st

from repro.core.hlo import CollectiveStats, parse_collectives
from repro.core.roofline import (CostTerms, PEAK_FLOPS, Roofline, collective_time,
                                 estimate_tpu_hbm, model_flops)

HLO = """
HloModule jit_step
%fused (p: f32[16,128]) -> f32[16,128] { ROOT %x = f32[16,128] parameter(0) }
ENTRY %main {
  %ag = f32[8,1024]{1,0} all-gather(%a), channel_id=1, replica_groups=[32,16]<=[512], dimensions={1}
  %ar = bf16[4,256]{1,0} all-reduce(%b), channel_id=2, replica_groups=[16,32]<=[16,2,16]T(1,0,2), to_apply=%add
  %rs = f32[2,64]{1,0} reduce-scatter(%c), channel_id=3, replica_groups=[32,16]<=[512], dimensions={0}
  %cp = bf16[128]{0} collective-permute(%d), source_target_pairs={{0,1},{1,0}}
  %a2a = s8[64,64]{1,0} all-to-all(%e), channel_id=5, replica_groups=[64,8]<=[512], dimensions={0}
  %ard = f32[] all-reduce(%f), channel_id=6, replica_groups={{0,1}}, to_apply=%add
}
"""


def test_parser_counts_and_bytes():
    s = parse_collectives(HLO)
    assert s.count == 6
    assert s.counts_by_op["all-gather"] == 1
    assert s.counts_by_op["all-reduce"] == 2
    # all-gather: result 8*1024*4 = 32768 B, g=16 -> (15/16)*32768
    assert abs(s.by_op["all-gather"] - 32768 * 15 / 16) < 1e-6
    # reduce-scatter: result 2*64*4=512 B, g=16 -> (g-1)*512
    assert abs(s.by_op["reduce-scatter"] - 15 * 512) < 1e-6
    # collective-permute: result bytes exactly
    assert abs(s.by_op["collective-permute"] - 128 * 2) < 1e-6
    # group sizes recorded: 16 (×3), 32, 2, 8, and 1 (collective-permute has
    # source-target pairs, not replica groups)
    assert set(int(k) for k in s.by_group_size) == {16, 32, 2, 8, 1}


def test_parser_ignores_non_collectives():
    s = parse_collectives("%x = f32[8] add(%a, %b)\n%y = f32[8] fusion(%x), calls=%all-reduce-like")
    assert s.count == 0


@given(
    g=st.integers(2, 512),
    elems=st.integers(1, 4096),
    dtype=st.sampled_from([("f32", 4), ("bf16", 2), ("s8", 1)]),
)
@settings(max_examples=100, deadline=None)
def test_property_wire_bytes_model(g, elems, dtype):
    name, size = dtype
    line = f"  %ar = {name}[{elems}] all-reduce(%x), replica_groups=[{512//g if 512%g==0 else 1},{g}]<=[512], to_apply=%a\n"
    s = parse_collectives(line)
    expect = 2 * (g - 1) / g * elems * size
    assert abs(s.wire_bytes - expect) < 1e-6
    # wire bytes are monotone in group size for fixed payload
    s2 = parse_collectives(line.replace(f",{g}]", f",{max(g//2,1)}]") if g >= 4 else line)
    assert s2.wire_bytes <= s.wire_bytes + 1e-9


def test_cost_terms_algebra():
    a = CostTerms(100.0, 1000.0, parse_collectives(HLO))
    b = CostTerms(40.0, 400.0, CollectiveStats())
    d = a - b
    assert d.flops == 60.0 and d.bytes_accessed == 600.0
    s = d.scaled(3.0)
    assert s.flops == 180.0
    assert abs(s.collectives.wire_bytes - 3 * a.collectives.wire_bytes) < 1e-6


def test_extrapolation_algebra_recovers_linear_model():
    """cost(G) = c0 + G·c_l must be exactly recovered from two probes."""
    c0, cl = 7.0, 3.0
    a1 = CostTerms(c0 + cl, 0.0, CollectiveStats())
    a2 = CostTerms(c0 + 2 * cl, 0.0, CollectiveStats())
    c_layer = a2 - a1
    full = (a1 - c_layer) + c_layer.scaled(80)
    assert abs(full.flops - (c0 + 80 * cl)) < 1e-9


def test_collective_time_uses_dci_for_pod_groups():
    s = CollectiveStats()
    s.add("all-reduce", 2, 1e9)  # pod-sized group
    s.add("all-reduce", 16, 1e9)  # ici group
    t_single = collective_time(s, n_pods=1)
    t_multi = collective_time(s, n_pods=2)
    assert t_multi > t_single  # DCI is slower than ICI


def test_model_flops_train_vs_serve():
    from repro.configs.archs import get_arch
    from repro.configs.base import SHAPES

    arch = get_arch("llama3.2-1b")
    t = model_flops(arch, SHAPES["train_4k"])
    p = model_flops(arch, SHAPES["prefill_32k"])
    d = model_flops(arch, SHAPES["decode_32k"])
    assert t == pytest.approx(6 * arch.param_count() * 4096 * 256)
    assert p == pytest.approx(2 * arch.param_count() * 32768 * 32)
    assert d == pytest.approx(2 * arch.param_count() * 128)


def test_roofline_bottleneck_and_mfu():
    r = Roofline(t_compute=1.0, t_memory=2.0, t_collective=0.5,
                 model_flops_global=PEAK_FLOPS * 256, hlo_flops_global=PEAK_FLOPS * 256 * 2,
                 n_chips=256)
    assert r.bottleneck == "memory"
    assert r.t_step == 2.0
    assert r.useful_flops_ratio == pytest.approx(0.5)
    assert r.roofline_fraction == pytest.approx(0.5)  # 1s ideal / 2s step


def test_tpu_hbm_estimator_directionality():
    """More microbatching -> less activation memory; fsdp -> less param memory."""
    from repro.configs.archs import get_arch
    from repro.configs.base import SHAPES, RunConfig
    from repro.launch.mesh import make_host_mesh

    arch = get_arch("qwen2-72b")
    shape = SHAPES["train_4k"]

    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)
            size = 256

    base = estimate_tpu_hbm(arch, RunConfig(), shape, FakeMesh)
    micro = estimate_tpu_hbm(arch, RunConfig(microbatch_size=16), shape, FakeMesh)
    assert micro["carries_gib"] < base["carries_gib"]
    no_zero = estimate_tpu_hbm(arch, RunConfig(zero_sharding="none"), shape, FakeMesh)
    assert no_zero["params_gib"] > base["params_gib"]


def test_cross_cell_probe_compile_cache():
    """Identical (arch, probe RunConfig, shape, mesh, step builder) probes
    compile once per process — a second evaluator for the same cell (the
    multi-cell matrix walk, a repeated session) reuses the extracted costs
    instead of recompiling; any key component changing recompiles."""
    from repro.configs.archs import get_arch
    from repro.configs.base import SHAPES, RunConfig
    from repro.core.roofline import (_compile_cost_probe, clear_probe_cache,
                                     probe_cache_stats)

    arch = get_arch("llama3.2-1b")
    shape = SHAPES["train_4k"]

    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)
            size = 256

    class FakeCompiled:
        def cost_analysis(self):
            return {"flops": 123.0, "bytes accessed": 456.0}

        def as_text(self):
            return ""

    class FakeBundle:
        def lower(self):
            return self

        def compile(self):
            return FakeCompiled()

    compiles = []

    def fake_make_step(arch, run, shape, mesh):
        compiles.append(run)
        return FakeBundle()

    clear_probe_cache()
    try:
        run = RunConfig()
        c1 = _compile_cost_probe(arch, run, shape, FakeMesh, fake_make_step)
        assert len(compiles) == 1
        # probe runs are normalized (scan_layers off, microbatch pinned)
        assert compiles[0].scan_layers is False

        # same cell, new "evaluator" (same args) -> cache hit, no compile
        c2 = _compile_cost_probe(arch, run, shape, FakeMesh, fake_make_step)
        assert len(compiles) == 1
        assert c2 is c1
        assert probe_cache_stats()["entries"] == 1

        # equal-but-distinct RunConfig still hits (value-keyed, not identity)
        c3 = _compile_cost_probe(arch, RunConfig(), shape, FakeMesh,
                                 fake_make_step)
        assert len(compiles) == 1 and c3 is c1

        # any key component changing -> fresh compile
        _compile_cost_probe(arch, run, shape, FakeMesh, fake_make_step,
                            microbatch=8)
        assert len(compiles) == 2
        _compile_cost_probe(arch, RunConfig(remat_policy="none"), shape,
                            FakeMesh, fake_make_step)
        assert len(compiles) == 3
        _compile_cost_probe(arch, run, SHAPES["prefill_32k"], FakeMesh,
                            fake_make_step)
        assert len(compiles) == 4

        class OtherMesh(FakeMesh):
            class devices:
                shape = (32, 8)
                size = 256

        _compile_cost_probe(arch, run, shape, OtherMesh, fake_make_step)
        assert len(compiles) == 5
    finally:
        clear_probe_cache()  # never leak fake costs into real compiles
