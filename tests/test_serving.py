"""Deterministic simulation suite for the online serving tuner.

Everything here runs on injected clocks and scripted latency traces — no JAX,
no wall time in any decision path — so every assertion about guard behaviour
(rollback inside the probation budget, baseline majority at every prefix,
exactly-once promotion, decision-stream determinism) is exact, not
statistical."""
import json

import pytest

from repro.core import Study
from repro.core.feasibility import Rejection
from repro.core.scheduler import INFEASIBLE
from repro.core.space import SERVE_SPACE
from repro.core.strategies import make_strategy
from repro.core.transfer import snap_into_space
from repro.serving import (
    DecodeWindowMonitor,
    GuardConfig,
    OnlineController,
    OnlineJournal,
    SyntheticServeModel,
    TrafficPhase,
    WindowStats,
    quantile,
    scripted_trace,
    surviving_baseline,
)

DEFAULTS = snap_into_space(SERVE_SPACE, {})


# ------------------------------------------------------------------ doubles


class FakeStrategy:
    """Ask/tell double: serves queued configs, records every tell."""

    tag = "fake"
    done = False

    def __init__(self, configs):
        self.queue = [dict(c) for c in configs]
        self.tells = []

    def ask(self, n):
        out = []
        while self.queue and len(out) < n:
            out.append(self.queue.pop(0))
        return out

    def tell(self, trials):
        self.tells.extend(trials)


class RecordingJournal:
    def __init__(self):
        self.windows = []   # (plan, stats)
        self.decisions = []  # (kind, fields)

    def window(self, plan, stats):
        self.windows.append((plan, stats))

    def decision(self, kind, **fields):
        self.decisions.append((kind, fields))


def stats(window, p99, p50=None):
    p50 = p99 * 0.9 if p50 is None else p50
    return WindowStats(window=window, count=24, p50=p50, p99=p99,
                       mean=p50, max=p99, tokens_per_s=100.0, wall_s=0.24)


def drive(controller, n_windows, base_p99=1.0, cand_p99=2.0):
    """Serve ``n_windows`` with scripted p99s keyed by the served config
    (CAND is genuinely cand_p99-fast, everything else base_p99), so a
    promoted candidate keeps its measured speed as the new baseline."""
    plans = []
    for w in range(n_windows):
        plan = controller.next_window()
        p = cand_p99 if plan.config == CAND else base_p99
        controller.observe(plan, stats(w, p))
        plans.append(plan)
    return plans


CAND = {**DEFAULTS, "attn_block_kv": 256}
GUARD = GuardConfig()  # slice_frac 0.2 -> round_length 5, warmup 2


# ------------------------------------------------------------------- metrics


def test_quantile_matches_numpy_convention():
    vals = [4.0, 1.0, 3.0, 2.0]
    assert quantile(vals, 0.5) == pytest.approx(2.5)
    assert quantile(vals, 0.0) == 1.0
    assert quantile(vals, 1.0) == 4.0
    assert quantile(vals, 0.99) == pytest.approx(3.97)
    assert quantile([7.0], 0.25) == 7.0


def test_quantile_rejects_bad_input():
    with pytest.raises(ValueError):
        quantile([], 0.5)
    with pytest.raises(ValueError):
        quantile([1.0], 1.5)


def test_monitor_clockless_windows_are_deterministic():
    def run():
        mon = DecodeWindowMonitor()
        mon.begin_window()
        for lat in (0.01, 0.02, 0.03, 0.04):
            mon.record(lat, tokens=8)
        return mon.end_window()

    a, b = run(), run()
    assert a == b
    assert a.count == 4
    assert a.wall_s == pytest.approx(0.10)  # clock=None: sum of latencies
    assert a.tokens_per_s == pytest.approx(32 / 0.10)
    assert a.p50 == pytest.approx(0.025)
    assert a.max == 0.04


def test_monitor_injected_clock_measures_wall_time():
    t = [0.0]
    mon = DecodeWindowMonitor(clock=lambda: t[0])
    mon.begin_window()
    mon.record(0.01)
    t[0] = 2.0
    s = mon.end_window()
    assert s.wall_s == pytest.approx(2.0)
    assert s.tokens_per_s == pytest.approx(0.5)


def test_monitor_protocol_misuse_raises():
    mon = DecodeWindowMonitor()
    with pytest.raises(RuntimeError):
        mon.record(0.01)
    with pytest.raises(RuntimeError):
        mon.end_window()
    mon.begin_window()
    with pytest.raises(RuntimeError):
        mon.begin_window()
    with pytest.raises(RuntimeError):
        mon.end_window()  # no samples
    mon.record(0.01)
    mon.end_window()
    agg = mon.aggregate()
    assert agg is not None and agg.count == 1


def test_monitor_reservoir_bounds_window_memory():
    mon = DecodeWindowMonitor(max_samples=8)
    mon.begin_window()
    for i in range(100):
        mon.record(float(i))
    s = mon.end_window()
    assert s.count == 8
    assert s.p50 == pytest.approx(quantile([92.0 + i for i in range(8)], 0.5))


# ---------------------------------------------------------------- guard cfg


@pytest.mark.parametrize("kwargs", [
    dict(safety_p99=1.0),
    dict(safety_p99=0.8),
    dict(slice_frac=0.0),
    dict(slice_frac=0.5),   # exactly half: baseline would lose its majority
    dict(slice_frac=0.7),
    dict(probation_windows=0),
    dict(promote_margin=1.0),
    dict(warmup_windows=0),
    dict(baseline_window=0),
])
def test_guard_config_validates(kwargs):
    with pytest.raises(ValueError):
        GuardConfig(**kwargs)


def test_round_length_keeps_baseline_majority():
    assert GuardConfig(slice_frac=0.2).round_length == 5
    assert GuardConfig(slice_frac=0.45).round_length == 3
    assert GuardConfig(slice_frac=0.01).round_length == 100


# --------------------------------------------------------------- controller


def test_regression_rolls_back_within_probation_budget():
    strategy = FakeStrategy([CAND])
    journal = RecordingJournal()
    ctrl = OnlineController(SERVE_SPACE, strategy, DEFAULTS,
                            guard=GUARD, journal=journal)
    drive(ctrl, 10, base_p99=1.0, cand_p99=2.0)  # 2.0 > 1.25 * 1.0
    assert ctrl.rollbacks == 1
    rollbacks = [f for k, f in journal.decisions if k == "rollback"]
    assert len(rollbacks) == 1
    # the rollback budget: a regressing candidate serves at most
    # probation_windows windows before it is gone
    assert rollbacks[0]["windows_served"] <= GUARD.probation_windows
    assert rollbacks[0]["bound"] == pytest.approx(1.25)
    # the regressing config never becomes the baseline
    assert ctrl.baseline == DEFAULTS
    # penalty observation: honest measurement, infeasible score
    penalty = strategy.tells[-1]
    assert penalty.status == "rollback"
    assert penalty.time_s == pytest.approx(2.0)
    assert penalty.score == INFEASIBLE
    assert "RollbackGuard" in penalty.error


def test_baseline_holds_majority_at_every_prefix():
    strategy = FakeStrategy([CAND] * 8)
    journal = RecordingJournal()
    ctrl = OnlineController(SERVE_SPACE, strategy, DEFAULTS,
                            guard=GUARD, journal=journal)
    drive(ctrl, 40, base_p99=1.0, cand_p99=2.0)
    base = cand = 0
    for plan, _stats in journal.windows:
        if plan.slice == "baseline":
            base += 1
        else:
            cand += 1
        assert base > cand, f"candidate majority at window {plan.window}"
    assert cand > 0  # the guarantee was actually exercised


def test_surviving_improvement_promotes_exactly_once():
    strategy = FakeStrategy([CAND])
    journal = RecordingJournal()
    ctrl = OnlineController(SERVE_SPACE, strategy, DEFAULTS,
                            guard=GUARD, journal=journal)
    drive(ctrl, 20, base_p99=1.0, cand_p99=0.9)  # 10% better, margin is 3%
    assert ctrl.promotions == 1
    promotes = [f for k, f in journal.decisions if k == "promote"]
    assert len(promotes) == 1
    assert promotes[0]["candidate_p99"] < promotes[0]["baseline_p99"]
    # the candidate is the new incumbent and serves the majority slice
    assert ctrl.baseline == CAND
    last_baseline_plan = [p for p, _ in journal.windows
                          if p.slice == "baseline"][-1]
    assert last_baseline_plan.config == CAND
    # the probation produced one honest (non-penalty) observation
    honest = strategy.tells[-1]
    assert honest.error is None
    assert honest.score == pytest.approx(0.9)
    # summary speaks the offline vocabulary
    s = ctrl.summary()
    assert s["best_time_s"] < s["default_time_s"]
    assert s["best_config"] == CAND
    assert s["promotions"] == 1 and s["rollbacks"] == 0


def test_survivor_without_improvement_is_demoted():
    strategy = FakeStrategy([CAND])
    journal = RecordingJournal()
    ctrl = OnlineController(SERVE_SPACE, strategy, DEFAULTS,
                            guard=GUARD, journal=journal)
    drive(ctrl, 20, base_p99=1.0, cand_p99=0.99)  # inside the 3% margin
    assert ctrl.promotions == 0 and ctrl.demotions == 1
    assert ctrl.baseline == DEFAULTS
    honest = strategy.tells[-1]
    assert honest.error is None and honest.time_s == pytest.approx(0.99)


def test_static_rejection_never_serves_traffic():
    doomed = {**DEFAULTS, "attn_block_kv": 2048}

    def prefilter(config, platform, fidelity):
        if config["attn_block_kv"] == 2048:
            return Rejection("test_rule", "doomed by construction",
                             {"bkv": 2048})
        return None

    strategy = FakeStrategy([doomed, CAND])
    journal = RecordingJournal()
    ctrl = OnlineController(SERVE_SPACE, strategy, DEFAULTS, guard=GUARD,
                            journal=journal, prefilter=prefilter)
    drive(ctrl, 10, base_p99=1.0, cand_p99=0.9)
    assert ctrl.rejections == 1
    rejects = [f for k, f in journal.decisions if k == "reject_static"]
    assert len(rejects) == 1 and rejects[0]["rule"] == "test_rule"
    # the doomed config never appears in any served window
    assert all(p.config != doomed for p, _ in journal.windows)
    # ...but was penalty-told so the strategy steers away
    first_tell = strategy.tells[0]
    assert first_tell.status == "infeasible_static"
    assert first_tell.score == INFEASIBLE
    # the vetted replacement candidate did serve
    assert any(p.slice == "candidate" and p.config == CAND
               for p, _ in journal.windows)


def test_observe_requires_matching_plan():
    ctrl = OnlineController(SERVE_SPACE, FakeStrategy([]), DEFAULTS,
                            guard=GUARD)
    with pytest.raises(RuntimeError):
        ctrl.observe(
            type("P", (), {"window": 0, "slice": "baseline",
                           "config": DEFAULTS, "candidate_id": None})(),
            stats(0, 1.0))
    plan = ctrl.next_window()
    with pytest.raises(RuntimeError):
        ctrl.next_window()  # previous plan not observed yet
    ctrl.observe(plan, stats(0, 1.0))


def test_off_grid_baseline_is_snapped():
    ctrl = OnlineController(
        SERVE_SPACE, FakeStrategy([]),
        {"attn_block_kv": 200, "kv_cache_dtype": "int8"}, guard=GUARD)
    assert ctrl.baseline["attn_block_kv"] == 256
    assert ctrl.baseline["kv_cache_dtype"] == "int8"
    assert ctrl.baseline["mesh_model_parallel"] == 16  # default filled


# ------------------------------------------------------------- determinism


def simulate(trace_name, seed):
    """One full synthetic run; returns the decision stream."""
    strategy = make_strategy("random", SERVE_SPACE, max_trials=32, seed=seed)
    journal = RecordingJournal()
    ctrl = OnlineController(SERVE_SPACE, strategy, DEFAULTS,
                            guard=GUARD, journal=journal)
    model = SyntheticServeModel(scripted_trace(trace_name), seed=seed)
    mon = DecodeWindowMonitor()
    for w in range(model.total_windows):
        plan = ctrl.next_window()
        mon.begin_window()
        for lat in model.latencies(w, plan.config, plan.slice):
            mon.record(lat, tokens=model.phase_at(w).batch)
        ctrl.observe(plan, mon.end_window())
    return journal.decisions, ctrl.summary()


def test_decision_stream_is_pure_function_of_seed_and_trace():
    d1, s1 = simulate("drift", seed=7)
    d2, s2 = simulate("drift", seed=7)
    assert d1 == d2
    assert s1 == s2
    d3, _ = simulate("drift", seed=8)
    assert d1 != d3  # the seed actually reaches the strategy and traffic


def test_flat_trace_never_rolls_back():
    decisions, summary = simulate("flat", seed=0)
    assert summary["rollbacks"] == 0
    assert not any(k == "rollback" for k, _ in decisions)


def test_regression_trace_rolls_back_every_candidate():
    decisions, summary = simulate("regression", seed=0)
    assert summary["rollbacks"] >= 1
    assert summary["promotions"] == 0
    assert all(f["windows_served"] <= GUARD.probation_windows
               for k, f in decisions if k == "rollback")


def test_drift_trace_promotes_a_better_baseline():
    decisions, summary = simulate("drift", seed=0)
    assert summary["promotions"] >= 1
    assert summary["best_time_s"] < summary["default_time_s"]
    for k, f in decisions:
        if k == "promote":
            assert f["candidate_p99"] < f["baseline_p99"]


# ----------------------------------------------------------------- traffic


def test_phase_schedule_and_final_phase_extension():
    model = SyntheticServeModel(scripted_trace("drift"))
    assert model.phase_at(0).name == "long-prompts"
    assert model.phase_at(15).name == "long-prompts"
    assert model.phase_at(16).name == "short-prompts"
    assert model.phase_at(10_000).name == "short-prompts"
    with pytest.raises(ValueError):
        model.phase_at(-1)
    with pytest.raises(ValueError):
        scripted_trace("nope")
    with pytest.raises(ValueError):
        SyntheticServeModel(())


def test_traffic_cost_prefers_phase_optimum():
    phase = TrafficPhase("p", windows=4, prompt_len=256, batch=8,
                         ideal_block_kv=128, ideal_kv_dtype="int8", amp=2.0)
    model = SyntheticServeModel((phase,))
    good = model.cost({"attn_block_kv": 128, "kv_cache_dtype": "int8"}, phase)
    far = model.cost({"attn_block_kv": 1024, "kv_cache_dtype": "int8"}, phase)
    wrong_dtype = model.cost(
        {"attn_block_kv": 128, "kv_cache_dtype": "bfloat16"}, phase)
    assert good < wrong_dtype < far
    assert far == pytest.approx(good * (1 + 2.0 * 0.25 * 3))


def test_traffic_p99_exceeds_p50():
    model = SyntheticServeModel(scripted_trace("flat"), seed=1)
    lats = model.latencies(3, DEFAULTS, "baseline")
    assert quantile(lats, 0.99) > quantile(lats, 0.5)


# ------------------------------------------------------ journal + Study


def run_journaled_session(study, n_windows=20, cand_p99=0.9):
    strategy = FakeStrategy([CAND])
    journal = OnlineJournal(study, "serve-online/test",
                            algorithm="online-fake", guard=GUARD,
                            baseline=DEFAULTS)
    ctrl = OnlineController(SERVE_SPACE, strategy, DEFAULTS, guard=GUARD,
                            journal=journal, platform="serve-online/test")
    drive(ctrl, n_windows, base_p99=1.0, cand_p99=cand_p99)
    return journal, ctrl


def test_online_session_lands_in_study_report(tmp_path):
    study = Study.create(tmp_path / "study")
    with study:
        journal, ctrl = run_journaled_session(study)
        journal.finish(ctrl.summary())

    loaded = Study.load(tmp_path / "study")
    rows = loaded.report()["sessions"]
    assert len(rows) == 1
    row = rows[0]
    assert row["mode"] == "online"
    assert row["status"] == "done"
    assert row["algorithm"] == "online-fake"
    assert row["promotions"] == 1 and row["rollbacks"] == 0
    assert row["windows"] == 20
    assert row["best_time_s"] < row["default_time_s"]
    # window records landed in the trial log with online provenance
    trials = [json.loads(line) for line in
              (tmp_path / "study" / "trials.jsonl").read_text().splitlines()]
    assert len(trials) == 20
    assert all(t["source"] == "online" for t in trials)
    slices = {t["info"]["slice"] for t in trials}
    assert slices == {"baseline", "candidate"}
    # guard decisions are session events in sessions.jsonl
    recs = [json.loads(line) for line in
            (tmp_path / "study" / "sessions.jsonl").read_text().splitlines()]
    kinds = [r.get("kind") for r in recs if r["event"] == "guard"]
    assert kinds == ["probation_start", "promote"]


def test_interrupted_run_resumes_with_surviving_baseline(tmp_path):
    study = Study.create(tmp_path / "study")
    with study:
        journal, ctrl = run_journaled_session(study)
        # no journal.finish(): the process died mid-run

    loaded = Study.load(tmp_path / "study")
    assert loaded.report()["sessions"][0]["status"] == "interrupted"
    # the promoted candidate — not the starting default — survives
    assert surviving_baseline(loaded, "serve-online/test") == CAND
    assert surviving_baseline(loaded, "serve-online/other") is None
    # offline resume() must NOT try to replay the online session
    with pytest.raises(ValueError, match="nothing to resume"):
        loaded.resume()


def test_surviving_baseline_prefers_latest_promotion(tmp_path):
    study = Study.create(tmp_path / "study")
    with study:
        j1, c1 = run_journaled_session(study, cand_p99=0.9)
        j1.finish(c1.summary())
        # second session: no promotion — its start baseline (the defaults
        # recorded at construction) must not clobber session 1's promote
        j2 = OnlineJournal(study, "serve-online/test",
                           algorithm="online-fake", guard=GUARD,
                           baseline=CAND)
        c2 = OnlineController(SERVE_SPACE, FakeStrategy([]), CAND,
                              guard=GUARD, journal=j2,
                              platform="serve-online/test")
        drive(c2, 6, base_p99=0.9)
        j2.finish(c2.summary())
    loaded = Study.load(tmp_path / "study")
    assert surviving_baseline(loaded, "serve-online/test") == CAND


def test_session_event_rejects_lifecycle_names(tmp_path):
    study = Study.create(tmp_path / "study")
    with study:
        sid = study.begin_session("p", "a", mode="online")
        with pytest.raises(ValueError):
            study.record_session_event(sid, "done", {})


# ------------------------------------------------------------- CLI smokes


def serve_main(argv):
    from repro.launch.serve import main

    return main(argv)


def load_summary(capsys):
    return json.loads(capsys.readouterr().out)


def test_cli_online_regression_smoke(tmp_path, capsys):
    rc = serve_main(["--online-tune", "--study", str(tmp_path / "s"),
                     "--traffic", "regression", "--strategy", "random",
                     "--windows", "20", "--seed", "0"])
    assert rc == 0
    s = load_summary(capsys)
    assert s["rollbacks"] >= 1
    assert s["promotions"] == 0
    assert s["windows_baseline"] > s["windows_candidate"]
    assert s["best_config"] == s["baseline_start"]


def test_cli_online_flat_smoke(tmp_path, capsys):
    rc = serve_main(["--online-tune", "--study", str(tmp_path / "s"),
                     "--traffic", "flat", "--strategy", "random",
                     "--windows", "20", "--seed", "0"])
    assert rc == 0
    assert load_summary(capsys)["rollbacks"] == 0


def test_cli_online_requires_study():
    with pytest.raises(SystemExit):
        serve_main(["--online-tune", "--traffic", "flat"])


def test_cli_drift_resumes_surviving_baseline(tmp_path, capsys):
    study = str(tmp_path / "s")
    argv = ["--online-tune", "--study", study, "--traffic", "drift",
            "--strategy", "tpe", "--seed", "0"]
    assert serve_main(argv) == 0
    s1 = load_summary(capsys)
    assert s1["promotions"] >= 1
    assert s1["best_time_s"] < s1["default_time_s"]
    # run 2 starts from run 1's surviving baseline, not the defaults
    assert serve_main(argv) == 0
    s2 = load_summary(capsys)
    assert s2["baseline_start"] == s1["best_config"]
