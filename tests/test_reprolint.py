"""The repo-invariant linter (tools/reprolint.py): each rule fires on a
minimal violating sample, the escape hatch suppresses, and the shipped
src/ tree is clean."""
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import reprolint  # noqa: E402


def run_lint(tmp_path, source, subdir=""):
    d = tmp_path / "pkg" / subdir if subdir else tmp_path / "pkg"
    d.mkdir(parents=True, exist_ok=True)
    f = d / "sample.py"
    f.write_text(source)
    return reprolint.lint_file(f)


def rules(findings):
    return [rule for _path, _line, rule, _msg in findings]


# ------------------------------------------------------- strategy purity


def test_wallclock_in_strategies_flagged(tmp_path):
    src = "import time\n\ndef propose():\n    return time.time()\n"
    findings = run_lint(tmp_path, src, subdir="strategies")
    assert rules(findings) == ["strategy-wallclock"]


def test_perf_counter_and_datetime_flagged(tmp_path):
    src = (
        "import time\nfrom datetime import datetime\n\n"
        "def f():\n"
        "    a = time.perf_counter()\n"
        "    b = datetime.now()\n"
        "    return a, b\n"
    )
    findings = run_lint(tmp_path, src, subdir="strategies")
    assert rules(findings).count("strategy-wallclock") >= 1


def test_wallclock_outside_strategies_allowed(tmp_path):
    # evaluators legitimately measure wall time — the rule is scoped
    src = "import time\n\ndef measure():\n    return time.perf_counter()\n"
    assert run_lint(tmp_path, src) == []


def test_unseeded_random_flagged_seeded_allowed(tmp_path):
    bad = "import random\n\ndef f():\n    return random.random()\n"
    findings = run_lint(tmp_path, bad, subdir="strategies")
    assert rules(findings) == ["strategy-unseeded-random"]

    good = (
        "import random\n\n"
        "def f(seed):\n"
        "    rng = random.Random(seed)\n"
        "    return rng.random()\n"
    )
    assert run_lint(tmp_path, good, subdir="strategies") == []


def test_np_random_flagged(tmp_path):
    src = "import numpy as np\n\ndef f():\n    return np.random.rand(3)\n"
    findings = run_lint(tmp_path, src, subdir="strategies")
    assert rules(findings) == ["strategy-unseeded-random"]


# -------------------------------------------------- evaluator declarations


def test_evaluator_without_parallel_safe_flagged(tmp_path):
    src = (
        "class ShinyEvaluator:\n"
        "    def __call__(self, config):\n"
        "        return 1.0, {}\n"
    )
    findings = run_lint(tmp_path, src)
    assert rules(findings) == ["evaluator-parallel-safe"]


def test_evaluator_declarations_satisfy_rule(tmp_path):
    class_attr = (
        "class AEvaluator:\n"
        "    parallel_safe = False\n"
        "    def __call__(self, config):\n"
        "        return 1.0, {}\n"
    )
    dataclass_field = (
        "from dataclasses import dataclass\n\n"
        "@dataclass\n"
        "class BEvaluator:\n"
        "    parallel_safe: bool = True\n"
        "    def __call__(self, config):\n"
        "        return 1.0, {}\n"
    )
    init_assign = (
        "class CEvaluator:\n"
        "    def __init__(self):\n"
        "        self.parallel_safe = True\n"
        "    def __call__(self, config):\n"
        "        return 1.0, {}\n"
    )
    for src in (class_attr, dataclass_field, init_assign):
        assert run_lint(tmp_path, src) == []


def test_evaluator_protocol_itself_exempt(tmp_path):
    src = (
        "class Evaluator:\n"
        "    def __call__(self, config):\n"
        "        ...\n"
    )
    assert run_lint(tmp_path, src) == []


# ------------------------------------------------------- fidelity contract


def test_supports_fidelity_with_bare_kwargs_flagged(tmp_path):
    src = (
        "class DEvaluator:\n"
        "    parallel_safe = True\n"
        "    supports_fidelity = True\n"
        "    def __call__(self, config, **kwargs):\n"
        "        return 1.0, {}\n"
    )
    findings = run_lint(tmp_path, src)
    assert rules(findings) == ["fidelity-explicit-param"]


def test_supports_fidelity_with_explicit_param_ok(tmp_path):
    src = (
        "class EEvaluator:\n"
        "    parallel_safe = True\n"
        "    supports_fidelity = True\n"
        "    def __call__(self, config, fidelity=1.0):\n"
        "        return 1.0, {}\n"
    )
    assert run_lint(tmp_path, src) == []


def test_supports_fidelity_false_not_checked(tmp_path):
    src = (
        "class FEvaluator:\n"
        "    parallel_safe = True\n"
        "    supports_fidelity = False\n"
        "    def __call__(self, config, **kwargs):\n"
        "        return 1.0, {}\n"
    )
    assert run_lint(tmp_path, src) == []


# ----------------------------------------------------------- escape hatch


def test_escape_hatch_suppresses(tmp_path):
    src = (
        "import time\n\n"
        "def f():\n"
        "    return time.time()  # reprolint: ok\n"
    )
    assert run_lint(tmp_path, src, subdir="strategies") == []


# -------------------------------------------------- serving injected clock


def test_wallclock_in_serving_flagged(tmp_path):
    src = ("import time\n\n"
           "def end_window():\n"
           "    return time.perf_counter()\n")
    findings = run_lint(tmp_path, src, subdir="serving")
    assert rules(findings) == ["serving-injected-clock"]


def test_datetime_now_in_serving_flagged(tmp_path):
    src = ("from datetime import datetime\nimport time\n\n"
           "def stamp():\n"
           "    return datetime.now(), time.time()\n")
    findings = run_lint(tmp_path, src, subdir="serving")
    assert rules(findings) == ["serving-injected-clock",
                               "serving-injected-clock"]


def test_wallclock_outside_serving_allowed(tmp_path):
    # the serve driver injects time.perf_counter from launch/ — reading the
    # clock is fine there, only serving/ decision code is banned
    src = "import time\n\ndef drive():\n    return time.perf_counter()\n"
    assert run_lint(tmp_path, src, subdir="launch") == []


def test_serving_escape_hatch(tmp_path):
    src = ("import time\n\n"
           "def f():\n"
           "    return time.time()  # reprolint: ok\n")
    assert run_lint(tmp_path, src, subdir="serving") == []


def test_injected_clock_reference_is_not_a_call(tmp_path):
    # passing the callable through (clock=time.perf_counter) is the whole
    # point of the injection seam — only *calls* are reads
    src = ("import time\n\n"
           "def make_monitor(Monitor):\n"
           "    return Monitor(clock=time.perf_counter)\n")
    assert run_lint(tmp_path, src, subdir="serving") == []


def test_syntax_error_reported_not_crashed(tmp_path):
    findings = run_lint(tmp_path, "def broken(:\n")
    assert rules(findings) == ["parse-error"]


# ------------------------------------------------------------- repo clean


def test_shipped_src_tree_is_clean():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "reprolint.py"),
         str(REPO / "src")],
        capture_output=True, text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_exits_nonzero_on_findings(tmp_path):
    bad = tmp_path / "strategies"
    bad.mkdir()
    (bad / "x.py").write_text(
        "import random\n\ndef f():\n    return random.random()\n")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "reprolint.py"), str(tmp_path)],
        capture_output=True, text=True,
    )
    assert proc.returncode == 1
    assert "strategy-unseeded-random" in proc.stdout
