"""Cross-cell transfer: deterministic-simulation suite.

A synthetic family of FunctionEvaluator-style cells over the real
``TRAIN_SPACE`` (``tests/synthetic_cells.py``) with a known shared optimum
and one shifted *outlier* cell. Everything is seeded and wall-clock-free, so
the headline claims are exact, not statistical:

  - transfer-on reaches the transfer-off run's incumbent in strictly fewer
    fresh evaluations at equal budget,
  - the outlier cell is not hurt beyond a bounded regret,
  - sibling trials never count toward the session budget,
  - the proposal stream is a pure function of (seed, observations, siblings),
  - a seeded transfer session replays identically (0 fresh) when repeated
    over its complete cache, resumes with the recorded sibling set, and
    refuses to resume when a recorded sibling namespace went missing.
"""
import json
import math
import threading

import pytest

from repro.core import (
    TRAIN_SPACE,
    SiblingHistory,
    Study,
    TrialScheduler,
    config_key,
    default_similarity,
    parse_namespace,
    snap_into_space,
)
from repro.core.scheduler import Trial, config_hash, read_cache_by_platform
from repro.core.strategies.crs import CRSStrategy
from repro.core.strategies.gsft import GridFinerStrategy
from repro.core.strategies.tpe import TPEStrategy
from repro.core.transfer import CellKey

from synthetic_cells import (
    SHARED_TARGET,
    SyntheticCellEvaluator,
    base_for,
    cell_time,
    target_for,
)

CELL_A = "train/cellA:train_4k"
CELL_B = "train/cellB:train_4k"
CELL_C = "train/cellC:train_4k"  # the outlier

BUDGET_A, SEED_A = 48, 1
BUDGET_B = 24


def _tune_family(tmp_path, name, second_cell, mode, seed_b, **algo_kwargs):
    """Tune cell A, then ``second_cell`` with the given transfer mode, in one
    fresh study. Returns (study, evaluator_A, outcome_A, evaluator_B,
    outcome_B)."""
    study = Study.create(tmp_path / name)
    ev_a = SyntheticCellEvaluator("cellA")
    out_a = study.optimize(CELL_A, "tpe", ev_a, budget=BUDGET_A, seed=SEED_A)
    arch_b = second_cell.split("/")[1].split(":")[0]
    ev_b = SyntheticCellEvaluator(arch_b)
    out_b = study.optimize(second_cell, "tpe", ev_b, budget=BUDGET_B,
                           seed=seed_b, transfer=mode, **algo_kwargs)
    return study, ev_a, out_a, ev_b, out_b


def _evals_to(trajectory, threshold):
    """1-based index of the first fresh evaluation at or under threshold;
    'budget exhausted without reaching it' reads as +inf."""
    for i, t in enumerate(trajectory, start=1):
        if t <= threshold:
            return i
    return math.inf


# ---------------------------------------------------- the headline guarantees


def test_transfer_prior_reaches_incumbent_in_fewer_fresh_evals(tmp_path):
    """At equal budget, the sibling cell under --transfer prior reaches the
    transfer-off run's own incumbent in strictly fewer fresh evaluations."""
    _, _, _, ev_off, out_off = _tune_family(tmp_path, "off", CELL_B, "off", 4)
    _, _, _, ev_pri, out_pri = _tune_family(tmp_path, "pri", CELL_B, "prior", 4)

    incumbent_time = out_off.best_time
    reached_off = _evals_to(ev_off.trajectory, incumbent_time)
    reached_pri = _evals_to(ev_pri.trajectory, incumbent_time)
    assert reached_pri < reached_off, (reached_pri, reached_off)
    # and the transferred run is at least as good at the same price
    assert out_pri.best_time <= out_off.best_time
    assert ev_pri.calls == ev_off.calls  # equal budget, equal fresh evals
    assert out_pri.detail.transfer_mode == "prior"
    assert out_pri.detail.sibling_observations > 0


def test_outlier_cell_not_hurt_beyond_bounded_regret(tmp_path):
    """Cell C's optimum is in the opposite corner — a misleading prior must
    cost a bounded number of early proposals, not the session. Regret is
    bounded across several seeds, not cherry-picked on one."""
    bound = 0.5  # objective spans ~2.5s; defaults sit ~1.0s over optimum
    for seed_b in (0, 1, 2, 3):
        _, _, _, _, out_off = _tune_family(
            tmp_path, f"off{seed_b}", CELL_C, "off", seed_b)
        _, _, _, _, out_pri = _tune_family(
            tmp_path, f"pri{seed_b}", CELL_C, "prior", seed_b)
        regret = out_pri.best_time - out_off.best_time
        assert regret <= bound, (seed_b, regret)


def test_sibling_trials_never_count_toward_budget(tmp_path):
    """The transferred session pays exactly its own budget (+1 defaults
    trial): sibling observations are free model evidence, not spent trials —
    and none of the sibling's configs are force-replayed into this cell."""
    _, ev_a, _, ev_b, out = _tune_family(tmp_path, "s", CELL_B, "prior", 2)
    assert out.evaluations == BUDGET_B + 1  # own budget + defaults, exactly
    assert out.detail.sibling_observations >= BUDGET_A  # prior ingested A
    assert ev_b.calls == BUDGET_B + 1  # all fresh evals were cell B's own


def test_warm_mode_seeds_tpe_startup_with_sibling_incumbent(tmp_path):
    """--transfer warm: the first proposal after the defaults trial is the
    sibling's incumbent snapped into this cell (budget-charged like any
    proposal — warm seeds are trials, not free evidence)."""
    _, _, out_a, ev_b, out_b = _tune_family(tmp_path, "w", CELL_B, "warm", 2)
    expected = cell_time(
        snap_into_space(TRAIN_SPACE, out_a.best_config),
        target=target_for("cellB"), base=base_for("cellB"),
    )
    # trajectory[0] is the defaults trial, [1] the first strategy proposal
    assert ev_b.trajectory[1] == pytest.approx(expected)
    assert out_b.detail.transfer_mode == "warm"
    assert out_b.detail.sibling_observations == 0  # warm adds no prior points
    assert out_b.evaluations == BUDGET_B + 1


# ------------------------------------------------ purity of the proposal flow


def _drive(strategy, objective, batch=None, limit=200):
    """Ask/tell loop against a deterministic objective; returns the proposed
    config-key stream."""
    stream = []
    while not strategy.done and len(stream) < limit:
        configs = strategy.ask(batch)
        if not configs:
            break
        stream += [config_key(c) for c in configs]
        strategy.tell([Trial(dict(c), objective(c)) for c in configs])
    return stream


def _siblings_from(evaluations):
    return [SiblingHistory("train/cellA:train_4k", 1.0, tuple(evaluations))]


def _family_history(n=20, seed=9):
    """Deterministic pseudo-history of cell A: n seeded samples of the space
    with their true cell-A times."""
    import random

    rng = random.Random(seed)
    out = []
    for _ in range(n):
        cfg = {p.name: p.sample(rng) for p in TRAIN_SPACE.params}
        t = cell_time(cfg, target=target_for("cellA"), base=base_for("cellA"))
        out.append((cfg, t, "tpe/round1"))
    return out


def test_proposal_stream_is_pure_function_of_seed_obs_siblings():
    sibs = _siblings_from(_family_history())
    objective = lambda c: cell_time(  # noqa: E731
        c, target=target_for("cellB"), base=base_for("cellB"))

    def fresh(seed):
        s = TPEStrategy(TRAIN_SPACE, max_trials=16, seed=seed)
        s.on_study_attach((), siblings=sibs, transfer="prior")
        return s

    # same (seed, siblings) -> byte-identical stream
    assert _drive(fresh(7), objective) == _drive(fresh(7), objective)
    # batch size changes scheduling, not the proposed set (round batching)
    assert set(_drive(fresh(7), objective, batch=1)) == \
        set(_drive(fresh(7), objective, batch=5))
    # the siblings are part of the function's domain: drop them, stream moves
    bare = TPEStrategy(TRAIN_SPACE, max_trials=16, seed=7)
    assert _drive(bare, objective) != _drive(fresh(7), objective)
    # and a different seed moves it too
    assert _drive(fresh(8), objective) != _drive(fresh(7), objective)


def test_attach_after_construction_equals_constructor_history():
    """on_study_attach(history, siblings) after construction is identical to
    constructor history + attach — the rng resets make attach idempotent."""
    hist = [(cfg, t) for cfg, t, _ in _family_history(8)]
    sibs = _siblings_from(_family_history(12, seed=3))
    objective = lambda c: cell_time(  # noqa: E731
        c, target=target_for("cellB"), base=base_for("cellB"))

    a = TPEStrategy(TRAIN_SPACE, max_trials=12, seed=5, history=hist)
    a.on_study_attach((), siblings=sibs, transfer="prior")
    b = TPEStrategy(TRAIN_SPACE, max_trials=12, seed=5)
    b.on_study_attach(hist, siblings=sibs, transfer="prior")
    assert _drive(a, objective) == _drive(b, objective)


def test_gsft_and_crs_warm_seed_sibling_incumbents():
    """The cheap warm mode: sibling incumbents (snapped into the local space)
    lead the initial candidate set of both paper algorithms."""
    incumbent = {p.name: p.default for p in TRAIN_SPACE.params}
    incumbent.update(SHARED_TARGET)
    sibs = [SiblingHistory("train/cellA:train_4k", 0.5,
                           ((incumbent, 3.0, "tpe/round1"),
                            ({**incumbent, "mesh_model_parallel": 32}, 9.0,
                             "tpe/round1")))]
    expected = snap_into_space(TRAIN_SPACE, incumbent)

    g = GridFinerStrategy(TRAIN_SPACE, samples_per_param=2)
    n_grid = len(g._pending)
    g.on_study_attach((), siblings=sibs, transfer="warm")
    assert g.ask(1)[0] == expected
    assert len(g._pending) == n_grid  # grid intact behind the seed

    c = CRSStrategy(TRAIN_SPACE, m=6, seed=0)
    c.on_study_attach((), siblings=sibs, transfer="warm")
    first = c.ask(1)[0]
    assert first == expected
    # the rng draw stream was untouched: the 6 random draws still follow
    assert len(c._pending) == 6


def test_transfer_off_or_no_siblings_is_a_noop():
    base = TPEStrategy(TRAIN_SPACE, max_trials=12, seed=3)
    objective = lambda c: cell_time(  # noqa: E731
        c, target=target_for("cellB"), base=base_for("cellB"))
    off = TPEStrategy(TRAIN_SPACE, max_trials=12, seed=3)
    off.on_study_attach((), siblings=_siblings_from(_family_history()),
                        transfer="off")
    empty = TPEStrategy(TRAIN_SPACE, max_trials=12, seed=3)
    empty.on_study_attach((), siblings=[], transfer="prior")
    expected = _drive(base, objective)
    assert _drive(off, objective) == expected
    assert _drive(empty, objective) == expected


def test_unsupported_strategy_with_transfer_raises(tmp_path):
    study = Study.create(tmp_path / "s")
    ev = SyntheticCellEvaluator("cellA")
    with pytest.raises(ValueError, match="does not support cross-cell"):
        study.optimize(CELL_A, "hillclimb", ev, transfer="prior", moves=[])
    with pytest.raises(ValueError, match="transfer must be one of"):
        study.optimize(CELL_A, "tpe", ev, transfer="bogus")


# ------------------------------------------------- provenance, replay, resume


def test_prior_request_on_warm_only_strategy_records_effective_mode(tmp_path):
    """gsft/crs only implement warm seeding; asking for 'prior' must run —
    and RECORD — 'warm', never provenance for a prior that didn't exist."""
    study = Study.create(tmp_path / "s")
    study.optimize(CELL_A, "tpe", SyntheticCellEvaluator("cellA"),
                   budget=10, seed=SEED_A)
    study.optimize(CELL_B, "gsft", SyntheticCellEvaluator("cellB"),
                   transfer="prior", samples_per_param=2)
    row = study.report()["sessions"][-1]
    assert row["transfer"] == "warm"
    rec = [r for r in study.sessions() if r.get("event") == "start"][-1]
    assert rec["transfer"]["mode"] == "warm"


def test_report_carries_transfer_column(tmp_path):
    study, _, _, _, _ = _tune_family(tmp_path, "r", CELL_B, "prior", 2)
    rows = study.report()["sessions"]
    assert [r["transfer"] for r in rows] == ["off", "prior"]
    assert "transfer_siblings" not in rows[0]
    assert rows[1]["transfer_siblings"] == 1  # cell A was the one sibling


def test_transfer_session_replays_identically_over_complete_cache(tmp_path):
    """Repeating the seeded transfer session over its complete cache pays
    ZERO fresh evaluations and lands on the identical incumbent — the
    warm-start history plus the recorded sibling set reproduce the run."""
    study, _, _, _, first = _tune_family(tmp_path, "rep", CELL_B, "prior", 2)
    ev2 = SyntheticCellEvaluator("cellB")
    again = study.optimize(CELL_B, "tpe", ev2, budget=BUDGET_B, seed=2,
                           transfer="prior")
    assert ev2.calls == 0
    assert again.cache_stats["fresh"] == 0
    assert again.best_time == first.best_time
    assert again.best_config == first.best_config


class KillAfter:
    """Synthetic cell that simulates SIGINT on the (n+1)-th fresh eval."""

    def __init__(self, arch, n):
        self.inner = SyntheticCellEvaluator(arch)
        self.n = n
        self._lock = threading.Lock()

    def __call__(self, config):
        with self._lock:
            if self.inner.calls >= self.n:
                raise KeyboardInterrupt
        return self.inner(config)


def test_interrupted_transfer_session_resumes_with_recorded_siblings(tmp_path):
    """Kill the transfer session mid-run; resume() pays only the remainder,
    reuses the RECORDED sibling set (the report row shows it), and the
    combined total equals one uninterrupted run."""
    _, _, _, ev_full, out_full = _tune_family(tmp_path, "full", CELL_B,
                                              "prior", 2)

    study = Study.create(tmp_path / "int")
    ev_a = SyntheticCellEvaluator("cellA")
    study.optimize(CELL_A, "tpe", ev_a, budget=BUDGET_A, seed=SEED_A)
    killer = KillAfter("cellB", 9)
    with pytest.raises(KeyboardInterrupt):
        study.optimize(CELL_B, "tpe", killer, budget=BUDGET_B, seed=2,
                       transfer="prior")
    paid_before = killer.inner.calls
    assert paid_before == 9

    ev_rest = SyntheticCellEvaluator("cellB")
    outcome = study.resume(evaluator=ev_rest)
    assert paid_before + ev_rest.calls == ev_full.calls  # only the remainder
    assert outcome.best_time <= out_full.best_time + 0.5  # sane incumbent
    rows = study.report()["sessions"]
    assert rows[-1]["transfer"] == "prior"
    assert rows[-1]["transfer_siblings"] == 1
    assert rows[-1]["resumes"] == rows[-2]["session"]
    assert rows[-1]["status"] == "done"


def test_resume_with_missing_sibling_namespace_raises(tmp_path):
    """A transfer session whose recorded sibling namespace vanished from the
    cache must refuse to resume — silently degrading to a no-prior rerun
    would not replay the same search."""
    study = Study.create(tmp_path / "s")
    ev_a = SyntheticCellEvaluator("cellA")
    study.optimize(CELL_A, "tpe", ev_a, budget=BUDGET_A, seed=SEED_A)
    killer = KillAfter("cellB", 5)
    with pytest.raises(KeyboardInterrupt):
        study.optimize(CELL_B, "tpe", killer, budget=BUDGET_B, seed=2,
                       transfer="prior")

    # rewrite the cache without cell A's namespace, then reopen the study
    cache = study.cache_path
    kept = [json.dumps(r) for r in map(json.loads,
                                       cache.read_text().splitlines())
            if r.get("platform") != CELL_A]
    cache.write_text("\n".join(kept) + "\n")
    reopened = Study.load(study.path)
    with pytest.raises(ValueError, match="sibling namespaces no longer"):
        reopened.resume(evaluator=SyntheticCellEvaluator("cellB"))


def test_resume_replays_a_prefix_when_the_sibling_grew(tmp_path):
    """Between interrupt and resume the sibling cell kept tuning: the resumed
    session must see exactly the recorded prefix of the sibling's records,
    not the grown set (the prior has to replay, not drift)."""
    study = Study.create(tmp_path / "s")
    ev_a = SyntheticCellEvaluator("cellA")
    study.optimize(CELL_A, "tpe", ev_a, budget=10, seed=SEED_A)
    killer = KillAfter("cellB", 4)
    with pytest.raises(KeyboardInterrupt):
        study.optimize(CELL_B, "tpe", killer, budget=12, seed=2,
                       transfer="prior")
    rec = [r for r in study.sessions() if r.get("event") == "start"][-1]
    recorded = rec["transfer"]["siblings"][0]["trials"]

    # the sibling grows by another session's worth of records
    study.optimize(CELL_A, "tpe", SyntheticCellEvaluator("cellA"),
                   budget=18, seed=SEED_A + 1)
    grown = study._siblings_from_record(rec, rec["transfer"]["siblings"])
    assert len(grown[0].trials) == recorded  # prefix, not the grown set
    all_now = study.histories_for(CELL_B)[0]
    assert len(all_now.trials) > recorded  # ...which HAS grown underneath


# ------------------------------------------- sibling buckets (cache plumbing)


def _cache_record(platform, config, time_s, tag="tpe/round1", **extra):
    rec = {"key": config_hash(config), "platform": platform, "tag": tag,
           "ts": 0.0, "config": config, "time_s": time_s, "info": {}}
    rec.update(extra)
    return rec


def _write_cache(path, records):
    path.write_text("\n".join(json.dumps(r) for r in records) + "\n")


def test_histories_for_buckets_by_stored_namespace(tmp_path):
    """@Nc chip-count variants and legacy unplatformed records must never
    leak into another cell's sibling bucket (the PR-4 keying, now honoured on
    the read side too)."""
    study = Study.create(tmp_path / "s")
    cfg = {"mesh_model_parallel": 8}
    _write_cache(study.cache_path, [
        _cache_record("train/a:train_4k", {**cfg, "x": 1}, 1.0),
        _cache_record("train/a:train_4k@512c", {**cfg, "x": 2}, 2.0),
        _cache_record("train/b:train_4k", {**cfg, "x": 3}, 3.0),
        # legacy record with no platform field: attributed to NO cell
        {"key": "legacy0", "config": {**cfg, "x": 4}, "time_s": 4.0,
         "ts": 0.0, "tag": "", "info": {}},
        # serve cell: infinite distance from any train cell
        _cache_record("serve/a:decode_32k", {**cfg, "x": 5}, 5.0),
        # non-ok records are not evidence
        _cache_record("train/a:train_4k", {**cfg, "x": 6}, 6.0,
                      status="timeout", error="t", wall_s=9.0),
    ])
    sibs = study.histories_for("train/b:train_4k")
    assert [s.namespace for s in sibs] == [
        "train/a:train_4k", "train/a:train_4k@512c"]
    # the same-chips sibling ranks closer than the @512c topology variant
    assert sibs[0].distance < sibs[1].distance
    # each bucket holds exactly its own records (and not the timeout one)
    assert [t[0]["x"] for t in sibs[0].trials] == [1]
    assert [t[0]["x"] for t in sibs[1].trials] == [2]
    # the receiving cell itself is never its own sibling
    assert all(s.namespace != "train/b:train_4k" for s in sibs)


def test_cached_observations_exposes_stored_namespace(tmp_path):
    """The scheduler-level read: with_platform=True appends each record's
    STORED namespace — and the @512c variant never shows up in the base
    cell's observations at all."""
    cache = tmp_path / "cache.jsonl"
    cfg_a, cfg_v = {"x": 1}, {"x": 2}
    _write_cache(cache, [
        _cache_record("train/a:train_4k", cfg_a, 1.0),
        _cache_record("train/a:train_4k@512c", cfg_v, 2.0),
    ])
    sched = TrialScheduler(lambda c: (0.0, {}), platform="train/a:train_4k",
                           cache_path=cache)
    assert sched.cached_observations() == [(cfg_a, 1.0, "tpe/round1")]
    assert sched.cached_observations(with_platform=True) == [
        (cfg_a, 1.0, "tpe/round1", "train/a:train_4k")]
    grouped = read_cache_by_platform(cache)
    assert set(grouped) == {"train/a:train_4k", "train/a:train_4k@512c"}


# ------------------------------------------------------- namespace/similarity


def test_parse_namespace_decodes_all_driver_shapes():
    assert parse_namespace("train") == CellKey("train")
    assert parse_namespace("wordcount/variant") == \
        CellKey("wordcount", arch="variant")
    assert parse_namespace("train/llama:train_4k") == \
        CellKey("train", "llama", "train_4k", 256)
    assert parse_namespace("train/llama:train_4k@512c") == \
        CellKey("train", "llama", "train_4k", 512)


def test_default_similarity_orders_cells_sensibly():
    me = parse_namespace("train/a:train_4k")
    same_arch_other_chips = parse_namespace("train/a:train_4k@512c")
    other_arch = parse_namespace("train/b:train_4k")
    other_platform = parse_namespace("serve/a:decode_32k")
    d_chips = default_similarity(me, same_arch_other_chips)
    d_arch = default_similarity(me, other_arch)
    assert 0 < d_chips < d_arch
    assert math.isinf(default_similarity(me, other_platform))
    assert default_similarity(me, me) == 0.0
