"""WordCount (the paper's benchmark job): correctness across the knob space
(property-based) and the measured knob effects the reproduction relies on."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.apps.wordcount import (WORDCOUNT_SPACE, build_wordcount, make_corpus,
                                  wordcount_reference)

CORPUS = make_corpus(1 << 16)
REF = wordcount_reference(np.asarray(CORPUS))


def test_default_config_correct():
    out = np.asarray(build_wordcount({}, CORPUS)())
    assert (out == REF).all()


@given(
    num_map_tasks=st.sampled_from([2, 4, 8, 16]),
    block_tokens=st.sampled_from([4096, 16384, 65536]),
    compress=st.booleans(),
    num_reduces=st.integers(1, 4),
    sort_factor=st.sampled_from([5, 10, 40, 80]),
    replication=st.integers(1, 3),
    sort_buffer=st.sampled_from([2048, 8192, 32768]),
)
@settings(max_examples=12, deadline=None)
def test_property_any_config_counts_correctly(
    num_map_tasks, block_tokens, compress, num_reduces, sort_factor, replication, sort_buffer
):
    """System invariant: EVERY legal configuration computes the same counts —
    tuning changes time, never results (the paper's correctness contract)."""
    cfg = {
        "num_map_tasks": num_map_tasks,
        "block_tokens": block_tokens,
        "map_output_compress": compress,
        "num_reduces": num_reduces,
        "sort_factor": sort_factor,
        "replication": replication,
        "sort_buffer_tokens": sort_buffer,
    }
    out = np.asarray(build_wordcount(cfg, CORPUS)())
    assert (out == REF).all(), cfg


def test_replication_knob_costs_time():
    """dfs.replication=3 (default) must be measurably slower than 1 — the
    effect the paper's Table IV tuning exploits."""
    import time

    big = make_corpus(1 << 20)

    def _time(job, n=3):
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            job()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    j3 = build_wordcount({"replication": 3}, big); j3()  # warmup/compile
    j1 = build_wordcount({"replication": 1}, big); j1()
    t3, t1 = _time(j3), _time(j1)
    assert t3 > 1.5 * t1, (t3, t1)


def test_space_has_twelve_params_like_table_one():
    assert len(WORDCOUNT_SPACE.params) == 12
    assert set(WORDCOUNT_SPACE.most_influential) <= set(WORDCOUNT_SPACE.names())
