"""Multi-fidelity ASHA: the fidelity axis (geometric rung ladder, trial
identity, rung-scaled deadlines), the scheduler's async submit/poll seam,
asynchronous promotion (no round barrier), equal-fidelity incumbent rules,
inline-vs-subprocess parity for ASHA sessions, and warm-cache resume.

Worker-side functions must be module-level: the spawn start method ships
them to workers by pickle-by-reference.
"""
import json
import math
import threading
import time
from pathlib import Path

import pytest

from repro.core import EngineConfig, Study, TrialScheduler
from repro.core.evaluators import FunctionEvaluator
from repro.core.fidelity import FidelitySchedule, full_fidelity
from repro.core.scheduler import (
    Trial,
    best_from_log,
    config_key,
    read_log,
    trial_key,
)
from repro.core.space import IntParam, TunableSpace
from repro.core.strategies import AshaStrategy, make_strategy

from _hyp import given, settings, st


def toy_space(hi: int = 40) -> TunableSpace:
    return TunableSpace(
        "toy",
        (IntParam("x", hi // 2, 1, hi), IntParam("y", hi // 2, 1, hi)),
        most_influential=("x",),
    )


# ---------------------------------------------------- worker-side functions


def _quad(cfg, fidelity=1.0):
    x, y = cfg["x"], cfg["y"]
    return (x - 7) ** 2 * 0.001 + (y - 3) ** 2 * 0.0005 + 0.01


def _hang(cfg):
    time.sleep(60.0)
    return 0.0


def make_quad_evaluator():
    return FunctionEvaluator(_quad)


# ------------------------------------------------------------ fidelity axis


def test_rung_ladder_geometric():
    s = FidelitySchedule(1.0 / 9.0, 1.0, 3.0)
    assert s.rungs() == pytest.approx([1.0 / 9.0, 1.0 / 3.0, 1.0])
    # degenerate ladder: min == max collapses to a single full rung
    assert FidelitySchedule(1.0, 1.0, 3.0).rungs() == [1.0]


def test_fidelity_schedule_validates():
    with pytest.raises(ValueError):
        FidelitySchedule(0.0, 1.0, 3.0)
    with pytest.raises(ValueError):
        FidelitySchedule(0.5, 0.25, 3.0)
    with pytest.raises(ValueError):
        FidelitySchedule(0.5, 1.0, 1.0)


def test_trial_key_full_fidelity_is_config_key():
    cfg = {"x": 3, "y": 4}
    assert trial_key(cfg, 1.0) == config_key(cfg)
    low = trial_key(cfg, 1.0 / 3.0)
    assert low != config_key(cfg) and "fidelity=" in low
    assert full_fidelity(1.0) and not full_fidelity(0.999)


def test_low_rung_result_never_replays_as_full(tmp_path):
    """A cached sub-fidelity measurement must miss on a full-fidelity ask."""
    cache = tmp_path / "cache.jsonl"
    calls = []

    def fn(cfg, fidelity=1.0):
        calls.append(fidelity)
        return 1.0 if fidelity >= 1.0 else 0.1

    with TrialScheduler(FunctionEvaluator(fn), cache_path=cache) as s:
        assert s.evaluate({"x": 1}, fidelity=0.25) == pytest.approx(0.1)
    with TrialScheduler(FunctionEvaluator(fn), cache_path=cache) as s:
        # full-fidelity ask pays fresh — the 0.25 record is a different trial
        assert s.evaluate({"x": 1}) == pytest.approx(1.0)
        assert s.cache_stats()["fresh"] == 1
        # while the same sub-fidelity ask replays for free
        assert s.evaluate({"x": 1}, fidelity=0.25) == pytest.approx(0.1)
        assert s.cache_stats()["cache_hits"] == 1
    assert calls == [0.25, 1.0]
    # on disk: sub-fidelity records carry the key, full records stay legacy
    recs = [json.loads(l) for l in cache.read_text().splitlines()]
    fids = sorted(r.get("fidelity", 1.0) for r in recs)
    assert fids == [0.25, 1.0]
    assert "fidelity" not in [r for r in recs if r.get("fidelity", 1.0) == 1.0][0]


# ------------------------------------------------- async submit/poll seam


def test_submit_poll_basic_and_memo():
    with TrialScheduler(FunctionEvaluator(_quad), max_workers=2) as s:
        t1 = s.submit({"x": 7, "y": 3})
        t2 = s.submit({"x": 1, "y": 1})
        t3 = s.submit({"x": 7, "y": 3})  # duplicate of in-flight t1
        got = {}
        while len(got) < 3:
            for ticket, trial in s.poll(timeout=5.0):
                got[ticket] = trial
        assert got[t1].time_s == got[t3].time_s
        assert s.cache_stats()["fresh"] == 2
        assert s.cache_stats()["memo_hits"] == 1
        # a later submit of a finished config resolves instantly via memo
        t4 = s.submit({"x": 1, "y": 1})
        out = s.poll(timeout=0.0)
        assert (t4, got[t2].time_s) in [(k, t.time_s) for k, t in out]


def test_promotion_dispatches_while_same_rung_trial_running():
    """The whole point of ASHA: no round barrier. With one rung-0 trial
    blocked mid-flight, a fast rung-0 completion must promote and its rung-1
    evaluation must *start* while the blocked peer is still running."""
    release = threading.Event()
    blocker_running = threading.Event()
    promoted_while_blocked = threading.Event()
    state = {"first": None}
    lock = threading.Lock()

    def fn(cfg, fidelity=1.0):
        if fidelity < 0.5:  # rung 0
            with lock:
                if state["first"] is None:
                    state["first"] = config_key(cfg)
            if state["first"] == config_key(cfg):
                blocker_running.set()
                release.wait(timeout=30.0)
                return 50.0
            return float(cfg["x"])
        # rung 1 (fidelity 1.0): a promotion reached the evaluator
        if blocker_running.is_set() and not release.is_set():
            promoted_while_blocked.set()
        release.set()  # unblock the straggler so the session drains
        return float(cfg["x"])

    space = toy_space()
    strat = make_strategy(
        "asha", space, seed=5, max_trials=6,
        min_fidelity=1.0 / 3.0, eta=3.0,
    )
    with TrialScheduler(FunctionEvaluator(fn), max_workers=2) as s:
        result = s.run(strat)
    assert promoted_while_blocked.is_set(), (
        "no promotion dispatched while a same-rung trial was still running "
        "— the async path has a round barrier"
    )
    assert result.promotions[0] >= 1
    assert result.rungs == pytest.approx([1.0 / 3.0, 1.0])


def test_asha_inline_subprocess_parity(tmp_path):
    """One worker makes completion order deterministic: the same seed must
    produce identical trial sequences and the same incumbent on both
    backends (async submit/poll runs through each backend's own path)."""
    logs = {}
    for iso in ("inline", "subprocess"):
        log = tmp_path / f"{iso}.jsonl"
        strat = make_strategy(
            "asha", toy_space(), seed=7, max_trials=9,
            min_fidelity=1.0 / 9.0, eta=3.0,
        )
        with TrialScheduler(
            FunctionEvaluator(_quad), max_workers=1, isolation=iso,
            log_path=log,
        ) as s:
            res = s.run(strat)
            logs[iso] = [
                (r["config"]["x"], r["config"]["y"], r.get("fidelity", 1.0))
                for r in read_log(log)
            ]
            if iso == "inline":
                ref = (res.best_config, res.best_time, res.promotions)
            else:
                assert (res.best_config, res.best_time, res.promotions) == ref
    assert logs["inline"] == logs["subprocess"]
    assert any(f < 1.0 for _, _, f in logs["inline"])


def test_hung_rung0_trial_killed_on_scaled_deadline():
    """EngineConfig.timeout_s is the *max-fidelity* deadline; a rung-0 trial
    at fidelity 0.25 gets 0.25x of it and is SIGKILLed on that short
    deadline, not the full one."""
    with TrialScheduler(
        FunctionEvaluator(_hang), isolation="subprocess", max_workers=1,
        timeout_s=8.0,
    ) as s:
        t0 = time.monotonic()
        s.submit({"x": 1}, fidelity=0.25)
        done = []
        while not done:
            done = s.poll(timeout=10.0)
        wall = time.monotonic() - t0
        (_, trial), = done
        assert trial.timed_out and not trial.ok
        assert trial.fidelity == 0.25
        assert "2" in trial.error  # scaled 2s deadline, not the 8s full one
        assert wall < 6.0, f"rung-0 kill took {wall:.1f}s (full deadline?)"


# ------------------------------------------- equal-fidelity incumbent rules


def test_low_rung_score_never_becomes_incumbent(tmp_path):
    log = tmp_path / "log.jsonl"

    def fn(cfg, fidelity=1.0):
        # sub-fidelity scores look (wrongly) amazing
        return 0.001 if fidelity < 1.0 else 1.0 + cfg["x"] * 0.1

    with TrialScheduler(FunctionEvaluator(fn), log_path=log) as s:
        s.evaluate({"x": 1}, fidelity=1.0 / 9.0)
        s.evaluate({"x": 2}, fidelity=1.0 / 9.0)
        s.evaluate({"x": 1})
        best = s.best()
        assert best.fidelity == 1.0 and best.time_s == pytest.approx(1.1)
    rec = best_from_log(log)
    assert rec.get("fidelity", 1.0) == 1.0
    assert rec["time_s"] == pytest.approx(1.1)


def test_patience_ignores_low_rung_improvements():
    """A stream of ever-better low-rung scores must not starve the patience
    counter: staleness is judged at the top fidelity only. If low-rung
    scores set the incumbent, every full-fidelity completion would look
    stale and the run would stop long before the budget."""
    full_calls = []

    def fn(cfg, fidelity=1.0):
        if fidelity < 1.0:
            return 0.0001 * cfg["x"]  # absurdly good, and "improving"
        full_calls.append(cfg["x"])
        return 10.0 - 0.05 * len(full_calls)  # strictly improving

    strat = make_strategy(
        "asha", toy_space(), seed=11, max_trials=9,
        min_fidelity=1.0 / 3.0, eta=3.0,
    )
    with TrialScheduler(FunctionEvaluator(fn), max_workers=1) as s:
        result = s.run_async(strat, patience=2)
    assert not result.stopped_early
    assert result.proposals == 9


def test_infeasible_trial_never_promotes():
    def fn(cfg, fidelity=1.0):
        raise RuntimeError("boom")

    strat = make_strategy(
        "asha", toy_space(), seed=1, max_trials=4,
        min_fidelity=1.0 / 3.0, eta=3.0,
    )
    with TrialScheduler(FunctionEvaluator(fn), max_workers=1) as s:
        result = s.run(strat)
    assert result.promotions == [0, 0]
    assert result.best_config is None


# ------------------------------------------------------- study integration


def test_study_asha_session_and_warm_resume(tmp_path):
    space = toy_space()
    kwargs = dict(
        space=space, budget=9, inner="random", eta=3.0,
        min_fidelity=1.0 / 9.0, seed=3,
    )
    with Study.create(tmp_path / "study", engine=EngineConfig(workers=2)) as st_:
        out = st_.optimize("toy", "asha", FunctionEvaluator(_quad), **kwargs)
        s = out.summary()
        # rung/promotion provenance lands in the summary (and sessions.jsonl)
        assert s["best_fidelity"] == 1.0
        assert [r["rung"] for r in s["rungs"]] == [0, 1, 2]
        assert s["rungs"][0]["launched"] == 9
        assert sum(r["promoted"] for r in s["rungs"]) > 0
        rep = st_.report()
        assert "probe_cache" in rep
        assert any("rungs" in r for r in rep["sessions"])
    # sessions.jsonl carries the rung table for post-hoc tooling
    lines = [json.loads(l)
             for l in (tmp_path / "study" / "sessions.jsonl").read_text().splitlines()]
    done = [l for l in lines if l.get("event") == "done"]
    assert done and "rungs" in done[-1]["summary"]

    # a warm re-run replays every rung from the cache: zero fresh work
    with Study.load(tmp_path / "study") as st2:
        out2 = st2.optimize("toy", "asha", FunctionEvaluator(_quad), **kwargs)
        s2 = out2.summary()
        assert s2["cache_stats"]["fresh"] == 0
        assert s2["best_config"] == s["best_config"]


def test_study_incumbent_requires_top_fidelity(tmp_path):
    """If ASHA's best never reached the top rung (tiny budget), the session
    falls back to the defaults measured at top fidelity rather than
    crowning a cheap-rung score."""

    def fn(cfg, fidelity=1.0):
        return 0.001 if fidelity < 1.0 else 5.0

    with Study(engine=EngineConfig(workers=1)) as st_:
        out = st_.optimize(
            "toy", "asha", FunctionEvaluator(fn), space=toy_space(),
            budget=1, inner="random", eta=3.0, min_fidelity=1.0 / 3.0, seed=0,
        )
        assert out.summary()["best_time_s"] == pytest.approx(5.0)


# ----------------------------------------------------------- property tests


@settings(max_examples=60, deadline=None)
@given(
    st.floats(min_value=0.01, max_value=1.0),
    st.floats(min_value=0.0, max_value=1.0),
    st.floats(min_value=1.1, max_value=6.0),
)
def test_prop_rungs_sorted_and_bounded(min_f, frac, eta):
    max_f = min_f + (1.0 - min_f) * frac
    rungs = FidelitySchedule(min_f, max_f, eta).rungs()
    assert rungs[0] == min_f or len(rungs) == 1
    assert rungs[-1] == max_f
    assert all(a < b for a, b in zip(rungs, rungs[1:]))
    assert all(min_f <= r <= max_f for r in rungs)


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=40),
       st.floats(min_value=1.5, max_value=5.0),
       st.integers(min_value=0, max_value=10_000))
def test_prop_promotions_are_ceil_n_over_eta(n, eta, seed):
    """Feed all n rung-0 completions before asking for work: exactly
    ceil(n/eta) distinct configs must then hold promotions out of rung 0."""
    strat = AshaStrategy(
        toy_space(200), max_trials=n, min_fidelity=1.0 / 4.0, eta=eta,
        seed=seed,
    )
    jobs = strat.next_jobs(n)
    assert len(jobs) == n and all(j.rung == 0 for j in jobs)
    for i, job in enumerate(jobs):
        strat.on_result(job, Trial(config=job.config, time_s=float((i * 7) % n),
                                   fidelity=job.fidelity))
    promoted = strat.next_jobs(10 * n)
    assert all(j.rung == 1 for j in promoted)
    assert len(promoted) == math.ceil(n / eta)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=2, max_value=18))
def test_prop_job_stream_is_pure_function_of_seed_and_order(seed, n):
    """Two strategies with the same seed, driven with the same completion
    order and scores, must emit byte-identical job streams."""

    def drive(strat):
        stream, pending = [], []
        while True:
            jobs = strat.next_jobs(2)
            for j in jobs:
                stream.append((config_key(j.config), j.rung, j.fidelity))
                pending.append(j)
            if not pending:
                break
            j = pending.pop(0)  # FIFO completion = deterministic order
            score = float(sum(hash(c) % 97 for c in (config_key(j.config),)))
            strat.on_result(j, Trial(config=j.config, time_s=score,
                                     fidelity=j.fidelity))
        return stream

    mk = lambda: AshaStrategy(toy_space(50), max_trials=n,
                              min_fidelity=1.0 / 9.0, eta=3.0, seed=seed)
    assert drive(mk()) == drive(mk())
