"""Deterministic modeled cells for the surrogate CI smoke.

The surrogate CI job compares ``--surrogate off`` vs ``rank`` target
sessions by fresh-evaluations-to-incumbent — a razor-thin race near the
optimum plateau that real walltime measurement (min-of-repeats on a shared
runner) flips from run to run. Exactly like the transfer smoke's
``synthetic_cells``, these evaluators replace the *measurement* with a pure
function of the config so the comparison is exact, not statistical, while
keeping the real cell namespaces and the real tunable spaces:

  - ``wc_model_time`` is an analytic cost model of the WordCount job in
    ``repro.apps.wordcount`` over the real ``WORDCOUNT_SPACE``: replica
    re-reads dominate (the paper's Table IV shape), block/sort-buffer/
    sort-factor shape the map and merge overheads, compression trades
    shuffle bytes for combine CPU, and the paper's long-tail knobs
    (``map_tasks_max``, ``slowstart``, ...) are exact no-ops the tuner has
    to discover.
  - ``ssm_model_time`` models one Pallas ``ssm_scan`` cell over the real
    ``KERNEL_SPACES['ssm_scan']``: grid-step launch overhead vs a
    working-set spill penalty, so the best (chunk, d_block) is interior
    and shifts with the shape — the cross-shape structure the surrogate
    is supposed to transfer.

Everything is a pure function of its inputs — no rng, no wall clock — so
"fewer fresh evaluations" assertions are exactly reproducible anywhere.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

from repro.apps.wordcount import VOCAB, WORDCOUNT_SPACE
from repro.core.evaluators import FunctionEvaluator
from repro.core.kernel_tune import (
    KERNEL_SPACES,
    kernel_platform_key,
    shape_class_for,
)

# The WordCount matrix: corpus sizes per cell, as in benchmarks/tables.py.
WC_CELLS = {"wc:1m": 1 << 20, "wc:2m": 1 << 21}


def wc_model_time(config: Dict[str, Any], num_tokens: int) -> float:
    """Modeled execution time of the WordCount job under ``config`` on a
    ``num_tokens`` corpus. Coefficients are sized to the measured job
    (~0.25 s default / ~0.09 s tuned at 2M tokens): the replication
    re-read term dominates, everything else is second order."""
    cfg = WORDCOUNT_SPACE.snap({**WORDCOUNT_SPACE.defaults(), **config})
    n = float(num_tokens)
    reps = float(cfg["replication"])
    n_map = float(cfg["num_map_tasks"])
    block = float(min(int(cfg["block_tokens"]), int(n / n_map)))
    buf = float(min(int(cfg["sort_buffer_tokens"]), int(block)))
    fan = float(cfg["sort_factor"])
    n_red = float(cfg["num_reduces"])
    compress = bool(cfg["map_output_compress"])

    t = 4.0e-8 * reps * n                  # replica corpus re-reads
    t += 2.0e-6 * reps * (n / block)       # per-block dispatch
    t += 1.2e-7 * reps * (n / buf)         # sort-buffer scan segments
    levels = math.ceil(math.log(max(n_map, 2.0)) / math.log(max(fan, 2.0)))
    t += 4.0e-9 * reps * levels * VOCAB * n_map   # tree-merge traffic
    shuffle = n_map * VOCAB * (2.0 if compress else 4.0)
    t += 3.0e-9 * reps * shuffle           # shuffle payload
    if compress:
        t += 2.5e-8 * reps * n_map * VOCAB  # map-side combine CPU
    t += 1.5e-3 * (n_red - 1.0)            # extra reducers on one host
    return t


def make_wc_evaluator(num_tokens: int) -> FunctionEvaluator:
    return FunctionEvaluator(fn=lambda cfg: wc_model_time(cfg, num_tokens))


def ssm_model_time(config: Dict[str, Any], shape: Tuple[int, ...]) -> float:
    """Modeled time of one ``ssm_scan`` launch at block config ``config``
    on ``shape`` = (batch, seq, d_inner, state): fixed per-grid-step launch
    overhead pulls toward big blocks, a working-set spill penalty pushes
    back, so the optimum is interior and shape-dependent."""
    b, s, d_inner, n = (float(x) for x in shape)
    cfg = KERNEL_SPACES["ssm_scan"].snap(
        {**KERNEL_SPACES["ssm_scan"].defaults(), **config}
    )
    chunk = float(min(int(cfg["chunk"]), int(s)))
    d_block = float(min(int(cfg["d_block"]), int(d_inner)))
    steps = math.ceil(s / chunk) * math.ceil(d_inner / d_block)
    t = 8.0e-6 * steps                     # per-step launch overhead
    t += 1.0e-9 * b * s * d_inner * n      # the scan work itself
    # padding waste when d_block does not divide d_inner
    t *= (math.ceil(d_inner / d_block) * d_block) / d_inner
    vmem = chunk * d_block * (n + 2.0) * 4.0
    if vmem > 65536.0:                     # working set spills: VMEM-shaped
        t *= 1.0 + 0.35 * math.log2(vmem / 65536.0)
    return t


def ssm_namespace(shape: Tuple[int, ...], dtype: str = "f32") -> str:
    return kernel_platform_key(
        "ssm_scan", dtype, shape_class_for("ssm_scan", shape)
    )


def make_ssm_evaluator(shape: Tuple[int, ...]) -> FunctionEvaluator:
    return FunctionEvaluator(fn=lambda cfg: ssm_model_time(cfg, shape))
