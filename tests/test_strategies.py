"""The ask/tell Strategy + TrialScheduler engine: cache accounting,
parallel-vs-serial equivalence, early stopping, persistent warm-cache
re-runs (zero fresh evaluations), the >=2x parallel wall-clock demo, and
ask/tell parity of the ported GSFT/CRS against their legacy wrappers."""
import json
import threading
import time

import pytest

from repro.core import (
    CMPE,
    TRAIN_SPACE,
    TrialScheduler,
    controlled_random_search,
    grid_search_finer_tuning,
    make_strategy,
    tune,
)
from repro.core.evaluators import FunctionEvaluator
from repro.core.scheduler import read_log
from repro.core.strategies import (
    CRSStrategy,
    CuratedHillclimbStrategy,
    GridFinerStrategy,
    Move,
)


def quad_objective(cfg):
    t = 10.0
    t += abs(cfg["mesh_model_parallel"] - 8) * 0.5
    t += abs((cfg["microbatch_size"] or 256) - 32) * 0.02
    t += {"none": 2.0, "dots": 0.0, "full": 1.0}[cfg["remat_policy"]]
    return t


ACTIVE = ["mesh_model_parallel", "microbatch_size", "remat_policy"]


class CountingEvaluator:
    """Deterministic objective that counts fresh evaluator invocations
    (thread-safely) and can inject per-call latency."""

    def __init__(self, fn=quad_objective, delay_s=0.0):
        self.fn = fn
        self.delay_s = delay_s
        self.calls = 0
        self._lock = threading.Lock()

    def __call__(self, config):
        with self._lock:
            self.calls += 1
        if self.delay_s:
            time.sleep(self.delay_s)
        return float(self.fn(config)), {}


# ------------------------------------------------------------ cache accounting


def test_cache_hit_miss_accounting(tmp_path):
    ev = CountingEvaluator()
    sched = TrialScheduler(ev, cache_path=tmp_path / "cache.jsonl")
    a = TRAIN_SPACE.defaults()
    b = {**a, "mesh_model_parallel": 8}

    sched.evaluate_batch([a, b, a])  # a fresh, b fresh, a = memo hit
    assert ev.calls == 2
    assert sched.cache_stats() == {"fresh": 2, "memo_hits": 1, "cache_hits": 0}

    sched.evaluate(b)  # repeat across batches = memo hit
    assert sched.cache_stats() == {"fresh": 2, "memo_hits": 2, "cache_hits": 0}
    # num_evaluations counts distinct trials, like the legacy CMPE
    assert sched.num_evaluations == 2


def test_warm_cache_rerun_performs_zero_fresh_evaluations(tmp_path):
    """Acceptance: a warm-cache re-run costs nothing fresh."""
    cache = tmp_path / "cache.jsonl"
    cold_ev = CountingEvaluator()
    cold = tune("train", "gsft", cold_ev, cache_path=cache,
                active_params=ACTIVE, samples_per_param=3)
    assert cold_ev.calls > 0

    warm_ev = CountingEvaluator()
    warm = tune("train", "gsft", warm_ev, cache_path=cache,
                active_params=ACTIVE, samples_per_param=3)
    assert warm_ev.calls == 0  # every trial replayed from the JSONL cache
    assert warm.best_config == cold.best_config
    assert warm.best_time == cold.best_time
    assert warm.cache_stats["fresh"] == 0
    assert warm.cache_stats["cache_hits"] > 0


def test_persistent_cache_is_platform_namespaced(tmp_path):
    cache = tmp_path / "cache.jsonl"
    cfg = TRAIN_SPACE.defaults()
    s1 = TrialScheduler(FunctionEvaluator(lambda c: 1.0),
                        platform="cell_a", cache_path=cache)
    assert s1.evaluate(cfg) == 1.0
    # same knob dict, different cell: must NOT collide
    s2 = TrialScheduler(FunctionEvaluator(lambda c: 2.0),
                        platform="cell_b", cache_path=cache)
    assert s2.evaluate(cfg) == 2.0
    # but the same cell replays from cache
    s3 = TrialScheduler(FunctionEvaluator(lambda c: 99.0),
                        platform="cell_a", cache_path=cache)
    assert s3.evaluate(cfg) == 1.0


def test_cache_survives_torn_tail_write(tmp_path):
    cache = tmp_path / "cache.jsonl"
    s1 = TrialScheduler(CountingEvaluator(), cache_path=cache)
    s1.evaluate(TRAIN_SPACE.defaults())
    with cache.open("a") as f:
        f.write('{"key": "truncated-rec')  # crashed session's torn line
    ev = CountingEvaluator()
    s2 = TrialScheduler(ev, cache_path=cache)
    s2.evaluate(TRAIN_SPACE.defaults())
    assert ev.calls == 0


# ------------------------------------------------- parallel batches + speedup


def test_parallel_matches_serial_results():
    """Deterministic objective: the engine must return identical trials
    regardless of max_workers / batch_size."""
    serial = TrialScheduler(CountingEvaluator())
    parallel = TrialScheduler(CountingEvaluator(), max_workers=8)

    res_s = serial.run(GridFinerStrategy(TRAIN_SPACE, active_params=ACTIVE,
                                         samples_per_param=3))
    res_p = parallel.run(GridFinerStrategy(TRAIN_SPACE, active_params=ACTIVE,
                                           samples_per_param=3), batch_size=8)
    assert res_s.best_config == res_p.best_config
    assert res_s.best_time == res_p.best_time
    assert res_s.phase1_best == res_p.phase1_best
    assert {t.time_s for t in serial.trials} == {t.time_s for t in parallel.trials}


def test_parallel_batches_at_least_2x_faster():
    """Acceptance: >=2x wall-clock reduction on a multi-trial tuning run."""
    delay = 0.05
    strategy_kw = dict(active_params=["mesh_model_parallel"], samples_per_param=6)

    t0 = time.perf_counter()
    serial = TrialScheduler(CountingEvaluator(delay_s=delay))
    serial.run(GridFinerStrategy(TRAIN_SPACE, **strategy_kw))
    t_serial = time.perf_counter() - t0

    t0 = time.perf_counter()
    parallel = TrialScheduler(CountingEvaluator(delay_s=delay), max_workers=8)
    parallel.run(GridFinerStrategy(TRAIN_SPACE, **strategy_kw))
    t_parallel = time.perf_counter() - t0

    assert serial.num_evaluations == parallel.num_evaluations
    assert t_serial >= 2.0 * t_parallel, (t_serial, t_parallel)


# --------------------------------------------------------------- early stop


def test_early_stopping_triggers_on_stale_batches():
    flat = FunctionEvaluator(lambda cfg: 5.0)  # nothing ever improves
    sched = TrialScheduler(flat)
    strategy = GridFinerStrategy(
        TRAIN_SPACE, active_params=["mesh_model_parallel", "attn_block_q"],
        samples_per_param=4,
    )
    res = sched.run(strategy, batch_size=1, patience=3)
    assert res.stopped_early
    # pruned long before the full cartesian grid
    assert sched.num_evaluations <= 5
    assert res.best_time == 5.0


def test_no_early_stop_without_patience():
    sched = TrialScheduler(FunctionEvaluator(lambda cfg: 5.0))
    strategy = GridFinerStrategy(TRAIN_SPACE, active_params=["mesh_model_parallel"],
                                 samples_per_param=3)
    res = sched.run(strategy, batch_size=1)
    assert not res.stopped_early


# -------------------------------------------------- timeout / retry / penalty


def test_retries_then_penalty():
    attempts = []

    def flaky(cfg):
        attempts.append(1)
        raise RuntimeError("injected crash")

    sched = TrialScheduler(FunctionEvaluator(flaky), retries=2,
                           infeasible_time=1e6)
    t = sched.evaluate(TRAIN_SPACE.defaults())
    assert len(attempts) == 3  # 1 try + 2 retries
    assert t == 1e6  # finite infeasible penalty instead of inf
    assert sched.trials[0].error and "injected crash" in sched.trials[0].error


def test_soft_timeout_marks_trial_infeasible():
    def slow(cfg):
        time.sleep(0.2)
        return 1.0

    sched = TrialScheduler(FunctionEvaluator(slow), timeout_s=0.05)
    t = sched.evaluate(TRAIN_SPACE.defaults())
    assert t == float("inf")
    assert "TrialTimeout" in sched.trials[0].error


def test_crs_early_stop_mid_round_keeps_best_so_far():
    """An early stop inside a CRS round must still report the best trial
    seen, not an empty result."""
    sched = TrialScheduler(FunctionEvaluator(quad_objective))
    res = sched.run(CRSStrategy(TRAIN_SPACE, m=12, k=4, max_rounds=4, seed=3),
                    batch_size=3, patience=1)
    assert res.best_config  # non-empty even if stopped before a round boundary
    assert res.best_time == min(t.time_s for t in sched.trials)


def test_clear_caches_clears_before_every_fresh_trial(monkeypatch):
    import jax

    calls = []
    monkeypatch.setattr(jax, "clear_caches", lambda: calls.append(1))
    sched = TrialScheduler(CountingEvaluator(), clear_caches_between_trials=True,
                           max_workers=4)
    cfgs = [{**TRAIN_SPACE.defaults(), "mesh_model_parallel": mp}
            for mp in (1, 2, 4)]
    sched.evaluate_batch(cfgs + cfgs[:1])  # 3 fresh + 1 memo hit
    assert len(calls) == 3  # one clear per fresh trial, none for the memo hit


def test_parallel_timeout_returns_promptly_with_hung_worker():
    def hang(cfg):
        time.sleep(1.0)
        return 1.0

    sched = TrialScheduler(FunctionEvaluator(hang), max_workers=2, timeout_s=0.1)
    cfgs = [{**TRAIN_SPACE.defaults(), "mesh_model_parallel": mp}
            for mp in (1, 2)]
    t0 = time.perf_counter()
    trials = sched.evaluate_batch(cfgs)
    elapsed = time.perf_counter() - t0
    assert elapsed < 0.8, elapsed  # did not join the hung workers
    assert all("TrialTimeout" in t.error for t in trials)


# --------------------------------------------- ask/tell parity vs legacy path


def test_gsft_askfell_parity_with_legacy_wrapper(tmp_path):
    """The strategy driven in parallel batches must reproduce the legacy
    serial wrapper exactly on a synthetic objective with a known optimum."""
    legacy = CMPE(FunctionEvaluator(quad_objective), log_path=tmp_path / "l.jsonl")
    res_legacy = grid_search_finer_tuning(
        TRAIN_SPACE, legacy, active_params=ACTIVE, samples_per_param=4
    )

    engine = TrialScheduler(FunctionEvaluator(quad_objective), max_workers=4)
    res_engine = engine.run(
        GridFinerStrategy(TRAIN_SPACE, active_params=ACTIVE, samples_per_param=4),
        batch_size=16,
    )
    assert res_legacy.best_config == res_engine.best_config
    assert res_legacy.best_time == res_engine.best_time
    assert res_legacy.grid_sizes == res_engine.grid_sizes
    assert res_engine.best_config["mesh_model_parallel"] == 8  # known optimum
    assert res_engine.best_config["remat_policy"] == "dots"


def test_crs_askfell_parity_with_legacy_wrapper():
    legacy = CMPE(FunctionEvaluator(quad_objective))
    res_legacy = controlled_random_search(
        TRAIN_SPACE, legacy, m=12, k=4, max_rounds=4, seed=7
    )

    engine = TrialScheduler(FunctionEvaluator(quad_objective), max_workers=4)
    res_engine = engine.run(
        CRSStrategy(TRAIN_SPACE, m=12, k=4, max_rounds=4, seed=7), batch_size=6
    )
    assert res_legacy.best_config == res_engine.best_config
    assert res_legacy.best_time == res_engine.best_time
    assert res_legacy.rounds == res_engine.rounds
    assert res_legacy.bound_history == res_engine.bound_history


# ------------------------------------------------------------ hillclimb port


def test_hillclimb_strategy_records_and_best():
    moves = [
        Move("baseline", "defaults", {}),
        Move("mp8", "TP=8 shrinks collectives", {"mesh_model_parallel": 8}),
        Move("bad", "hypothesis that fails", {"mesh_model_parallel": 64}),
    ]
    sched = TrialScheduler(FunctionEvaluator(quad_objective))
    res = sched.run(CuratedHillclimbStrategy(TRAIN_SPACE, moves=moves))
    assert [r["name"] for r in res.records] == ["baseline", "mp8", "bad"]
    assert res.best_name == "mp8"
    assert res.best_config["mesh_model_parallel"] == 8
    assert res.records[1]["hypothesis"] == "TP=8 shrinks collectives"
    assert res.evaluations == 3


def test_hillclimb_records_tolerate_info_echoing_t_step(tmp_path):
    """The roofline evaluator's info dict echoes t_step_s (and report.py
    indexes hbm_penalized/mfu unconditionally) — records must stay sane."""

    def roofy(cfg):
        return 2.0, {"t_step_s": 2.0, "bottleneck": "compute",
                     "roofline_fraction_mfu": 0.4, "hbm_est_gib": 9.0}

    sched = TrialScheduler(roofy)
    res = sched.run(CuratedHillclimbStrategy(
        TRAIN_SPACE, moves=[Move("baseline", "defaults", {})]))
    rec = res.records[0]
    assert rec["t_step_s"] == 2.0
    assert rec["hbm_penalized"] is False
    assert rec["mfu"] == 0.4


def test_hillclimb_failed_move_is_recorded_not_raised():
    def explode(cfg):
        if cfg["mesh_model_parallel"] == 64:
            raise MemoryError("HBM overflow")
        return 1.0

    moves = [Move("ok", "fits", {}), Move("oom", "too big", {"mesh_model_parallel": 64})]
    sched = TrialScheduler(FunctionEvaluator(explode))
    res = sched.run(CuratedHillclimbStrategy(TRAIN_SPACE, moves=moves))
    assert "MemoryError" in res.records[1]["error"]
    assert res.best_name == "ok"


# ------------------------------------------------------------------ registry


def test_make_strategy_registry():
    s = make_strategy("gsft", TRAIN_SPACE, active_params=["mesh_model_parallel"])
    assert isinstance(s, GridFinerStrategy)
    with pytest.raises(ValueError, match="unknown strategy"):
        make_strategy("bayesian", TRAIN_SPACE)


def test_tune_supports_hillclimb_algorithm():
    out = tune(
        "train", "hillclimb", FunctionEvaluator(quad_objective),
        moves=[("baseline", "defaults", {}),
               ("mp8", "smaller collectives", {"mesh_model_parallel": 8})],
    )
    assert out.best_config["mesh_model_parallel"] == 8
    assert out.reduction_pct > 0


# ------------------------------------------------------------------- logging


def test_batch_log_records_match_legacy_shape(tmp_path):
    log = tmp_path / "log.jsonl"
    sched = TrialScheduler(FunctionEvaluator(quad_objective), log_path=log,
                           max_workers=4)
    cfg = TRAIN_SPACE.defaults()
    sched.evaluate_batch([cfg, cfg], tag="t")
    recs = read_log(log)
    assert len(recs) == 2
    assert recs[0]["cached"] is False and recs[1]["cached"] is True
    assert recs[0]["tag"] == "t"
    assert {"ts", "platform", "config", "time_s", "wall_s", "error"} <= set(recs[0])
