"""XLA blockwise attention vs naive oracle: shape/dtype/mask sweeps, dynamic
(traced) sliding windows, decode path with kv_length masking."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import attention, attention_reference


def _mk(b, s, t, hq, hkv, dh, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, hq, dh), dtype)
    k = jax.random.normal(ks[1], (b, t, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, t, hkv, dh), dtype)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return q, k, v, pos


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("b,s,hq,hkv,dh,window,cap", [
    (2, 128, 4, 2, 32, 0, 0.0),
    (1, 257, 4, 1, 64, 0, 0.0),      # odd length -> padded block path
    (2, 192, 8, 8, 32, 64, 0.0),     # sliding window (MHA)
    (1, 128, 4, 2, 32, 0, 30.0),     # logit softcap
])
def test_blockwise_matches_reference(dtype, tol, b, s, hq, hkv, dh, window, cap):
    q, k, v, pos = _mk(b, s, s, hq, hkv, dh, dtype)
    out = attention(q, k, v, q_positions=pos, window=window, softcap_val=cap, block_kv=64)
    ref = attention_reference(q, k, v, q_positions=pos, window=window, softcap_val=cap)
    assert jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))) < tol


def test_dynamic_window_matches_static():
    """A traced window scalar must behave exactly like the static value, and
    window<=0 must mean 'full' (the unified local/global stack contract)."""
    q, k, v, pos = _mk(2, 128, 128, 4, 2, 32, jnp.float32)
    static = attention(q, k, v, q_positions=pos, window=32, block_kv=64)
    dyn = jax.jit(
        lambda w: attention(q, k, v, q_positions=pos, window=w, block_kv=64)
    )(jnp.asarray(32, jnp.int32))
    assert jnp.max(jnp.abs(static - dyn)) < 1e-6
    full_static = attention(q, k, v, q_positions=pos, window=0, block_kv=64)
    full_dyn = jax.jit(
        lambda w: attention(q, k, v, q_positions=pos, window=w, block_kv=64)
    )(jnp.asarray(0, jnp.int32))
    assert jnp.max(jnp.abs(full_static - full_dyn)) < 1e-6


def test_decode_kv_length_mask():
    """Single-token decode against a partially-filled cache only sees the
    valid prefix."""
    b, t, hq, hkv, dh = 2, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, 1, hq, dh))
    k = jax.random.normal(ks[1], (b, t, hkv, dh))
    v = jax.random.normal(ks[2], (b, t, hkv, dh))
    valid = 40
    pos = jnp.full((b, 1), valid - 1, jnp.int32)
    kv_len = jnp.full((b,), valid, jnp.int32)
    out = attention(q, k, v, q_positions=pos, kv_length=kv_len)
    # poisoning the masked-out tail must not change the result
    k2 = k.at[:, valid:].set(1e3)
    v2 = v.at[:, valid:].set(-1e3)
    out2 = attention(q, k2, v2, q_positions=pos, kv_length=kv_len)
    assert jnp.max(jnp.abs(out - out2)) < 1e-6
