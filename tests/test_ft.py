"""Fault tolerance: restartable runner (bit-exact recovery from injected
failures), straggler monitor, elastic mesh planning."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.archs import get_arch
from repro.configs.base import RunConfig, ShapeConfig
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import PipelineConfig, SyntheticLMPipeline
from repro.distributed.steps import init_train_state, make_train_step
from repro.ft.elastic import plan_mesh_shape
from repro.ft.monitor import StepTimeMonitor
from repro.ft.runner import ResilientTrainer, RunnerConfig
from repro.compat import set_mesh
from repro.launch.mesh import make_host_mesh


def _trainer(tmp_path, fail_at=(), steps=8, sub="a"):
    arch = get_arch("llama3.2-1b", smoke=True)
    shape = ShapeConfig("t", 32, 4, "train")
    mesh = make_host_mesh(model_parallel=1)
    run = RunConfig(mesh_model_parallel=1, learning_rate=3e-2)  # fast smoke descent
    with set_mesh(mesh):
        bundle = make_train_step(arch, run, shape, mesh)
        state = init_train_state(bundle)
        pipeline = SyntheticLMPipeline(arch, shape, PipelineConfig(seed=0))
        trainer = ResilientTrainer(
            step_fn=bundle.jit(),
            state=state,
            pipeline=pipeline,
            ckpt=CheckpointManager(tmp_path / sub, keep_n=10, async_save=False),
            cfg=RunnerConfig(total_steps=steps, checkpoint_every=2),
            fail_at=fail_at,
        )
    return trainer, mesh


def test_recovery_is_bit_exact(tmp_path):
    """A run with two injected failures must converge to the identical final
    state as an undisturbed run (deterministic data + restore)."""
    clean, mesh = _trainer(tmp_path, fail_at=(), sub="clean")
    with set_mesh(mesh):
        s_clean = clean.run()
        faulty, _ = _trainer(tmp_path, fail_at=(3, 5), sub="faulty")
        s_faulty = faulty.run()
    assert faulty.restarts == 2
    for a, b in zip(jax.tree.leaves(s_clean["params"]), jax.tree.leaves(s_faulty["params"])):
        assert jnp.array_equal(a, b), "recovery diverged from the clean run"


def test_loss_decreases_through_failures(tmp_path):
    tr, mesh = _trainer(tmp_path, fail_at=(4,), steps=10)
    with set_mesh(mesh):
        tr.run()
    losses = [h["loss"] for h in tr.history]
    assert losses[-1] < losses[0]


def test_too_many_failures_raises(tmp_path):
    tr, mesh = _trainer(tmp_path, fail_at=(2, 3, 4, 5), steps=8)
    tr.cfg.max_restarts = 2
    from repro.ft.runner import FailureError

    with pytest.raises(FailureError), set_mesh(mesh):
        tr.run()


def test_straggler_monitor_flags_outlier():
    mon = StepTimeMonitor(warmup_steps=3)
    flags = [mon.record(i, 0.10 + 0.001 * (i % 3)) for i in range(10)]
    assert not any(flags)
    assert mon.record(10, 1.0) is True  # 10× step time
    assert mon.record(11, 0.10) is False  # recovered; EMA not poisoned
    assert mon.stragglers == [10]


@pytest.mark.parametrize("n,expect", [
    (256, (16, 16)), (255, (8, 16)), (128, (8, 16)), (96, (4, 16)), (16, (1, 16)), (8, (1, 8)),
])
def test_elastic_mesh_planning(n, expect):
    data, model = plan_mesh_shape(n, prefer_model=16)
    assert (data, model) == expect
    assert data * model <= n


def test_elastic_respects_divisibility():
    arch = get_arch("gemma3-1b")  # d_model 1152 = 2^7 * 9 -> model <= 128? (1152/64=18) ✓ 64
    data, model = plan_mesh_shape(256, prefer_model=256, arch=arch)
    assert arch.d_model % model == 0
