"""Subprocess execution backend (hard per-trial isolation) + the
scheduler-correctness sweep that rode along with it: SIGKILLed hung trials,
crash containment, warm worker reuse, spec serialization, per-future batch
deadlines, over-deadline measurement persistence, robust log readers,
per-run accounting, and the tune()/scheduler conflict guard.

Worker-side functions must be module-level: the spawn start method ships
them to workers by pickle-by-reference.
"""
import json
import os
import time

import pytest

from repro.core import TrialScheduler, tune
from repro.core.evaluators import FunctionEvaluator
from repro.core.executors import EvaluatorSpec, SubprocessBackend, make_backend
from repro.core.scheduler import best_from_log, read_log
from repro.core.space import TRAIN_SPACE
from repro.core.strategies import GridFinerStrategy


# ---------------------------------------------------- worker-side functions


def _quad(cfg):
    return 10.0 + abs(cfg.get("x", 0) - 3) * 0.5


def _sleep_forever(cfg):
    time.sleep(60.0)
    return 0.0


def _sleep_3s(cfg):
    time.sleep(3.0)
    return 1.0


def _crash_on_flag(cfg):
    if cfg.get("crash"):
        os._exit(13)  # simulated segfault/OOM-kill: no exception, no cleanup
    return 1.0


def _pid_time(cfg):
    return float(os.getpid())


def _raise_on_flag(cfg):
    if cfg.get("boom"):
        raise RuntimeError("injected evaluator failure")
    return 2.0


def make_pid_evaluator():
    """Factory resolved by dotted path inside workers."""
    return FunctionEvaluator(_pid_time)


def _cfgs(n, **extra):
    return [{"x": i, **extra} for i in range(n)]


# -------------------------------------------------------- subprocess backend


def test_subprocess_matches_inline_on_function_evaluator():
    with TrialScheduler(FunctionEvaluator(_quad)) as inline, TrialScheduler(
        FunctionEvaluator(_quad), isolation="subprocess", max_workers=2
    ) as sub:
        t_inline = inline.evaluate_batch(_cfgs(4))
        t_sub = sub.evaluate_batch(_cfgs(4))
    assert [t.time_s for t in t_sub] == [t.time_s for t in t_inline]
    assert all(t.ok and t.status == "ok" for t in t_sub)
    assert sub.run_stats()["fresh"] == 4


def test_subprocess_kills_hung_trials_within_deadline():
    """Acceptance: sleep-60 trials under timeout 2 are SIGKILLed; the whole
    batch completes in well under N×timeout wall clock."""
    sched = TrialScheduler(
        FunctionEvaluator(_sleep_forever),
        isolation="subprocess", max_workers=2, timeout_s=2.0,
    )
    with sched:
        t0 = time.perf_counter()
        trials = sched.evaluate_batch(_cfgs(2))
        elapsed = time.perf_counter() - t0
    assert elapsed < 10.0, elapsed
    assert all(t.status == "timeout" for t in trials)
    assert all("SIGKILL" in t.error for t in trials)
    assert sched.run_stats()["timeouts"] == 2


def test_subprocess_contains_hard_crash_and_session_continues():
    """os._exit(13) inside a trial becomes a status="error" Trial; the
    scheduler keeps serving later trials and batches."""
    with TrialScheduler(
        FunctionEvaluator(_crash_on_flag), isolation="subprocess", max_workers=2
    ) as sched:
        trials = sched.evaluate_batch(
            [{"x": 0, "crash": True}, {"x": 1}, {"x": 2}]
        )
        assert trials[0].status == "error"
        assert "WorkerCrash" in trials[0].error and "13" in trials[0].error
        assert trials[1].ok and trials[1].time_s == 1.0
        assert trials[2].ok
        # the session survives: a fresh batch still works
        again = sched.evaluate_batch([{"x": 3}])
        assert again[0].ok
    assert sched.run_stats()["errors"] == 1


def test_subprocess_workers_are_reused_warm():
    """With one worker, every trial reports the same pid — the process (and
    whatever device/jit state it built) is paid for once, not per trial."""
    with TrialScheduler(
        FunctionEvaluator(_pid_time), isolation="subprocess", max_workers=1
    ) as sched:
        first = sched.evaluate_batch(_cfgs(3))
        second = sched.evaluate_batch([{"x": 99}])  # across batches too
    pids = {t.time_s for t in first} | {second[0].time_s}
    assert len(pids) == 1
    assert pids != {float(os.getpid())}  # and it is NOT this process


def test_subprocess_retries_evaluator_exception_then_records_error():
    with TrialScheduler(
        FunctionEvaluator(_raise_on_flag), isolation="subprocess",
        max_workers=1, retries=1, infeasible_time=1e6,
    ) as sched:
        trials = sched.evaluate_batch([{"boom": True}, {"x": 1}])
    assert trials[0].status == "error"
    assert "injected evaluator failure" in trials[0].error
    assert trials[0].time_s == 1e6
    assert trials[1].ok and trials[1].time_s == 2.0


def test_evaluator_spec_dotted_path_factory():
    backend = SubprocessBackend(
        spec=EvaluatorSpec.factory("test_executors:make_pid_evaluator")
    )
    with TrialScheduler(
        FunctionEvaluator(_quad),  # parent-side evaluator is NOT used
        backend=backend, max_workers=1,
    ) as sched:
        trial = sched.evaluate_batch([{"x": 0}])[0]
    assert trial.ok
    assert trial.time_s != float(os.getpid())  # ran in the worker


def test_unpicklable_evaluator_raises_helpful_error():
    box = []
    ev = FunctionEvaluator(lambda cfg: box and 1.0 or 2.0)  # closure: unpicklable
    with pytest.raises(TypeError, match="EvaluatorSpec"):
        TrialScheduler(ev, isolation="subprocess")


def test_make_backend_registry():
    assert make_backend("inline").name == "inline"
    assert make_backend("subprocess").name == "subprocess"
    with pytest.raises(ValueError, match="unknown isolation backend"):
        make_backend("threads")


def test_subprocess_tune_end_to_end(tmp_path):
    """Full tune() through the subprocess backend: same optimum as inline."""
    out = tune(
        "train", "gsft", FunctionEvaluator(_mesh_objective),
        active_params=["mesh_model_parallel"], samples_per_param=3,
        isolation="subprocess", max_workers=2,
        log_path=tmp_path / "log.jsonl",
    )
    ref = tune(
        "train", "gsft", FunctionEvaluator(_mesh_objective),
        active_params=["mesh_model_parallel"], samples_per_param=3,
    )
    assert out.best_config == ref.best_config
    assert out.best_time == ref.best_time


def _mesh_objective(cfg):
    return 10.0 + abs(cfg["mesh_model_parallel"] - 8) * 0.5


# ------------------------------------------- satellite: per-future deadlines


def test_parallel_thread_deadlines_not_cumulative():
    """Four 3s trials under a 0.4s timeout must fail in ~one timeout_s of
    wall clock, not 4 sequential ones (the old cumulative-deadline bug:
    each later future inherited the time earlier result() calls burned)."""
    sched = TrialScheduler(
        FunctionEvaluator(_sleep_3s), max_workers=4, timeout_s=0.4
    )
    t0 = time.perf_counter()
    trials = sched.evaluate_batch(_cfgs(4))
    elapsed = time.perf_counter() - t0
    assert elapsed < 1.2, elapsed  # old behaviour: >= 4 * 0.4 = 1.6s
    assert all(t.status == "timeout" for t in trials)


def _sleep_300ms(cfg):
    time.sleep(0.3)
    return 1.0


def test_queued_trials_are_not_falsely_timed_out():
    """timeout_s is per-trial execution time: trials queued behind a full
    pool must not inherit the batch's age as their own deadline."""
    sched = TrialScheduler(
        FunctionEvaluator(_sleep_300ms), max_workers=2, timeout_s=0.5
    )
    trials = sched.evaluate_batch(_cfgs(4))  # two waves of 0.3s < 0.5s each
    assert all(t.ok for t in trials), [t.error for t in trials]


# ---------------------------- satellite: over-deadline measurement survives


def _slow_but_finishes(cfg):
    time.sleep(0.15)
    return 7.0


def test_over_deadline_measurement_kept_and_persisted(tmp_path):
    cache = tmp_path / "cache.jsonl"
    sched = TrialScheduler(
        FunctionEvaluator(_slow_but_finishes), timeout_s=0.05, cache_path=cache
    )
    score = sched.evaluate({"x": 1})
    assert score == float("inf")  # scalar API still scores it infeasible
    trial = sched.trials[0]
    assert trial.status == "timeout"
    assert trial.time_s == 7.0  # the real measurement is kept...
    assert trial.score == float("inf")  # ...but never ranks

    # ...and persisted: a resume replays it instead of re-paying the trial
    calls = []

    def _counting(cfg):
        calls.append(1)
        return 7.0

    resumed = TrialScheduler(
        FunctionEvaluator(_counting), timeout_s=0.05, cache_path=cache
    )
    replay = resumed.evaluate_batch([{"x": 1}])[0]
    assert calls == []
    assert replay.source == "cache"
    assert replay.status == "timeout" and replay.time_s == 7.0


def test_cached_timeout_rejudged_against_current_deadline(tmp_path):
    """A cache written under a tight deadline must not permanently poison a
    config: replay re-judges the persisted wall against the live timeout."""
    cache = tmp_path / "cache.jsonl"
    sched = TrialScheduler(
        FunctionEvaluator(_slow_but_finishes), timeout_s=0.05, cache_path=cache
    )
    sched.evaluate({"x": 1})
    assert sched.trials[0].status == "timeout"

    relaxed = TrialScheduler(
        FunctionEvaluator(_quad), timeout_s=1.0, cache_path=cache
    )
    replay = relaxed.evaluate_batch([{"x": 1}])[0]
    assert replay.source == "cache"
    assert replay.ok and replay.status == "ok" and replay.time_s == 7.0

    no_deadline = TrialScheduler(FunctionEvaluator(_quad), cache_path=cache)
    assert no_deadline.evaluate({"x": 1}) == 7.0  # scores as a plain result


def test_init_failure_policy_cold_vs_warm():
    """Cold pool: init death raises. Warm pool: transient, up to a streak."""
    backend = SubprocessBackend(spec=EvaluatorSpec(target=_quad, construct=False))
    with pytest.raises(RuntimeError, match="boom"):
        backend._init_failed("boom")  # never been ready -> config error
    backend._ever_ready = True
    backend._init_failures = 0
    backend._init_failed("transient")  # tolerated
    backend._init_failed("transient")
    with pytest.raises(RuntimeError, match="transient"):
        backend._init_failed("transient")  # third consecutive -> raise


def test_legacy_cache_record_without_status_loads_as_ok(tmp_path):
    cache = tmp_path / "cache.jsonl"
    from repro.core.scheduler import config_hash

    cfg = {"x": 5}
    cache.write_text(json.dumps({
        "key": config_hash(cfg), "platform": "train", "tag": "",
        "ts": 0.0, "config": cfg, "time_s": 4.0, "info": {},
    }) + "\n")
    sched = TrialScheduler(FunctionEvaluator(_quad), cache_path=cache)
    trial = sched.evaluate_batch([cfg])[0]
    assert trial.source == "cache" and trial.ok and trial.time_s == 4.0


def test_ok_cache_records_carry_no_status_key(tmp_path):
    """Byte-compat: records for successful trials keep the pre-existing
    schema — status/error keys appear only on timeout records."""
    cache = tmp_path / "cache.jsonl"
    sched = TrialScheduler(FunctionEvaluator(_quad), cache_path=cache)
    sched.evaluate({"x": 1})
    rec = json.loads(cache.read_text().splitlines()[0])
    assert "status" not in rec and "error" not in rec


# ------------------------------------------ satellite: robust log readers


def test_read_log_tolerates_torn_tail_and_filters_platform(tmp_path):
    log = tmp_path / "log.jsonl"
    recs = [
        {"platform": "cell_a", "config": {"x": 1}, "time_s": 1.0, "error": None},
        {"platform": "cell_b", "config": {"x": 2}, "time_s": 2.0, "error": None},
        {"platform": "cell_a", "config": {"x": 3}, "time_s": 3.0, "error": None},
    ]
    with log.open("w") as f:
        for r in recs:
            f.write(json.dumps(r) + "\n")
        f.write('{"platform": "cell_a", "config": {"x": 4}, "time_')  # torn
    assert len(read_log(log)) == 3
    cell_a = read_log(log, platform="cell_a")
    assert [r["time_s"] for r in cell_a] == [1.0, 3.0]
    assert best_from_log(log, platform="cell_a")["time_s"] == 1.0
    assert best_from_log(log, platform="cell_b")["time_s"] == 2.0


def test_best_from_log_raises_clearly_when_nothing_succeeded(tmp_path):
    log = tmp_path / "log.jsonl"
    log.write_text(json.dumps({
        "platform": "train", "config": {}, "time_s": float("inf"),
        "error": "TrialTimeout: ...",
    }) + "\n")
    with pytest.raises(ValueError, match="no successful trials"):
        best_from_log(log)


# ---------------------------------------- satellite: per-run accounting


def test_shared_scheduler_reports_per_run_deltas():
    sched = TrialScheduler(FunctionEvaluator(_mesh_objective))
    r1 = sched.run(GridFinerStrategy(
        TRAIN_SPACE, active_params=["mesh_model_parallel"], samples_per_param=3))
    n1 = sched.num_evaluations
    assert r1.evaluations == n1
    r2 = sched.run(GridFinerStrategy(
        TRAIN_SPACE, active_params=["microbatch_size"], samples_per_param=3))
    assert r2.evaluations == sched.num_evaluations - n1
    assert r2.evaluations < sched.num_evaluations  # NOT the lifetime total


def test_shared_scheduler_tune_outcome_not_inflated():
    sched = TrialScheduler(FunctionEvaluator(_mesh_objective))
    out1 = tune("train", "gsft", sched.evaluator, scheduler=sched,
                active_params=["mesh_model_parallel"], samples_per_param=3)
    out2 = tune("train", "gsft", sched.evaluator, scheduler=sched,
                active_params=["microbatch_size"], samples_per_param=3)
    assert out1.evaluations + out2.evaluations == sched.num_evaluations


# ------------------------------- satellite: tune() vs scheduler conflict


def test_tune_rejects_engine_kwargs_with_explicit_scheduler():
    sched = TrialScheduler(FunctionEvaluator(_mesh_objective))
    with pytest.raises(ValueError, match="max_workers.*ignored"):
        tune("train", "gsft", sched.evaluator, scheduler=sched,
             max_workers=4, active_params=["mesh_model_parallel"])
    with pytest.raises(ValueError, match="timeout_s, retries"):
        tune("train", "gsft", sched.evaluator, scheduler=sched,
             timeout_s=1.0, retries=2, active_params=["mesh_model_parallel"])
    with pytest.raises(ValueError, match="isolation"):
        tune("train", "gsft", sched.evaluator, scheduler=sched,
             isolation="subprocess", active_params=["mesh_model_parallel"])
    with pytest.raises(ValueError, match="log_path"):
        tune("train", "gsft", sched.evaluator, scheduler=sched,
             log_path=__import__("pathlib").Path("x.jsonl"),
             active_params=["mesh_model_parallel"])
