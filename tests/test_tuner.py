"""Tuner invariants: the paper's two algorithms + CMPE, on synthetic
objectives with known optima (property-based where it pays)."""
import json
import math
from pathlib import Path

import pytest
from _hyp import given, settings, st

from repro.core import (CMPE, SPACES, best_from_log, controlled_random_search,
                        grid_search_finer_tuning, read_log, tune)
from repro.core.evaluators import FunctionEvaluator
from repro.core.space import TRAIN_SPACE


def quad_objective(cfg):
    t = 10.0
    t += abs(cfg["mesh_model_parallel"] - 8) * 0.5
    t += abs((cfg["microbatch_size"] or 256) - 32) * 0.02
    t += {"none": 2.0, "dots": 0.0, "full": 1.0}[cfg["remat_policy"]]
    return t


def test_gsft_finds_known_optimum(tmp_path):
    out = tune(
        "train", "gsft", FunctionEvaluator(quad_objective),
        log_path=tmp_path / "log.jsonl",
        active_params=["mesh_model_parallel", "microbatch_size", "remat_policy"],
        samples_per_param=4,
    )
    assert out.best_config["mesh_model_parallel"] == 8
    assert out.best_config["remat_policy"] == "dots"
    assert out.best_time <= quad_objective({**TRAIN_SPACE.defaults()})
    assert out.reduction_pct > 0


def test_gsft_finer_pass_improves_or_holds(tmp_path):
    """Phase 2 (finer tuning) may never return something worse than phase 1."""
    cmpe = CMPE(FunctionEvaluator(quad_objective), log_path=tmp_path / "l.jsonl")
    res = grid_search_finer_tuning(
        TRAIN_SPACE, cmpe,
        active_params=["mesh_model_parallel", "microbatch_size"],
        samples_per_param=3,
    )
    assert res.best_time <= res.phase1_time


def test_crs_bounds_contract_and_improve():
    cmpe = CMPE(FunctionEvaluator(quad_objective))
    res = controlled_random_search(TRAIN_SPACE, cmpe, m=16, k=4, max_rounds=4, seed=1)
    # bounds must contract monotonically per numeric parameter
    for name in ("mesh_model_parallel", "microbatch_size"):
        widths = [hi - lo for (lo, hi) in (b[name] for b in res.bound_history)]
        assert all(w2 <= w1 + 1e-9 for w1, w2 in zip(widths, widths[1:])), widths
    default_t = quad_objective(TRAIN_SPACE.defaults())
    assert res.best_time <= default_t


def test_gsft_beats_or_matches_crs_same_objective():
    """The paper's comparison (§XI): GSFT found better configs than CRS."""
    g = tune("train", "gsft", FunctionEvaluator(quad_objective),
             active_params=["mesh_model_parallel", "microbatch_size", "remat_policy"],
             samples_per_param=4)
    c = tune("train", "crs", FunctionEvaluator(quad_objective), m=12, k=4,
             max_rounds=4, seed=0)
    assert g.best_time <= c.best_time + 1e-9


def test_cmpe_logs_and_memoizes(tmp_path):
    calls = []

    def f(cfg):
        calls.append(1)
        return 1.0

    cmpe = CMPE(FunctionEvaluator(f), log_path=tmp_path / "log.jsonl")
    cfg = TRAIN_SPACE.defaults()
    cmpe.evaluate(cfg)
    cmpe.evaluate(cfg)  # memoized — evaluator runs once
    assert len(calls) == 1
    recs = read_log(tmp_path / "log.jsonl")
    assert len(recs) == 2 and recs[1]["cached"]
    assert best_from_log(tmp_path / "log.jsonl")["time_s"] == 1.0


def test_cmpe_failed_trial_is_logged_not_raised(tmp_path):
    def f(cfg):
        raise RuntimeError("injected OOM")

    cmpe = CMPE(FunctionEvaluator(f), log_path=tmp_path / "log.jsonl")
    t = cmpe.evaluate(TRAIN_SPACE.defaults())
    assert t == float("inf")
    assert read_log(tmp_path / "log.jsonl")[0]["error"]


def test_tuner_never_returns_worse_than_default():
    """Even a hostile objective (defaults optimal) can't regress the outcome."""

    def hostile(cfg):
        return 1.0 if cfg == TRAIN_SPACE.defaults() else 5.0

    out = tune("train", "crs", FunctionEvaluator(hostile), m=6, k=2, max_rounds=2)
    assert out.best_time == 1.0
    assert out.best_config == TRAIN_SPACE.defaults()


# --------------------------------------------------------------- properties


@given(st.integers(-10_000, 10_000))
@settings(max_examples=200, deadline=None)
def test_property_snap_idempotent_and_bounded(v):
    for p in TRAIN_SPACE.params:
        if p.numeric:
            s1 = p.snap(v)
            assert p.lo <= s1 <= p.hi
            assert p.snap(s1) == s1  # idempotent
            if getattr(p, "pow2", False) and s1 > 0:
                assert s1 & (s1 - 1) == 0  # power of two


@given(st.data())
@settings(max_examples=50, deadline=None)
def test_property_random_configs_valid(data):
    import random

    rng = random.Random(data.draw(st.integers(0, 2**16)))
    for space in SPACES.values():
        cfg = {p.name: p.sample(rng) for p in space.params}
        snapped = space.snap(cfg)
        assert snapped == space.snap(snapped)
        rc = space.to_run_config(snapped)  # must build a valid RunConfig
        assert rc.mesh_model_parallel >= 1
