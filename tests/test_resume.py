"""Crash-resume regression tests for the persistent eval cache, plus the
soft-timeout reporting contract.

A tuning session killed mid-batch (simulated with an evaluator that raises
``KeyboardInterrupt`` after k calls — pytest's Ctrl-C analog, a BaseException
the scheduler deliberately does NOT swallow) must lose nothing: every trial
that completed before the kill was persisted the moment it finished, so the
re-run replays them from the JSONL cache, pays fresh evaluations only for the
remainder, and lands on the incumbent of a never-crashed run. TPE resumes
through its warm-started observation history as well.
"""
import threading
import time

import pytest

from repro.core import TRAIN_SPACE, TrialScheduler, tune
from repro.core.evaluators import FunctionEvaluator
from repro.core.scheduler import read_log
from repro.core.strategies import CRSStrategy, GridFinerStrategy, TPEStrategy


def quad_objective(cfg):
    t = 10.0
    t += abs(cfg["mesh_model_parallel"] - 8) * 0.5
    t += abs((cfg["microbatch_size"] or 256) - 32) * 0.02
    t += {"none": 2.0, "dots": 0.0, "full": 1.0}[cfg["remat_policy"]]
    return t


class KillAfter:
    """Deterministic objective that simulates the session being killed
    (SIGINT) on the (n+1)-th fresh evaluation."""

    def __init__(self, n, fn=quad_objective):
        self.n = n
        self.fn = fn
        self.calls = 0
        self._lock = threading.Lock()

    def __call__(self, config):
        with self._lock:
            if self.calls >= self.n:
                raise KeyboardInterrupt
            self.calls += 1
        return float(self.fn(config)), {}


class Counting:
    def __init__(self, fn=quad_objective):
        self.fn = fn
        self.calls = 0
        self._lock = threading.Lock()

    def __call__(self, config):
        with self._lock:
            self.calls += 1
        return float(self.fn(config)), {}


def _crs(seed=5):
    return CRSStrategy(TRAIN_SPACE, m=8, k=3, max_rounds=3, seed=seed)


# ------------------------------------------------------------- crash + resume


def test_crash_mid_batch_then_resume_only_pays_remainder(tmp_path):
    cache = tmp_path / "cache.jsonl"

    # reference: the same seeded sweep, never crashed, no cache
    ref_sched = TrialScheduler(Counting())
    ref = ref_sched.run(_crs(), batch_size=4)
    total = ref_sched.fresh_evaluations

    # run 1: killed mid-batch after 7 fresh evaluations
    killed = 7
    sched1 = TrialScheduler(KillAfter(killed), cache_path=cache)
    with pytest.raises(KeyboardInterrupt):
        sched1.run(_crs(), batch_size=4)
    # every completed trial was persisted the moment it finished — the kill
    # landed mid-batch, not at a batch boundary, and still lost nothing
    assert len(cache.read_text().splitlines()) == killed

    # run 2: same command, same cache — replays the 7, pays the remainder
    ev2 = Counting()
    sched2 = TrialScheduler(ev2, cache_path=cache)
    res2 = sched2.run(_crs(), batch_size=4)
    assert ev2.calls == total - killed
    assert sched2.cache_stats()["cache_hits"] == killed
    assert res2.best_config == ref.best_config
    assert res2.best_time == ref.best_time

    # run 3: complete cache — zero fresh evaluations, identical incumbent
    ev3 = Counting()
    sched3 = TrialScheduler(ev3, cache_path=cache)
    res3 = sched3.run(_crs(), batch_size=4)
    assert ev3.calls == 0
    assert sched3.fresh_evaluations == 0
    assert res3.best_config == ref.best_config
    assert res3.best_time == ref.best_time


def test_crash_resume_gsft_full_rerun_zero_fresh(tmp_path):
    cache = tmp_path / "cache.jsonl"
    kw = dict(active_params=["mesh_model_parallel", "remat_policy"],
              samples_per_param=3)

    sched1 = TrialScheduler(KillAfter(5), cache_path=cache)
    with pytest.raises(KeyboardInterrupt):
        sched1.run(GridFinerStrategy(TRAIN_SPACE, **kw), batch_size=3)

    ev2 = Counting()
    sched2 = TrialScheduler(ev2, cache_path=cache)
    res2 = sched2.run(GridFinerStrategy(TRAIN_SPACE, **kw), batch_size=3)
    assert sched2.cache_stats()["cache_hits"] == 5

    ev3 = Counting()
    sched3 = TrialScheduler(ev3, cache_path=cache)
    res3 = sched3.run(GridFinerStrategy(TRAIN_SPACE, **kw), batch_size=3)
    assert ev3.calls == 0
    assert res3.best_config == res2.best_config
    assert res3.best_time == res2.best_time


def test_tpe_crash_resume_warm_history_pays_only_remaining_budget(tmp_path):
    """TPE resumes via warm-started history: cached observations count toward
    max_trials, so the re-run proposes only the unpaid remainder and a
    complete cache proposes nothing at all."""
    cache = tmp_path / "cache.jsonl"
    budget, killed = 20, 9

    sched1 = TrialScheduler(KillAfter(killed), platform="train", cache_path=cache)
    with pytest.raises(KeyboardInterrupt):
        sched1.run(TPEStrategy(TRAIN_SPACE, max_trials=budget, seed=3), batch_size=4)
    assert len(cache.read_text().splitlines()) == killed

    # resume through tune(): the cache warm-starts the observation history
    ev2 = Counting()
    out2 = tune("train", "tpe", ev2, cache_path=cache, max_trials=budget, seed=3)
    assert out2.detail.warm_started == killed
    # fresh = remaining budget + the defaults trial tune() always measures
    assert ev2.calls <= budget - killed + 1
    assert out2.detail.n_observations >= budget

    # complete cache: nothing fresh, incumbent identical
    ev3 = Counting()
    out3 = tune("train", "tpe", ev3, cache_path=cache, max_trials=budget, seed=3)
    assert ev3.calls == 0
    assert out3.cache_stats["fresh"] == 0
    assert out3.best_config == out2.best_config
    assert out3.best_time == out2.best_time


def test_tpe_warm_history_at_budget_proposes_nothing():
    history = []
    import random

    rng = random.Random(0)
    for _ in range(12):
        cfg = {p.name: p.sample(rng) for p in TRAIN_SPACE.params}
        history.append((cfg, quad_objective(cfg)))
    strat = TPEStrategy(TRAIN_SPACE, max_trials=12, history=history)
    assert strat.done
    assert strat.ask(8) == []
    best_cfg, best_t = min(history, key=lambda ct: ct[1])
    res = strat.result()
    assert res.warm_started == 12
    assert res.best_time == best_t
    assert res.best_config == TRAIN_SPACE.snap(best_cfg)


# ------------------------------------------------------- timeout reporting


def test_soft_timeout_counted_as_timeout_not_error(tmp_path):
    log = tmp_path / "log.jsonl"

    def slow(cfg):
        time.sleep(0.2)
        return 1.0

    sched = TrialScheduler(FunctionEvaluator(slow), timeout_s=0.05, log_path=log)
    sched.evaluate(TRAIN_SPACE.defaults())
    trial = sched.trials[0]
    assert trial.status == "timeout" and trial.timed_out
    assert sched.timeout_trials == 1
    assert sched.error_trials == 0  # NOT folded into the failure count
    assert sched.run_stats()["timeouts"] == 1
    assert read_log(log)[0]["status"] == "timeout"


def test_abandoned_thread_timeouts_counted_and_logged(tmp_path):
    """Parallel batch: hung workers are abandoned; their trials must be
    reported as timeouts (status + counter), not generic failures."""
    log = tmp_path / "log.jsonl"

    def hang(cfg):
        time.sleep(1.0)
        return 1.0

    sched = TrialScheduler(FunctionEvaluator(hang), max_workers=2,
                           timeout_s=0.1, log_path=log)
    cfgs = [{**TRAIN_SPACE.defaults(), "mesh_model_parallel": mp}
            for mp in (1, 2)]
    trials = sched.evaluate_batch(cfgs)
    assert all(t.status == "timeout" for t in trials)
    assert sched.timeout_trials == 2
    assert sched.error_trials == 0
    assert all(r["status"] == "timeout" for r in read_log(log))


def test_error_trials_not_counted_as_timeouts():
    def boom(cfg):
        raise RuntimeError("injected")

    sched = TrialScheduler(FunctionEvaluator(boom))
    sched.evaluate(TRAIN_SPACE.defaults())
    assert sched.trials[0].status == "error"
    assert sched.error_trials == 1 and sched.timeout_trials == 0


def test_timeouts_surfaced_in_tune_summary():
    def sometimes_slow(cfg):
        if cfg["mesh_model_parallel"] >= 32:
            time.sleep(0.2)
        return float(cfg["mesh_model_parallel"])

    out = tune(
        "train", "gsft", FunctionEvaluator(sometimes_slow),
        active_params=["mesh_model_parallel"], samples_per_param=7,
        timeout_s=0.1,
    )
    assert out.timeouts > 0
    assert out.summary()["timeouts"] == out.timeouts
