"""Property-based tests for ``repro.core.space`` — the invariants every
strategy (grid phases, CRS bound contraction, TPE kernel sampling) leans on:

  - ``snap`` is idempotent and always lands in bounds / in choices
  - ``grid(num)`` is sorted, deduplicated, and within range
  - ``sample(rng, lo, hi)`` respects the override window (up to one snap
    quantum of slack for stepped/pow2 integer knobs)
  - ``pow2`` snapping returns powers of two (or the 0 sentinel when lo == 0)

The checks live in plain ``_check_*`` helpers. The ``@given`` wrappers drive
them from hypothesis (via the optional shim in ``_hyp`` — clean skip when
hypothesis is absent); the ``test_*_fallback`` loops drive the *same* helpers
from a seeded rng so the invariants stay enforced on a bare install too.

NOTE on pow2 bounds: ``snap`` is only contractive when the bounds themselves
are powers of two (or the 0 sentinel) — ``IntParam(lo=3, pow2=True)`` would
oscillate 3 -> 4. Every shipped space satisfies this, and the generators
below only build pow2 params with representable bounds.
"""
import math
import random

from _hyp import HAVE_HYPOTHESIS, given, settings, st

from repro.core.space import CatParam, FloatParam, IntParam, SPACES
from repro.core.transfer import snap_into_space
from repro.apps.wordcount import WORDCOUNT_SPACE

_POW2_LOS = (0, 1, 2, 4, 8, 16)
_POW2_HIS = (16, 32, 64, 128, 512, 2048)
_CHOICES = ("alpha", "beta", "gamma", "delta", "epsilon")


def _is_pow2(v) -> bool:
    v = int(v)
    return v >= 1 and (v & (v - 1)) == 0


# ------------------------------------------------------------ check helpers


def _check_snap(p, raw):
    """snap is idempotent, in bounds / in choices, and on the step grid."""
    s = p.snap(raw)
    assert p.snap(s) == s, (p, raw, s)
    if isinstance(p, CatParam):
        assert s in p.choices
    else:
        assert p.lo <= s <= p.hi, (p, raw, s)
    if getattr(p, "pow2", False):
        assert s == 0 or _is_pow2(s), (p, raw, s)
        if s == 0:
            assert p.lo == 0
    if isinstance(p, FloatParam) and p.step > 0:
        # snap quantizes to the step grid anchored at lo (like IntParam);
        # the only off-grid escape is the hi clamp when a quantum rounds past
        k = (s - p.lo) / p.step
        assert s == p.hi or abs(k - round(k)) <= 1e-3, (p, raw, s, k)


def _check_grid(p, num):
    """grid(num) is non-empty, sorted, deduped, within range."""
    g = p.grid(num)
    assert g, (p, num)
    if isinstance(p, CatParam):
        assert list(g) == list(p.choices)  # full choice set, num ignored
        return
    assert g == sorted(g), (p, num, g)
    assert len(set(g)) == len(g), (p, num, g)
    for v in g:
        assert p.lo <= v <= p.hi, (p, num, v)
        if getattr(p, "pow2", False):
            assert v == 0 or _is_pow2(v)


def _check_sample_overrides(p, rng, frac_lo, frac_hi):
    """sample(rng, lo, hi) stays inside the override window (modulo one snap
    quantum for stepped ints, one pow2 rounding for pow2 ints)."""
    if isinstance(p, CatParam):
        assert p.sample(rng) in p.choices
        return
    lo2 = p.lo + frac_lo * (p.hi - p.lo)
    hi2 = lo2 + frac_hi * (p.hi - lo2)
    v = p.sample(rng, lo2, hi2)
    assert p.lo <= v <= p.hi, (p, lo2, hi2, v)
    if isinstance(p, FloatParam):
        # step quantization may move a sample up to half a quantum outside
        slack = p.step / 2 + 1e-9
        assert lo2 - slack <= v <= hi2 + slack, (p, lo2, hi2, v)
    elif getattr(p, "pow2", False):
        # nearest-pow2 rounding moves a value by < 2x either way
        assert v == 0 or (v >= max(p.lo, lo2 / 2 - 1) and v <= min(p.hi, 2 * hi2 + 1)), \
            (p, lo2, hi2, v)
        if v == 0:
            assert p.lo == 0 and lo2 < 1
    else:
        assert lo2 - p.step <= v <= hi2 + p.step, (p, lo2, hi2, v)


def _check_pow2_snap(p, raw):
    v = p.snap(raw)
    assert v == 0 or _is_pow2(v), (p, raw, v)
    assert p.lo <= v <= p.hi


def _check_snap_into_space(space, raw_config):
    """Cross-cell transfer invariant: any sibling config snapped into a
    (possibly different) cell's space lands in-bounds, on-grid, and the
    result is a fixed point — including pow2 and step-grid params. Foreign
    keys are dropped; missing params fall back to the space default."""
    snapped = snap_into_space(space, raw_config)
    assert set(snapped) == {p.name for p in space.params}, snapped
    # idempotent: snapping a snapped config is the identity
    assert snap_into_space(space, snapped) == snapped
    for p in space.params:
        v = snapped[p.name]
        assert p.snap(v) == v, (p, raw_config.get(p.name), v)  # on-grid fixed point
        if p.name not in raw_config:
            # missing params land on the SNAPPED default (a shipped default
            # may sit off its own step grid — wordcount's io_sort_mb)
            assert v == p.snap(p.default)
        _check_snap(p, v)  # in bounds / in choices / pow2 / step grid


# ------------------------------------------------------- param constructors


def _int_param(lo, width, step):
    return IntParam("k", lo, lo=lo, hi=lo + width, step=step)


def _pow2_param(lo, hi):
    hi = max(hi, lo, 1)
    return IntParam("k", max(lo, 1), lo=lo, hi=hi, pow2=True)


def _float_param(lo, width):
    return FloatParam("k", lo, lo=lo, hi=lo + width, step=max(width / 10.0, 1e-6))


def _cat_param(n):
    choices = _CHOICES[: max(1, min(n, len(_CHOICES)))]
    return CatParam("k", choices[0], choices=choices)


ALL_SHIPPED_PARAMS = [
    p for space in (*SPACES.values(), WORDCOUNT_SPACE) for p in space.params
]


# -------------------------------------------------------- hypothesis drivers


@given(st.integers(-200, 200), st.integers(0, 500), st.integers(1, 64),
       st.integers(-100_000, 100_000))
@settings(max_examples=150, deadline=None)
def test_property_int_snap_idempotent_inbounds(lo, width, step, raw):
    _check_snap(_int_param(lo, width, step), raw)


@given(st.sampled_from(_POW2_LOS), st.sampled_from(_POW2_HIS),
       st.integers(-10, 100_000))
@settings(max_examples=150, deadline=None)
def test_property_pow2_snap_returns_powers_of_two(lo, hi, raw):
    if max(lo, 1) <= hi:
        p = _pow2_param(lo, hi)
        _check_pow2_snap(p, raw)
        _check_snap(p, raw)


@given(st.floats(-1e3, 1e3), st.floats(1e-3, 1e3), st.floats(-1e6, 1e6))
@settings(max_examples=150, deadline=None)
def test_property_float_snap_idempotent_inbounds(lo, width, raw):
    _check_snap(_float_param(lo, width), raw)


@given(st.floats(-1e3, 1e3), st.floats(1e-3, 1e3), st.floats(-1e6, 1e6))
@settings(max_examples=150, deadline=None)
def test_property_float_snap_respects_step(lo, width, raw):
    """FloatParam.snap must quantize to the step grid the way IntParam does —
    CRS/TPE proposals land on the same grid the sweeps (grid_between) walk."""
    p = _float_param(lo, width)
    s = p.snap(raw)
    k = (s - p.lo) / p.step
    assert s == p.hi or abs(k - round(k)) <= 1e-3, (p, raw, s, k)
    if s != p.hi:
        # ...and a value constructed ON the grid is a fixed point (catches a
        # quantizer anchored anywhere other than lo)
        on_grid = p.lo + round(k) * p.step
        assert p.snap(on_grid) == on_grid, (p, raw, s, on_grid)


@given(st.integers(1, 5), st.text(min_size=0, max_size=3))
@settings(max_examples=50, deadline=None)
def test_property_cat_snap_lands_in_choices(n, raw):
    _check_snap(_cat_param(n), raw)


@given(st.integers(-200, 200), st.integers(1, 500), st.integers(1, 64),
       st.integers(1, 9))
@settings(max_examples=150, deadline=None)
def test_property_int_grid_sorted_deduped_inrange(lo, width, step, num):
    _check_grid(_int_param(lo, width, step), num)


@given(st.sampled_from(_POW2_LOS), st.sampled_from(_POW2_HIS), st.integers(1, 9))
@settings(max_examples=100, deadline=None)
def test_property_pow2_grid_sorted_deduped_inrange(lo, hi, num):
    if max(lo, 1) <= hi:
        _check_grid(_pow2_param(lo, hi), num)


@given(st.floats(-1e3, 1e3), st.floats(1e-3, 1e3), st.integers(1, 9))
@settings(max_examples=150, deadline=None)
def test_property_float_grid_sorted_deduped_inrange(lo, width, num):
    _check_grid(_float_param(lo, width), num)


@given(st.integers(0, 2**16), st.floats(0.0, 1.0), st.floats(0.0, 1.0))
@settings(max_examples=150, deadline=None)
def test_property_sample_respects_overrides_int(seed, frac_lo, frac_hi):
    rng = random.Random(seed)
    _check_sample_overrides(_int_param(-50, 200, 7), rng, frac_lo, frac_hi)
    _check_sample_overrides(_int_param(0, 10, 1), rng, frac_lo, frac_hi)


@given(st.integers(0, 2**16), st.floats(0.0, 1.0), st.floats(0.0, 1.0))
@settings(max_examples=150, deadline=None)
def test_property_sample_respects_overrides_pow2_float_cat(seed, frac_lo, frac_hi):
    rng = random.Random(seed)
    _check_sample_overrides(_pow2_param(1, 2048), rng, frac_lo, frac_hi)
    _check_sample_overrides(_pow2_param(0, 128), rng, frac_lo, frac_hi)
    _check_sample_overrides(_float_param(0.025, 0.875), rng, frac_lo, frac_hi)
    _check_sample_overrides(_cat_param(4), rng, frac_lo, frac_hi)


@given(st.integers(0, 2**16))
@settings(max_examples=50, deadline=None)
def test_property_shipped_spaces_hold_invariants(seed):
    """Every param of the train/serve/wordcount spaces satisfies all four
    invariant families at once."""
    rng = random.Random(seed)
    for p in ALL_SHIPPED_PARAMS:
        raw = rng.uniform(-1e5, 1e5)
        _check_snap(p, raw if p.numeric else "bogus")
        _check_grid(p, rng.randint(1, 8))
        _check_sample_overrides(p, rng, rng.random(), rng.random())


_SHIPPED_SPACES = (*SPACES.values(), WORDCOUNT_SPACE)


def _donor_config(rng, donor):
    """A sibling-cell config as the transfer path can see it: legal samples,
    wildly out-of-bounds raw values, junk categoricals, missing params, and
    keys the target space has never heard of."""
    cfg = {}
    for p in donor.params:
        r = rng.random()
        if r < 0.4:
            cfg[p.name] = p.sample(rng)
        elif r < 0.7:
            cfg[p.name] = rng.uniform(-1e6, 1e6) if p.numeric else "junk"
        # else: omit — snapping must fall back to the target-space default
    cfg["totally_foreign_knob"] = rng.random()
    return cfg


@given(st.integers(0, 2**16))
@settings(max_examples=150, deadline=None)
def test_property_sibling_config_snaps_into_any_space(seed):
    """Any donor cell's config lands in any target space in-bounds, on-grid,
    idempotent — across every shipped (train/serve/wordcount) space pair, so
    pow2, step-grid int, step-grid float, and categorical params are all
    exercised."""
    rng = random.Random(seed)
    donor = _SHIPPED_SPACES[rng.randrange(len(_SHIPPED_SPACES))]
    target = _SHIPPED_SPACES[rng.randrange(len(_SHIPPED_SPACES))]
    _check_snap_into_space(target, _donor_config(rng, donor))


@given(st.integers(-100_000, 100_000), st.integers(-100_000, 100_000),
       st.floats(-1e6, 1e6, allow_nan=False))
@settings(max_examples=150, deadline=None)
def test_property_sibling_snap_handles_pow2_and_step_grids(raw_pow2, raw_step,
                                                          raw_float):
    """The adversarial corners by construction: pow2, step-grid int, and
    step-grid float params all snap raw sibling values onto their grids."""
    from repro.core.space import TunableSpace

    space = TunableSpace(
        platform="synthetic",
        params=(
            _pow2_param(1, 2048),
            IntParam("stepped", 128, lo=128, hi=2048, step=128),
            FloatParam("frac", 0.05, lo=0.025, hi=0.9, step=0.025),
        ),
        most_influential=("stepped",),
    )
    _check_snap_into_space(space, {
        "k": raw_pow2, "stepped": raw_step, "frac": raw_float,
        "alien": "value",
    })


# --------------------------------------- seeded fallback (no hypothesis req.)


def test_fallback_snap_grid_sample_invariants():
    """The same helpers, driven by a seeded rng — keeps the invariants
    enforced (and this module honest) when hypothesis is not installed."""
    rng = random.Random(0)
    for _ in range(200):
        params = [
            _int_param(rng.randint(-200, 200), rng.randint(0, 500), rng.randint(1, 64)),
            _float_param(rng.uniform(-1e3, 1e3), rng.uniform(1e-3, 1e3)),
            _cat_param(rng.randint(1, 5)),
        ]
        lo = rng.choice(_POW2_LOS)
        hi = rng.choice(_POW2_HIS)
        if max(lo, 1) <= hi:
            params.append(_pow2_param(lo, hi))
        for p in params:
            raw = rng.uniform(-1e5, 1e5)
            _check_snap(p, raw if p.numeric else "bogus")
            _check_grid(p, rng.randint(1, 8))
            _check_sample_overrides(p, rng, rng.random(), rng.random())
            if getattr(p, "pow2", False):
                _check_pow2_snap(p, rng.randint(-10, 100_000))


def test_fallback_shipped_spaces_hold_invariants():
    rng = random.Random(1)
    for _ in range(25):
        for p in ALL_SHIPPED_PARAMS:
            _check_snap(p, rng.uniform(-1e5, 1e5) if p.numeric else "bogus")
            _check_grid(p, rng.randint(1, 8))
            _check_sample_overrides(p, rng, rng.random(), rng.random())


def test_fallback_sibling_config_snapping():
    """Seeded drive of the sibling-snap invariants — enforced on bare
    installs too."""
    rng = random.Random(2)
    for _ in range(150):
        donor = _SHIPPED_SPACES[rng.randrange(len(_SHIPPED_SPACES))]
        target = _SHIPPED_SPACES[rng.randrange(len(_SHIPPED_SPACES))]
        _check_snap_into_space(target, _donor_config(rng, donor))
