"""Surrogate CI smoke — the acceptance claim, asserted from journaled records.

From the checked-in donor study (``results/studies/surrogate_donor``: a deep
TPE sweep on the WordCount wc:1m cell plus one ssm_scan kernel cell), run
``--surrogate off`` vs ``rank`` target sessions at equal budget and seed on
a sibling cell of each family, and assert via each run's ``trials.jsonl``
that rank reaches the off control's incumbent (within 2%) in strictly fewer
fresh evaluations.

The cells are the deterministic modeled ones from ``surrogate_cells`` (pure
functions, no walltime), so the comparison is exact, not statistical — the
same design as the transfer CI smoke. The donor cells never re-run: the
surrogate trains on them through ``Study.histories_for`` sibling delivery,
which is also what this smoke regression-tests.

    PYTHONPATH=src:tests python tests/surrogate_ci_smoke.py [workdir]
    PYTHONPATH=src:tests python tests/surrogate_ci_smoke.py --regen-donor
"""
from __future__ import annotations

import json
import shutil
import sys
from pathlib import Path

from surrogate_cells import (
    WC_CELLS,
    make_ssm_evaluator,
    make_wc_evaluator,
    ssm_namespace,
)

from repro.core import Study
from repro.core.kernel_tune import KERNEL_SPACES, kernel_similarity

DONOR = Path("results/studies/surrogate_donor")


def evals_to(trials_path: Path, namespace: str, incumbent: float):
    """1-based index of the first fresh ok trial in ``namespace`` at or
    under ``incumbent``, or None — read from the journal, not the summary."""
    fresh = 0
    for line in open(trials_path):
        rec = json.loads(line)
        if rec.get("platform") != namespace or rec.get("status") != "ok":
            continue
        if rec.get("cached") or rec.get("source") != "fresh":
            continue
        fresh += 1
        t = rec.get("time_s")
        if isinstance(t, (int, float)) and t <= incumbent:
            return fresh
    return None


def run_cell(work: Path, tag: str, namespace: str, budget: int, seed: int,
             make_ev, space=None, similarity=None) -> None:
    out = {}
    for mode in ("off", "rank"):
        d = work / f"{tag}_{mode}"
        if d.exists():
            shutil.rmtree(d)
        d.parent.mkdir(parents=True, exist_ok=True)
        shutil.copytree(DONOR, d)
        study = Study.load(d)
        kwargs = dict(budget=budget, seed=seed, n_startup=4,
                      engine=study.engine.replace(surrogate=mode))
        if space is not None:
            kwargs["space"] = space
        if similarity is not None:
            kwargs["similarity"] = similarity
        res = study.optimize(namespace, "tpe", make_ev(), **kwargs)
        out[mode] = (d / "trials.jsonl", res.best_time)

    (off_path, off_best), (rank_path, _) = out["off"], out["rank"]
    incumbent = off_best * 1.02
    off_at = evals_to(off_path, namespace, incumbent)
    rank_at = evals_to(rank_path, namespace, incumbent)
    print(f"{tag}: off incumbent {off_best:.6g} reached@{off_at}, "
          f"rank reached@{rank_at}")
    assert off_at is not None, f"{tag}: off never reached its own incumbent"
    assert rank_at is not None, f"{tag}: rank never reached off incumbent+2%"
    assert rank_at < off_at, (
        f"{tag}: rank needed {rank_at} fresh evals vs off {off_at} — "
        f"surrogate pre-ranking did not help")


def regen_donor() -> None:
    """Rebuild the checked-in donor study. The evaluators are deterministic,
    so regeneration reproduces the same trials (timestamps aside)."""
    shutil.rmtree(DONOR, ignore_errors=True)
    study = Study.create(DONOR)
    study.optimize("wordcount/wc:1m", "tpe",
                   make_wc_evaluator(WC_CELLS["wc:1m"]), budget=48, seed=3)
    study.optimize(ssm_namespace((2, 128, 64, 8)), "tpe",
                   make_ssm_evaluator((2, 128, 64, 8)),
                   space=KERNEL_SPACES["ssm_scan"], budget=20, seed=0,
                   similarity=kernel_similarity)
    print(f"donor study rebuilt at {DONOR}")


def main() -> int:
    if "--regen-donor" in sys.argv:
        regen_donor()
        return 0
    work = Path(sys.argv[1]) if len(sys.argv) > 1 else Path("results/ci_surrogate")
    # WordCount matrix: donor wc:1m, target the wc:2m sibling (2x corpus)
    run_cell(work, "wc", "wordcount/wc:2m", budget=24, seed=5,
             make_ev=lambda: make_wc_evaluator(WC_CELLS["wc:2m"]))
    # kernel cell: donor ssm_scan b2s128di64n8, target the b1s256di64n16
    # sibling shape — sibling delivery rides kernel_similarity
    run_cell(work, "kern", ssm_namespace((1, 256, 64, 16)), budget=12, seed=5,
             make_ev=lambda: make_ssm_evaluator((1, 256, 64, 16)),
             space=KERNEL_SPACES["ssm_scan"], similarity=kernel_similarity)
    print("surrogate CI smoke OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
