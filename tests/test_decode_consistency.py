"""Cache-correctness integration test: prefill(N) + K decode steps must match
a single prefill over N+K tokens, for every architecture family (KV caches,
RWKV states, Mamba conv/ssm caches, whisper cross-attention caches)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.archs import ARCH_NAMES, get_arch
from repro.configs.base import RunConfig

B, N, K = 2, 12, 4


def _pad_cache(caches, extra):
    def pad_leaf(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v", "ks", "vs"):
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, extra)  # (groups, B, T, ...)
            return jnp.pad(x, pad)
        return x

    return jax.tree_util.tree_map_with_path(pad_leaf, caches)


def _run(name, run: RunConfig, tol: float):
    from repro.models.model import Model

    arch = get_arch(name, smoke=True)
    if arch.num_experts:
        arch = dataclasses.replace(arch, moe_capacity_factor=64.0)  # no drops
    m = Model(arch, run)
    params = m.init_params(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, N + K), 0, arch.vocab_size, jnp.int32)
    extras = {}
    if arch.frontend == "vision":
        extras["patches"] = 0.02 * jax.random.normal(jax.random.PRNGKey(3), (B, arch.frontend_seq, arch.d_model))
    elif arch.frontend == "audio":
        extras["frames"] = 0.02 * jax.random.normal(jax.random.PRNGKey(3), (B, arch.frontend_seq, arch.d_model))

    full_logits, _ = m.prefill(params, {"tokens": toks, **extras})
    _, caches = m.prefill(params, {"tokens": toks[:, :N], **extras})
    caches = _pad_cache(caches, K)
    logits = None
    for i in range(K):
        batch = {"tokens": toks[:, N + i : N + i + 1],
                 "cache_len": jnp.asarray(N + i, jnp.int32)}
        logits, caches = m.decode_step(params, caches, batch)
    err = float(jnp.max(jnp.abs(full_logits - logits)))
    assert err < tol, (name, err)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_prefill(name):
    # rwkv/mamba: chunked-parallel vs step recurrence differ by f32 noise
    tol = 5e-2 if name in ("rwkv6-7b", "jamba-1.5-large-398b") else 2e-3
    _run(name, RunConfig(), tol)


@pytest.mark.parametrize("name", ["llama3.2-1b", "gemma2-9b"])
def test_decode_matches_prefill_int8_kv(name):
    """int8 KV caches trade accuracy for 2× cache capacity — still close."""
    _run(name, RunConfig(kv_cache_dtype="int8"), tol=0.35)
