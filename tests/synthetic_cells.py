"""Deterministic synthetic cell family for the cross-cell transfer suite.

A family of (arch × shape) cells over the real ``TRAIN_SPACE`` whose
objectives share one known optimum (``SHARED_TARGET``) — except for the
designated *outlier* arches, whose optimum sits in the opposite corner of the
space (``OUTLIER_TARGET``). Per-cell base offsets differ, so sibling times
live on different absolute scales (transfer must survive that, exactly like
real cells' step times do).

Used two ways:

  - ``tests/test_transfer.py`` drives the evaluators directly through
    ``Study.optimize`` with synthetic cell namespaces,
  - the CI transfer smoke runs the real ``launch/multicell.py`` CLI with
    ``--evaluator-factory synthetic_cells:make_evaluator``
    (``PYTHONPATH=src:tests``).

Everything here is a pure function of its inputs — no rng, no wall clock —
so every assertion about "fewer fresh evaluations" is exactly reproducible.
"""
from __future__ import annotations

import math
import threading
from typing import Any, Dict, Tuple

# On-grid values of TRAIN_SPACE (pow2 / step-128 / categorical) — the
# synthetic optimum must be representable or no strategy can ever reach it.
SHARED_TARGET = {
    "mesh_model_parallel": 4,
    "microbatch_size": 16,
    "remat_policy": "dots",
    "attn_block_q": 1024,
}
OUTLIER_TARGET = {
    "mesh_model_parallel": 64,
    "microbatch_size": 128,
    "remat_policy": "none",
    "attn_block_q": 128,
}

# Arches whose cells do NOT share the family optimum (the bounded-regret
# case: transfer priors must not wreck them).
OUTLIER_ARCHES = frozenset({"qwen2-72b", "cellC"})

# Distinct per-cell base offsets: sibling observations arrive on a different
# absolute time scale than the local cell's.
BASES = {"cellA": 5.0, "cellB": 3.0, "cellC": 4.0,
         "llama3.2-1b": 5.0, "gemma2-9b": 3.0, "qwen2-72b": 4.0}
DEFAULT_BASE = 4.5

# A config within EPS of the cell's base has found the optimum basin.
EPS = 0.05


def cell_time(config: Dict[str, Any], *, target: Dict[str, Any],
              base: float) -> float:
    """Deterministic objective over TRAIN_SPACE: four influential knobs with
    a known optimum plus a long tail of nearly-flat ones (the paper's
    Table VII shape — the tuner has to discover what matters)."""
    mb = config["microbatch_size"] or 256
    target_mb = target["microbatch_size"] or 256
    t = base
    t += abs(math.log2(config["mesh_model_parallel"])
             - math.log2(target["mesh_model_parallel"])) * 0.30
    t += abs(math.log2(mb) - math.log2(target_mb)) * 0.10
    t += 0.25 * (config["remat_policy"] != target["remat_policy"])
    t += abs(config["attn_block_q"] - target["attn_block_q"]) / 2048 * 0.40
    # long tail: barely-moving knobs so densities have something to model
    t += 0.01 * (config["matmul_precision"] != "bf16")
    t += 0.01 * (not config["scan_layers"])
    return t


def target_for(arch: str) -> Dict[str, Any]:
    return OUTLIER_TARGET if arch in OUTLIER_ARCHES else SHARED_TARGET


def base_for(arch: str) -> float:
    return BASES.get(arch, DEFAULT_BASE)


class SyntheticCellEvaluator:
    """Counts fresh evaluations thread-safely and keeps the returned-time
    trajectory, so tests can ask 'after how many fresh evaluations did this
    cell first land within EPS of its optimum?'."""

    parallel_safe = True

    def __init__(self, arch: str, shape: str = "train_4k",
                 platform: str = "train"):
        self.arch = arch
        self.target = target_for(arch)
        self.base = base_for(arch)
        self.calls = 0
        self.trajectory: list = []
        self._lock = threading.Lock()

    def __call__(self, config: Dict[str, Any]) -> Tuple[float, Dict[str, Any]]:
        t = cell_time(config, target=self.target, base=self.base)
        with self._lock:
            self.calls += 1
            self.trajectory.append(t)
        return t, {}

    def evals_to_optimum(self, eps: float = EPS):
        """1-based index of the first fresh evaluation within ``eps`` of the
        optimum; None if the trajectory never got there."""
        for i, t in enumerate(self.trajectory, start=1):
            if t <= self.base + eps:
                return i
        return None


def make_evaluator(arch: str, shape: str, space, platform: str):
    """``tune_cells`` / ``--evaluator-factory`` entry point."""
    return SyntheticCellEvaluator(arch, shape, platform)
