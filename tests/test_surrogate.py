"""Learned cost surrogate (repro.core.surrogate) invariants.

Model-level: deterministic ridge fit, log2/one-hot encoding, per-namespace
intercepts absorbing sibling scale offsets, under-trained fallback.

Strategy-level (mirroring the PR 5 transfer purity suite): under
``surrogate="rank"`` the TPE proposal stream stays a pure function of
(seed, observations, siblings, training set); pre-ranking only *reorders*
candidates within a round; random startup coverage is untouched; training
never charges budget.

Study-level: ``EngineConfig.surrogate`` plumbs through ``optimize``, the
sibling training set is recorded as session provenance even with
``transfer="off"``, replay over a complete cache pays zero fresh
evaluations, and resume reuses the recorded sibling set.
"""
import math

import pytest

from repro.core import (
    TRAIN_SPACE,
    EngineConfig,
    SiblingHistory,
    Study,
    config_key,
)
from repro.core.scheduler import Trial
from repro.core.strategies.tpe import TPEStrategy
from repro.core.surrogate import (
    SURROGATE_MODES,
    CostSurrogate,
    cell_features,
    encode_config,
)

from synthetic_cells import (
    SyntheticCellEvaluator,
    base_for,
    cell_time,
    target_for,
)

CELL_A = "train/cellA:train_4k"
CELL_B = "train/cellB:train_4k"


def _rows(arch, n=32, seed=9, namespace=CELL_A):
    """Deterministic (config, time, namespace) training rows for one cell."""
    import random

    rng = random.Random(seed)
    out = []
    for _ in range(n):
        cfg = {p.name: p.sample(rng) for p in TRAIN_SPACE.params}
        t = cell_time(cfg, target=target_for(arch), base=base_for(arch))
        out.append((cfg, t, namespace))
    return out


def _siblings(rows):
    return [SiblingHistory(rows[0][2], 0.5,
                           tuple((c, t, "tpe/round1") for c, t, _ in rows))]


def _drive(strategy, objective, batch=None, limit=200):
    stream = []
    while not strategy.done and len(stream) < limit:
        configs = strategy.ask(batch)
        if not configs:
            break
        stream += [config_key(c) for c in configs]
        strategy.tell([Trial(dict(c), objective(c)) for c in configs])
    return stream


def _objective(arch):
    return lambda c: cell_time(c, target=target_for(arch),
                               base=base_for(arch))


# ------------------------------------------------------------- model itself


def test_encode_config_log2_and_onehot():
    cfg = dict(TRAIN_SPACE.defaults())
    cfg["mesh_model_parallel"] = 16  # pow2 knob -> log2 space
    cfg["remat_policy"] = "dots"     # categorical -> one-hot
    feats = encode_config(TRAIN_SPACE, cfg)
    assert feats["cfg:mesh_model_parallel"] == 4.0
    assert feats["cfg:remat_policy='dots'"] == 1.0
    assert "cfg:remat_policy='full'" not in feats
    # a partial config falls back to space defaults rather than KeyError
    partial = encode_config(TRAIN_SPACE, {"mesh_model_parallel": 8})
    assert partial["cfg:mesh_model_parallel"] == 3.0


def test_cell_features_geometry():
    feats = cell_features("train/cellA:train_4k@512c")
    assert feats["geo:log2_chips"] == 9.0
    assert feats["geo:log2_seq"] == 12.0  # train_4k: seq_len 4096
    assert feats["geo:log2_batch"] == 8.0  # global_batch 256
    assert feats["geo:kind=train"] == 1.0
    # unknown shape: topology only (the ns intercept carries the rest)
    bare = cell_features("wordcount/wc:1m")
    assert set(bare) == {"geo:log2_chips"}


def test_under_trained_model_falls_back():
    rows = _rows("cellA", n=4)
    m = CostSurrogate(TRAIN_SPACE).fit(rows)
    assert not m.ready
    cand = [r[0] for r in _rows("cellA", n=6, seed=11)]
    assert m.rank(cand) == cand  # identity: no reordering on noise
    with pytest.raises(RuntimeError):
        m.predict(cand[0])


def test_fit_is_deterministic_and_ranks_toward_optimum():
    rows = _rows("cellA", n=48)
    m1 = CostSurrogate(TRAIN_SPACE).fit(rows)
    m2 = CostSurrogate(TRAIN_SPACE).fit(list(rows))
    assert m1.ready and m1.n_rows == 48
    cand = [r[0] for r in _rows("cellA", n=24, seed=17)]
    assert [m1.predict(c, CELL_A) for c in cand] == \
        [m2.predict(c, CELL_A) for c in cand]
    ranked = m1.rank(cand, CELL_A)
    truth = sorted(cand, key=_objective("cellA"))
    # the model's top pick is in the true top quartile of the candidates
    true_order = [config_key(c) for c in truth]
    assert true_order.index(config_key(ranked[0])) < len(cand) // 4


def test_namespace_intercept_absorbs_sibling_scale():
    # same config-effect structure, 2x absolute scale in the sibling cell:
    # training on both must not corrupt the local ranking
    local = _rows("cellB", n=24, namespace=CELL_B)
    sib = [(c, 2.0 * t, CELL_A) for c, t, _ in _rows("cellB", n=24, seed=3)]
    m = CostSurrogate(TRAIN_SPACE).fit(local + sib)
    cand = [r[0] for r in _rows("cellB", n=24, seed=21)]
    ranked = m.rank(cand, CELL_B)
    truth = sorted(cand, key=_objective("cellB"))
    assert config_key(ranked[0]) in {config_key(c) for c in truth[:6]}
    # and the intercept shows up as a roughly-constant per-cell offset
    deltas = [m.predict(c, CELL_A) - m.predict(c, CELL_B) for c in cand[:8]]
    assert max(deltas) - min(deltas) < 1e-9  # exactly the intercept gap


def test_invalid_modes_raise():
    with pytest.raises(ValueError, match="surrogate"):
        TPEStrategy(TRAIN_SPACE, surrogate="bogus")
    with pytest.raises(ValueError, match="surrogate"):
        EngineConfig(surrogate="bogus")
    assert SURROGATE_MODES == ("off", "rank")


# -------------------------------------------------------- strategy purity


def test_proposal_stream_pure_function_with_surrogate_rank():
    sibs = _siblings(_rows("cellA", n=24))
    objective = _objective("cellB")

    def fresh(seed):
        s = TPEStrategy(TRAIN_SPACE, max_trials=16, seed=seed,
                        surrogate="rank", platform=CELL_B)
        s.on_study_attach((), siblings=sibs, transfer="off")
        return s

    # same (seed, siblings/training set) -> byte-identical stream
    assert _drive(fresh(7), objective) == _drive(fresh(7), objective)
    # batch size changes scheduling, not the proposed set (round batching)
    assert set(_drive(fresh(7), objective, batch=1)) == \
        set(_drive(fresh(7), objective, batch=5))
    # the training set is part of the function's domain: drop it, stream moves
    bare = TPEStrategy(TRAIN_SPACE, max_trials=16, seed=7,
                       surrogate="rank", platform=CELL_B)
    assert _drive(bare, objective) != _drive(fresh(7), objective)
    # and a different seed moves it too
    assert _drive(fresh(8), objective) != _drive(fresh(7), objective)


def test_rank_only_reorders_candidates(monkeypatch):
    """Every surrogate call permutes the oversampled candidate list — it
    never invents or drops configs; the round keeps a prefix of the ranked
    permutation."""
    calls = []
    orig = CostSurrogate.rank

    def spy(self, configs, namespace=""):
        out = orig(self, configs, namespace)
        calls.append(([config_key(c) for c in configs],
                      [config_key(c) for c in out]))
        return out

    monkeypatch.setattr(CostSurrogate, "rank", spy)
    sibs = _siblings(_rows("cellA", n=24))
    s = TPEStrategy(TRAIN_SPACE, max_trials=16, seed=7,
                    surrogate="rank", platform=CELL_B)
    s.on_study_attach((), siblings=sibs, transfer="off")
    _drive(s, _objective("cellB"))
    assert calls  # model rounds actually ranked
    for cand, ranked in calls:
        assert sorted(cand) == sorted(ranked)  # a permutation, nothing else
        assert len(set(cand)) == len(cand)


def test_startup_coverage_and_budget_match_surrogate_off():
    """Rank mode must not eat the n_startup random coverage (the surrogate
    only touches model rounds) and must spend exactly the same budget —
    training is free."""
    sibs = _siblings(_rows("cellA", n=24))
    objective = _objective("cellB")

    def run(mode):
        s = TPEStrategy(TRAIN_SPACE, max_trials=16, seed=7,
                        surrogate=mode, platform=CELL_B)
        s.on_study_attach((), siblings=sibs, transfer="off")
        return _drive(s, objective), s

    stream_rank, s_rank = run("rank")
    stream_off, s_off = run("off")
    n_startup = s_off.n_startup
    # with transfer off, sibling rows feed ONLY the surrogate: the random
    # startup prefix is identical between modes (same seed, same rng path)
    assert stream_rank[:n_startup] == stream_off[:n_startup]
    # equal budget, equal proposals, training never charged
    assert len(stream_rank) == len(stream_off) == 16
    assert s_rank._paid == s_off._paid == 16
    assert s_rank.result().surrogate == "rank"
    assert s_rank.result().surrogate_rows > 0
    assert s_off.result().surrogate_rows == 0


# ------------------------------------------------------------- study seam


def test_study_plumbs_engine_surrogate_and_records_provenance(tmp_path):
    study = Study.create(tmp_path / "s")
    study.optimize(CELL_A, "tpe", SyntheticCellEvaluator("cellA"),
                   budget=20, seed=1)
    eng = study.engine.replace(surrogate="rank")
    ev = SyntheticCellEvaluator("cellB")
    out = study.optimize(CELL_B, "tpe", ev, budget=12, seed=4, engine=eng)
    assert out.detail.surrogate == "rank"
    assert out.detail.surrogate_rows > 0
    assert out.evaluations == 12 + 1  # budget + defaults, nothing extra
    rec = [r for r in study.sessions() if r["event"] == "start"][-1]
    # sibling training set is provenance even though transfer stayed off
    assert rec["args"]["surrogate"] == "rank"
    assert rec["transfer"]["mode"] == "off"
    assert [s["namespace"] for s in rec["transfer"]["siblings"]] == [CELL_A]
    row = study.report()["sessions"][-1]
    assert row["surrogate"] == "rank"
    assert row["transfer"] == "off"


def test_surrogate_session_replays_identically_over_complete_cache(tmp_path):
    study = Study.create(tmp_path / "s")
    study.optimize(CELL_A, "tpe", SyntheticCellEvaluator("cellA"),
                   budget=20, seed=1)
    eng = study.engine.replace(surrogate="rank")
    first = study.optimize(CELL_B, "tpe", SyntheticCellEvaluator("cellB"),
                           budget=12, seed=4, engine=eng)
    ev2 = SyntheticCellEvaluator("cellB")
    again = study.optimize(CELL_B, "tpe", ev2, budget=12, seed=4, engine=eng)
    assert ev2.calls == 0
    assert again.cache_stats["fresh"] == 0
    assert again.best_time == first.best_time
    assert again.best_config == first.best_config


def test_unsupported_strategy_ignores_engine_surrogate(tmp_path):
    # gsft has no supports_surrogate: engine surrogate="rank" must be a
    # silent no-op (no bogus kwarg injected), not a crash
    study = Study.create(tmp_path / "s")
    eng = study.engine.replace(surrogate="rank")
    out = study.optimize(CELL_A, "gsft", SyntheticCellEvaluator("cellA"),
                         samples_per_param=2, engine=eng,
                         active_params=["mesh_model_parallel"])
    assert out.best_time <= out.default_time
    rec = [r for r in study.sessions() if r["event"] == "start"][-1]
    assert "surrogate" not in rec["args"]
    assert "transfer" not in rec
