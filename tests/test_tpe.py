"""TPE strategy: registry drop-in, search quality, seeded determinism,
batch-size invariance, constant-liar batch diversity, warm start, and the
>=2x wall-clock speedup from batched acquisition."""
import threading
import time

import pytest

from repro.core import TRAIN_SPACE, TrialScheduler, make_strategy, tune
from repro.core.evaluators import FunctionEvaluator
from repro.core.scheduler import Trial, config_key
from repro.core.strategies import CRSStrategy, TPEStrategy
from repro.core.strategies.tpe import TPEResult


def quad_objective(cfg):
    t = 10.0
    t += abs(cfg["mesh_model_parallel"] - 8) * 0.5
    t += abs((cfg["microbatch_size"] or 256) - 32) * 0.02
    t += {"none": 2.0, "dots": 0.0, "full": 1.0}[cfg["remat_policy"]]
    return t


class CountingEvaluator:
    def __init__(self, fn=quad_objective, delay_s=0.0):
        self.fn = fn
        self.delay_s = delay_s
        self.calls = 0
        self._lock = threading.Lock()

    def __call__(self, config):
        with self._lock:
            self.calls += 1
        if self.delay_s:
            time.sleep(self.delay_s)
        return float(self.fn(config)), {}


def _trial_keys(scheduler):
    return [config_key(t.config) for t in scheduler.trials]


# ----------------------------------------------------------------- registry


def test_tpe_registered_in_strategy_registry():
    for name in ("tpe", "bayes"):
        s = make_strategy(name, TRAIN_SPACE, max_trials=8)
        assert isinstance(s, TPEStrategy)


def test_tune_supports_tpe_algorithm():
    out = tune("train", "tpe", FunctionEvaluator(quad_objective),
               max_trials=40, seed=1)
    assert isinstance(out.detail, TPEResult)
    assert out.evaluations >= 1
    assert out.best_time < out.default_time  # beat the all-defaults config
    assert out.detail.rounds >= 1


# ------------------------------------------------------------ search quality


def test_tpe_beats_pure_random_at_equal_budget():
    """Acceptance-shaped check: the model rounds must add value over the
    startup distribution — same budget, same seed family, pure random via a
    single uncontracted CRS round."""
    budget = 48
    tpe = tune("train", "tpe", FunctionEvaluator(quad_objective),
               max_trials=budget, seed=1)
    rand = tune("train", "crs", FunctionEvaluator(quad_objective),
                m=budget, k=4, max_rounds=1, seed=1)
    assert tpe.best_time <= rand.best_time
    assert tpe.best_config["mesh_model_parallel"] == 8  # found the optimum knob


def test_tpe_proposals_respect_space_and_fixed():
    fixed = {"remat_policy": "dots", "scan_layers": True}
    strat = TPEStrategy(TRAIN_SPACE, fixed=fixed, max_trials=24,
                        n_startup=6, seed=2)
    sched = TrialScheduler(FunctionEvaluator(quad_objective))
    sched.run(strat, batch_size=4)
    assert sched.num_evaluations > 0
    for t in sched.trials:
        assert t.config == TRAIN_SPACE.snap(t.config)  # snap-stable values
        for k, v in fixed.items():
            assert t.config[k] == v


# -------------------------------------------------------------- determinism


@pytest.mark.parametrize("strategy_factory", [
    lambda seed: TPEStrategy(TRAIN_SPACE, max_trials=30, n_startup=8, seed=seed),
    lambda seed: CRSStrategy(TRAIN_SPACE, m=10, k=3, max_rounds=3, seed=seed),
], ids=["tpe", "crs"])
def test_fixed_seed_identical_trial_sequences_across_runs(strategy_factory):
    runs = []
    for _ in range(2):
        sched = TrialScheduler(CountingEvaluator())
        sched.run(strategy_factory(seed=11), batch_size=4)
        runs.append(_trial_keys(sched))
    assert runs[0] == runs[1]


@pytest.mark.parametrize("strategy_factory", [
    lambda seed: TPEStrategy(TRAIN_SPACE, max_trials=30, n_startup=8, seed=seed),
    lambda seed: CRSStrategy(TRAIN_SPACE, m=10, k=3, max_rounds=3, seed=seed),
], ids=["tpe", "crs"])
def test_batch_size_1_vs_4_proposes_identical_config_sets(strategy_factory):
    """Acquisition is round-batched: every round is drawn before any of its
    results is consumed, so the proposed-config set cannot depend on how the
    scheduler slices rounds into batches."""
    keys = {}
    for bs in (1, 4):
        sched = TrialScheduler(CountingEvaluator())
        sched.run(strategy_factory(seed=11), batch_size=bs)
        keys[bs] = _trial_keys(sched)
    assert set(keys[1]) == set(keys[4])
    assert len(keys[1]) == len(keys[4])


# ------------------------------------------------- batched acquisition


def test_constant_liar_round_is_diverse():
    """Post-startup, one ask must deliver distinct configs (the lie pushes
    each in-flight proposal into the bad density, repelling repeats)."""
    strat = TPEStrategy(TRAIN_SPACE, max_trials=40, n_startup=8,
                        round_size=8, seed=4)
    startup = strat.ask(None)
    assert len(startup) == 8
    strat.tell([Trial(c, quad_objective(c)) for c in startup])

    model_round = strat.ask(None)
    assert len(model_round) == 8
    assert strat.tag.startswith("tpe/round")
    keys = {config_key(c) for c in model_round}
    assert len(keys) == len(model_round)  # all distinct in-flight
    seen = {config_key(c) for c in startup}
    assert not (keys & seen)  # and none already evaluated


def test_ask_n_batching_speedup_at_least_2x():
    """Acceptance: with round-batched acquisition the scheduler keeps its
    pool full — >=2x wall-clock over batch_size=1 on a slow evaluator."""
    delay = 0.05
    kw = dict(max_trials=24, n_startup=8, round_size=8, seed=0)

    t0 = time.perf_counter()
    serial = TrialScheduler(CountingEvaluator(delay_s=delay))
    serial.run(TPEStrategy(TRAIN_SPACE, **kw), batch_size=1)
    t_serial = time.perf_counter() - t0

    t0 = time.perf_counter()
    parallel = TrialScheduler(CountingEvaluator(delay_s=delay), max_workers=8)
    parallel.run(TPEStrategy(TRAIN_SPACE, **kw), batch_size=8)
    t_parallel = time.perf_counter() - t0

    assert serial.num_evaluations == parallel.num_evaluations
    assert t_serial >= 2.0 * t_parallel, (t_serial, t_parallel)


# ---------------------------------------------------------------- warm start


def test_tpe_warm_start_skips_paid_startup():
    import random

    rng = random.Random(0)
    history = []
    for _ in range(10):
        cfg = {p.name: p.sample(rng) for p in TRAIN_SPACE.params}
        history.append((cfg, quad_objective(cfg)))

    strat = TPEStrategy(TRAIN_SPACE, max_trials=16, n_startup=10,
                        history=history, seed=0)
    assert strat.warm_started == 10
    first = strat.ask(None)
    # history covers the startup budget: the first round is already model-based
    assert strat.tag.startswith("tpe/round")
    assert len(first) <= 6  # only the unpaid remainder


def test_tpe_warm_start_ignores_records_contradicting_fixed():
    base = {p.name: p.default for p in TRAIN_SPACE.params}
    matching = {**base, "remat_policy": "dots"}
    foreign = {**base, "remat_policy": "none"}  # contradicts the pin below
    strat = TPEStrategy(TRAIN_SPACE, fixed={"remat_policy": "dots"},
                        max_trials=8, history=[(matching, 1.0), (foreign, 0.5)])
    assert strat.warm_started == 1  # only the compatible record is history
    assert strat.result().best_time == 1.0


def test_tpe_warm_start_rejects_foreign_space_records():
    """Cache records from another space (e.g. roofline 'train' trials leaking
    into a wordcount session) must not collapse to the defaults config and
    silently eat the trial budget."""
    from repro.apps.wordcount import WORDCOUNT_SPACE

    train_cfg = {p.name: p.default for p in TRAIN_SPACE.params}
    strat = TPEStrategy(WORDCOUNT_SPACE, max_trials=12,
                        history=[(train_cfg, 1.0)] * 20)
    assert strat.warm_started == 0
    assert not strat.done
    assert len(strat.ask(None)) > 0  # full budget still available


def test_tpe_foreign_strategy_history_is_free_evidence_not_budget():
    """gsft/crs records sharing the cache must inform the model (skip random
    startup) but never consume TPE's own trial budget."""
    import random

    rng = random.Random(0)
    history = []
    for _ in range(20):
        cfg = {p.name: p.sample(rng) for p in TRAIN_SPACE.params}
        history.append((cfg, quad_objective(cfg), "gsft/grid"))

    strat = TPEStrategy(TRAIN_SPACE, max_trials=8, n_startup=10,
                        history=history, seed=0)
    assert strat.warm_started == 20
    assert not strat.done  # budget untouched by foreign records
    first = strat.ask(None)
    assert strat.tag.startswith("tpe/round")  # evidence defused the startup
    assert len(first) == 8  # full own budget still available


def test_tpe_budget_survives_shared_cache_with_other_strategy(tmp_path):
    """The documented shared-cache workflow: gsft first, then tpe with the
    same --cache. TPE must still run its own fresh trials."""
    cache = tmp_path / "cache.jsonl"
    tune("train", "gsft", FunctionEvaluator(quad_objective), cache_path=cache,
         active_params=["mesh_model_parallel", "remat_policy"],
         samples_per_param=3)

    ev = CountingEvaluator()
    out = tune("train", "tpe", ev, cache_path=cache, max_trials=12, seed=0)
    assert ev.calls > 0  # budget was NOT pre-consumed by gsft's records
    assert out.detail.warm_started > 0  # but their evidence was used
    assert out.detail.n_observations >= out.detail.warm_started + 12


def test_tpe_infeasible_observations_land_in_bad_group():
    """inf objective values must not break the split or the densities."""
    strat = TPEStrategy(TRAIN_SPACE, max_trials=20, n_startup=6, seed=5)
    startup = strat.ask(None)
    trials = []
    for i, c in enumerate(startup):
        t = float("inf") if i % 2 else quad_objective(c)
        trials.append(Trial(c, t, error="boom" if i % 2 else None,
                            status="error" if i % 2 else "ok"))
    strat.tell(trials)
    nxt = strat.ask(None)  # model round fits on mixed finite/inf history
    assert nxt
    res = strat.result()
    assert res.best_time < float("inf")
