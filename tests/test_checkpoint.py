"""Checkpoint manager: roundtrip, async publish, keep-N GC, restart resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,))},
        "opt": {"mu": {"w": jnp.ones((8, 16)), "b": jnp.zeros((16,))}},
        "step": jnp.asarray(7, jnp.int32),
    }


def _equal(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def test_roundtrip_blocking(tmp_path):
    m = CheckpointManager(tmp_path, async_save=False)
    s = _state()
    m.save(7, s)
    r = m.restore(s)
    assert _equal(s, r)


def test_async_save_and_wait(tmp_path):
    m = CheckpointManager(tmp_path, async_save=True)
    s = _state(1)
    m.save(3, s)
    m.wait()
    assert m.latest_step() == 3
    assert _equal(s, m.restore(s))


def test_keep_n_gc(tmp_path):
    m = CheckpointManager(tmp_path, keep_n=2, async_save=False)
    s = _state()
    for step in (1, 2, 3, 4):
        m.save(step, s)
    assert m.steps() == [3, 4]


def test_restore_specific_step(tmp_path):
    m = CheckpointManager(tmp_path, keep_n=5, async_save=False)
    s1, s2 = _state(1), _state(2)
    m.save(1, s1)
    m.save(2, s2)
    assert _equal(s1, m.restore(s1, step=1))
    assert _equal(s2, m.restore(s2, step=2))


def test_atomicity_no_tmp_left(tmp_path):
    m = CheckpointManager(tmp_path, async_save=False)
    m.save(9, _state())
    assert not list(tmp_path.glob("*.tmp"))
    assert (tmp_path / "step_00000009" / "manifest.json").exists()


def test_restore_onto_different_mesh_subprocess(subproc):
    """Elastic re-shard: save on a (4,2) mesh, restore onto (2,2) of a
    4-device world — the cross-topology checkpoint move."""
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint.manager import CheckpointManager
from repro.ft.elastic import make_elastic_mesh

mesh8 = make_elastic_mesh(8, prefer_model=2)
w = jnp.arange(8*16, dtype=jnp.float32).reshape(8, 16)
sh = NamedSharding(mesh8, P("data", "model"))
state = {"w": jax.device_put(w, sh)}
d = tempfile.mkdtemp()
m = CheckpointManager(d, async_save=False)
m.save(1, state)

mesh4 = make_elastic_mesh(4, prefer_model=2)  # lost half the fleet
restored = m.restore(state, shardings={"w": P("data", "model")}, mesh=mesh4)
assert np.array_equal(np.asarray(restored["w"]), np.asarray(w))
assert restored["w"].sharding.mesh.devices.size == 4
print("RESHARD_OK")
""",
        devices=8,
    )
    assert "RESHARD_OK" in out
