#!/usr/bin/env python
"""reprolint — AST lint for this repo's reproducibility invariants.

The tuner's correctness rests on a few non-local contracts that nothing in
the type system enforces, and that have each been broken (or nearly broken)
once already:

  ``strategy-wallclock``
      Strategy code (``src/repro/core/strategies/``) must be a pure function
      of its observation history — no wall-clock reads (``time.time``,
      ``time.perf_counter``, ``time.monotonic``, ``datetime.now``, ...).
      A strategy that keys decisions off the clock makes warm cache replays
      diverge from the original run, silently breaking resume determinism.

  ``strategy-unseeded-random``
      Same files: no unseeded randomness. Module-level ``random.*`` /
      ``numpy.random.*`` draws ignore the ``seed=`` every strategy accepts;
      only an explicit ``random.Random(seed)`` / ``np.random.default_rng``
      instance is allowed.

  ``evaluator-parallel-safe``
      Every ``*Evaluator`` class must *declare* ``parallel_safe`` (class
      attribute or dataclass field). The TrialScheduler fans batches over a
      thread pool only when the evaluator says that is sound; an undeclared
      attribute falls back to a scheduler default picked far from the code
      that knows the answer.

  ``fidelity-explicit-param``
      A class declaring ``supports_fidelity = True`` must take an explicit
      ``fidelity`` parameter in ``__call__`` — a bare ``**kwargs`` would
      swallow the kwarg, run the full-size job, and get cached under a
      low-fidelity key as if it were the scaled one.

  ``serving-injected-clock``
      Online-tuner code (``src/repro/serving/``) must not read the wall
      clock directly — time enters only through injected ``clock=``
      callables. The simulation suite and the rollback/promotion CI
      assertions replay decision streams as pure functions of
      (seed, trace); one stray ``time.perf_counter()`` in a decision path
      makes guard behaviour unreproducible.

Suppress a finding by appending ``# reprolint: ok`` to the flagged line.

Usage::

    python tools/reprolint.py [PATHS...]     # default: src/

Exit status 1 when findings remain, with one ``path:line: [rule] message``
per finding.
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path
from typing import Iterator, List, Optional, Tuple

ESCAPE_HATCH = "# reprolint: ok"

# wall-clock attribute reads banned in strategy code: (module, attr)
WALLCLOCK_CALLS = {
    ("time", "time"),
    ("time", "perf_counter"),
    ("time", "monotonic"),
    ("time", "process_time"),
    ("time", "time_ns"),
    ("time", "perf_counter_ns"),
    ("time", "monotonic_ns"),
    ("datetime", "now"),
    ("datetime", "utcnow"),
    ("datetime", "today"),
}

# unseeded module-level RNG draws banned in strategy code (the seeded
# random.Random(seed) / np.random.default_rng(seed) instances are fine —
# they are constructor calls, not draws)
UNSEEDED_RANDOM = {
    "random", "randint", "randrange", "uniform", "choice", "choices",
    "shuffle", "sample", "gauss", "normalvariate", "betavariate", "seed",
    "random_sample", "rand", "randn", "standard_normal", "permutation",
}
RANDOM_MODULES = {"random", "np.random", "numpy.random"}


class Finding(Tuple[str, int, str, str]):
    """(path, line, rule, message)"""


def _dotted(node: ast.AST) -> Optional[str]:
    """Best-effort dotted name for an attribute chain (``np.random.rand``)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _suppressed(source_lines: List[str], lineno: int) -> bool:
    if 1 <= lineno <= len(source_lines):
        return ESCAPE_HATCH in source_lines[lineno - 1]
    return False


def _iter_calls(tree: ast.AST) -> Iterator[ast.Call]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node


def lint_strategy_purity(path: Path, tree: ast.AST,
                         lines: List[str]) -> Iterator[Tuple[int, str, str]]:
    """strategy-wallclock + strategy-unseeded-random over one strategy file."""
    for call in _iter_calls(tree):
        name = _dotted(call.func)
        if name is None:
            continue
        parts = name.split(".")
        head, tail = ".".join(parts[:-1]), parts[-1]
        if (parts[0], tail) in WALLCLOCK_CALLS or (
            tail in ("now", "utcnow", "today") and "datetime" in parts
        ):
            yield (call.lineno, "strategy-wallclock",
                   f"wall-clock read `{name}()` in strategy code — "
                   "strategies must be pure functions of their history")
        elif head in RANDOM_MODULES and tail in UNSEEDED_RANDOM:
            yield (call.lineno, "strategy-unseeded-random",
                   f"unseeded RNG draw `{name}()` — use the "
                   "`random.Random(seed)` instance every strategy carries")


def lint_serving_clock(path: Path, tree: ast.AST,
                       lines: List[str]) -> Iterator[Tuple[int, str, str]]:
    """serving-injected-clock over one serving/ file."""
    for call in _iter_calls(tree):
        name = _dotted(call.func)
        if name is None:
            continue
        parts = name.split(".")
        tail = parts[-1]
        if (parts[0], tail) in WALLCLOCK_CALLS or (
            tail in ("now", "utcnow", "today") and "datetime" in parts
        ):
            yield (call.lineno, "serving-injected-clock",
                   f"wall-clock read `{name}()` in serving/ — time enters "
                   "the online tuner only through injected `clock=` "
                   "callables (decision streams must replay exactly)")


def _class_declares(cls: ast.ClassDef, attr: str) -> bool:
    """Whether ``attr`` appears as a class attribute, an annotated dataclass
    field, or an assignment inside ``__init__``/``__post_init__``."""
    for stmt in cls.body:
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                if isinstance(t, ast.Name) and t.id == attr:
                    return True
        elif isinstance(stmt, ast.AnnAssign):
            if isinstance(stmt.target, ast.Name) and stmt.target.id == attr:
                return True
        elif isinstance(stmt, ast.FunctionDef) and stmt.name in (
            "__init__", "__post_init__",
        ):
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Assign):
                    for t in sub.targets:
                        if (isinstance(t, ast.Attribute)
                                and isinstance(t.value, ast.Name)
                                and t.value.id == "self"
                                and t.attr == attr):
                            return True
    return False


def _truthy_class_attr(cls: ast.ClassDef, attr: str) -> bool:
    for stmt in cls.body:
        targets: List[ast.AST] = []
        value: Optional[ast.AST] = None
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        for t in targets:
            if isinstance(t, ast.Name) and t.id == attr:
                if isinstance(value, ast.Constant):
                    return bool(value.value)
                return True  # non-literal: assume meaningful
    return False


def _find_call(cls: ast.ClassDef) -> Optional[ast.FunctionDef]:
    for stmt in cls.body:
        if isinstance(stmt, ast.FunctionDef) and stmt.name == "__call__":
            return stmt
    return None


def _has_explicit_fidelity(fn: ast.FunctionDef) -> bool:
    named = fn.args.args + fn.args.kwonlyargs
    return any(a.arg == "fidelity" for a in named)


def lint_evaluator_contracts(path: Path, tree: ast.AST,
                             lines: List[str]) -> Iterator[Tuple[int, str, str]]:
    """evaluator-parallel-safe + fidelity-explicit-param over one file."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        is_evaluator = node.name.endswith("Evaluator") and node.name != "Evaluator"
        # the Evaluator Protocol itself and *Spec helpers are exempt
        if is_evaluator:
            if not _class_declares(node, "parallel_safe"):
                yield (node.lineno, "evaluator-parallel-safe",
                       f"{node.name} does not declare `parallel_safe` — "
                       "the scheduler must not guess whether batches of "
                       "this evaluator may share a thread pool")
        if _truthy_class_attr(node, "supports_fidelity"):
            call = _find_call(node)
            if call is not None and not _has_explicit_fidelity(call):
                yield (call.lineno, "fidelity-explicit-param",
                       f"{node.name} declares supports_fidelity=True but "
                       "__call__ has no explicit `fidelity` parameter — "
                       "a bare **kwargs would silently swallow the rung "
                       "fraction")


def lint_file(path: Path) -> List[Tuple[Path, int, str, str]]:
    try:
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
    except (OSError, SyntaxError) as e:
        return [(path, getattr(e, "lineno", 0) or 0, "parse-error", str(e))]
    lines = source.splitlines()
    findings: List[Tuple[Path, int, str, str]] = []

    checks = [lint_evaluator_contracts]
    if "strategies" in path.parts:
        checks.append(lint_strategy_purity)
    if "serving" in path.parts:
        checks.append(lint_serving_clock)
    for check in checks:
        for lineno, rule, msg in check(path, tree, lines):
            if not _suppressed(lines, lineno):
                findings.append((path, lineno, rule, msg))
    return findings


def iter_targets(paths: List[str]) -> Iterator[Path]:
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            yield from sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            yield p


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    targets = argv or ["src"]
    findings: List[Tuple[Path, int, str, str]] = []
    checked = 0
    for path in iter_targets(targets):
        checked += 1
        findings.extend(lint_file(path))
    for path, lineno, rule, msg in findings:
        print(f"{path}:{lineno}: [{rule}] {msg}")
    print(f"reprolint: {checked} files checked, {len(findings)} finding(s)",
          file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
